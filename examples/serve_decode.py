"""Serve a small LM with batched requests through the continuous-batching
engine — decode is the SpMV-shaped regime the paper targets.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.serving import Request, ServingEngine


def main():
    cfg = ModelConfig(
        name="serve-demo", family="dense",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=1024, vocab_size=8192, remat="none", attn_chunk=128,
        sparse_mlp=True, sparse_block=32, sparse_keep=0.5,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"CB-sparse MLPs (keep={cfg.sparse_keep})")

    engine = ServingEngine(model, params, slots=8, max_len=128)
    rng = np.random.default_rng(0)
    n_requests = 24
    for uid in range(n_requests):
        plen = int(rng.integers(2, 16))
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 20)),
        ))

    t0 = time.monotonic()
    done = engine.run_until_done()
    dt = time.monotonic() - t0
    tokens = sum(len(r.generated) for r in done)
    print(f"{len(done)}/{n_requests} requests served, {tokens} tokens in "
          f"{engine.ticks} ticks, {dt:.1f}s ({tokens / dt:.1f} tok/s, "
          f"continuous batching over 8 slots)")
    for r in sorted(done, key=lambda r: r.uid)[:3]:
        print(f"  req {r.uid}: {len(r.generated)} tokens -> {r.generated[:8]}...")


if __name__ == "__main__":
    main()
