"""End-to-end driver: train a ~100M-param LM with CB block-sparse MLPs for
a few hundred steps on the synthetic stream, with checkpointing and fault
monitoring — the paper's technique as a first-class training feature.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models import Model
from repro.runtime import HeartbeatMonitor
from repro.training import TrainLoopConfig, run_training


def build_config(sparse: bool) -> ModelConfig:
    # ~100M params: 12L x 512d x 2048ff, 32k vocab
    return ModelConfig(
        name="lm100m-cb" if sparse else "lm100m",
        family="dense",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
        d_ff=2048, vocab_size=32_000,
        sparse_mlp=sparse, sparse_block=64, sparse_keep=0.5,
        remat="none", attn_chunk=256, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dense", action="store_true",
                    help="baseline without CB sparsity")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = build_config(sparse=not args.dense)
    model = Model(cfg)
    n_params = cfg.param_count()
    print(f"config: {cfg.name}  ~{n_params / 1e6:.0f}M params "
          f"(sparse_mlp={cfg.sparse_mlp})")

    stream = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
    ))
    ck = Checkpointer(f"checkpoints/{cfg.name}")
    monitor = HeartbeatMonitor(num_hosts=1)
    loop = TrainLoopConfig(
        total_steps=args.steps,
        checkpoint_every=max(50, args.steps // 4),
        log_every=max(10, args.steps // 20),
        peak_lr=6e-4, warmup_steps=30,
    )
    state, history = run_training(model, stream, loop,
                                  checkpointer=ck, monitor=monitor)
    ck.wait()
    print(f"step {history[0]['step']}: loss {history[0]['loss']:.3f}")
    print(f"step {history[-1]['step']}: loss {history[-1]['loss']:.3f}")
    dloss = history[0]["loss"] - history[-1]["loss"]
    print(f"loss improved by {dloss:.3f} over {args.steps} steps "
          f"({'OK' if dloss > 0 else 'NOT LEARNING'})")


if __name__ == "__main__":
    main()
