"""Solve a 2-D Poisson problem with CG on the batched CB engine.

    PYTHONPATH=src python examples/solve_poisson.py

The canonical iterative-solver workload: the 5-point-stencil Laplacian of
a g x g grid (SPD, n = g^2 unknowns) solved to 1e-6 relative residual by
preconditioned conjugate gradients. The matrix is preprocessed ONCE into
a ``CBLinearOperator`` (super-block streams + block-Jacobi inverse); the
solve itself is a single jit trace whose inner matvec runs the batched
super-block engine — the regime where CB preprocessing amortizes to zero
(paper fig. 12 extended: cost / iteration-count curves below).
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import CBMatrix
from repro.solvers import CBLinearOperator, block_jacobi, cg


def poisson_2d(g: int):
    """5-point stencil Laplacian on a g x g grid -> COO triplets."""
    n = g * g
    idx = np.arange(n).reshape(g, g)
    rows, cols, vals = [idx.reshape(-1)], [idx.reshape(-1)], [np.full(n, 4.0)]
    for shift_axis, sl_a, sl_b in (
        (0, (slice(1, None), slice(None)), (slice(None, -1), slice(None))),
        (1, (slice(None), slice(1, None)), (slice(None), slice(None, -1))),
    ):
        a, b = idx[sl_a].reshape(-1), idx[sl_b].reshape(-1)
        rows += [a, b]
        cols += [b, a]
        vals += [np.full(len(a), -1.0)] * 2
    return (np.concatenate(rows), np.concatenate(cols),
            np.concatenate(vals).astype(np.float32), (n, n))


def main():
    g = 40
    rows, cols, vals, shape = poisson_2d(g)
    n = shape[0]
    print(f"Poisson {g}x{g} grid: n={n}, nnz={len(vals)}")

    # -- plan time: full CB preprocessing, paid once --------------------
    t0 = time.perf_counter()
    cb = CBMatrix.from_coo(rows, cols, vals, shape, block_size=16,
                           val_dtype=np.float32)
    op = CBLinearOperator.from_cb(cb)
    M = block_jacobi(cb)
    t_pre = time.perf_counter() - t0
    print(f"preprocessing: {t_pre * 1e3:.1f} ms "
          f"(group_size={op.group_size}, {cb.stats()['num_blocks']} blocks)")

    # -- solve: one trace, every iteration inside lax.while_loop --------
    x_true = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    b = jnp.asarray(cb.to_dense() @ x_true)
    impl = "reference"  # pure-XLA path; "pallas" targets compiled TPU
    res = cg(op, b, M, tol=1e-6, maxiter=500, impl=impl)
    res.x.block_until_ready()

    t0 = time.perf_counter()
    res = cg(op, b, M, tol=1e-6, maxiter=500, impl=impl)
    res.x.block_until_ready()
    t_solve = time.perf_counter() - t0

    iters = int(res.iterations)
    t_iter = t_solve / max(iters, 1)
    err = float(np.linalg.norm(np.asarray(res.x) - x_true)
                / np.linalg.norm(x_true))
    print(f"CG+block-Jacobi: {iters} iters, converged={bool(res.converged)}, "
          f"relative error {err:.2e}")
    print(f"solve: {t_solve * 1e3:.1f} ms total, {t_iter * 1e6:.0f} us/iter")

    # -- the fig. 12 story, extended to solves --------------------------
    print("preprocessing amortization (overhead / total vs iterations):")
    for k in (1, 10, 100, iters):
        frac = t_pre / (t_pre + k * t_iter)
        print(f"  {k:>4} iterations: preprocessing is {frac * 100:5.1f}% "
              f"of end-to-end time")
    hist = np.asarray(res.history)
    hist = hist[hist >= 0]
    print("residual history:", " ".join(f"{h:.1e}" for h in hist[:8]),
          "..." if len(hist) > 8 else "")
    assert bool(res.converged)
    print("OK")


if __name__ == "__main__":
    main()
