"""Device-parallel CB-SpMV: the paper's pq balancer scaled to a mesh axis.

Runs on 8 simulated devices (this script sets the XLA flag itself — it is
an example, not a test).

    PYTHONPATH=src python examples/distributed_spmv.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import CBMatrix  # noqa: E402
from repro.core import distributed as dist  # noqa: E402
from repro.core.spmv_ref import dense_oracle  # noqa: E402
from repro.data import matrices  # noqa: E402


def main():
    m = n = 2048
    rows, cols, vals = matrices.power_law(m, n, seed=4)
    cb = CBMatrix.from_coo(rows, cols, vals, (m, n), block_size=16,
                           val_dtype=np.float32)
    print(f"matrix {m}x{n} nnz={cb.nnz}, blocks={cb.num_blocks}")

    n_dev = len(jax.devices())
    sharded = dist.shard_streams(cb, n_dev)
    print(f"pq-balanced over {n_dev} devices: nnz per device = "
          f"{sharded.device_nnz.tolist()} "
          f"(imbalance {sharded.load_imbalance:.3f})")

    mesh = compat.make_mesh((n_dev,), ("model",))
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    y = dist.distributed_spmv(sharded, jnp.asarray(x), mesh,
                              impl="reference")
    y_ref = dense_oracle(rows, cols, vals.astype(np.float32), (m, n), x)
    err = float(np.abs(np.asarray(y) - y_ref).max())
    print(f"distributed CB-SpMV max abs error: {err:.2e}")
    assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
