"""Quickstart: convert a sparse matrix to CB format and run CB-SpMV.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import CBMatrix
from repro.core.spmv_ref import dense_oracle
from repro.core.streams import build_streams
from repro.data import matrices
from repro.kernels import ops


def main():
    # 1. a SuiteSparse-like matrix (power-law graph, the paper's hard case)
    m = n = 1024
    rows, cols, vals = matrices.power_law(m, n, seed=0)
    print(f"matrix: {m}x{n}, nnz={len(vals)}")

    # 2. the full CB conversion pipeline (Fig. 5): blocking -> th0 check ->
    #    column aggregation -> format selection -> VP packing -> TB balance
    cb = CBMatrix.from_coo(rows, cols, vals, (m, n), block_size=16,
                           val_dtype=np.float32)
    stats = cb.stats()
    print("CB structure:", {k: stats[k] for k in
          ("num_blocks", "fmt_coo", "fmt_csr", "fmt_dense",
           "column_aggregated", "super_sparse_fraction")})
    print(f"TB load imbalance after pq balance: "
          f"{stats['tb_load_imbalance']:.3f} (1.0 = perfect)")

    # 3. typed kernel streams + the Pallas kernels (interpret=True on CPU)
    streams = build_streams(cb).device_put()
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    y = ops.cb_spmv(streams, jnp.asarray(x))   # pallas on TPU, interpret on CPU

    # 4. validate against the dense oracle
    y_ref = dense_oracle(rows, cols, vals.astype(np.float32), (m, n), x)
    err = float(np.abs(np.asarray(y) - y_ref).max())
    print(f"CB-SpMV max abs error vs dense oracle: {err:.2e}")
    assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
