#!/usr/bin/env python
"""Explain one matrix end to end: plan decision, modeled traffic, roofline.

    PYTHONPATH=src python scripts/explain.py [--matrix NAME]
                                             [--scale small|bench]
                                             [--top-k K] [--json PATH]

For one corpus matrix this renders the whole decision chain the engine
takes and what it buys:

  * the feature vector the planner saw (``autotune.feature_vector``),
  * the cost model's top-k candidate ranking and the plan it produced
    (heuristic mode — bit-deterministic, no wall clock),
  * modeled cache traffic of the planned super-block pipeline vs the
    flat CSR/BSR/TileSpMV baselines (``repro.obs.locality``: L1/L2 hit
    rates, misses/nnz, bytes moved),
  * the roofline position: arithmetic intensity (flops per DRAM byte,
    where DRAM traffic = modeled L2-miss bytes) against a nominal
    v5e-ish machine balance — SpMV lives deep in the memory-bound
    regime, which is why the padded-bytes-streamed cost model ranks
    plans by traffic, not FLOPs.

``main(argv)`` returns the report as a dict (schema ``cb-explain/v1``)
so tests validate the payload without parsing stdout; ``--json`` dumps
the same dict.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

EXPLAIN_SCHEMA = "cb-explain/v1"

# Nominal single-core v5e-ish peaks — stand-ins, like the cache sizes in
# the locality model: the *position* relative to the ridge is the point,
# not the absolute TFLOPs.
PEAK_FLOPS = 4.9e13   # f32 FLOP/s
PEAK_BW = 8.19e11     # HBM bytes/s


def _build_report(name: str, scale: str, top_k: int) -> dict:
    import numpy as np

    from benchmarks import formats as F
    from repro.autotune import (SearchSettings, cost, extract_features,
                                feature_vector)
    from repro.core import CBMatrix
    from repro.core.streams import build_super_streams
    from repro.data import matrices
    from repro.obs import locality as loc

    corpus = {spec.name: (spec, r, c, v, shape)
              for spec, r, c, v, shape in matrices.corpus(scale)}
    if name is None:
        name = next(iter(corpus))
    if name not in corpus:
        raise SystemExit(
            f"explain: unknown matrix {name!r}; corpus({scale}) has: "
            + ", ".join(corpus))
    spec, r, c, v, shape = corpus[name]
    nnz = len(v)
    v32 = v.astype(np.float32)

    # -- decision: features + cost-model ranking + the chosen plan -------
    features = extract_features(r, c, v32, shape)
    ranked = cost.rank(features, cost.default_candidates())
    decision = [{
        "rank": i,
        "block_size": cand.block_size,
        "colagg": str(cand.colagg),
        "group_size": cand.resolved_group_size(),
        "score": est.score,
        "predicted_padded_elems": est.padded_elems,
        "predicted_steps": est.steps,
        "colagg_applied": est.colagg_applied,
    } for i, (cand, est) in enumerate(ranked[:top_k])]

    plan = CBMatrix.plan_for(r, c, v32, shape,
                             settings=SearchSettings(mode="heuristic"))
    cb = CBMatrix.from_plan(r, c, v32, shape, plan)
    streams = build_super_streams(cb, group_size=plan.group_size)

    # -- modeled traffic: planned pipeline vs flat baselines -------------
    locality = {"cb": loc.stream_stats(
        loc.access_stream_super(streams), nnz=nnz)}
    for fmt, gen in (("csr", F.access_stream_csr),
                     ("bsr", F.access_stream_bsr),
                     ("tile", F.access_stream_tile)):
        lines, _ = gen(r, c, v, shape, vbytes=4)
        locality[fmt] = loc.stream_stats(np.asarray(lines), nnz=nnz)

    flops = loc.FLOPS_PER_NNZ * nnz
    bytes_moved = locality["cb"]["bytes_moved"]
    ai = locality["cb"]["arith_intensity"]
    balance = PEAK_FLOPS / PEAK_BW
    roofline = {
        "flops": flops,
        "bytes_moved": bytes_moved,
        "arith_intensity": ai,
        "machine_balance": balance,
        "bound": "memory" if ai < balance else "compute",
        "attainable_fraction_of_peak": min(1.0, ai / balance),
    }

    return {
        "schema": EXPLAIN_SCHEMA,
        "matrix": spec.name,
        "family": spec.family,
        "shape": list(shape),
        "nnz": nnz,
        "features": feature_vector(features),
        "decision": decision,
        "plan": plan.to_json(),
        "locality": locality,
        "roofline": roofline,
    }


def _render(rep: dict) -> None:
    print(f"== {rep['matrix']} ({rep['family']}) "
          f"{rep['shape'][0]}x{rep['shape'][1]}, nnz={rep['nnz']} ==")

    plan = rep["plan"]
    print(f"\nplan {plan['structure_hash'][:12]}: B={plan['block_size']} "
          f"group={plan['group_size']} colagg={plan['colagg']} "
          f"th=({plan['th0']},{plan['th1']},{plan['th2']}) "
          f"mode={plan['mode']}")
    print(f"  predicted padded_elems={plan['predicted_padded_elems']} "
          f"steps={plan['predicted_steps']}; "
          f"measured padded_elems={plan['measured_padded_elems']} "
          f"steps={plan['measured_steps']}")

    print("\ncost-model ranking (lower score wins):")
    print(f"  {'rank':<5}{'B':>3}{'group':>6}{'colagg':>7}"
          f"{'padded':>10}{'steps':>7}{'score':>12}")
    for d in rep["decision"]:
        print(f"  {d['rank']:<5}{d['block_size']:>3}{d['group_size']:>6}"
              f"{str(d['colagg_applied']):>7}"
              f"{d['predicted_padded_elems']:>10}{d['predicted_steps']:>7}"
              f"{d['score']:>12.1f}")

    print("\nkey features:")
    feats = rep["features"]
    for key in ("density", "row_nnz_mean", "row_nnz_cv", "bandwidth_mean",
                f"b{plan['block_size']}_block_fill_mean",
                f"b{plan['block_size']}_super_sparse_fraction"):
        if key in feats:
            print(f"  {key:<32}{feats[key]:.4g}")

    print("\nmodeled locality (LRU line model, planned CB vs flat):")
    print(f"  {'format':<8}{'l1_hit':>8}{'l2_hit':>8}{'l1miss/nnz':>12}"
          f"{'l2miss/nnz':>12}{'lines':>8}{'MB moved':>10}")
    for fmt, st in rep["locality"].items():
        print(f"  {fmt:<8}{st['l1_hit_rate']:>8.3f}{st['l2_hit_rate']:>8.3f}"
              f"{st['l1_misses_per_nnz']:>12.4f}"
              f"{st['l2_misses_per_nnz']:>12.4f}"
              f"{st['unique_lines']:>8}"
              f"{st['bytes_moved'] / 1e6:>10.3f}")

    roof = rep["roofline"]
    print(f"\nroofline: {roof['flops']:.3g} flops / "
          f"{roof['bytes_moved']:.3g} bytes = "
          f"AI {roof['arith_intensity']:.2f} flop/B vs machine balance "
          f"{roof['machine_balance']:.1f} -> {roof['bound']}-bound "
          f"({roof['attainable_fraction_of_peak'] * 100:.2f}% of peak "
          f"attainable)")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--matrix", default=None,
                    help="corpus matrix name (default: first of the corpus)")
    ap.add_argument("--scale", default="small", choices=["small", "bench"])
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the report dict as JSON")
    args = ap.parse_args(argv)

    rep = _build_report(args.matrix, args.scale, args.top_k)
    _render(rep)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=1, sort_keys=True)
        print(f"\n[wrote {args.json}]")
    return rep


if __name__ == "__main__":
    main()
