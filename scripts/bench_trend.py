#!/usr/bin/env python
"""Render the bench-history trajectory and flag metric regressions.

    python scripts/bench_trend.py [--history PATH] [--metric SUBSTR]
                                  [--last K] [--check]

Reads the append-only JSONL that every ``benchmarks/run.py --json`` run
extends (``benchmarks/history/history.jsonl``, or ``$REPRO_BENCH_HISTORY``
/ ``--history``) and prints, per deterministic metric, its value across
runs oldest->newest with the git sha each value came from.

``--check`` exits non-zero when the newest record regressed any
deterministic lower-is-better metric (padded work, grid steps, solver
iterations, modeled cache misses, lint findings) by more than 5% vs the
best of the preceding ``--last`` records. Timings are never checked —
history files cross machines. Dependency-free by design (stdlib only,
same contract as ``scripts/bench_guard.py``).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import history  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="history JSONL (default: $REPRO_BENCH_HISTORY or "
                         "benchmarks/history/history.jsonl)")
    ap.add_argument("--metric", default=None, metavar="SUBSTR",
                    help="only print metrics containing SUBSTR")
    ap.add_argument("--last", type=int, default=5, metavar="K",
                    help="regression window: compare vs best of the "
                         "preceding K records (default %(default)s)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any deterministic-metric regression")
    args = ap.parse_args(argv)

    path = history.history_path(args.history)
    try:
        records = history.read_history(path)
    except ValueError as e:
        print(f"bench_trend: corrupt history: {e}", file=sys.stderr)
        return 1
    if not records:
        print(f"bench_trend: no records in {path}")
        return 0

    print(f"{len(records)} record(s) in {path}; newest: "
          f"sha={str(records[-1].get('git_sha'))[:12]} "
          f"scale={records[-1].get('scale')}")
    trajs = history.trajectories(records)
    shown = 0
    for name, points in sorted(trajs.items()):
        if args.metric and args.metric not in name:
            continue
        shown += 1
        vals = " -> ".join(f"{v:g}[{sha}]" for sha, v in points)
        print(f"  {name}: {vals}")
    if args.metric and not shown:
        print(f"  (no metric matches {args.metric!r})")

    problems = history.detect_regressions(records, last_k=args.last)
    if problems:
        print(f"\n{len(problems)} regression(s) vs last "
              f"{args.last} record(s):")
        for p in problems:
            print(f"  REGRESSION: {p}")
        if args.check:
            return 1
    elif len(records) < 2:
        print("\n(single record — nothing to compare yet)")
    else:
        print(f"\nno regressions vs last {args.last} record(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
