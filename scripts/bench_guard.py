#!/usr/bin/env python
"""Coarse benchmark regression gate for ``benchmarks/run.py --json`` output.

    python scripts/bench_guard.py NEW.json [BASELINE.json]

Two checks, both cheap enough for every CI run:

  * **schema** — the file is well-formed ``cb-spmv-bench/v1`` output,
    every ``spmv_batch``/``spmm``/``solvers`` row carries its required,
    finite metrics, and every solver row converged;
  * **regression** — deterministic metrics (``padded_*``, ``steps_*``)
    are compared row by row against the baseline (a 2x jump is always a
    genuine packing bug). Timings are guarded as the **batched /
    unbatched ratio**, geomean'd across matched rows, compared against
    the same ratio in the baseline — machine speed cancels out, so the
    checked-in baseline stays valid on any box; a 2x relative drift
    means batching itself got slower, not the machine. The ``solvers``
    section is guarded through its ``t_per_iter / t_ref_per_iter``
    ratio (jit solver vs scipy on the same box) — raw machine speed
    cancels, though the JAX-dispatch-vs-scipy overhead balance can
    still shift across toolchain upgrades, so regenerate the baseline
    when bumping either. Absolute wall times are never compared across
    machines. (Real perf gating needs TPU hardware — see ROADMAP.)

Exit status: 0 clean, 1 on any violation (messages on stderr).
"""
from __future__ import annotations

import json
import math
import sys

REQUIRED_SPMV_BATCH_KEYS = (
    "matrix", "nnz", "group_size", "steps_unbatched", "steps_batched",
    "padded_elems_unbatched", "padded_elems_batched",
    "padded_ratio_unbatched", "padded_ratio_batched",
    "t_unbatched", "t_batched",
)
# the SpMM section mirrors spmv_batch's schema exactly (same batched-
# engine claims: step shrink, padded weight stream, kernel-path timing)
REQUIRED_SPMM_KEYS = REQUIRED_SPMV_BATCH_KEYS
REQUIRED_SOLVER_KEYS = (
    "matrix", "solver", "n", "nnz", "iters_to_tol", "iters_ref",
    "converged", "t_per_iter", "t_ref_per_iter",
)
REQUIRED_KEYS_PER_SECTION = {
    "spmv_batch": REQUIRED_SPMV_BATCH_KEYS,
    "spmm": REQUIRED_SPMM_KEYS,
    "solvers": REQUIRED_SOLVER_KEYS,
}
ROW_GUARDED_PREFIXES = ("padded_elems_", "padded_ratio_", "steps_", "iters_")
# (numerator, denominator): the machine-independent relative timing signals
TIMING_PAIRS = (
    ("t_batched", "t_unbatched"),
    ("t_ref_batched", "t_ref_unbatched"),
    ("t_per_iter", "t_ref_per_iter"),
)
MAX_RATIO = 2.0


def fail(msg: str) -> None:
    print(f"bench_guard: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {path}: {e}")
    if not isinstance(data, dict) or data.get("schema") != "cb-spmv-bench/v1":
        fail(f"{path}: not cb-spmv-bench/v1 output")
    if not isinstance(data.get("sections"), dict) or not data["sections"]:
        fail(f"{path}: missing or empty 'sections'")
    return data


def check_schema(data: dict, path: str) -> None:
    for section, required in REQUIRED_KEYS_PER_SECTION.items():
        rows = data["sections"].get(section)
        if rows is None:
            continue
        if not isinstance(rows, list) or not rows:
            fail(f"{path}: {section} section is empty")
        for i, row in enumerate(rows):
            for key in required:
                if key not in row:
                    fail(f"{path}: {section}[{i}] missing '{key}'")
                val = row[key]
                if isinstance(val, (int, float)) and not math.isfinite(val):
                    fail(f"{path}: {section}[{i}]['{key}'] is not finite")
            if section == "solvers" and row.get("converged") is not True:
                fail(f"{path}: solvers[{i}] "
                     f"({row.get('matrix')}/{row.get('solver')}) "
                     f"did not converge")


def index_rows(rows) -> dict:
    """Rows keyed by matrix name (+ solver, for sections with several
    solvers per matrix)."""
    if not isinstance(rows, list):
        return {}
    return {f"{r['matrix']}/{r['solver']}" if "solver" in r else r["matrix"]: r
            for r in rows if isinstance(r, dict) and "matrix" in r}


def check_regressions(new: dict, base: dict) -> list[str]:
    problems = []
    for section, base_rows in base["sections"].items():
        new_rows = new["sections"].get(section)
        if new_rows is None:
            continue  # section not executed this run — nothing to compare
        base_idx = index_rows(base_rows)
        rel_drift: dict[str, list[float]] = {}
        for name, new_row in index_rows(new_rows).items():
            base_row = base_idx.get(name)
            if base_row is None:
                continue
            for key, new_val in new_row.items():
                old_val = base_row.get(key)
                if (not isinstance(old_val, (int, float)) or old_val <= 0
                        or not isinstance(new_val, (int, float))):
                    continue
                if key.startswith(ROW_GUARDED_PREFIXES):
                    if new_val > MAX_RATIO * old_val:
                        problems.append(
                            f"{section}/{name}/{key}: {new_val:.4g} > "
                            f"{MAX_RATIO}x baseline {old_val:.4g}")
            for num, den in TIMING_PAIRS:
                vals = [r.get(k) for r in (new_row, base_row)
                        for k in (num, den)]
                if not all(isinstance(v, (int, float)) and v > 0
                           for v in vals):
                    continue
                new_rel = new_row[num] / new_row[den]
                base_rel = base_row[num] / base_row[den]
                rel_drift.setdefault(f"{num}/{den}", []).append(
                    new_rel / base_rel)
        for pair, drifts in rel_drift.items():
            geo = math.exp(sum(math.log(d) for d in drifts) / len(drifts))
            if geo > MAX_RATIO:
                problems.append(
                    f"{section}/{pair}: relative timing drifted "
                    f"{geo:.2f}x > {MAX_RATIO}x vs baseline across "
                    f"{len(drifts)} rows")
    return problems


def main(argv: list[str]) -> int:
    if len(argv) not in (2, 3):
        print(__doc__, file=sys.stderr)
        return 1
    new = load(argv[1])
    check_schema(new, argv[1])
    if len(argv) == 3:
        base = load(argv[2])
        check_schema(base, argv[2])
        problems = check_regressions(new, base)
        if problems:
            for p in problems:
                print(f"bench_guard: REGRESSION {p}", file=sys.stderr)
            return 1
    print("bench_guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
