#!/usr/bin/env python
"""Coarse benchmark regression gate for ``benchmarks/run.py --json`` output.

    python scripts/bench_guard.py NEW.json [BASELINE.json]

Guard schemas are *data*, declared once per section in
``benchmarks/registry.py`` (required keys, timing-ratio pairs, must-be-
true keys, per-row minimums, geomean bounds) — this script only
interprets them. Two checks, both cheap enough for every CI run:

  * **schema** — the file is well-formed ``cb-spmv-bench/v1`` output and
    every guarded section's rows satisfy their declared contract: the
    required metrics present and finite, ``require_true`` keys true
    (e.g. every solver row converged), ``min_values`` bounds held (e.g.
    the plan-cache hit rate), and ``geomean_max`` bounds held (e.g.
    autotuned padded work <= the default-constants baseline).
  * **regression** — deterministic metrics (``padded_*``, ``steps_*``,
    ``iters_*``) are compared row by row against the baseline (a 2x jump
    is always a genuine packing bug). Timings are guarded as each
    section's declared ratio pairs, geomean'd across matched rows,
    compared against the same ratio in the baseline — machine speed
    cancels out, so the checked-in baseline stays valid on any box; a 2x
    relative drift means the engine itself got slower, not the machine.
    Absolute wall times are never compared across machines. (Real perf
    gating needs TPU hardware — see ROADMAP.)

Exit status: 0 clean, 1 on any violation (messages on stderr).
"""
from __future__ import annotations

import json
import math
import os
import sys

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)
from benchmarks.registry import SECTIONS  # noqa: E402

ROW_GUARDED_PREFIXES = ("padded_elems_", "padded_ratio_", "steps_", "iters_",
                        "l1_misses_per_nnz_", "l2_misses_per_nnz_",
                        "bytes_moved_")
MAX_RATIO = 2.0


def fail(msg: str) -> None:
    print(f"bench_guard: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {path}: {e}")
    if not isinstance(data, dict) or data.get("schema") != "cb-spmv-bench/v1":
        fail(f"{path}: not cb-spmv-bench/v1 output")
    if not isinstance(data.get("sections"), dict) or not data["sections"]:
        fail(f"{path}: missing or empty 'sections'")
    return data


def _geomean(xs) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def check_schema(data: dict, path: str) -> None:
    for name, section in SECTIONS.items():
        if not section.guarded:
            continue
        rows = data["sections"].get(name)
        if rows is None:
            continue
        if not isinstance(rows, list) or not rows:
            fail(f"{path}: {name} section is empty")
        for i, row in enumerate(rows):
            for key in section.required_keys:
                if key not in row:
                    fail(f"{path}: {name}[{i}] missing '{key}'")
                val = row[key]
                if isinstance(val, (int, float)) and not math.isfinite(val):
                    fail(f"{path}: {name}[{i}]['{key}'] is not finite")
            for key in section.require_true:
                if row.get(key) is not True:
                    fail(f"{path}: {name}[{i}] "
                         f"({row.get('matrix')}/{row.get('solver', '-')}) "
                         f"'{key}' is not True")
            for key, bound in section.min_values:
                val = row.get(key)
                if not isinstance(val, (int, float)) or val < bound:
                    fail(f"{path}: {name}[{i}]['{key}'] = {val} < "
                         f"required minimum {bound}")
        for num, den, bound in section.geomean_max:
            # clamp: a zero numerator (e.g. an empty planned stream) is a
            # very-good ratio, not a math domain error
            ratios = [max(row[num] / row[den], 1e-12) for row in rows
                      if isinstance(row.get(num), (int, float))
                      and isinstance(row.get(den), (int, float))
                      and row[den] > 0]
            if not ratios:
                fail(f"{path}: {name} has no rows for "
                     f"geomean({num}/{den}) bound")
            geo = _geomean(ratios)
            if geo > bound:
                fail(f"{path}: {name} geomean {num}/{den} = {geo:.4f} > "
                     f"bound {bound} across {len(ratios)} rows")


def index_rows(rows) -> dict:
    """Rows keyed by matrix name (+ solver, for sections with several
    solvers per matrix)."""
    if not isinstance(rows, list):
        return {}
    return {f"{r['matrix']}/{r['solver']}" if "solver" in r else r["matrix"]: r
            for r in rows if isinstance(r, dict) and "matrix" in r}


def check_regressions(new: dict, base: dict) -> list[str]:
    problems = []
    for name, base_rows in base["sections"].items():
        new_rows = new["sections"].get(name)
        if new_rows is None:
            continue  # section not executed this run — nothing to compare
        timing_pairs = (SECTIONS[name].timing_pairs
                        if name in SECTIONS else ())
        base_idx = index_rows(base_rows)
        rel_drift: dict[str, list[float]] = {}
        for row_name, new_row in index_rows(new_rows).items():
            base_row = base_idx.get(row_name)
            if base_row is None:
                continue
            for key, new_val in new_row.items():
                old_val = base_row.get(key)
                if (not isinstance(old_val, (int, float)) or old_val <= 0
                        or not isinstance(new_val, (int, float))):
                    continue
                if key.startswith(ROW_GUARDED_PREFIXES):
                    if new_val > MAX_RATIO * old_val:
                        problems.append(
                            f"{name}/{row_name}/{key}: {new_val:.4g} > "
                            f"{MAX_RATIO}x baseline {old_val:.4g}")
            for num, den in timing_pairs:
                vals = [r.get(k) for r in (new_row, base_row)
                        for k in (num, den)]
                if not all(isinstance(v, (int, float)) and v > 0
                           for v in vals):
                    continue
                new_rel = new_row[num] / new_row[den]
                base_rel = base_row[num] / base_row[den]
                rel_drift.setdefault(f"{num}/{den}", []).append(
                    new_rel / base_rel)
        for pair, drifts in rel_drift.items():
            geo = _geomean(drifts)
            if geo > MAX_RATIO:
                problems.append(
                    f"{name}/{pair}: relative timing drifted "
                    f"{geo:.2f}x > {MAX_RATIO}x vs baseline across "
                    f"{len(drifts)} rows")
    return problems


def main(argv: list[str]) -> int:
    if len(argv) not in (2, 3):
        print(__doc__, file=sys.stderr)
        return 1
    new = load(argv[1])
    check_schema(new, argv[1])
    if len(argv) == 3:
        base = load(argv[2])
        check_schema(base, argv[2])
        problems = check_regressions(new, base)
        if problems:
            for p in problems:
                print(f"bench_guard: REGRESSION {p}", file=sys.stderr)
            return 1
    print("bench_guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
