#!/usr/bin/env python
"""Run a tiny traced workload and render the obs subsystem's exports.

    PYTHONPATH=src python scripts/obs_report.py [--out PATH.trace.json]

Drives one ``robust_solve`` on an SPD corpus matrix plus a few serving
ticks on a toy model — both under the default tracer — then:

  * writes the spans as Chrome ``trace_event`` JSON (load the file in
    ``chrome://tracing`` / Perfetto);
  * prints a per-span-name summary table (count / total / mean / max);
  * prints the metrics snapshot's headline counters, including the
    per-plan measured-vs-predicted launch accounting so cost-model
    fidelity is visible at a glance.

``main`` returns the payload dict (trace path, chrome trace object,
snapshot) so the tier-1 smoke test can validate the export schema
without re-parsing stdout.
"""
from __future__ import annotations

import argparse
import json
import sys


def _build_workload():
    """One robust_solve + a short serving run, all under obs."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.core.cb_matrix import CBMatrix
    from repro.data import matrices
    from repro.models.model import Model
    from repro.serving import Request, ServingEngine
    from repro.solvers import CBLinearOperator, robust_solve

    d = 96
    r, c, v = matrices.spd_banded(d, bandwidth=7, seed=3)
    cb = CBMatrix.from_coo(r, c, v.astype(np.float32), (d, d),
                           block_size=16, val_dtype=np.float32)
    op = CBLinearOperator.from_cb(cb, plan="auto")
    locality = _locality_stats(op, int(cb.nnz))
    b = jnp.asarray(
        np.random.default_rng(0).standard_normal(d).astype(np.float32))
    res = robust_solve(op, b, tol=1e-6, maxiter=300)

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
                      attn_chunk=32, remat="none", dtype="float32")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, slots=2, max_len=64)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=np.array([i + 1], np.int32),
                           max_new_tokens=2))
    eng.run_until_done(max_ticks=16)
    return res, eng, locality


def _locality_stats(op, nnz: int) -> dict:
    """Modeled cache traffic of the operator's planned super-streams."""
    from repro.obs import locality as loc

    return loc.stream_stats(loc.access_stream_super(op.streams), nnz=nnz)


def _counter_rows(snap: dict, name: str) -> list[tuple[str, float]]:
    entry = snap.get(name)
    if not entry:
        return []
    return [
        (",".join(f"{k}={v}" for k, v in sorted(s["labels"].items())) or "-",
         s["value"])
        for s in entry["series"]
    ]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="obs_demo.trace.json",
                    help="Chrome trace output path (default %(default)s)")
    args = ap.parse_args(argv)

    from repro import obs

    obs.configure(enabled=True)
    obs.reset()
    res, eng, locality = _build_workload()

    trace_path = obs.export_chrome_trace(args.out)
    trace = obs.chrome_trace()
    snap = obs.snapshot()

    print(f"solve: converged={res.converged} solver={res.solver} "
          f"attempts={len(res.attempts)}; "
          f"serving: ticks={eng.health()['ticks']} "
          f"completed={eng.health()['completed']}")
    print(f"\n[chrome trace: {trace_path} — "
          f"{len(trace['traceEvents'])} events]")

    print(f"\n{'span':<24}{'count':>7}{'total_ms':>10}"
          f"{'mean_ms':>9}{'max_ms':>9}")
    for row in obs.tracer().summary():
        print(f"{row['name']:<24}{row['count']:>7}"
              f"{row['total_s'] * 1e3:>10.2f}"
              f"{row['mean_s'] * 1e3:>9.2f}{row['max_s'] * 1e3:>9.2f}")

    print(f"\n{'metric / labels':<58}{'value':>10}")
    headline = (
        "repro.ops.spmv.calls",
        "repro.ops.spmv.launches",
        "repro.ops.spmv.steps",
        "repro.ops.spmv.padded_elems",
        "repro.solvers.traces",
        "repro.solvers.robust.attempts",
        "repro.solvers.robust.outcome",
        "repro.serving.ticks",
        "repro.serving.completed",
    )
    for name in headline:
        for labels, value in _counter_rows(snap, name):
            print(f"{name + '{' + labels + '}':<58}{value:>10g}")

    print("\nplan accounting (measured vs predicted, per structure hash):")
    for metric in ("repro.autotune.exec.padded_elems",
                   "repro.autotune.exec.steps"):
        rows = dict(_counter_rows(snap, metric))
        plans = sorted({lab.split(",")[1] for lab in rows})
        for plan in plans:
            meas = rows.get(f"kind=measured,{plan}", 0)
            pred = rows.get(f"kind=predicted,{plan}", 0)
            ratio = meas / pred if pred else float("nan")
            print(f"  {metric.split('.')[-1]:<14}{plan:<24}"
                  f"measured={meas:<10g}predicted={pred:<10g}"
                  f"ratio={ratio:.3f}")

    print("\nmodeled locality (planned super-streams, LRU line model):")
    print(f"  l1_hit={locality['l1_hit_rate']:.3f} "
          f"l2_hit={locality['l2_hit_rate']:.3f} "
          f"l1miss/nnz={locality['l1_misses_per_nnz']:.4f} "
          f"l2miss/nnz={locality['l2_misses_per_nnz']:.4f} "
          f"lines={locality['unique_lines']} "
          f"bytes_moved={locality['bytes_moved']} "
          f"AI={locality['arith_intensity']:.2f}")

    return {"trace_path": trace_path, "trace": trace, "snapshot": snap,
            "summary": obs.tracer().summary(), "locality": locality}


if __name__ == "__main__":
    main()
    sys.exit(0)
