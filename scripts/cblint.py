#!/usr/bin/env python
"""cblint CLI — run the repo-invariant static analysis.

    python scripts/cblint.py [PATH ...]          # default: src/repro
    python scripts/cblint.py --json              # machine-readable report
    python scripts/cblint.py --changed           # only git-modified files
    python scripts/cblint.py --update-baseline   # grandfather current hits

Exit status: 0 clean, 1 findings, 2 bad invocation. Human output is one
``path:line:col: CBxxx message  [fix: hint]`` line per finding; the
``--json`` report is byte-deterministic (sorted findings, no
timestamps). Rule catalog: ``src/repro/analysis/README.md``.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Standalone-invocable: `python scripts/cblint.py` works without an
# exported PYTHONPATH (check.sh exports it; a bare shell may not).
_SRC = os.path.join(_REPO_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro import analysis  # noqa: E402


def _changed_files(paths: list[str]) -> list[str]:
    """git-modified + untracked .py files under ``paths``."""
    def git(*args: str) -> list[str]:
        out = subprocess.run(
            ["git", *args], cwd=_REPO_ROOT, check=True,
            capture_output=True, text=True,
        ).stdout
        return [line for line in out.splitlines() if line.strip()]

    candidates = set(git("diff", "--name-only", "HEAD"))
    candidates.update(git("ls-files", "--others", "--exclude-standard"))
    roots = [os.path.normpath(p) for p in paths]
    chosen = []
    for rel in sorted(candidates):
        if not rel.endswith(".py"):
            continue
        norm = os.path.normpath(rel)
        if any(norm == r or norm.startswith(r + os.sep) for r in roots):
            full = os.path.join(_REPO_ROOT, rel)
            if os.path.exists(full):
                chosen.append(full)
    return chosen


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="cblint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="emit the deterministic JSON report")
    ap.add_argument("--changed", action="store_true",
                    help="lint only git-modified/untracked files under "
                         "the given paths")
    ap.add_argument("--baseline", default=analysis.DEFAULT_BASELINE,
                    metavar="PATH",
                    help="baseline JSON (default: the checked-in one); "
                         "'none' disables")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to excuse every current "
                         "finding, then exit 0")
    ap.add_argument("--no-obs", action="store_true",
                    help="skip publishing counts to the obs registry")
    args = ap.parse_args(argv)

    paths = args.paths or [os.path.join(_REPO_ROOT, "src", "repro")]
    if args.changed:
        paths = _changed_files(paths)
        if not paths:
            if not args.json:
                print("cblint: no changed python files")
            return 0

    baseline = None if args.baseline == "none" else args.baseline
    if args.update_baseline:
        result = analysis.lint_paths(paths, root=_REPO_ROOT,
                                     baseline_path=None)
        target = baseline or analysis.DEFAULT_BASELINE
        analysis.save_baseline(target, result.findings)
        print(f"cblint: baselined {len(result.findings)} finding(s) "
              f"-> {os.path.relpath(target, _REPO_ROOT)}")
        return 0

    result = analysis.lint_paths(paths, root=_REPO_ROOT,
                                 baseline_path=baseline,
                                 record_obs=not args.no_obs)

    if args.json:
        print(result.to_json())
    else:
        for finding in result.findings:
            print(finding.format())
        tail = (f"cblint: {len(result.findings)} finding(s) in "
                f"{result.files} file(s)")
        if result.suppressed:
            tail += f", {result.suppressed} suppressed"
        if result.baseline_used:
            tail += f", {sum(e['count'] for e in result.baseline_used)} " \
                    "baselined"
        print(tail)
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
