#!/usr/bin/env bash
# Repo health check: the tier-1 gate plus a fast benchmark smoke.
#
#   scripts/check.sh            # full tier-1 suite + fig34 smoke
#   scripts/check.sh --fast     # skip slow/system tests (quick iteration)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow and not system")
fi

echo "== tier-1: pytest ${PYTEST_ARGS[*]} =="
python -m pytest "${PYTEST_ARGS[@]}"

echo "== cblint: repo-invariant static analysis (src/repro) =="
python scripts/cblint.py src/repro

echo "== benchmark smoke: fig34 (distribution + balance) =="
python -m benchmarks.run --scale small --only fig34

echo "== robustness: fault-injection axis (pytest -m robustness) =="
python -m pytest -q -m robustness

echo "== benchmark smoke: spmv_batch + spmm + solvers + autotune + dynamic + robustness + obs + locality (--json + regression guard) =="
BENCH_JSON="$(mktemp /tmp/bench_spmv.XXXXXX.json)"
# run.py --json appends a bench-history record; point it at a scratch
# copy of the checked-in history so CI runs never dirty the tree, then
# trend-check the extended copy (newest record vs checked-in trajectory).
BENCH_HISTORY="$(mktemp /tmp/bench_history.XXXXXX.jsonl)"
trap 'rm -f "$BENCH_JSON" "$BENCH_HISTORY"' EXIT
cp benchmarks/history/history.jsonl "$BENCH_HISTORY"
REPRO_BENCH_HISTORY="$BENCH_HISTORY" python -m benchmarks.run --scale small --only spmv_batch,spmm,solvers,autotune,dynamic,robustness,obs,locality --json "$BENCH_JSON"
python scripts/bench_guard.py "$BENCH_JSON" benchmarks/BENCH_spmv.json

echo "== bench trend: deterministic-metric trajectory check =="
python scripts/bench_trend.py --history "$BENCH_HISTORY" --check
