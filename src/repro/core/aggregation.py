"""Intra-block data aggregation (paper §3.2, Fig. 7).

All of a sub-block's data — packed coordinates and values, of different
dtypes — is serialized into ONE contiguous byte region of a single flat
uint8 buffer (``mtx_data`` in the paper). A *virtual pointer* per block
(``vp_per_blk``) records the region's start offset; on-device access is by
pointer offset only, so a block is fetched with one sequential read.

Faithful details preserved from the paper:
  * 16x16 coordinates pack into a single uint8: ``byte = col << 4 | row``
    (Alg. 3 decodes ``row = b & 15; col = b >> 4``). Larger blocks use a
    uint16 with the same ``col << bits | row`` layout.
  * Alignment padding between the coordinate section and the value section:
    ``padding = (-idx_bytes) % sizeof(val)`` (Alg. 3 lines 6-7), plus each
    block region starts on a ``sizeof(val)``-aligned boundary so that the
    value pointer arithmetic is alignment-safe (Fig. 7(b)).
  * COO / CSR / Dense intra-block layouts, selected per block.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .formats import FMT_COO, FMT_CSR, FMT_DENSE
from repro import errors


def coord_bits(block_size: int) -> int:
    return max(1, (block_size - 1).bit_length())


def coord_dtype(block_size: int) -> np.dtype:
    """uint8 when row+col nibbles fit (B<=16), else uint16 (B<=256)."""
    bits = coord_bits(block_size)
    if 2 * bits <= 8:
        return np.dtype(np.uint8)
    if 2 * bits <= 16:
        return np.dtype(np.uint16)
    raise errors.InvalidArgError(f"block_size {block_size} too large for packed coordinates")


def encode_coords(local_rows: np.ndarray, local_cols: np.ndarray, block_size: int) -> np.ndarray:
    bits = coord_bits(block_size)
    dt = coord_dtype(block_size)
    packed = (local_cols.astype(np.uint32) << bits) | local_rows.astype(np.uint32)
    return packed.astype(dt)


def decode_coords(packed: np.ndarray, block_size: int) -> tuple[np.ndarray, np.ndarray]:
    bits = coord_bits(block_size)
    mask = (1 << bits) - 1
    p = packed.astype(np.uint32)
    return (p & mask).astype(np.int32), (p >> bits).astype(np.int32)


def _align(offset: int, alignment: int) -> int:
    return offset + (-offset) % alignment


def _csr_rowptr_dtype(block_size: int) -> np.dtype:
    # B*B max nnz: 256 for B=16 needs uint16; 16384 for B=128 also uint16.
    return np.dtype(np.uint16) if block_size * block_size <= 0xFFFF else np.dtype(np.uint32)


def pack_block(
    fmt: int,
    local_rows: np.ndarray,
    local_cols: np.ndarray,
    values: np.ndarray,
    block_size: int,
) -> np.ndarray:
    """Serialize one sub-block into a uint8 byte string (no leading pad)."""
    B = block_size
    val = np.ascontiguousarray(values)
    vsize = val.dtype.itemsize
    if fmt == FMT_DENSE:
        tile = np.zeros((B, B), dtype=val.dtype)
        tile[local_rows, local_cols] = val
        return tile.reshape(-1).view(np.uint8).copy()
    if fmt == FMT_COO:
        idx = encode_coords(local_rows, local_cols, B)
        idx_bytes = idx.view(np.uint8)
        pad = (-len(idx_bytes)) % vsize
        return np.concatenate(
            [idx_bytes, np.zeros(pad, np.uint8), val.view(np.uint8)]
        )
    if fmt == FMT_CSR:
        # Elements arrive row-major (blocking.partition_coo guarantees it).
        rp_dt = _csr_rowptr_dtype(B)
        row_ptr = np.zeros(B + 1, dtype=np.int64)
        np.add.at(row_ptr, local_rows.astype(np.int64) + 1, 1)
        row_ptr = np.cumsum(row_ptr).astype(rp_dt)
        cols = local_cols.astype(coord_dtype(B))
        head = np.concatenate([row_ptr.view(np.uint8), cols.view(np.uint8)])
        pad = (-len(head)) % vsize
        return np.concatenate([head, np.zeros(pad, np.uint8), val.view(np.uint8)])
    raise errors.InvalidArgError(f"unknown format {fmt}")


def unpack_block(
    buf: np.ndarray,
    vp: int,
    fmt: int,
    nnz: int,
    block_size: int,
    val_dtype: np.dtype,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Inverse of pack_block: returns (local_rows, local_cols, values)."""
    B = block_size
    vsize = np.dtype(val_dtype).itemsize
    if fmt == FMT_DENSE:
        nbytes = B * B * vsize
        tile = buf[vp : vp + nbytes].view(val_dtype).reshape(B, B)
        r, c = np.nonzero(tile)
        return r.astype(np.int32), c.astype(np.int32), tile[r, c]
    if fmt == FMT_COO:
        idx_nbytes = nnz * coord_dtype(B).itemsize
        idx = buf[vp : vp + idx_nbytes].view(coord_dtype(B))
        pad = (-idx_nbytes) % vsize
        voff = vp + idx_nbytes + pad
        vals = buf[voff : voff + nnz * vsize].view(val_dtype)
        r, c = decode_coords(idx, B)
        return r, c, vals
    if fmt == FMT_CSR:
        rp_dt = _csr_rowptr_dtype(B)
        rp_nbytes = (B + 1) * rp_dt.itemsize
        row_ptr = buf[vp : vp + rp_nbytes].view(rp_dt).astype(np.int64)
        cdt = coord_dtype(B)
        coff = vp + rp_nbytes
        cols = buf[coff : coff + nnz * cdt.itemsize].view(cdt).astype(np.int32)
        head = rp_nbytes + nnz * cdt.itemsize
        pad = (-head) % vsize
        voff = vp + head + pad
        vals = buf[voff : voff + nnz * vsize].view(val_dtype)
        rows = np.repeat(np.arange(B, dtype=np.int32), np.diff(row_ptr))
        return rows, cols, vals
    raise errors.InvalidArgError(f"unknown format {fmt}")


@dataclasses.dataclass
class PackedBlocks:
    """The aggregated single-buffer representation (``mtx_data`` + VPs)."""

    packed: np.ndarray        # (total_bytes,) uint8
    vp_per_blk: np.ndarray    # (nblk,) int64 byte offsets
    nbytes_per_blk: np.ndarray  # (nblk,) int64


def aggregate_blocks(
    fmts: np.ndarray,
    block_elems: list[tuple[np.ndarray, np.ndarray, np.ndarray]],
    block_size: int,
    val_dtype: np.dtype,
    alignment: int | None = None,
) -> PackedBlocks:
    """Pack every block back-to-back into one flat uint8 buffer.

    Each block's region starts on an ``alignment``-aligned boundary
    (default: value dtype size, min 4) — the Fig. 7(b) padding strategy.
    """
    vsize = np.dtype(val_dtype).itemsize
    align = alignment or max(vsize, 4)
    chunks: list[np.ndarray] = []
    vps = np.zeros(len(block_elems), dtype=np.int64)
    sizes = np.zeros(len(block_elems), dtype=np.int64)
    off = 0
    for i, (r, c, v) in enumerate(block_elems):
        blob = pack_block(int(fmts[i]), r, c, v.astype(val_dtype), block_size)
        start = _align(off, align)
        if start != off:
            chunks.append(np.zeros(start - off, np.uint8))
        vps[i] = start
        sizes[i] = len(blob)
        chunks.append(blob)
        off = start + len(blob)
    packed = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
    return PackedBlocks(packed=packed, vp_per_blk=vps, nbytes_per_blk=sizes)
