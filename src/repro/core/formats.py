"""Per-sub-block storage-format selection (paper §3.3.2).

The paper fixes a 16x16 block and thresholds th1=32, th2=128:
    nnz <  th1  -> COO     (super-sparse; warp-level atomics path on GPU)
    th1 <= nnz <= th2 -> CSR (intermediate)
    nnz >  th2  -> Dense   (MXU/Tensor-core friendly)

We keep those exact numbers for B=16 and scale them with block area for
other block sizes (the thresholds are density thresholds in disguise:
32/256 = 12.5%, 128/256 = 50%).

th0 (paper §3.3.1) gates *matrix-level* column aggregation: it is applied
iff the fraction of super-sparse sub-blocks (nnz < 2*B) is >= th0 = 0.15.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from repro import errors

# Format codes stored in ``type_per_blk`` (uint8).
FMT_COO = 0
FMT_CSR = 1
FMT_DENSE = 2

FMT_NAMES = {FMT_COO: "coo", FMT_CSR: "csr", FMT_DENSE: "dense"}


@dataclasses.dataclass(frozen=True)
class FormatThresholds:
    """Thresholds controlling CB-SpMV's computational adaptation."""

    th0: float = 0.15   # matrix-level column-aggregation gate
    th1: int | None = None  # COO/CSR boundary (defaults to B*B/8, =32 at B=16)
    th2: int | None = None  # CSR/Dense boundary (defaults to B*B/2, =128 at B=16)

    def resolve(self, block_size: int) -> tuple[int, int]:
        area = block_size * block_size
        th1 = self.th1 if self.th1 is not None else max(1, area // 8)
        th2 = self.th2 if self.th2 is not None else max(th1, area // 2)
        if th1 < 1:
            raise errors.InvalidArgError(
                f"th1 must be >= 1 (a block always holds at least one "
                f"element), got th1={th1} for B={block_size}"
            )
        if th2 < th1:
            raise errors.InvalidArgError(
                f"th2 must be >= th1 (the CSR band cannot be negative), "
                f"got th1={th1} > th2={th2} for B={block_size}"
            )
        if th2 > area:
            raise errors.InvalidArgError(
                f"th2 must be <= B*B={area} (no block holds more than its "
                f"area), got th2={th2} for B={block_size}"
            )
        return th1, th2


DEFAULT_THRESHOLDS = FormatThresholds()


def super_sparse_threshold(block_size: int) -> int:
    """nnz below which a block is 'super-sparse' (paper: 32 for B=16)."""
    return 2 * block_size


def super_sparse_fraction(nnz_per_blk: np.ndarray, block_size: int) -> float:
    """Fraction of non-zero sub-blocks that are super-sparse (Fig. 3)."""
    if len(nnz_per_blk) == 0:
        return 0.0
    return float(np.mean(nnz_per_blk < super_sparse_threshold(block_size)))


def should_column_aggregate(
    nnz_per_blk: np.ndarray, block_size: int, thresholds: FormatThresholds = DEFAULT_THRESHOLDS
) -> bool:
    """Matrix-level column-aggregation decision (paper §3.3.1, th0)."""
    return super_sparse_fraction(nnz_per_blk, block_size) >= thresholds.th0


def coerce_thresholds(thresholds) -> FormatThresholds:
    """Accept a ``FormatThresholds`` or anything carrying one (a ``Plan``).

    The autotune subsystem's ``Plan`` exposes its chosen thresholds as a
    ``.thresholds`` property; selectors take either the bare record or the
    plan so callers never unwrap by hand.
    """
    if isinstance(thresholds, FormatThresholds):
        return thresholds
    inner = getattr(thresholds, "thresholds", None)
    if isinstance(inner, FormatThresholds):
        return inner
    raise TypeError(
        f"expected FormatThresholds or a Plan carrying one, "
        f"got {type(thresholds).__name__}"
    )


def select_formats(
    nnz_per_blk: np.ndarray,
    block_size: int,
    thresholds: FormatThresholds = DEFAULT_THRESHOLDS,
) -> np.ndarray:
    """Vectorized per-block format selection. Returns uint8 codes.

    ``thresholds`` may be a ``FormatThresholds`` or an autotune ``Plan``
    (anything with a ``.thresholds`` property) — see ``coerce_thresholds``.
    """
    th1, th2 = coerce_thresholds(thresholds).resolve(block_size)
    nnz = np.asarray(nnz_per_blk)
    fmt = np.full(nnz.shape, FMT_CSR, dtype=np.uint8)
    fmt[nnz < th1] = FMT_COO
    fmt[nnz > th2] = FMT_DENSE
    return fmt
