"""CBMatrix — the end-to-end CB-SpMV data structure (paper Fig. 5 / Fig. 6).

Conversion pipeline (COO input -> CB structure), exactly the paper's flow:

  1. load block-based COO           (blocking.partition_coo)
  2. matrix characteristics check   (formats.should_column_aggregate, th0)
  3. block-aware column aggregation (column_agg.column_aggregate)
  4. 2D structure + format select   (formats.select_formats, th1/th2)
  5. intra-block data aggregation   (aggregation.aggregate_blocks -> VP)
  6. inter-TB load balance          (balance.tb_load_balance, Alg. 2)

The resulting object holds the high-level block-COO metadata in *balanced
slot order* plus the single packed byte buffer — the faithful portable
format. Kernel-facing typed streams are derived by core/streams.py.
"""
from __future__ import annotations

import dataclasses
import hashlib
import zipfile
import zlib

import numpy as np

from repro import errors

from . import aggregation, balance, blocking, column_agg, formats


def _nonfinite_policy(vals: np.ndarray, policy: str, where: str) -> np.ndarray:
    """Apply the non-finite payload policy (``repro.errors`` taxonomy).

    ``"raise"`` (the hardened default) rejects NaN/Inf with a typed
    ``NonFiniteError``; ``"sanitize"`` maps them to 0.0; ``"allow"``
    keeps them (the caller owns downstream NaN propagation — the solver
    loops flag it as ``SolverStatus.NONFINITE``).
    """
    if policy == "allow" or not np.issubdtype(vals.dtype, np.inexact):
        return vals
    finite = np.isfinite(vals)
    if finite.all():
        return vals
    if policy == "raise":
        bad = int((~finite).sum())
        raise errors.NonFiniteError(
            f"{where}: {bad} non-finite value(s) in payload "
            f"(pass nonfinite='sanitize' to zero them or 'allow' to keep)"
        )
    if policy == "sanitize":
        return np.where(finite, vals, vals.dtype.type(0))
    raise errors.InvalidArgError(
        f"unknown nonfinite policy {policy!r}; "
        "expected 'raise', 'sanitize' or 'allow'"
    )


def _npz_checksum(entries: dict) -> str:
    """Deterministic sha256 over named arrays (key + dtype + shape + bytes)."""
    h = hashlib.sha256()
    for key in sorted(entries):
        arr = np.asarray(entries[key])
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(np.asarray(arr.shape, np.int64).tobytes())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class ValueLayout:
    """The once-per-structure value-scatter index (``value_layout``).

    ``byte_pos[i]`` is the first byte of canonical element ``i``'s value
    inside ``CBMatrix.packed``; ``keys[i]`` is its ``row * n + col`` key
    in canonical ascending order.
    """

    count: int
    byte_pos: np.ndarray   # (count,) int64
    keys: np.ndarray       # (count,) int64


@dataclasses.dataclass
class CBMatrix:
    shape: tuple[int, int]
    block_size: int
    val_dtype: np.dtype
    thresholds: formats.FormatThresholds

    # High-level block-COO metadata, in balanced slot order (padded with
    # empty slots so every group holds exactly `group_size` blocks).
    blk_row_idx: np.ndarray    # (nslots,) int32 — block-row (panel) index
    blk_col_idx: np.ndarray    # (nslots,) int32 — block-col in (compacted) space
    nnz_per_blk: np.ndarray    # (nslots,) int32 — 0 for pad slots
    type_per_blk: np.ndarray   # (nslots,) uint8
    vp_per_blk: np.ndarray     # (nslots,) int64 byte offsets (0 for pads)

    packed: np.ndarray         # (total_bytes,) uint8 — ``mtx_data``
    colagg: column_agg.ColumnAggregation
    balance_result: balance.BalanceResult
    nnz: int

    # ------------------------------------------------------------------
    @classmethod
    def from_coo(
        cls,
        rows: np.ndarray,
        cols: np.ndarray,
        vals: np.ndarray,
        shape: tuple[int, int],
        block_size: int = 16,
        val_dtype=np.float32,
        thresholds: formats.FormatThresholds = formats.DEFAULT_THRESHOLDS,
        use_column_aggregation: bool | str = "auto",
        warps_per_tb: int = 8,
        nonfinite: str = "raise",
    ) -> "CBMatrix":
        val_dtype = np.dtype(val_dtype)
        thresholds = formats.coerce_thresholds(thresholds)
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = np.asarray(vals, dtype=val_dtype)
        vals = _nonfinite_policy(vals, nonfinite, "CBMatrix.from_coo")

        # (1)+(2): probe partition to decide column aggregation (th0 gate).
        probe = blocking.partition_coo(rows, cols, vals, shape, block_size)
        if use_column_aggregation == "auto":
            apply_agg = formats.should_column_aggregate(
                probe.nnz_per_blk, block_size, thresholds
            )
        else:
            apply_agg = bool(use_column_aggregation)

        # (3): panel-level column compaction.
        if apply_agg:
            agg = column_agg.column_aggregate(rows, cols, shape, block_size)
            part = blocking.partition_coo(rows, agg.new_cols, vals, shape, block_size)
        else:
            agg = column_agg.identity_aggregation(cols, shape, block_size)
            part = probe

        # (4): per-block format selection.
        fmts = formats.select_formats(part.nnz_per_blk, block_size, thresholds)

        # (5): intra-block aggregation into the flat buffer + VPs.
        elems = [part.block_elems(i) for i in range(part.num_blocks)]
        packed = aggregation.aggregate_blocks(fmts, elems, block_size, val_dtype)

        # (6): inter-TB load balance (Alg. 2) and metadata permutation.
        bal = balance.tb_load_balance(part.nnz_per_blk, warps_per_tb)
        brow, bcol, nnzb, typb, vps = balance.apply_balance(
            bal,
            part.blk_row_idx,
            part.blk_col_idx,
            part.nnz_per_blk,
            fmts,
            packed.vp_per_blk,
            pad_values=(0, 0, 0, formats.FMT_COO, 0),
        )

        return cls(
            shape=tuple(shape),
            block_size=block_size,
            val_dtype=val_dtype,
            thresholds=thresholds,
            blk_row_idx=brow,
            blk_col_idx=bcol,
            nnz_per_blk=nnzb,
            type_per_blk=typb,
            vp_per_blk=vps,
            packed=packed.packed,
            colagg=agg,
            balance_result=bal,
            nnz=part.nnz,
        )

    # ------------------------------------------------------------------
    # Planning — the autotune subsystem's entry points, surfaced here so
    # ``from_coo``'s callers find them next to the constructor they tune.
    # ------------------------------------------------------------------

    @classmethod
    def plan_for(
        cls,
        rows: np.ndarray,
        cols: np.ndarray,
        vals: np.ndarray,
        shape: tuple[int, int],
        *,
        val_dtype=np.float32,
        cache=None,
        settings=None,
    ):
        """``from_coo``'s companion: pick a per-matrix configuration.

        Runs the autotune search (features -> cost model -> empirical
        refinement; see ``src/repro/autotune/``) and returns a ``Plan``
        whose (block size, thresholds, colagg, group size) can be applied
        via :meth:`from_plan`. ``cache`` is an optional
        ``autotune.PlanCache`` — a content-hash hit skips the search
        entirely, the MERBIT cross-process amortization regime.
        """
        from repro.autotune.search import plan_search

        return plan_search(rows, cols, vals, shape, val_dtype=val_dtype,
                           cache=cache, settings=settings)

    @classmethod
    def from_plan(
        cls,
        rows: np.ndarray,
        cols: np.ndarray,
        vals: np.ndarray,
        shape: tuple[int, int],
        plan,
    ) -> "CBMatrix":
        """Build the CB structure with a ``Plan``'s chosen configuration.

        The plan's colagg decision was *resolved* at planning time, so it
        is passed as an explicit bool — rebuilding from a cached plan is
        bit-identical to the freshly-planned build even if the th0 gate
        would flip on a re-probe.

        The plan is validated before any work runs: shape against the
        matrix, plus internal consistency (thresholds must resolve at
        the plan's block size) — a stale or hand-edited plan fails here
        with a reason instead of mis-building silently. The cache path
        (``autotune.PlanCache.get``) performs the same validation and
        treats failures as a counted miss.
        """
        checker = getattr(plan, "check_valid", None)
        if checker is not None:
            reason = checker(shape=shape)
        else:
            reason = (None if tuple(shape) == tuple(plan.shape) else
                      f"plan was made for shape {plan.shape}, "
                      f"got {tuple(shape)}")
        if reason is not None:
            raise errors.PlanStaleError(reason)
        return cls.from_coo(
            rows, cols, vals, shape,
            block_size=plan.block_size,
            val_dtype=np.dtype(plan.val_dtype),
            thresholds=plan.thresholds,
            use_column_aggregation=plan.colagg,
        )

    # ------------------------------------------------------------------
    # Persistence — amortize preprocessing across *processes* (a solver
    # restart or benchmark rerun loads the plan instead of rebuilding it).
    # ------------------------------------------------------------------

    SAVE_SCHEMA = "cb-matrix/v1"

    def save(self, path) -> None:
        """Serialize the full CB structure to a single ``.npz`` file.

        The payload is integrity-checked: a sha256 over every named
        array (key, dtype, shape, bytes — deterministic order) rides
        along as ``checksum`` and is re-verified by :meth:`load`, so a
        truncated or byte-flipped artifact fails with a typed
        ``errors.ArtifactError`` instead of mis-building silently.
        """
        th = self.thresholds
        entries = dict(
            schema=np.asarray(self.SAVE_SCHEMA),
            shape=np.asarray(self.shape, np.int64),
            block_size=np.int64(self.block_size),
            val_dtype=np.asarray(np.dtype(self.val_dtype).name),
            # None thresholds (the "derive from B" default) ride as -1.
            thresholds=np.asarray(
                [th.th0,
                 -1 if th.th1 is None else th.th1,
                 -1 if th.th2 is None else th.th2], np.float64
            ),
            blk_row_idx=self.blk_row_idx,
            blk_col_idx=self.blk_col_idx,
            nnz_per_blk=self.nnz_per_blk,
            type_per_blk=self.type_per_blk,
            vp_per_blk=self.vp_per_blk,
            packed=self.packed,
            colagg_applied=np.bool_(self.colagg.applied),
            colagg_new_cols=self.colagg.new_cols,
            colagg_restore_cols=self.colagg.restore_cols,
            colagg_cols_offset=self.colagg.cols_offset,
            colagg_panel_width=self.colagg.panel_width,
            bal_slots=self.balance_result.slots,
            bal_group_loads=self.balance_result.group_loads,
            bal_geom=np.asarray(
                [self.balance_result.num_groups,
                 self.balance_result.group_size], np.int64
            ),
            nnz=np.int64(self.nnz),
        )
        entries["checksum"] = np.asarray(_npz_checksum(entries))
        np.savez(path, **entries)

    @classmethod
    def load(cls, path, *, validate: bool = True) -> "CBMatrix":
        """Inverse of :meth:`save`; rejects unknown schemas and corruption.

        Every failure mode is typed (``repro.errors``): an unreadable or
        byte-damaged file (zip/zlib/truncation errors, checksum
        mismatch) raises ``ArtifactError``; a wrong schema tag raises
        ``SchemaError``; a payload that decodes but violates the CB
        structural invariants fails :meth:`validate` (skippable via
        ``validate=False`` for forensics on damaged artifacts).
        Pre-checksum ``cb-matrix/v1`` files (no ``checksum`` entry)
        still load.
        """
        try:
            with np.load(path, allow_pickle=False) as z:
                entries = {k: np.asarray(z[k]) for k in z.files}
        except (OSError, zipfile.BadZipFile, zlib.error, EOFError,
                KeyError, ValueError, NotImplementedError) as e:
            # NotImplementedError: zipfile raises it when a byte flip lands
            # in the archive's version-needed field.
            raise errors.ArtifactError(
                f"{path}: unreadable cb-matrix artifact: {e}"
            ) from e
        schema = str(entries.get("schema"))
        if schema != cls.SAVE_SCHEMA:
            raise errors.SchemaError(
                f"{path}: schema {schema!r} != {cls.SAVE_SCHEMA!r}"
            )
        stored = entries.pop("checksum", None)
        if stored is not None:
            digest = _npz_checksum(entries)
            if str(stored) != digest:
                raise errors.ArtifactError(
                    f"{path}: checksum mismatch — artifact bytes are "
                    f"corrupted (stored {str(stored)[:12]}..., "
                    f"recomputed {digest[:12]}...)"
                )
        try:
            th0, th1, th2 = entries["thresholds"]
            cb = cls(
                shape=tuple(int(v) for v in entries["shape"]),
                block_size=int(entries["block_size"]),
                val_dtype=np.dtype(str(entries["val_dtype"])),
                thresholds=formats.FormatThresholds(
                    th0=float(th0),
                    th1=None if th1 < 0 else int(th1),
                    th2=None if th2 < 0 else int(th2),
                ),
                blk_row_idx=entries["blk_row_idx"],
                blk_col_idx=entries["blk_col_idx"],
                nnz_per_blk=entries["nnz_per_blk"],
                type_per_blk=entries["type_per_blk"],
                vp_per_blk=entries["vp_per_blk"],
                packed=entries["packed"],
                colagg=column_agg.ColumnAggregation(
                    applied=bool(entries["colagg_applied"]),
                    new_cols=entries["colagg_new_cols"],
                    restore_cols=entries["colagg_restore_cols"],
                    cols_offset=entries["colagg_cols_offset"],
                    panel_width=entries["colagg_panel_width"],
                    num_panels=len(entries["colagg_panel_width"]),
                ),
                balance_result=balance.BalanceResult(
                    slots=entries["bal_slots"],
                    group_loads=entries["bal_group_loads"],
                    num_groups=int(entries["bal_geom"][0]),
                    group_size=int(entries["bal_geom"][1]),
                ),
                nnz=int(entries["nnz"]),
            )
        except (KeyError, TypeError, ValueError) as e:
            raise errors.ArtifactError(
                f"{path}: cb-matrix payload is incomplete or malformed: {e}"
            ) from e
        return cb.validate() if validate else cb

    # ------------------------------------------------------------------
    def validate(self, *, check_finite: bool = False) -> "CBMatrix":
        """Assert the CB structural invariants; raise ``ArtifactError``.

        Vectorized checks over the balanced-slot metadata and the packed
        buffer: consistent stream shapes, in-bounds block indices
        (colagg-aware), legal format codes, per-format payload byte
        spans inside ``packed``, pad-slot conventions, and the nnz
        ledger. ``check_finite=True`` additionally decodes every stored
        value (via :meth:`value_layout`) and applies the non-finite
        detection — opt-in because it walks the blocks.

        Returns ``self`` so call sites can chain
        (``CBMatrix.load(p).validate()`` is load's default behavior).
        """
        def bad(msg: str) -> errors.ArtifactError:
            return errors.ArtifactError(f"CBMatrix.validate: {msg}")

        m, n = (int(v) for v in self.shape)
        B = int(self.block_size)
        if m < 1 or n < 1 or B < 1:
            raise bad(f"nonsense geometry shape={self.shape} B={B}")
        meta = (self.blk_row_idx, self.blk_col_idx, self.nnz_per_blk,
                self.type_per_blk, self.vp_per_blk)
        nslots = len(self.blk_row_idx)
        if any(a.ndim != 1 or len(a) != nslots for a in meta):
            raise bad(
                "metadata stream shapes disagree: "
                f"{[a.shape for a in meta]}"
            )
        bal = self.balance_result
        if bal.num_groups * bal.group_size != nslots:
            raise bad(
                f"balance geometry {bal.num_groups}x{bal.group_size} "
                f"!= {nslots} slots"
            )
        nnzb = self.nnz_per_blk.astype(np.int64)
        if (nnzb < 0).any() or (nnzb > B * B).any():
            raise bad(f"per-block nnz outside [0, {B * B}]")
        if int(nnzb.sum()) != int(self.nnz):
            raise bad(
                f"nnz ledger mismatch: blocks sum to {int(nnzb.sum())}, "
                f"matrix claims {self.nnz}"
            )
        real = nnzb > 0
        if (self.vp_per_blk[~real] != 0).any():
            raise bad("pad slot with a nonzero value pointer")
        if real.any():
            brow = self.blk_row_idx[real].astype(np.int64)
            bcol = self.blk_col_idx[real].astype(np.int64)
            fmt = self.type_per_blk[real].astype(np.int64)
            vp = self.vp_per_blk[real].astype(np.int64)
            cnt = nnzb[real]
            if (brow < 0).any() or (brow * B >= m).any():
                raise bad(f"block-row index outside [0, {-(-m // B)})")
            if self.colagg.applied:
                width = self.colagg.panel_width[brow]
            else:
                width = np.full(len(brow), n, np.int64)
            if (bcol < 0).any() or (bcol * B >= width).any():
                raise bad("block-col index outside its panel's width")
            known = np.isin(
                fmt, [formats.FMT_COO, formats.FMT_CSR, formats.FMT_DENSE]
            )
            if not known.all():
                raise bad(
                    f"unknown format code(s) {np.unique(fmt[~known])}"
                )
            vsize = self.val_dtype.itemsize
            cdt_size = aggregation.coord_dtype(B).itemsize
            rp_size = (B + 1) * aggregation._csr_rowptr_dtype(B).itemsize
            head = np.where(
                fmt == formats.FMT_DENSE, 0,
                np.where(fmt == formats.FMT_COO, cnt * cdt_size,
                         rp_size + cnt * cdt_size))
            body = np.where(fmt == formats.FMT_DENSE, B * B * vsize,
                            cnt * vsize)
            need = head + (-head) % vsize + body
            if (vp < 0).any() or (vp + need > len(self.packed)).any():
                raise bad(
                    "value pointer + payload span exceeds the packed "
                    f"buffer ({len(self.packed)} bytes)"
                )
        if check_finite:
            layout = self.value_layout()
            if layout.count:
                vsize = self.val_dtype.itemsize
                idx = (layout.byte_pos[:, None]
                       + np.arange(vsize, dtype=np.int64))
                vals = self.packed[idx].reshape(-1).view(self.val_dtype)
                if not np.isfinite(vals).all():
                    raise errors.NonFiniteError(
                        "CBMatrix.validate: packed payload contains "
                        f"{int((~np.isfinite(vals)).sum())} non-finite "
                        "value(s)"
                    )
        return self

    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return int(np.sum(self.nnz_per_blk > 0))

    @property
    def num_slots(self) -> int:
        return len(self.blk_row_idx)

    def iter_blocks(self):
        """Yield (brow, bcol, fmt, local_r, local_c, vals) for real blocks."""
        for i in range(self.num_slots):
            nnz = int(self.nnz_per_blk[i])
            if nnz == 0:
                continue
            fmt = int(self.type_per_blk[i])
            r, c, v = aggregation.unpack_block(
                self.packed, int(self.vp_per_blk[i]), fmt, nnz,
                self.block_size, self.val_dtype,
            )
            yield int(self.blk_row_idx[i]), int(self.blk_col_idx[i]), fmt, r, c, v

    def to_coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Recover the original-coordinate triplets, row-major sorted.

        Column aggregation is folded back through ``global_x_index``, so
        the triplets are position-faithful to the input of ``from_coo``.
        The canonical (row, col) sort makes the output independent of the
        balanced slot order — two CBMatrix builds of the same matrix
        yield bit-identical triplets (the determinism the autotuner's
        content hash relies on).

        Caveat: *explicitly stored zeros* do not survive. A 0.0 value
        inside a dense-format block is indistinguishable from structural
        padding in the packed tile (inherent to the CB byte format, same
        as ``to_dense``), so such entries are dropped. The autotuner's
        hashes canonicalize (drop explicit zeros) for exactly this
        reason, so original triplets and round-tripped triplets land on
        the same plan-cache entry either way.

        The (row, col)-sorted output order is the matrix's *canonical
        value order* — the order ``update_values`` consumes.
        """
        rs, cs, vs = [], [], []
        B = self.block_size
        for brow, bcol, _fmt, r, c, v in self.iter_blocks():
            rs.append(brow * B + r.astype(np.int64))
            cs.append(self.global_x_index(brow, bcol, c))
            vs.append(v)
        if not rs:
            return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, self.val_dtype))
        r_all = np.concatenate(rs)
        c_all = np.concatenate(cs)
        v_all = np.concatenate(vs)
        order = np.lexsort((c_all, r_all))
        return r_all[order], c_all[order], v_all[order]

    # ------------------------------------------------------------------
    # Dynamic-sparsity fast path: rewrite values without re-planning.
    #
    # Every structural decision (blocking, colagg, format select, Alg. 2
    # balance, byte layout) depends only on the sparsity pattern, so a
    # matrix whose values churn can keep its entire CB structure and
    # scatter fresh values straight into the packed buffer. The scatter
    # index — one byte offset per canonical element — is recorded once
    # per structure and reused for every update.
    # ------------------------------------------------------------------

    def value_layout(self) -> "ValueLayout":
        """The value-scatter index: canonical order -> packed byte offsets.

        Walks the balanced slots once, recording for every *recoverable*
        element its global (row, col) key and the byte offset of its
        value inside ``packed`` (replicating ``aggregation``'s intra-block
        layouts), then sorts by key into the canonical (row, col) order
        ``to_coo`` emits. Cached on the instance; ``update_values``
        propagates the cache to the copies it returns, so a churn loop
        pays the walk exactly once.
        """
        layout = getattr(self, "_value_layout_cache", None)
        if layout is not None:
            return layout
        B = self.block_size
        vsize = self.val_dtype.itemsize
        n = self.shape[1]
        cdt_size = aggregation.coord_dtype(B).itemsize
        rp_size = (B + 1) * aggregation._csr_rowptr_dtype(B).itemsize
        pos_l: list[np.ndarray] = []
        key_l: list[np.ndarray] = []
        for i in range(self.num_slots):
            nnz = int(self.nnz_per_blk[i])
            if nnz == 0:
                continue
            fmt = int(self.type_per_blk[i])
            vp = int(self.vp_per_blk[i])
            r, c, v = aggregation.unpack_block(
                self.packed, vp, fmt, nnz, B, self.val_dtype
            )
            brow = int(self.blk_row_idx[i])
            bcol = int(self.blk_col_idx[i])
            if fmt == formats.FMT_DENSE:
                pos = vp + (r.astype(np.int64) * B + c) * vsize
            else:
                head = (nnz * cdt_size if fmt == formats.FMT_COO
                        else rp_size + nnz * cdt_size)
                voff = vp + head + (-head) % vsize
                pos = voff + np.arange(len(v), dtype=np.int64) * vsize
            gr = brow * B + r.astype(np.int64)
            gc = self.global_x_index(brow, bcol, c)
            pos_l.append(pos)
            key_l.append(gr * n + gc)
        if pos_l:
            pos = np.concatenate(pos_l)
            keys = np.concatenate(key_l)
        else:
            pos = np.zeros(0, np.int64)
            keys = np.zeros(0, np.int64)
        order = np.argsort(keys, kind="stable")
        layout = ValueLayout(count=len(pos), byte_pos=pos[order],
                             keys=keys[order])
        self._value_layout_cache = layout
        return layout

    def update_values(self, new_vals: np.ndarray, *,
                      nonfinite: str = "raise") -> "CBMatrix":
        """Rewrite the packed values in place of a full rebuild.

        ``new_vals`` is one value per element in **canonical order** —
        the (row, col)-sorted order ``to_coo`` returns (use
        :meth:`update_from_coo` for arbitrary triplet order). Returns a
        new ``CBMatrix`` sharing every metadata array (same blocking,
        colagg, formats, balance, byte layout) with only the packed
        buffer replaced — no re-planning, re-balancing, or re-selection
        runs.

        Writing an exact 0.0 into a dense-format slot makes that element
        unrecoverable on the next ``to_coo`` (the format cannot
        distinguish it from padding); keep update values nonzero when
        round-trip fidelity matters.
        """
        layout = self.value_layout()
        vals = np.ascontiguousarray(new_vals, self.val_dtype)
        vals = _nonfinite_policy(vals, nonfinite, "CBMatrix.update_values")
        if vals.shape != (layout.count,):
            raise errors.InvalidArgError(
                f"update_values expects {layout.count} canonical values "
                f"(see to_coo), got array of shape {vals.shape}"
            )
        vsize = self.val_dtype.itemsize
        packed = self.packed.copy()
        idx = layout.byte_pos[:, None] + np.arange(vsize, dtype=np.int64)
        packed[idx] = vals.view(np.uint8).reshape(-1, vsize)
        new = dataclasses.replace(self, packed=packed)
        # The scatter index is pattern-derived; hand it to the copy so
        # chained updates never re-walk the blocks.
        new._value_layout_cache = layout
        return new

    def update_from_coo(
        self,
        rows: np.ndarray,
        cols: np.ndarray,
        vals: np.ndarray,
        *,
        nonfinite: str = "raise",
    ) -> "CBMatrix":
        """``update_values`` for triplets in arbitrary order.

        Duplicates are merged by summation (matching ``from_coo``); the
        resulting coordinate set must equal this matrix's structure
        exactly — structure drift (new or missing coordinates) raises,
        because only a full ``from_coo`` rebuild can re-plan the
        blocking for a changed pattern.
        """
        layout = self.value_layout()
        n = self.shape[1]
        rows = np.ascontiguousarray(rows, np.int64)
        cols = np.ascontiguousarray(cols, np.int64)
        vals = np.ascontiguousarray(vals, self.val_dtype)
        key = rows * n + cols
        uniq, inv = np.unique(key, return_inverse=True)
        summed = np.zeros(len(uniq), self.val_dtype)
        np.add.at(summed, inv, vals)
        if len(uniq) != layout.count or not np.array_equal(uniq, layout.keys):
            raise errors.StructureDriftError(errors.reason(
                errors.STRUCTURE_DRIFT,
                "sparsity pattern differs from this CBMatrix's structure; "
                "update_from_coo only rewrites values — rebuild with "
                "from_coo (and re-plan) for structure drift",
            ))
        return self.update_values(summed, nonfinite=nonfinite)

    def global_x_index(self, brow: int, bcol: int, local_c: np.ndarray) -> np.ndarray:
        """Map (block, local col) -> original global column of x."""
        B = self.block_size
        if not self.colagg.applied:
            return bcol * B + local_c.astype(np.int64)
        base = self.colagg.cols_offset[brow] + bcol * B
        return self.colagg.restore_cols[base + local_c.astype(np.int64)].astype(np.int64)

    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        out = np.zeros((m, n), dtype=self.val_dtype)
        B = self.block_size
        for brow, bcol, fmt, r, c, v in self.iter_blocks():
            gc = self.global_x_index(brow, bcol, c)
            np.add.at(out, (brow * B + r, gc), v)
        return out

    # -- storage accounting (paper §4.4.1) ------------------------------
    def nbytes_structure(self) -> dict:
        meta = (
            self.blk_row_idx.nbytes
            + self.blk_col_idx.nbytes
            + self.nnz_per_blk.nbytes
            + self.type_per_blk.nbytes
            + self.vp_per_blk.nbytes
        )
        agg = self.colagg.restore_cols.nbytes + self.colagg.cols_offset.nbytes
        return {
            "high_level_metadata": int(meta),
            "column_agg_maps": int(agg) if self.colagg.applied else 0,
            "packed_data": int(self.packed.nbytes),
            "total": int(meta + self.packed.nbytes + (agg if self.colagg.applied else 0)),
        }

    def stats(self) -> dict:
        real = self.nnz_per_blk[self.nnz_per_blk > 0]
        fmt = self.type_per_blk[self.nnz_per_blk > 0]
        return {
            "nnz": self.nnz,
            "num_blocks": int(len(real)),
            "block_size": self.block_size,
            "column_aggregated": bool(self.colagg.applied),
            "fmt_coo": int(np.sum(fmt == formats.FMT_COO)),
            "fmt_csr": int(np.sum(fmt == formats.FMT_CSR)),
            "fmt_dense": int(np.sum(fmt == formats.FMT_DENSE)),
            "super_sparse_fraction": formats.super_sparse_fraction(real, self.block_size),
            "tb_load_std": self.balance_result.load_std,
            "tb_load_imbalance": self.balance_result.load_imbalance,
        }
