"""Reference (oracle) SpMV over the CB structure — pure numpy.

This mirrors the kernels' Alg. 3 / Alg. 4 logic verbatim, unpacking the
packed buffer through virtual pointers, so it exercises the *format*, not
just the linear algebra. Used as the ground truth for every kernel test.
"""
from __future__ import annotations

import numpy as np

from .cb_matrix import CBMatrix


def spmv_ref(cb: CBMatrix, x: np.ndarray) -> np.ndarray:
    """y = A @ x computed by walking the CB structure (Alg. 3/4 semantics)."""
    m, n = cb.shape
    x = np.asarray(x)
    acc_dtype = np.result_type(cb.val_dtype, x.dtype, np.float32)
    y = np.zeros(m, dtype=acc_dtype)
    B = cb.block_size
    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        gx = cb.global_x_index(brow, bcol, c)
        np.add.at(y, brow * B + r, v.astype(acc_dtype) * x[gx].astype(acc_dtype))
    return y


def spmm_ref(cb: CBMatrix, X: np.ndarray) -> np.ndarray:
    """Y = A @ X for a dense right-hand side (n, k)."""
    m, n = cb.shape
    X = np.asarray(X)
    acc_dtype = np.result_type(cb.val_dtype, X.dtype, np.float32)
    Y = np.zeros((m, X.shape[1]), dtype=acc_dtype)
    B = cb.block_size
    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        gx = cb.global_x_index(brow, bcol, c)
        np.add.at(Y, brow * B + r, v[:, None].astype(acc_dtype) * X[gx].astype(acc_dtype))
    return Y


def dense_oracle(rows, cols, vals, shape, x) -> np.ndarray:
    """Straight COO mat-vec, independent of the CB machinery."""
    m, n = shape
    acc_dtype = np.result_type(np.asarray(vals).dtype, np.asarray(x).dtype, np.float32)
    y = np.zeros(m, dtype=acc_dtype)
    np.add.at(y, np.asarray(rows), np.asarray(vals, acc_dtype) * np.asarray(x, acc_dtype)[np.asarray(cols)])
    return y
