"""Inter-thread-block load balancing (paper §3.4, Alg. 2).

A min-heap keyed on accumulated nnz assigns sub-blocks (largest first) to
(thread-block, warp-slot) pairs so every thread block processes the same
NUMBER of sub-blocks while the total NNZ per thread block is near-equal.
The block-COO high-level metadata then gets permuted once — enabled by the
independence property of the 2D structure.

Two deployments of the same algorithm:

  * ``tb_load_balance``     — the paper's: slots = thread blocks x warps.
    On TPU we reuse it to order a kernel's sequential grid into equal-nnz
    work groups (keeps DMA queue depth even) and to pick megacore halves.
  * ``device_load_balance`` — scaled up: slots = devices in the ``model``
    axis of the mesh; used by core/distributed.py to shard the matrix with
    near-equal nnz AND equal block count per device (equal block count ==
    uniform shard shapes, which shard_map requires anyway).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class BalanceResult:
    """Permutation produced by the balancer.

    ``slots[s]`` = original block index occupying slot ``s`` (or -1 pad).
    ``perm`` = slots with -1 kept (length = num_groups * group_size).
    ``group_loads[g]`` = total nnz assigned to group g.
    """

    slots: np.ndarray
    group_loads: np.ndarray
    num_groups: int
    group_size: int

    @property
    def load_std(self) -> float:
        return float(np.std(self.group_loads))

    @property
    def load_imbalance(self) -> float:
        """max/mean load ratio (1.0 = perfect)."""
        mean = self.group_loads.mean() if len(self.group_loads) else 0.0
        return float(self.group_loads.max() / mean) if mean > 0 else 1.0


def _heap_assign(nnz_per_blk: np.ndarray, num_groups: int, group_size: int) -> BalanceResult:
    """Alg. 2: sort desc by nnz; repeatedly give next block to the least
    loaded group that still has a free slot."""
    nblk = len(nnz_per_blk)
    order = np.argsort(-np.asarray(nnz_per_blk, dtype=np.int64), kind="stable")
    slots = np.full(num_groups * group_size, -1, dtype=np.int64)
    loads = np.zeros(num_groups, dtype=np.int64)
    # heap entries: (load, group_id, used_slots)
    heap: list[list[int]] = [[0, g, 0] for g in range(num_groups)]
    heapq.heapify(heap)
    for blk in order:
        top = heapq.heappop(heap)
        load, gid, used = top
        slots[gid * group_size + used] = blk
        loads[gid] = load + int(nnz_per_blk[blk])
        if used + 1 < group_size:
            heapq.heappush(heap, [int(loads[gid]), gid, used + 1])
    return BalanceResult(slots=slots, group_loads=loads, num_groups=num_groups, group_size=group_size)


def tb_load_balance(nnz_per_blk: np.ndarray, warps_per_tb: int = 8) -> BalanceResult:
    """Paper Alg. 2: one warp per sub-block, ``warps_per_tb`` warps per TB."""
    nblk = len(nnz_per_blk)
    num_tb = max(1, -(-nblk // warps_per_tb))
    return _heap_assign(nnz_per_blk, num_tb, warps_per_tb)


def grid_group_balance(load_per_blk: np.ndarray, group_size: int) -> BalanceResult:
    """Alg. 2 at *grid-step* granularity (the batched execution engine).

    A "group" is the set of sub-blocks one Pallas grid step executes (the
    TPU analogue of the paper's thread block). Each group holds at most
    ``group_size`` blocks; the heap hands the heaviest remaining block to
    the lightest group, so the per-step loads come out near-equal.

    ``load_per_blk`` is whatever each block costs the step: nnz for dense
    tiles (uniform-shape groups, cache balance), or the *padded payload
    width* for panel/COO groups — there the array width every step DMAs is
    ``max_g sum(widths in g)``, so equalizing summed width across groups
    directly minimizes the padding the widest group forces on the rest.
    """
    nblk = len(load_per_blk)
    num_groups = max(1, -(-nblk // group_size))
    return _heap_assign(load_per_blk, num_groups, group_size)


def device_load_balance(nnz_per_blk: np.ndarray, num_devices: int) -> BalanceResult:
    """Equal block count + near-equal nnz per device (uniform shard shapes)."""
    nblk = len(nnz_per_blk)
    per_dev = max(1, -(-nblk // num_devices))
    return _heap_assign(nnz_per_blk, num_devices, per_dev)


def apply_balance(result: BalanceResult, *metadata: np.ndarray, pad_values=None):
    """Permute parallel metadata arrays into slot order.

    Empty slots get ``pad_values[k]`` (default 0). Returns a tuple of
    arrays of length num_groups * group_size.
    """
    out = []
    for k, arr in enumerate(metadata):
        pad = 0 if pad_values is None else pad_values[k]
        dest = np.full(len(result.slots), pad, dtype=np.asarray(arr).dtype)
        mask = result.slots >= 0
        dest[mask] = np.asarray(arr)[result.slots[mask]]
        out.append(dest)
    return tuple(out)


def tb_load_stddev(nnz_per_blk: np.ndarray, blk_row_idx: np.ndarray | None = None,
                   warps_per_tb: int = 8) -> tuple[float, float]:
    """Fig. 4 metric: stddev of per-TB nnz before (naive block order) and
    after pq balancing."""
    nblk = len(nnz_per_blk)
    if nblk == 0:
        return 0.0, 0.0
    num_tb = -(-nblk // warps_per_tb)
    padded = np.zeros(num_tb * warps_per_tb, dtype=np.int64)
    padded[:nblk] = nnz_per_blk
    naive = padded.reshape(num_tb, warps_per_tb).sum(axis=1)
    balanced = tb_load_balance(nnz_per_blk, warps_per_tb).group_loads
    return float(np.std(naive)), float(np.std(balanced))
