"""Block-aware column aggregation (paper §3.3.1, Fig. 6(b)).

Within each block-row *panel* (B consecutive matrix rows), columns that are
entirely zero in that panel are removed and the remaining columns shifted
left. Two maps are kept:

  * ``restore_cols`` — concatenated original (global) column index of each
    surviving panel column,
  * ``cols_offset``  — per-panel start offset into ``restore_cols``.

After aggregation every non-zero B-wide block in compacted coordinates has
at least one non-zero per column, so a full-width block carries >= B
non-zeros — the paper's ">=16 non-zeros per block ⇒ >=50% warp utilization"
guarantee, which on TPU becomes "every surviving lane of the panel does
useful work".

The transform is applied matrix-wide iff the super-sparse block fraction
exceeds th0 (see formats.should_column_aggregate).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ColumnAggregation:
    """Panel-compacted coordinates plus the restore maps."""

    applied: bool
    # Element columns re-expressed in panel-compacted coordinate space.
    # (Only meaningful when applied=True; otherwise identical to input.)
    new_cols: np.ndarray          # (nnz,) int64 compacted column coordinate
    restore_cols: np.ndarray      # (sum_p K_p,) int32 original global column
    cols_offset: np.ndarray       # (num_panels + 1,) int64 prefix offsets
    panel_width: np.ndarray       # (num_panels,) int32  K_p
    num_panels: int

    def original_col(self, panel: int, compact_col: int) -> int:
        """Map a compacted column index back to the original global column."""
        return int(self.restore_cols[self.cols_offset[panel] + compact_col])


def identity_aggregation(cols: np.ndarray, shape: tuple[int, int], block_size: int) -> ColumnAggregation:
    m, n = shape
    num_panels = -(-m // block_size)
    return ColumnAggregation(
        applied=False,
        new_cols=np.asarray(cols, dtype=np.int64),
        restore_cols=np.zeros(0, dtype=np.int32),
        cols_offset=np.zeros(num_panels + 1, dtype=np.int64),
        panel_width=np.full(num_panels, n, dtype=np.int32),
        num_panels=num_panels,
    )


def column_aggregate(
    rows: np.ndarray,
    cols: np.ndarray,
    shape: tuple[int, int],
    block_size: int,
) -> ColumnAggregation:
    """Compute panel-level column compaction for COO coordinates."""
    m, n = shape
    B = int(block_size)
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    num_panels = -(-m // B)

    panel = rows // B
    # Unique (panel, col) pairs, sorted: gives each panel's surviving
    # columns in ascending original order.
    pc = panel * n + cols
    uniq = np.unique(pc)
    u_panel = uniq // n
    u_col = (uniq % n).astype(np.int32)

    panel_width = np.zeros(num_panels, dtype=np.int32)
    np.add.at(panel_width, u_panel.astype(np.int64), 1)
    cols_offset = np.zeros(num_panels + 1, dtype=np.int64)
    np.cumsum(panel_width, out=cols_offset[1:])

    # Rank of each element's (panel, col) among its panel's unique columns.
    idx = np.searchsorted(uniq, pc)
    new_cols = idx - cols_offset[panel]

    return ColumnAggregation(
        applied=True,
        new_cols=new_cols.astype(np.int64),
        restore_cols=u_col,
        cols_offset=cols_offset,
        panel_width=panel_width,
        num_panels=num_panels,
    )


def restore_for_block(
    agg: ColumnAggregation, panel: int, blk_col: int, block_size: int, n: int
) -> np.ndarray:
    """Global x-indices for the B columns of block (panel, blk_col).

    Columns past the panel's compacted width map to index 0 — callers must
    pair them with zero values (the dense-tile padding convention).
    """
    B = block_size
    if not agg.applied:
        base = blk_col * B
        out = base + np.arange(B, dtype=np.int64)
        return np.minimum(out, n - 1)  # safe-pad boundary blocks
    start = agg.cols_offset[panel] + blk_col * B
    width = int(agg.panel_width[panel])
    local = blk_col * B + np.arange(B)
    valid = local < width
    idx = np.where(valid, start + np.arange(B), agg.cols_offset[panel])
    out = agg.restore_cols[np.minimum(idx, len(agg.restore_cols) - 1)].astype(np.int64)
    return np.where(valid, out, 0)
