"""Kernel-facing typed streams derived from the portable CB format.

The portable ``CBMatrix`` stores mixed-dtype byte-packed blocks behind
virtual pointers (paper Fig. 7). Mosaic DMAs are typed, so the TPU kernels
consume *typed streams*: one stream per storage format, each a struct of
uniform arrays where block ``i`` owns row ``i`` of every array. Contiguity
— the actual locality mechanism of the paper — is preserved: a block's
payload occupies one contiguous row of the stream, fetched with a single
sequential HBM->VMEM DMA per grid step.

Three streams mirror the paper's three intra-block formats:

  * ``dense``  — (B, B) value tiles (FMT_DENSE blocks), MXU/VPU path.
  * ``panel``  — (B, K) column-compacted micro-panels (FMT_CSR blocks):
                 the block's non-zero columns are packed left, K padded to
                 a sublane multiple. This is the per-block analogue of the
                 paper's column aggregation — dense math on compacted data.
  * ``coo``    — element lists with the paper's packed coordinates
                 (``code = col << bits | row``), FMT_COO blocks.

Every stream carries per-block x gather indices (``*_xidx``) that already
encode the column-aggregation ``restore_cols`` mapping (or the trivial
``bcol*B + j`` mapping), so kernels never consult the restore maps at run
time — matching Alg. 3's precomputed ``cols_offset``/``restore_cols``
lookups but resolved at preprocessing time where they are free.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from . import column_agg as column_agg_mod
from .aggregation import coord_bits
from .cb_matrix import CBMatrix
from .formats import FMT_COO, FMT_CSR, FMT_DENSE


def _round_up(v: int, mult: int) -> int:
    return max(mult, -(-v // mult) * mult)


@dataclasses.dataclass
class SpMVStreams:
    """Typed per-format streams for the CB-SpMV kernels.

    Array fields are jax/numpy arrays (pytree leaves); the ints are static
    metadata. Block order within each stream is the balanced slot order of
    the source ``CBMatrix`` — the kernels' scatter-add combine makes the
    result independent of order, so the paper's load-balanced schedule is
    kept verbatim.
    """

    # -- static ---------------------------------------------------------
    block_size: int
    m: int
    n: int
    mb: int               # number of block rows = ceil(m / B)
    colagg_applied: bool
    # -- dense tile stream ----------------------------------------------
    dense_tiles: jax.Array   # (nd, B, B) val
    dense_brow: jax.Array    # (nd,) int32
    dense_bcol: jax.Array    # (nd,) int32 (compacted-space block col)
    dense_xidx: jax.Array    # (nd, B) int32 global x index per tile column
    # -- panel stream (CSR blocks, column-compacted) ---------------------
    panel_vals: jax.Array    # (np_, B, Kp) val
    panel_brow: jax.Array    # (np_,) int32
    panel_xidx: jax.Array    # (np_, Kp) int32
    # -- coo element stream ----------------------------------------------
    coo_codes: jax.Array     # (nc, Ep) int32 packed (col << bits | row)
    coo_vals: jax.Array      # (nc, Ep) val (0 on padding)
    coo_brow: jax.Array      # (nc,) int32
    coo_xidx: jax.Array      # (nc, Ep) int32

    @property
    def num_dense(self) -> int:
        return self.dense_tiles.shape[0]

    @property
    def num_panel(self) -> int:
        return self.panel_vals.shape[0]

    @property
    def num_coo(self) -> int:
        return self.coo_codes.shape[0]

    def device_put(self) -> "SpMVStreams":
        return jax.tree_util.tree_map(jax.numpy.asarray, self)


jax.tree_util.register_dataclass(
    SpMVStreams,
    data_fields=[
        "dense_tiles", "dense_brow", "dense_bcol", "dense_xidx",
        "panel_vals", "panel_brow", "panel_xidx",
        "coo_codes", "coo_vals", "coo_brow", "coo_xidx",
    ],
    meta_fields=["block_size", "m", "n", "mb", "colagg_applied"],
)


def _block_x_indices(cb: CBMatrix, brow: int, bcol: int) -> np.ndarray:
    """Global x index for each of the B columns of block (brow, bcol)."""
    return column_agg_mod.restore_for_block(
        cb.colagg, brow, bcol, cb.block_size, cb.shape[1]
    ).astype(np.int32)


def build_streams(cb: CBMatrix) -> SpMVStreams:
    """Derive the typed kernel streams from a CBMatrix (host-side).

    The packed-coordinate bit layout is fixed by ``aggregation.coord_bits``
    — the kernels and oracles recompute it from the block size, so it is
    deliberately not a parameter here (an encoder-side override would
    silently desync the decoders).
    """
    B = cb.block_size
    bits = coord_bits(B)
    m, n = cb.shape
    mb = -(-m // B)
    vdt = cb.val_dtype

    dense_tiles, dense_brow, dense_bcol, dense_xidx = [], [], [], []
    panels: list[tuple[int, np.ndarray, np.ndarray]] = []  # (brow, panel, xidx)
    coos: list[tuple[int, np.ndarray, np.ndarray, np.ndarray]] = []

    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        if fmt == FMT_DENSE:
            tile = np.zeros((B, B), dtype=vdt)
            tile[r, c] = v
            dense_tiles.append(tile)
            dense_brow.append(brow)
            dense_bcol.append(bcol)
            dense_xidx.append(_block_x_indices(cb, brow, bcol))
        elif fmt == FMT_CSR:
            ucols, rank = np.unique(c, return_inverse=True)
            panel = np.zeros((B, len(ucols)), dtype=vdt)
            panel[r, rank] = v
            xidx = cb.global_x_index(brow, bcol, ucols).astype(np.int32)
            panels.append((brow, panel, xidx))
        elif fmt == FMT_COO:
            codes = (c.astype(np.int64) << bits) | r.astype(np.int64)
            xidx = cb.global_x_index(brow, bcol, c).astype(np.int32)
            coos.append((brow, codes.astype(np.int32), v.astype(vdt), xidx))
        else:  # pragma: no cover - format codes are exhaustive
            raise ValueError(f"unknown format {fmt}")

    # ---- dense stream ---------------------------------------------------
    nd = len(dense_tiles)
    d_tiles = np.stack(dense_tiles) if nd else np.zeros((0, B, B), vdt)
    d_brow = np.asarray(dense_brow, np.int32)
    d_bcol = np.asarray(dense_bcol, np.int32)
    d_xidx = np.stack(dense_xidx).astype(np.int32) if nd else np.zeros((0, B), np.int32)

    # ---- panel stream ---------------------------------------------------
    np_ = len(panels)
    Kp = _round_up(max((p.shape[1] for _, p, _ in panels), default=1), 8)
    p_vals = np.zeros((np_, B, Kp), vdt)
    p_brow = np.zeros(np_, np.int32)
    p_xidx = np.zeros((np_, Kp), np.int32)
    for i, (brow, panel, xidx) in enumerate(panels):
        k = panel.shape[1]
        p_vals[i, :, :k] = panel
        p_brow[i] = brow
        p_xidx[i, :k] = xidx

    # ---- coo stream -----------------------------------------------------
    nc = len(coos)
    Ep = _round_up(max((len(v) for _, _, v, _ in coos), default=1), 8)
    c_codes = np.zeros((nc, Ep), np.int32)
    c_vals = np.zeros((nc, Ep), vdt)
    c_brow = np.zeros(nc, np.int32)
    c_xidx = np.zeros((nc, Ep), np.int32)
    for i, (brow, codes, vals, xidx) in enumerate(coos):
        e = len(vals)
        c_codes[i, :e] = codes
        c_vals[i, :e] = vals
        c_brow[i] = brow
        c_xidx[i, :e] = xidx

    return SpMVStreams(
        block_size=B, m=m, n=n, mb=mb, colagg_applied=cb.colagg.applied,
        dense_tiles=d_tiles, dense_brow=d_brow, dense_bcol=d_bcol,
        dense_xidx=d_xidx,
        panel_vals=p_vals, panel_brow=p_brow, panel_xidx=p_xidx,
        coo_codes=c_codes, coo_vals=c_vals, coo_brow=c_brow, coo_xidx=c_xidx,
    )


# ---------------------------------------------------------------------------
# SpMM tile stream: block-dense weights for the training/prefill path.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TileStream:
    """Block-dense (BSR-like) stream for CB-SpMM.

    Blocks are sorted block-row-major and padded so that *every* block row
    owns at least one (possibly all-zero) tile — the coverage requirement
    of the kernel's output-revisiting accumulation (the TPU-deterministic
    replacement for the paper's atomicAdd, DESIGN.md §2).
    """

    block_size: int
    m: int
    n: int
    mb: int
    nb: int
    tiles: jax.Array   # (nt, B, B)
    brow: jax.Array    # (nt,) int32, ascending
    bcol: jax.Array    # (nt,) int32

    @property
    def num_tiles(self) -> int:
        return self.tiles.shape[0]


jax.tree_util.register_dataclass(
    TileStream,
    data_fields=["tiles", "brow", "bcol"],
    meta_fields=["block_size", "m", "n", "mb", "nb"],
)


def build_tile_stream(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    block_size: int,
) -> TileStream:
    """Build the block-dense stream directly from COO triplets."""
    from .blocking import partition_coo

    m, n = shape
    B = block_size
    mb, nb = -(-m // B), -(-n // B)
    part = partition_coo(rows, cols, vals, shape, B)

    tiles, brows, bcols = [], [], []
    for i in range(part.num_blocks):
        r, c, v = part.block_elems(i)
        tile = np.zeros((B, B), dtype=v.dtype)
        tile[r, c] = v
        tiles.append(tile)
        brows.append(int(part.blk_row_idx[i]))
        bcols.append(int(part.blk_col_idx[i]))

    # Coverage: every block row must own >= 1 tile (revisit init correctness).
    present = set(brows)
    for rb in range(mb):
        if rb not in present:
            tiles.append(np.zeros((B, B), dtype=vals.dtype))
            brows.append(rb)
            bcols.append(0)

    order = np.argsort(np.asarray(brows), kind="stable")
    tiles_arr = np.stack(tiles)[order] if tiles else np.zeros((0, B, B), vals.dtype)
    return TileStream(
        block_size=B, m=m, n=n, mb=mb, nb=nb,
        tiles=tiles_arr,
        brow=np.asarray(brows, np.int32)[order],
        bcol=np.asarray(bcols, np.int32)[order],
    )


def tile_stream_from_cb(cb: CBMatrix) -> TileStream:
    """Densify every CB block into the tile stream (all formats -> tiles).

    Used when the SpMM path must run over a matrix preprocessed with the
    full CB pipeline; x-index indirection (column aggregation) is folded
    back to original coordinates so the stream is position-faithful.
    """
    B = cb.block_size
    m, n = cb.shape
    mb, nb = -(-m // B), -(-n // B)
    acc: dict[tuple[int, int], np.ndarray] = {}
    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        gc = cb.global_x_index(brow, bcol, c)
        for rr, cc, vv in zip(r, gc, v):
            key = (brow, int(cc) // B)
            tile = acc.setdefault(key, np.zeros((B, B), dtype=cb.val_dtype))
            tile[rr, int(cc) % B] += vv
    for rb in range(mb):
        if not any(k[0] == rb for k in acc):
            acc[(rb, 0)] = np.zeros((B, B), dtype=cb.val_dtype)
    keys = sorted(acc.keys())
    return TileStream(
        block_size=B, m=m, n=n, mb=mb, nb=nb,
        tiles=np.stack([acc[k] for k in keys]),
        brow=np.asarray([k[0] for k in keys], np.int32),
        bcol=np.asarray([k[1] for k in keys], np.int32),
    )
