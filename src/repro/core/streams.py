"""Kernel-facing typed streams derived from the portable CB format.

The portable ``CBMatrix`` stores mixed-dtype byte-packed blocks behind
virtual pointers (paper Fig. 7). Mosaic DMAs are typed, so the TPU kernels
consume *typed streams*: one stream per storage format, each a struct of
uniform arrays where block ``i`` owns row ``i`` of every array. Contiguity
— the actual locality mechanism of the paper — is preserved: a block's
payload occupies one contiguous row of the stream, fetched with a single
sequential HBM->VMEM DMA per grid step.

Three streams mirror the paper's three intra-block formats:

  * ``dense``  — (B, B) value tiles (FMT_DENSE blocks), MXU/VPU path.
  * ``panel``  — (B, K) column-compacted micro-panels (FMT_CSR blocks):
                 the block's non-zero columns are packed left, K padded to
                 a sublane multiple. This is the per-block analogue of the
                 paper's column aggregation — dense math on compacted data.
  * ``coo``    — element lists with the paper's packed coordinates
                 (``code = col << bits | row``), FMT_COO blocks.

Every stream carries per-block x gather indices (``*_xidx``) that already
encode the column-aggregation ``restore_cols`` mapping (or the trivial
``bcol*B + j`` mapping), so kernels never consult the restore maps at run
time — matching Alg. 3's precomputed ``cols_offset``/``restore_cols``
lookups but resolved at preprocessing time where they are free.

Three stream granularities share this layout:

  * ``SpMVStreams``       — one block per stream row (one per grid step).
  * ``SuperBlockStreams`` — ``build_super_streams``: up to ``group_size``
    blocks per stream row. Dense tiles stack vertically into a
    (G*B, B) super-tile; panel/COO payloads are width-*bucketed* (each
    block's width rounded to a sublane multiple) and lane-packed side by
    side, with a per-lane segment map telling the kernel which block slot
    each lane belongs to. The Alg. 2 balancer assigns blocks to groups so
    every grid step carries near-equal payload — the paper's inter-block
    load balancing applied at grid-step granularity.
  * ``SuperTileStream``   — ``build_super_tile_stream``: the SpMM
    (multi-RHS) analogue. Up to ``group_size`` block-dense weight tiles
    stack vertically into a (G*B, B) super-tile per grid step, with
    per-group ``brow``/``bcol`` slot maps; the same Alg. 2 balancer
    equalizes nnz per group. ``spmm_block_n`` is the single home of the
    SpMM lane rule (activation tile widths are LANE multiples).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from . import balance as balance_mod
from . import column_agg as column_agg_mod
from .aggregation import coord_bits
from .cb_matrix import CBMatrix
from .formats import FMT_COO, FMT_CSR, FMT_DENSE
from repro import errors

# ---------------------------------------------------------------------------
# Padding policy — the single place payload widths get aligned.
# ---------------------------------------------------------------------------

SUBLANE = 8  # float32 sublane count; payload widths align to this for DMA

LANE = 128  # VPU/MXU lane count; SpMM activation tile widths align to this


def pad_width(width: int, mult: int = SUBLANE) -> int:
    """Round a payload width up to the DMA-friendly multiple.

    Zero stays zero: an empty stream allocates genuinely empty arrays
    (the dispatch layer skips the format entirely), instead of the old
    behaviour of silently materializing a phantom ``(0, B, 8)`` buffer.
    """
    return -(-int(width) // mult) * mult


def spmm_block_n(n_cols: int, block_n: int = LANE) -> int:
    """The SpMM activation-tile width: lane-aligned, at most ``block_n``.

    THE single place the SpMM lane rule lives. The compiled Mosaic
    pipeline requires the minor (lane) dimension of every block to be a
    multiple of ``LANE`` (= 128 for float32); the old
    ``min(block_n, max(8, N))`` policy produced e.g. a 100-wide lane
    block for N=100, which only ever worked because tests run in
    interpret mode. Here ``N`` is rounded *up* to a lane multiple and
    capped at ``block_n`` (itself validated to be lane-aligned), so the
    chosen width always satisfies ``bn % LANE == 0`` and callers pad the
    activation matrix to ``ceil(N / bn) * bn`` columns.
    """
    if block_n % LANE:
        raise errors.InvalidArgError(
            f"block_n must be a multiple of {LANE} lanes, got {block_n}"
        )
    return min(block_n, pad_width(max(int(n_cols), 1), LANE))


# Aim each grid step's payload at about this many elements: big enough to
# amortize per-step DMA/launch overhead, small enough that many steps
# remain for the megacore "parallel" partitioning and the per-step one-hot
# scratch stays comfortably inside VMEM. These are the *default* knob
# values; the autotune subsystem (src/repro/autotune/) overrides them per
# matrix through ``group_size_for``.
TARGET_STEP_ELEMS = 4096

# Upper bound on blocks per grid step: caps the unrolled dense loop and
# the (W, G*B) segment one-hot width in the batched kernels.
MAX_GROUP_SIZE = 16


def group_size_for(
    block_size: int,
    target_step_elems: int = TARGET_STEP_ELEMS,
    max_group: int = MAX_GROUP_SIZE,
) -> int:
    """THE single home of the blocks-per-grid-step occupancy rule.

    ``target_step_elems // B^2`` blocks per step, clamped to
    ``[1, max_group]``. Every stream builder (``build_super_streams``,
    ``build_super_tile_stream``) routes its ``group_size=None`` default
    through here, and the autotuner's cost model sweeps the two knobs as
    per-matrix decisions instead of module constants.
    """
    g = int(target_step_elems) // (int(block_size) * int(block_size))
    return int(min(max(g, 1), int(max_group)))


def auto_group_size(block_size: int) -> int:
    """Occupancy heuristic at the default knobs (see ``group_size_for``)."""
    return group_size_for(block_size)


def even_group(count: int, group_size: int) -> tuple[int, int]:
    """(num_groups, slots per group) for ``count`` blocks at target G.

    Slots are evened across the ``ceil(count / G)`` groups so the last
    group is never mostly empty padding (count=40, G=16 -> 3 groups of
    14, not two full ones plus a third at 8/16). Shared by the host-side
    packer and the jit-side regroup so both agree on group geometry.
    """
    if count == 0:
        return 0, group_size
    ng = -(-count // group_size)
    return ng, -(-count // ng)


@dataclasses.dataclass
class SpMVStreams:
    """Typed per-format streams for the CB-SpMV kernels.

    Array fields are jax/numpy arrays (pytree leaves); the ints are static
    metadata. Block order within each stream is the balanced slot order of
    the source ``CBMatrix`` — the kernels' scatter-add combine makes the
    result independent of order, so the paper's load-balanced schedule is
    kept verbatim.
    """

    # -- static ---------------------------------------------------------
    block_size: int
    m: int
    n: int
    mb: int               # number of block rows = ceil(m / B)
    colagg_applied: bool
    # -- dense tile stream ----------------------------------------------
    dense_tiles: jax.Array   # (nd, B, B) val
    dense_brow: jax.Array    # (nd,) int32
    dense_xidx: jax.Array    # (nd, B) int32 global x index per tile column
    # -- panel stream (CSR blocks, column-compacted) ---------------------
    panel_vals: jax.Array    # (np_, B, Kp) val
    panel_brow: jax.Array    # (np_,) int32
    panel_xidx: jax.Array    # (np_, Kp) int32
    # -- coo element stream ----------------------------------------------
    coo_codes: jax.Array     # (nc, Ep) int32 packed (col << bits | row)
    coo_vals: jax.Array      # (nc, Ep) val (0 on padding)
    coo_brow: jax.Array      # (nc,) int32
    coo_xidx: jax.Array      # (nc, Ep) int32

    @property
    def num_dense(self) -> int:
        return self.dense_tiles.shape[0]

    @property
    def num_panel(self) -> int:
        return self.panel_vals.shape[0]

    @property
    def num_coo(self) -> int:
        return self.coo_codes.shape[0]

    def device_put(self) -> "SpMVStreams":
        return jax.tree_util.tree_map(jax.numpy.asarray, self)

    def padded_work(self) -> dict:
        """Elements each kernel actually streams, padding included."""
        B = self.block_size
        return {
            "dense": int(self.num_dense * B * B),
            "panel": int(self.num_panel * B * self.panel_vals.shape[-1]),
            "coo": int(self.num_coo * self.coo_codes.shape[-1]),
        }


jax.tree_util.register_dataclass(
    SpMVStreams,
    data_fields=[
        "dense_tiles", "dense_brow", "dense_xidx",
        "panel_vals", "panel_brow", "panel_xidx",
        "coo_codes", "coo_vals", "coo_brow", "coo_xidx",
    ],
    meta_fields=["block_size", "m", "n", "mb", "colagg_applied"],
)


def _block_x_indices(cb: CBMatrix, brow: int, bcol: int) -> np.ndarray:
    """Global x index for each of the B columns of block (brow, bcol)."""
    return column_agg_mod.restore_for_block(
        cb.colagg, brow, bcol, cb.block_size, cb.shape[1]
    ).astype(np.int32)


def _collect_blocks(cb: CBMatrix):
    """Walk the CBMatrix once, typing each block's payload for its stream.

    Returns ``(dense, panels, coos)`` where
      dense  — (brow, (B, B) tile, (B,) xidx, nnz) per FMT_DENSE block,
      panels — (brow, (B, k) compacted panel, (k,) xidx) per FMT_CSR,
      coos   — (brow, (e,) codes, (e,) vals, (e,) xidx) per FMT_COO.
    """
    B = cb.block_size
    bits = coord_bits(B)
    vdt = cb.val_dtype
    dense, panels, coos = [], [], []
    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        if fmt == FMT_DENSE:
            tile = np.zeros((B, B), dtype=vdt)
            tile[r, c] = v
            dense.append((brow, tile, _block_x_indices(cb, brow, bcol), len(v)))
        elif fmt == FMT_CSR:
            ucols, rank = np.unique(c, return_inverse=True)
            panel = np.zeros((B, len(ucols)), dtype=vdt)
            panel[r, rank] = v
            xidx = cb.global_x_index(brow, bcol, ucols).astype(np.int32)
            panels.append((brow, panel, xidx))
        elif fmt == FMT_COO:
            codes = (c.astype(np.int64) << bits) | r.astype(np.int64)
            xidx = cb.global_x_index(brow, bcol, c).astype(np.int32)
            coos.append((brow, codes.astype(np.int32), v.astype(vdt), xidx))
        else:  # pragma: no cover - format codes are exhaustive
            raise errors.InvalidArgError(f"unknown format {fmt}")
    return dense, panels, coos


def build_streams(cb: CBMatrix) -> SpMVStreams:
    """Derive the typed kernel streams from a CBMatrix (host-side).

    The packed-coordinate bit layout is fixed by ``aggregation.coord_bits``
    — the kernels and oracles recompute it from the block size, so it is
    deliberately not a parameter here (an encoder-side override would
    silently desync the decoders).
    """
    B = cb.block_size
    bits = coord_bits(B)
    m, n = cb.shape
    mb = -(-m // B)
    vdt = cb.val_dtype

    dense, panels, coos = _collect_blocks(cb)

    # ---- dense stream ---------------------------------------------------
    nd = len(dense)
    d_tiles = (np.stack([t for _, t, _, _ in dense]) if nd
               else np.zeros((0, B, B), vdt))
    d_brow = np.asarray([b for b, _, _, _ in dense], np.int32)
    d_xidx = (np.stack([x for _, _, x, _ in dense]).astype(np.int32) if nd
              else np.zeros((0, B), np.int32))

    # ---- panel stream ---------------------------------------------------
    np_ = len(panels)
    Kp = pad_width(max((p.shape[1] for _, p, _ in panels), default=0))
    p_vals = np.zeros((np_, B, Kp), vdt)
    p_brow = np.zeros(np_, np.int32)
    p_xidx = np.zeros((np_, Kp), np.int32)
    for i, (brow, panel, xidx) in enumerate(panels):
        k = panel.shape[1]
        p_vals[i, :, :k] = panel
        p_brow[i] = brow
        p_xidx[i, :k] = xidx

    # ---- coo stream -----------------------------------------------------
    nc = len(coos)
    Ep = pad_width(max((len(v) for _, _, v, _ in coos), default=0))
    c_codes = np.zeros((nc, Ep), np.int32)
    c_vals = np.zeros((nc, Ep), vdt)
    c_brow = np.zeros(nc, np.int32)
    c_xidx = np.zeros((nc, Ep), np.int32)
    for i, (brow, codes, vals, xidx) in enumerate(coos):
        e = len(vals)
        c_codes[i, :e] = codes
        c_vals[i, :e] = vals
        c_brow[i] = brow
        c_xidx[i, :e] = xidx

    return SpMVStreams(
        block_size=B, m=m, n=n, mb=mb, colagg_applied=cb.colagg.applied,
        dense_tiles=d_tiles, dense_brow=d_brow, dense_xidx=d_xidx,
        panel_vals=p_vals, panel_brow=p_brow, panel_xidx=p_xidx,
        coo_codes=c_codes, coo_vals=c_vals, coo_brow=c_brow, coo_xidx=c_xidx,
    )


# ---------------------------------------------------------------------------
# Super-block streams: the batched execution engine's input format.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SuperBlockStreams:
    """Typed streams with many blocks fused per stream row.

    One stream row = one Pallas grid step. Layouts per format:

      * dense — tiles stacked vertically: slot ``g`` of a group owns
        sublanes ``[g*B, (g+1)*B)`` of the ``(Gd*B, B)`` super-tile; its
        partial lands in row ``g`` of the ``(Gd, B)`` output tile.
      * panel / coo — payloads lane-packed side by side at
        sublane-aligned offsets (each block's width rounded up to
        ``SUBLANE`` — its width *bucket*), so a wide outlier pads only
        its own group. Lane->slot routing is **implicit**: slot =
        ``lane // SUBLANE``. A block wider than one slot occupies
        ``width / SUBLANE`` consecutive slots, each carrying the block's
        row in ``*_brow``; the pieces' partials are reunited by the
        additive scatter combine, which is exactly why no explicit
        segment map is needed — and why the kernels can split a fused
        payload with a plain reshape-sum instead of a data-dependent
        segment contraction (O(payload) on every backend).

    Slots that the packer left empty have zero payload and ``brow`` 0:
    they scatter-add zeros into block-row 0, which is exact.
    """

    # -- static ---------------------------------------------------------
    block_size: int
    m: int
    n: int
    mb: int
    colagg_applied: bool
    group_size: int          # requested blocks per step (packer target)
    # -- dense super-tiles ----------------------------------------------
    dense_tiles: jax.Array   # (gd, Gd*B, B) val
    dense_brow: jax.Array    # (gd, Gd) int32
    dense_xidx: jax.Array    # (gd, Gd, B) int32
    # -- lane-packed panel groups (Sp = Wp // SUBLANE slots) -------------
    panel_vals: jax.Array    # (gp, B, Wp) val
    panel_brow: jax.Array    # (gp, Sp) int32 slot -> block row
    panel_xidx: jax.Array    # (gp, Wp) int32
    # -- lane-packed coo groups (Sc = Wc // SUBLANE slots) ---------------
    coo_codes: jax.Array     # (gc, Wc) int32 packed (col << bits | row)
    coo_vals: jax.Array      # (gc, Wc) val (0 on padding)
    coo_brow: jax.Array      # (gc, Sc) int32
    coo_xidx: jax.Array      # (gc, Wc) int32

    @property
    def num_dense_groups(self) -> int:
        return self.dense_tiles.shape[0]

    @property
    def num_panel_groups(self) -> int:
        return self.panel_vals.shape[0]

    @property
    def num_coo_groups(self) -> int:
        return self.coo_codes.shape[0]

    def device_put(self) -> "SuperBlockStreams":
        return jax.tree_util.tree_map(jax.numpy.asarray, self)

    def padded_work(self) -> dict:
        """Elements each kernel streams per full pass, padding included."""
        return {
            "dense": int(np.prod(self.dense_tiles.shape)),
            "panel": int(np.prod(self.panel_vals.shape)),
            "coo": int(np.prod(self.coo_codes.shape)),
        }

    @property
    def val_itemsize(self) -> int:
        """Bytes per value element (payload dtype width)."""
        return int(np.dtype(self.dense_tiles.dtype).itemsize)

    def region_nbytes(self) -> dict:
        """Byte size of every device buffer one SpMV pass touches.

        Read-only shape metadata (no values are read), keyed by buffer
        name in DMA order plus the ``x``/``y`` operand vectors — the
        address-space layout the locality profiler
        (``repro.obs.locality``) models traffic over.
        """
        vb = self.val_itemsize
        ib = np.dtype(np.int32).itemsize
        return {
            "dense_tiles": int(self.dense_tiles.size) * vb,
            "dense_xidx": int(self.dense_xidx.size) * ib,
            "panel_vals": int(self.panel_vals.size) * vb,
            "panel_xidx": int(self.panel_xidx.size) * ib,
            "coo_codes": int(self.coo_codes.size) * ib,
            "coo_vals": int(self.coo_vals.size) * vb,
            "coo_xidx": int(self.coo_xidx.size) * ib,
            "x": int(self.n) * vb,
            "y": int(self.m) * vb,
        }


jax.tree_util.register_dataclass(
    SuperBlockStreams,
    data_fields=[
        "dense_tiles", "dense_brow", "dense_xidx",
        "panel_vals", "panel_brow", "panel_xidx",
        "coo_codes", "coo_vals", "coo_brow", "coo_xidx",
    ],
    meta_fields=["block_size", "m", "n", "mb", "colagg_applied", "group_size"],
)


def build_super_streams(
    cb: CBMatrix, group_size: int | None = None
) -> SuperBlockStreams:
    """Pack CB blocks into balanced super-block groups (host-side).

    ``group_size=None`` picks ``group_size_for(B)`` — the occupancy
    heuristic targeting ~``TARGET_STEP_ELEMS`` payload elements per grid
    step. Group assignment reuses the paper's Alg. 2 heap balancer
    (``balance.grid_group_balance``): dense groups balance nnz across
    uniform-shape super-tiles; panel/COO groups balance *bucketed width*
    so the shared array width ``W = max_g sum(widths)`` — the padded
    payload every step DMAs — is as small and as equal as the block mix
    allows.
    """
    B = cb.block_size
    m, n = cb.shape
    mb = -(-m // B)
    vdt = cb.val_dtype
    G = group_size_for(B) if group_size is None else int(group_size)
    if G < 1:
        raise errors.InvalidArgError(f"group_size must be >= 1, got {G}")

    dense, panels, coos = _collect_blocks(cb)

    # ---- dense: nnz-balanced tiles, evened slots per super-tile ---------
    nd = len(dense)
    if nd:
        _, Gd = even_group(nd, G)
        bal = balance_mod.grid_group_balance(
            np.asarray([e[3] for e in dense], np.int64), Gd
        )
        gd = bal.num_groups
        d_tiles = np.zeros((gd, Gd * B, B), vdt)
        d_brow = np.zeros((gd, Gd), np.int32)
        d_xidx = np.zeros((gd, Gd, B), np.int32)
        for s, blk in enumerate(bal.slots):
            if blk < 0:
                continue
            g, slot = divmod(s, Gd)
            brow, tile, xidx, _ = dense[blk]
            d_tiles[g, slot * B : (slot + 1) * B, :] = tile
            d_brow[g, slot] = brow
            d_xidx[g, slot] = xidx
    else:
        d_tiles = np.zeros((0, G * B, B), vdt)
        d_brow = np.zeros((0, G), np.int32)
        d_xidx = np.zeros((0, G, B), np.int32)

    # ---- panel / coo: lane-packed, width-balanced -----------------------
    def _pack_lanes(widths, payload_rows):
        """Assign blocks to groups by bucketed width and lay out lanes.

        ``widths[i]`` is block i's bucketed lane count (a SUBLANE
        multiple). Returns the per-(group, member) block index map
        (-1 = empty), each member's lane offset, and zeroed packed
        arrays sized to the balanced width ``W = max_g sum(widths)``
        with a per-slot brow array of ``W // SUBLANE`` slots.
        """
        _, Gs = even_group(len(widths), G)
        bal = balance_mod.grid_group_balance(np.asarray(widths, np.int64), Gs)
        ng = bal.num_groups
        slot_map = bal.slots.reshape(ng, Gs)
        W = 0
        for g in range(ng):
            blks = slot_map[g][slot_map[g] >= 0]
            W = max(W, int(np.sum(np.asarray(widths)[blks])) if len(blks) else 0)
        vals = np.zeros((ng, payload_rows, W) if payload_rows else (ng, W), vdt)
        brow = np.zeros((ng, W // SUBLANE), np.int32)
        xidx = np.zeros((ng, W), np.int32)
        offsets = np.zeros((ng, Gs), np.int64)
        for g in range(ng):
            off = 0
            for member in range(Gs):
                if slot_map[g, member] >= 0:
                    offsets[g, member] = off
                    off += int(widths[slot_map[g, member]])
        return slot_map, offsets, vals, brow, xidx

    def _place_brow(brow_arr, g, off, w, brow):
        """A block's ``w`` lanes span ``w // SUBLANE`` consecutive slots,
        every one pointing at the block's row (pieces merge in the
        scatter-add)."""
        brow_arr[g, off // SUBLANE : (off + w) // SUBLANE] = brow

    np_ = len(panels)
    if np_:
        widths = [pad_width(p.shape[1]) for _, p, _ in panels]
        slot_map, offsets, p_vals, p_brow, p_xidx = _pack_lanes(
            widths, payload_rows=B
        )
        for (g, member), blk in np.ndenumerate(slot_map):
            if blk < 0:
                continue
            brow, panel, xidx = panels[blk]
            k = panel.shape[1]
            off = int(offsets[g, member])
            p_vals[g, :, off : off + k] = panel
            p_xidx[g, off : off + k] = xidx
            _place_brow(p_brow, g, off, widths[blk], brow)
    else:
        p_vals = np.zeros((0, B, 0), vdt)
        p_brow = np.zeros((0, 0), np.int32)
        p_xidx = np.zeros((0, 0), np.int32)

    nc = len(coos)
    if nc:
        widths = [pad_width(len(v)) for _, _, v, _ in coos]
        slot_map, offsets, c_vals, c_brow, c_xidx = _pack_lanes(
            widths, payload_rows=0
        )
        c_codes = np.zeros((c_vals.shape[0], c_vals.shape[-1]), np.int32)
        for (g, member), blk in np.ndenumerate(slot_map):
            if blk < 0:
                continue
            brow, codes, vals, xidx = coos[blk]
            e = len(vals)
            off = int(offsets[g, member])
            c_codes[g, off : off + e] = codes
            c_vals[g, off : off + e] = vals
            c_xidx[g, off : off + e] = xidx
            _place_brow(c_brow, g, off, widths[blk], brow)
    else:
        c_codes = np.zeros((0, 0), np.int32)
        c_vals = np.zeros((0, 0), vdt)
        c_brow = np.zeros((0, 0), np.int32)
        c_xidx = np.zeros((0, 0), np.int32)

    return SuperBlockStreams(
        block_size=B, m=m, n=n, mb=mb, colagg_applied=cb.colagg.applied,
        group_size=G,
        dense_tiles=d_tiles, dense_brow=d_brow, dense_xidx=d_xidx,
        panel_vals=p_vals, panel_brow=p_brow, panel_xidx=p_xidx,
        coo_codes=c_codes, coo_vals=c_vals, coo_brow=c_brow, coo_xidx=c_xidx,
    )


# ---------------------------------------------------------------------------
# Transposed streams: the solver subsystem's rmatvec path.
# ---------------------------------------------------------------------------

def transpose_cb(cb: CBMatrix) -> CBMatrix:
    """Rebuild the full CB pipeline for ``A^T`` (host-side, plan time).

    Krylov methods on nonsymmetric systems (BiCGStab's shadow residual,
    least-squares solves) need ``A^T @ y`` with the same amortized-
    preprocessing story as ``A @ x``. Rather than bolt a transposed
    execution mode onto the kernels (which would double every kernel's
    surface), the transpose gets its *own* CB structure: collect the
    matrix's triplets in original global coordinates, swap them, and run
    the whole preprocessing pipeline again. Block formats, column
    aggregation and balance are re-decided for A^T's structure — the
    transpose of a panel-heavy matrix may well be COO-heavy.

    Triplets are gathered in canonical row-major order of the transpose
    so the result is bit-identical to building ``CBMatrix.from_coo`` on
    the transposed triplets directly (determinism contract relied on by
    the solver tests).
    """
    B = cb.block_size
    m, n = cb.shape
    rs, cs, vs = [], [], []
    for brow, bcol, _fmt, r, c, v in cb.iter_blocks():
        gc = cb.global_x_index(brow, bcol, c)
        rs.append(brow * B + r.astype(np.int64))
        cs.append(gc.astype(np.int64))
        vs.append(v)
    if rs:
        r_all = np.concatenate(rs)
        c_all = np.concatenate(cs)
        v_all = np.concatenate(vs)
    else:
        r_all = c_all = np.zeros(0, np.int64)
        v_all = np.zeros(0, cb.val_dtype)
    order = np.lexsort((r_all, c_all))  # row-major in transposed coords
    return CBMatrix.from_coo(
        c_all[order], r_all[order], v_all[order], (n, m),
        block_size=B, val_dtype=cb.val_dtype, thresholds=cb.thresholds,
    )


def build_transposed_super_streams(
    cb: CBMatrix, group_size: int | None = None
) -> SuperBlockStreams:
    """Batched super-block streams for ``A^T`` (see :func:`transpose_cb`)."""
    return build_super_streams(transpose_cb(cb), group_size=group_size)


# ---------------------------------------------------------------------------
# SpMM tile stream: block-dense weights for the training/prefill path.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TileStream:
    """Block-dense (BSR-like) stream for CB-SpMM.

    Blocks are sorted in canonical ``(brow, bcol)`` order — BOTH builders
    (``build_tile_stream`` from raw COO, ``tile_stream_from_cb`` from the
    full CB pipeline) emit this exact order, so two streams of the same
    matrix are bit-identical regardless of which path produced them.
    Every block row owns at least one (possibly all-zero) coverage tile;
    the batched kernel's scatter-add combine no longer *needs* coverage
    for initialization (the accumulator starts at zero), but the
    guarantee is kept so stream geometry stays stable across builders.
    """

    block_size: int
    m: int
    n: int
    mb: int
    nb: int
    tiles: jax.Array   # (nt, B, B)
    brow: jax.Array    # (nt,) int32, ascending
    bcol: jax.Array    # (nt,) int32, ascending within each block row

    @property
    def num_tiles(self) -> int:
        return self.tiles.shape[0]


jax.tree_util.register_dataclass(
    TileStream,
    data_fields=["tiles", "brow", "bcol"],
    meta_fields=["block_size", "m", "n", "mb", "nb"],
)


def build_tile_stream(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    block_size: int,
) -> TileStream:
    """Build the block-dense stream directly from COO triplets."""
    from .blocking import partition_coo

    m, n = shape
    B = block_size
    mb, nb = -(-m // B), -(-n // B)
    part = partition_coo(rows, cols, vals, shape, B)

    tiles, brows, bcols = [], [], []
    for i in range(part.num_blocks):
        r, c, v = part.block_elems(i)
        tile = np.zeros((B, B), dtype=v.dtype)
        tile[r, c] = v
        tiles.append(tile)
        brows.append(int(part.blk_row_idx[i]))
        bcols.append(int(part.blk_col_idx[i]))

    # Coverage: every block row must own >= 1 tile (stable stream geometry).
    present = set(brows)
    for rb in range(mb):
        if rb not in present:
            tiles.append(np.zeros((B, B), dtype=vals.dtype))
            brows.append(rb)
            bcols.append(0)

    # Canonical (brow, bcol) order — bit-identical to tile_stream_from_cb.
    order = np.lexsort((np.asarray(bcols), np.asarray(brows)))
    tiles_arr = np.stack(tiles)[order] if tiles else np.zeros((0, B, B), vals.dtype)
    return TileStream(
        block_size=B, m=m, n=n, mb=mb, nb=nb,
        tiles=tiles_arr,
        brow=np.asarray(brows, np.int32)[order],
        bcol=np.asarray(bcols, np.int32)[order],
    )


def tile_stream_from_cb(cb: CBMatrix) -> TileStream:
    """Densify every CB block into the tile stream (all formats -> tiles).

    Used when the SpMM path must run over a matrix preprocessed with the
    full CB pipeline; x-index indirection (column aggregation) is folded
    back to original coordinates so the stream is position-faithful.
    """
    B = cb.block_size
    m, n = cb.shape
    mb, nb = -(-m // B), -(-n // B)

    # One pass over blocks to collect flat triplets (block granularity),
    # then pure batch ops — no per-element Python.
    rs, gcs, vs, brs = [], [], [], []
    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        gc = cb.global_x_index(brow, bcol, c)
        rs.append(np.asarray(r, np.int64))
        gcs.append(np.asarray(gc, np.int64))
        vs.append(v)
        brs.append(np.full(len(v), brow, np.int64))
    if rs:
        r_all = np.concatenate(rs)
        gc_all = np.concatenate(gcs)
        v_all = np.concatenate(vs)
        br_all = np.concatenate(brs)
    else:
        r_all = gc_all = br_all = np.zeros(0, np.int64)
        v_all = np.zeros(0, cb.val_dtype)

    key = br_all * nb + gc_all // B  # ascending unique keys = (brow, bcol)
    ukeys, inv = np.unique(key, return_inverse=True)
    tiles = np.zeros((len(ukeys), B, B), dtype=cb.val_dtype)
    np.add.at(tiles, (inv, r_all, gc_all % B), v_all)
    brow_arr = (ukeys // nb).astype(np.int32)
    bcol_arr = (ukeys % nb).astype(np.int32)

    # Coverage: every block row must own >= 1 tile (revisit init correctness).
    missing = np.setdiff1d(np.arange(mb, dtype=np.int32), brow_arr)
    if len(missing):
        tiles = np.concatenate(
            [tiles, np.zeros((len(missing), B, B), cb.val_dtype)]
        )
        brow_arr = np.concatenate([brow_arr, missing])
        bcol_arr = np.concatenate([bcol_arr, np.zeros(len(missing), np.int32)])
    # Canonical (brow, bcol) order — bit-identical to build_tile_stream.
    order = np.lexsort((bcol_arr, brow_arr))
    return TileStream(
        block_size=B, m=m, n=n, mb=mb, nb=nb,
        tiles=tiles[order],
        brow=brow_arr[order],
        bcol=bcol_arr[order],
    )


# ---------------------------------------------------------------------------
# Super-tile stream: the batched SpMM execution engine's input format.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SuperTileStream:
    """Tile stream with ``Gt`` weight tiles fused per grid step.

    One stream row = one Pallas grid step (per activation n-tile). Slot
    ``g`` of group ``i`` owns sublanes ``[g*B, (g+1)*B)`` of the
    ``(Gt*B, B)`` super-tile; ``brow``/``bcol`` are the per-group slot
    maps routing that slot's partial to output block-row ``brow[i, g]``
    and its activation DMA to X block-row ``bcol[i, g]``. Slots the
    packer left empty hold a zero tile with ``brow``/``bcol`` 0: they
    DMA X block 0 and scatter-add exact zeros into output row 0.

    Unlike the SpMV super streams there is no lane packing — dense
    ``(B, B)`` tiles are already uniform — so the only balancing axis is
    nnz per tile, which Alg. 2 equalizes across groups to keep each
    step's useful-FLOP fraction even.
    """

    # -- static ---------------------------------------------------------
    block_size: int
    m: int
    n: int
    mb: int
    nb: int
    group_size: int          # requested tiles per step (packer target)
    # -- data ------------------------------------------------------------
    tiles: jax.Array   # (gt, Gt*B, B)
    brow: jax.Array    # (gt, Gt) int32
    bcol: jax.Array    # (gt, Gt) int32

    @property
    def num_groups(self) -> int:
        return self.tiles.shape[0]

    @property
    def slots(self) -> int:
        return self.brow.shape[1]

    def padded_work(self) -> dict:
        """Weight elements one full sweep streams, padding included."""
        return {"tiles": int(np.prod(self.tiles.shape))}

    @property
    def val_itemsize(self) -> int:
        """Bytes per weight element (payload dtype width)."""
        return int(np.dtype(self.tiles.dtype).itemsize)

    def region_nbytes(self) -> dict:
        """Byte size of the weight buffer one SpMM sweep streams.

        Read-only shape metadata for the locality profiler; the X/Y
        activation regions depend on the activation width and are laid
        out by ``repro.obs.locality.access_stream_super_tile``.
        """
        return {"tiles": int(self.tiles.size) * self.val_itemsize}


jax.tree_util.register_dataclass(
    SuperTileStream,
    data_fields=["tiles", "brow", "bcol"],
    meta_fields=["block_size", "m", "n", "mb", "nb", "group_size"],
)


def build_super_tile_stream(
    ts: TileStream, group_size: int | None = None
) -> SuperTileStream:
    """Pack SpMM tiles into nnz-balanced super-tile groups (host-side).

    Mirrors ``build_super_streams`` for the tile stream: ``group_size=
    None`` picks ``group_size_for(B)``; tiles are assigned to groups by
    the Alg. 2 heap balancer (``balance.grid_group_balance``) on per-tile
    nnz, with slots evened via ``even_group`` so the tail group is never
    mostly padding. Group order inside the balancer result is preserved
    verbatim — the scatter-add combine makes the output independent of
    slot order, so the balanced schedule rides through unchanged.
    """
    B = ts.block_size
    G = group_size_for(B) if group_size is None else int(group_size)
    if G < 1:
        raise errors.InvalidArgError(f"group_size must be >= 1, got {G}")

    tiles = np.asarray(ts.tiles)
    brow = np.asarray(ts.brow)
    bcol = np.asarray(ts.bcol)
    nt = tiles.shape[0]
    if nt:
        _, Gt = even_group(nt, G)
        bal = balance_mod.grid_group_balance(
            np.count_nonzero(tiles, axis=(1, 2)).astype(np.int64), Gt
        )
        gt = bal.num_groups
        s_tiles = np.zeros((gt, Gt * B, B), tiles.dtype)
        s_brow = np.zeros((gt, Gt), np.int32)
        s_bcol = np.zeros((gt, Gt), np.int32)
        for s, blk in enumerate(bal.slots):
            if blk < 0:
                continue
            g, slot = divmod(s, Gt)
            s_tiles[g, slot * B : (slot + 1) * B, :] = tiles[blk]
            s_brow[g, slot] = brow[blk]
            s_bcol[g, slot] = bcol[blk]
    else:
        s_tiles = np.zeros((0, G * B, B), tiles.dtype)
        s_brow = np.zeros((0, G), np.int32)
        s_bcol = np.zeros((0, G), np.int32)

    return SuperTileStream(
        block_size=B, m=ts.m, n=ts.n, mb=ts.mb, nb=ts.nb, group_size=G,
        tiles=s_tiles, brow=s_brow, bcol=s_bcol,
    )


def super_tile_stream_from_cb(
    cb: CBMatrix, group_size: int | None = None
) -> SuperTileStream:
    """Full CB pipeline -> densified tiles -> balanced super-tile groups."""
    return build_super_tile_stream(tile_stream_from_cb(cb),
                                   group_size=group_size)


# ---------------------------------------------------------------------------
# Stream updaters: the dynamic-sparsity fast path at stream granularity.
#
# Every stream builder above permutes values (balanced slot order, lane
# packing, tile stacking) but decides the permutation from the sparsity
# pattern alone. The updaters record that permutation ONCE — by building
# the stream from a "shadow" CBMatrix whose payload values are canonical
# indices — and afterwards re-materialize a stream for fresh values with
# a single vectorized scatter, never re-running the builders.
# ---------------------------------------------------------------------------


def _index_cb(cb: CBMatrix) -> CBMatrix:
    """A shadow of ``cb`` whose payload values are ``canonical_rank + 1``.

    Same blocking / colagg / format / balance metadata; int64 values, all
    nonzero — so every value-sensitive step inside the stream builders
    (dense-tile nonzero recovery, nnz balancing, ``count_nonzero`` on
    densified tiles) sees the structure an all-nonzero real build would.
    Building any stream from the shadow therefore yields payload arrays
    holding ``src_index + 1`` at exactly the positions the real builder
    would place canonical value ``src_index`` — the value-scatter index,
    extracted with zero changes to the builders themselves.
    """
    from . import aggregation

    layout = cb.value_layout()
    B = cb.block_size
    n = cb.shape[1]
    elems, fmts, slot_idx = [], [], []
    for i in range(cb.num_slots):
        nnz = int(cb.nnz_per_blk[i])
        if nnz == 0:
            continue
        fmt = int(cb.type_per_blk[i])
        r, c, _v = aggregation.unpack_block(
            cb.packed, int(cb.vp_per_blk[i]), fmt, nnz, B, cb.val_dtype
        )
        brow = int(cb.blk_row_idx[i])
        bcol = int(cb.blk_col_idx[i])
        key = ((brow * B + r.astype(np.int64)) * n
               + cb.global_x_index(brow, bcol, c))
        rank = np.searchsorted(layout.keys, key)
        elems.append((r, c, rank + 1))
        fmts.append(fmt)
        slot_idx.append(i)
    packed = aggregation.aggregate_blocks(
        np.asarray(fmts, np.uint8), elems, B, np.dtype(np.int64)
    )
    vp = np.zeros_like(cb.vp_per_blk)
    nnzb = np.zeros_like(cb.nnz_per_blk)
    for j, i in enumerate(slot_idx):
        vp[i] = packed.vp_per_blk[j]
        nnzb[i] = len(elems[j][0])
    return dataclasses.replace(
        cb, val_dtype=np.dtype(np.int64), nnz_per_blk=nnzb,
        vp_per_blk=vp, packed=packed.packed,
    )


def _scatter_from_index(arr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(flat positions, canonical source index) of a shadow payload array."""
    flat = np.asarray(arr).reshape(-1)
    pos = np.flatnonzero(flat)
    return pos, (flat[pos] - 1).astype(np.int64)


def _scatter_payload(shape, dtype, pos, src, vals):
    """Zeros of ``shape`` with ``vals[src]`` scattered at flat ``pos``.

    numpy in, numpy out (the cheap host path the benchmarks compare
    against a full rebuild); anything else goes through ``jax.numpy`` so
    the scatter is traceable inside jit (pos/src are static constants).
    """
    size = int(np.prod(shape))
    if isinstance(vals, np.ndarray):
        out = np.zeros(size, dtype)
        out[pos] = np.ascontiguousarray(vals, dtype)[src]
        return out.reshape(shape)
    import jax.numpy as jnp

    out = jnp.zeros((size,), dtype)
    if len(pos):
        out = out.at[pos].set(jnp.asarray(vals).astype(dtype)[src])
    return out.reshape(shape)


@dataclasses.dataclass(eq=False)
class SuperStreamUpdater:
    """Value-scatter index for a ``SuperBlockStreams`` layout.

    ``apply(canonical_vals)`` returns a stream bit-identical to
    ``build_super_streams`` on the same structure with those values
    (values in the canonical ``to_coo`` order), at vectorized-scatter
    cost. ``eq=False`` keeps the object identity-hashable so it can ride
    jit static metadata (same discipline as ``sparse.linear``'s spec).
    """

    template: SuperBlockStreams   # real metadata, zeroed payloads
    val_dtype: np.dtype
    dense_pos: np.ndarray
    dense_src: np.ndarray
    panel_pos: np.ndarray
    panel_src: np.ndarray
    coo_pos: np.ndarray
    coo_src: np.ndarray

    def apply(self, canonical_vals) -> SuperBlockStreams:
        t = self.template
        return dataclasses.replace(
            t,
            dense_tiles=_scatter_payload(
                t.dense_tiles.shape, self.val_dtype,
                self.dense_pos, self.dense_src, canonical_vals),
            panel_vals=_scatter_payload(
                t.panel_vals.shape, self.val_dtype,
                self.panel_pos, self.panel_src, canonical_vals),
            coo_vals=_scatter_payload(
                t.coo_vals.shape, self.val_dtype,
                self.coo_pos, self.coo_src, canonical_vals),
        )


def _super_updater_from_shadow(
    shadow: SuperBlockStreams, vdt: np.dtype
) -> SuperStreamUpdater:
    dense_pos, dense_src = _scatter_from_index(shadow.dense_tiles)
    panel_pos, panel_src = _scatter_from_index(shadow.panel_vals)
    coo_pos, coo_src = _scatter_from_index(shadow.coo_vals)
    template = dataclasses.replace(
        shadow,
        dense_tiles=np.zeros(shadow.dense_tiles.shape, vdt),
        panel_vals=np.zeros(shadow.panel_vals.shape, vdt),
        coo_vals=np.zeros(shadow.coo_vals.shape, vdt),
    )
    return SuperStreamUpdater(
        template=template, val_dtype=vdt,
        dense_pos=dense_pos, dense_src=dense_src,
        panel_pos=panel_pos, panel_src=panel_src,
        coo_pos=coo_pos, coo_src=coo_src,
    )


def super_stream_updater(
    cb: CBMatrix, group_size: int | None = None
) -> SuperStreamUpdater:
    """Record ``build_super_streams``'s value permutation once.

    The returned updater's ``apply`` matches a fresh
    ``build_super_streams(cb.update_values(v), group_size)`` bit for bit
    whenever the new values are nonzero (an exact 0.0 would change which
    elements a dense tile recovers — structure drift, not an update).
    """
    shadow = build_super_streams(_index_cb(cb), group_size=group_size)
    return _super_updater_from_shadow(shadow, np.dtype(cb.val_dtype))


def transposed_super_stream_updater(
    cb: CBMatrix, group_size: int | None = None
) -> SuperStreamUpdater:
    """Value-scatter index for the ``A^T`` stream, in **forward** order.

    ``transpose_cb`` re-runs the whole CB pipeline on swapped triplets
    but carries values through untouched, so transposing the shadow
    matrix lands forward canonical indices at the transposed stream's
    payload positions: one ``apply(forward_canonical_vals)`` updates the
    rmatvec path with no transposed-order bookkeeping anywhere.
    """
    shadow = build_super_streams(transpose_cb(_index_cb(cb)),
                                 group_size=group_size)
    return _super_updater_from_shadow(shadow, np.dtype(cb.val_dtype))


@dataclasses.dataclass(eq=False)
class SuperTileUpdater:
    """Value-scatter index for a ``SuperTileStream`` layout (SpMM path)."""

    template: SuperTileStream     # real slot maps, zeroed tiles
    val_dtype: np.dtype
    pos: np.ndarray
    src: np.ndarray

    def apply(self, canonical_vals) -> SuperTileStream:
        t = self.template
        return dataclasses.replace(
            t,
            tiles=_scatter_payload(t.tiles.shape, self.val_dtype,
                                   self.pos, self.src, canonical_vals),
        )


def super_tile_updater(
    cb: CBMatrix, group_size: int | None = None
) -> SuperTileUpdater:
    """Record ``super_tile_stream_from_cb``'s value permutation once."""
    shadow = super_tile_stream_from_cb(_index_cb(cb), group_size=group_size)
    vdt = np.dtype(cb.val_dtype)
    pos, src = _scatter_from_index(shadow.tiles)
    template = dataclasses.replace(
        shadow, tiles=np.zeros(shadow.tiles.shape, vdt)
    )
    return SuperTileUpdater(template=template, val_dtype=vdt,
                            pos=pos, src=src)
