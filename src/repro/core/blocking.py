"""2D blocking structure (paper §3.1).

Partitions a COO matrix into uniform B x B sub-blocks and produces the
high-level block-COO metadata (blk_row_idx, blk_col_idx, nnz_per_blk) plus
per-block element slices with *block-local* coordinates.

The key property the paper exploits — and we preserve — is that after
partitioning, every sub-block is self-contained: its coordinates are
relative to the sub-block, so blocks can be stored, permuted and scheduled
independently.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from repro import errors


@dataclasses.dataclass
class BlockPartition:
    """Result of 2D blocking. Elements are sorted block-major.

    ``elem_*`` arrays are parallel arrays of length nnz holding every
    non-zero in block-major order (block i owns the slice
    ``blk_ptr[i]:blk_ptr[i+1]``). ``local_rows/local_cols`` are coordinates
    relative to the owning block (in ``[0, B)``).
    """

    shape: tuple[int, int]
    block_size: int
    blk_row_idx: np.ndarray   # (nblk,) int32
    blk_col_idx: np.ndarray   # (nblk,) int32
    nnz_per_blk: np.ndarray   # (nblk,) int32
    blk_ptr: np.ndarray       # (nblk+1,) int64, element offsets
    local_rows: np.ndarray    # (nnz,) int32
    local_cols: np.ndarray    # (nnz,) int32
    values: np.ndarray        # (nnz,) val dtype

    @property
    def num_blocks(self) -> int:
        return len(self.blk_row_idx)

    @property
    def nnz(self) -> int:
        return len(self.values)

    def block_elems(self, i: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        s, e = self.blk_ptr[i], self.blk_ptr[i + 1]
        return self.local_rows[s:e], self.local_cols[s:e], self.values[s:e]


def partition_coo(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    block_size: int,
) -> BlockPartition:
    """Partition COO triplets into B x B sub-blocks (block-major order).

    Duplicate coordinates are summed (standard COO semantics), so the
    partition is a faithful linear-algebra representation of the input.
    """
    m, n = shape
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    if rows.size:
        if rows.min() < 0 or rows.max() >= m or cols.min() < 0 or cols.max() >= n:
            raise errors.InvalidArgError("coordinate out of bounds")

    B = int(block_size)
    nbc = -(-n // B)  # ceil
    brow = rows // B
    bcol = cols // B
    # Sort elements by (block key, row, col) so intra-block order is
    # row-major — required for CSR packing and deterministic accumulation.
    key = (brow * nbc + bcol) * (B * B) + (rows % B) * B + (cols % B)
    order = np.argsort(key, kind="stable")
    key = key[order]
    rows, cols, vals = rows[order], cols[order], vals[order]
    brow, bcol = brow[order], bcol[order]

    # Merge duplicates.
    full_key = key  # key already encodes exact (block, r, c)
    if len(full_key):
        uniq_mask = np.empty(len(full_key), dtype=bool)
        uniq_mask[0] = True
        np.not_equal(full_key[1:], full_key[:-1], out=uniq_mask[1:])
        if not uniq_mask.all():
            seg_ids = np.cumsum(uniq_mask) - 1
            summed = np.zeros(seg_ids[-1] + 1, dtype=vals.dtype)
            np.add.at(summed, seg_ids, vals)
            rows, cols, brow, bcol = (a[uniq_mask] for a in (rows, cols, brow, bcol))
            vals = summed
            key = key[uniq_mask]

    blk_key = brow * nbc + bcol
    if len(blk_key):
        blk_start = np.flatnonzero(np.r_[True, blk_key[1:] != blk_key[:-1]])
        blk_ptr = np.r_[blk_start, len(blk_key)].astype(np.int64)
        blk_row_idx = (blk_key[blk_start] // nbc).astype(np.int32)
        blk_col_idx = (blk_key[blk_start] % nbc).astype(np.int32)
        nnz_per_blk = np.diff(blk_ptr).astype(np.int32)
    else:
        blk_ptr = np.zeros(1, dtype=np.int64)
        blk_row_idx = np.zeros(0, dtype=np.int32)
        blk_col_idx = np.zeros(0, dtype=np.int32)
        nnz_per_blk = np.zeros(0, dtype=np.int32)

    return BlockPartition(
        shape=(m, n),
        block_size=B,
        blk_row_idx=blk_row_idx,
        blk_col_idx=blk_col_idx,
        nnz_per_blk=nnz_per_blk,
        blk_ptr=blk_ptr,
        local_rows=(rows % B).astype(np.int32),
        local_cols=(cols % B).astype(np.int32),
        values=vals,
    )


def block_nnz_histogram(nnz_per_blk: np.ndarray, block_size: int, bins: int = 8) -> np.ndarray:
    """Fig. 3(a): histogram of block nnz over `bins` equal ranges of [1, B*B]."""
    area = block_size * block_size
    edges = np.linspace(0, area, bins + 1)
    edges[0] = 0.5  # blocks have >= 1 nnz
    hist, _ = np.histogram(nnz_per_blk, bins=edges)
    return hist
