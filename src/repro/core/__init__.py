"""CB-SpMV core: the paper's contribution as a composable library."""
from .formats import (  # noqa: F401
    FMT_COO,
    FMT_CSR,
    FMT_DENSE,
    FormatThresholds,
    select_formats,
    should_column_aggregate,
    super_sparse_fraction,
)
from .blocking import BlockPartition, partition_coo  # noqa: F401
from .column_agg import ColumnAggregation, column_aggregate  # noqa: F401
from .aggregation import PackedBlocks, aggregate_blocks, pack_block, unpack_block  # noqa: F401
from .balance import (  # noqa: F401
    BalanceResult,
    apply_balance,
    device_load_balance,
    tb_load_balance,
    tb_load_stddev,
)
from .cb_matrix import CBMatrix, ValueLayout  # noqa: F401
from .spmv_ref import dense_oracle, spmm_ref, spmv_ref  # noqa: F401
