"""Device-level CB-SpMV: the paper's load balancer, scaled to a mesh axis.

The paper balances sub-blocks across thread blocks (8 warp slots each);
here the same min-heap algorithm balances sub-blocks across the devices of
the ``model`` mesh axis (core/balance.device_load_balance). Equal block
count per device gives uniform shard shapes (a shard_map requirement) and
near-equal nnz gives near-equal work — the straggler story at mesh scale.

Pipeline:
  1. ``shard_streams``   (host) — pq-assign blocks to devices, build one
     SpMVStreams per device, pad every stream to the max per-device shape
     with zero blocks, stack into leading-axis-``D`` arrays.
  2. ``distributed_spmv`` — shard_map over the model axis: each device
     runs the single-device kernels on its shard against a replicated x,
     then a single ``psum`` (or ``psum_scatter``) combines partial y.

x stays replicated (SpMV x is tiny relative to the matrix); y combine is
one collective — the communication-minimal schedule for 1D row-partitioned
SpMV (cf. the paper's related work on distributed SpMV [37]).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import errors

from . import balance
from .cb_matrix import CBMatrix
from .streams import SpMVStreams, build_streams


def _pad_axis0(arr: np.ndarray, target: int) -> np.ndarray:
    if arr.shape[0] == target:
        return arr
    pad = np.zeros((target - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def _pad_axis_last(arr: np.ndarray, target: int) -> np.ndarray:
    if arr.shape[-1] == target:
        return arr
    widths = [(0, 0)] * (arr.ndim - 1) + [(0, target - arr.shape[-1])]
    return np.pad(arr, widths)


@dataclasses.dataclass
class ShardedStreams:
    """Per-device SpMV streams stacked on a leading device axis."""

    num_devices: int
    streams: SpMVStreams      # every array has leading dim D
    device_nnz: np.ndarray    # (D,) achieved nnz per device (diagnostics)

    @property
    def load_imbalance(self) -> float:
        mean = self.device_nnz.mean()
        return float(self.device_nnz.max() / mean) if mean > 0 else 1.0


def shard_streams(cb: CBMatrix, num_devices: int) -> ShardedStreams:
    """pq-balance CB blocks across devices and build uniform stacked streams."""
    real = cb.nnz_per_blk > 0
    real_idx = np.flatnonzero(real)
    result = balance.device_load_balance(cb.nnz_per_blk[real_idx], num_devices)

    per_dev: list[SpMVStreams] = []
    for d in range(num_devices):
        slots = result.slots[d * result.group_size : (d + 1) * result.group_size]
        blocks = real_idx[slots[slots >= 0]]
        sub = _sub_matrix(cb, blocks)
        per_dev.append(build_streams(sub))

    # Uniform shapes: pad block counts and inner pads to the per-axis max.
    nd = max(s.num_dense for s in per_dev)
    np_ = max(s.num_panel for s in per_dev)
    nc = max(s.num_coo for s in per_dev)
    Kp = max(s.panel_vals.shape[2] for s in per_dev)
    Ep = max(s.coo_codes.shape[1] for s in per_dev)

    def pad(s: SpMVStreams) -> SpMVStreams:
        return SpMVStreams(
            block_size=s.block_size, m=s.m, n=s.n, mb=s.mb,
            colagg_applied=s.colagg_applied,
            dense_tiles=_pad_axis0(np.asarray(s.dense_tiles), nd),
            dense_brow=_pad_axis0(np.asarray(s.dense_brow), nd),
            dense_xidx=_pad_axis0(np.asarray(s.dense_xidx), nd),
            panel_vals=_pad_axis0(_pad_axis_last(np.asarray(s.panel_vals), Kp), np_),
            panel_brow=_pad_axis0(np.asarray(s.panel_brow), np_),
            panel_xidx=_pad_axis0(_pad_axis_last(np.asarray(s.panel_xidx), Kp), np_),
            coo_codes=_pad_axis0(_pad_axis_last(np.asarray(s.coo_codes), Ep), nc),
            coo_vals=_pad_axis0(_pad_axis_last(np.asarray(s.coo_vals), Ep), nc),
            coo_brow=_pad_axis0(np.asarray(s.coo_brow), nc),
            coo_xidx=_pad_axis0(_pad_axis_last(np.asarray(s.coo_xidx), Ep), nc),
        )

    padded = [pad(s) for s in per_dev]
    stacked = jax.tree_util.tree_map(
        lambda *xs: np.stack(xs), *padded
    )
    # tree_map over dataclass keeps meta from the first element.
    return ShardedStreams(
        num_devices=num_devices,
        streams=stacked,
        device_nnz=result.group_loads.copy(),
    )


def _sub_matrix(cb: CBMatrix, block_slots: np.ndarray) -> CBMatrix:
    """A view-style CBMatrix restricted to the given metadata slots."""
    return dataclasses.replace(
        cb,
        blk_row_idx=cb.blk_row_idx[block_slots],
        blk_col_idx=cb.blk_col_idx[block_slots],
        nnz_per_blk=cb.nnz_per_blk[block_slots],
        type_per_blk=cb.type_per_blk[block_slots],
        vp_per_blk=cb.vp_per_blk[block_slots],
        nnz=int(cb.nnz_per_blk[block_slots].sum()),
    )


def distributed_spmv(
    sharded: ShardedStreams,
    x: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "model",
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    combine: str = "psum_scatter",
) -> jax.Array:
    """y = A @ x with A's blocks pq-balanced over ``axis``; x replicated.

    ``combine`` picks the partial-y reduction:

      * ``"psum_scatter"`` (default) — each device keeps only its y shard
        after the reduce-scatter, so the combine moves ``m`` elements per
        device instead of ``D * m`` and the output stays sharded over
        ``axis`` (the ROADMAP scale-out item). The returned global array
        is sliced back to length ``m``.
      * ``"psum"`` — the legacy fully-replicated combine, kept for the
        multi-pod dry-run whose CPU stand-in lowering only exercises the
        all-reduce collective.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.kernels import ops

    if combine not in ("psum", "psum_scatter"):
        raise errors.InvalidArgError(f"unknown combine {combine!r}")
    dev_spec = jax.tree_util.tree_map(lambda _: P(axis), sharded.streams)
    m = sharded.streams.m
    D = sharded.num_devices
    m_pad = -(-m // D) * D  # reduce-scatter needs an axis divisible by D

    @partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(dev_spec, P()),
        out_specs=P() if combine == "psum" else P(axis),
        # pallas_call out_shapes carry no varying-mesh-axes info
        check_vma=False,
    )
    def run(streams_shard, x_rep):
        local = jax.tree_util.tree_map(lambda a: a[0], streams_shard)
        y = ops.cb_spmv(local, x_rep, impl=impl, interpret=interpret)
        if combine == "psum":
            return jax.lax.psum(y, axis)
        y_pad = jnp.pad(y, (0, m_pad - y.shape[0]))
        return jax.lax.psum_scatter(y_pad, axis, scatter_dimension=0,
                                    tiled=True)

    y = run(sharded.streams, x)
    if combine == "psum" or m == m_pad:
        return y  # still sharded over ``axis`` in the scatter case
    return y[:m]  # ragged tail: the slice re-gathers the last shard
