"""Finding: one lint diagnostic, stable and deterministically ordered.

A finding is a plain value object — ``(code, path, line, col, message,
hint)`` — so two analyzer runs over the same tree produce byte-identical
JSON (``tests/test_lint.py`` asserts this). ``path`` is always
POSIX-style and repo-relative; line/col are 1-based like every compiler
diagnostic the shell understands (``file:line:col``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic emitted by a rule.

    Ordering is ``(path, line, col, code, message)`` via field order, so
    ``sorted(findings)`` is the canonical report order.
    """

    path: str
    line: int
    col: int
    code: str
    message: str
    hint: str = ""

    def format(self) -> str:
        """Human one-liner: ``path:line:col: CBxxx message  [fix: ...]``."""
        text = f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"
        if self.hint:
            text += f"  [fix: {self.hint}]"
        return text

    def to_dict(self) -> dict:
        return {
            "code": self.code,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "hint": self.hint,
        }

    def baseline_key(self) -> tuple[str, str, str]:
        """Identity used for baseline matching.

        Deliberately excludes line/col so a baselined (grandfathered)
        finding survives unrelated edits above it in the file.
        """
        return (self.code, self.path, self.message)
