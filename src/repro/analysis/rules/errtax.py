"""CB4xx — error taxonomy (PR 7's structured failure model).

Library code raises ``repro.errors`` types so every failure carries a
stable machine-matchable ``.code``; a bare ``ValueError("prose")``
reintroduces the untyped failures the fault-injection axis exists to
prevent. The taxonomy types subclass the historical builtins, so
switching a raise site never breaks an existing ``except ValueError``.

``errors.py`` itself is exempt (it defines the hierarchy).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.context import FileContext
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

_BARE_BUILTINS = ("ValueError", "RuntimeError")


@rule("CB401", "bare-builtin-raise",
      "library raises carry a reason code via repro.errors types")
def check_bare_raise(ctx: FileContext) -> Iterator[Finding]:
    if ctx.path.rsplit("/", 1)[-1] == "errors.py":
        return
    for node in ctx.walk():
        if not isinstance(node, ast.Raise) or node.exc is None:
            continue
        exc = node.exc
        name = None
        if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
            name = exc.func.id
        elif isinstance(exc, ast.Name):
            name = exc.id
        if name in _BARE_BUILTINS:
            yield Finding(
                path=ctx.path, line=node.lineno, col=node.col_offset + 1,
                code="CB401",
                message=f"raises bare builtin {name}",
                hint="raise a repro.errors type (InvalidArgError, "
                     "IngestError, ...) so the failure carries a .code",
            )
