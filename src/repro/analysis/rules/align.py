"""CB3xx — kernel lane/sublane alignment (the PR 4 lane rule).

``core/streams.py`` is the single home of the hardware layout rule:
``LANE`` (= 128), ``SUBLANE`` (= 8), ``spmm_block_n`` (bn % 128 == 0),
and ``group_size_for``. A magic ``128`` / ``8`` at a kernel call site
re-hardcodes the rule the PR 4 lane-misalignment bug taught us to
centralize — it keeps working right up until someone changes the one
true constant.

  * CB301: literal ``128``/``8`` as a ``block_n`` default or keyword
    argument anywhere in the tree.
  * CB302: literal ``128``/``8`` as the right operand of ``%`` or
    ``//`` inside ``kernels/`` — alignment arithmetic must spell
    ``LANE``/``SUBLANE``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.context import FileContext
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

_LANE_LITERALS = (128, 8)
_HINT = ("use core.streams.LANE / SUBLANE (or spmm_block_n / "
         "group_size_for) instead of the literal")


def _at(ctx: FileContext, node: ast.AST, code: str,
        message: str) -> Finding:
    return Finding(path=ctx.path, line=node.lineno, col=node.col_offset + 1,
                   code=code, message=message, hint=_HINT)


def _is_lane_literal(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant) and type(node.value) is int
            and node.value in _LANE_LITERALS)


@rule("CB301", "magic-block-n",
      "block_n is the SpMM lane width; only streams.LANE may spell it")
def check_block_n_literal(ctx: FileContext) -> Iterator[Finding]:
    for node in ctx.walk():
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            pos = [*a.posonlyargs, *a.args]
            pairs = list(zip(pos[len(pos) - len(a.defaults):], a.defaults))
            pairs += [(p, d) for p, d in zip(a.kwonlyargs, a.kw_defaults)
                      if d is not None]
            for p, default in pairs:
                if p.arg == "block_n" and _is_lane_literal(default):
                    yield _at(ctx, default, "CB301",
                              f"magic literal {default.value} as block_n "
                              f"default in {node.name}")
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "block_n" and _is_lane_literal(kw.value):
                    yield _at(ctx, kw.value, "CB301",
                              f"magic literal {kw.value.value} passed as "
                              "block_n=")


@rule("CB302", "kernel-magic-literal",
      "alignment arithmetic in kernels/ must use LANE/SUBLANE")
def check_kernel_modulo_literal(ctx: FileContext) -> Iterator[Finding]:
    if "kernels/" not in ctx.path:
        return
    for node in ctx.walk():
        if isinstance(node, ast.BinOp) and \
                isinstance(node.op, (ast.Mod, ast.FloorDiv)) and \
                _is_lane_literal(node.right) and \
                not isinstance(node.left, ast.Constant):
            op = "%" if isinstance(node.op, ast.Mod) else "//"
            yield _at(ctx, node, "CB302",
                      f"alignment arithmetic `{op} {node.right.value}` "
                      "with a magic literal")
