"""Rule modules — importing this package registers every checker.

One module per invariant family; the stable code blocks are assigned in
``registry.py``'s docstring and cataloged in ``analysis/README.md``.
"""
from repro.analysis.rules import (  # noqa: F401
    align,
    compat_only,
    errtax,
    metric_names,
    trace_safety,
)
