"""CB2xx — trace safety (the PR 8 "instrumentation outside jit" contract).

Obs recording, printing, host RNG, and wall-clock reads are Python-level
side effects: inside a jitted entry (``_*_jit``, ``@jax.jit``) or a
Pallas kernel body they fire once per *trace*, not per call — silently
wrong accounting at best, a retrace-dependent heisenbug at worst.
Likewise ``.item()`` / ``float()`` on a traced array is a concrete
error under jit, and a dict/list passed for a static argument defeats
the jit cache with an unhashable-static TypeError.

Scope is computed by :meth:`FileContext.trace_scopes` — only function
bodies that actually run under tracing are scanned, so host-side CLI
``print``\\ s and the deliberate trace-*time* counters in the solver
builders (which are not jit entries themselves) never false-positive.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.context import FileContext, dotted_name, root_name
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

# Call chains that are host-side side effects or nondeterminism sources.
_HOST_RNG_PREFIXES = ("np.random.", "numpy.random.", "random.", "secrets.")
_CLOCK_CALLS = (
    "time.time", "time.perf_counter", "time.monotonic", "time.sleep",
    "datetime.now", "datetime.datetime.now", "datetime.utcnow",
)

_MUTABLE_LITERALS = (
    ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp,
    ast.GeneratorExp,
)


def _at(ctx: FileContext, node: ast.AST, code: str, message: str,
        hint: str) -> Finding:
    return Finding(path=ctx.path, line=node.lineno, col=node.col_offset + 1,
                   code=code, message=message, hint=hint)


def _traced_params(scope) -> frozenset[str]:
    """Parameter names that hold tracers (everything not jit-static)."""
    a = scope.node.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    return frozenset(names) - scope.static_names


@rule("CB201", "trace-side-effect",
      "obs/print/host-RNG/clock calls must stay outside jitted code")
def check_trace_side_effects(ctx: FileContext) -> Iterator[Finding]:
    for scope in ctx.trace_scopes:
        where = f"{scope.kind} {scope.node.name!r}"
        for node in scope.walk():
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                yield _at(ctx, node, "CB201",
                          f"print() inside {where}",
                          "log from the host-side shim, not traced code")
                continue
            if root_name(node.func) == "obs":
                yield _at(ctx, node, "CB201",
                          f"obs call inside {where}",
                          "record metrics/spans in the host-side shim "
                          "(see kernels/ops.py pattern)")
                continue
            callee = dotted_name(node.func)
            if callee is None:
                continue
            if callee.startswith(_HOST_RNG_PREFIXES):
                yield _at(ctx, node, "CB201",
                          f"host RNG {callee} inside {where}",
                          "thread jax.random keys through the trace")
            elif callee in _CLOCK_CALLS:
                yield _at(ctx, node, "CB201",
                          f"wall-clock read {callee} inside {where}",
                          "time at the host call site; traces must be "
                          "value-deterministic")


@rule("CB202", "trace-host-sync",
      ".item()/float() on a tracer breaks (or silently constant-folds) jit")
def check_host_sync(ctx: FileContext) -> Iterator[Finding]:
    for scope in ctx.trace_scopes:
        where = f"{scope.kind} {scope.node.name!r}"
        traced = _traced_params(scope)
        for node in scope.walk():
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                yield _at(ctx, node, "CB202",
                          f".item() inside {where}",
                          "return the array; materialize on the host side")
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in ("float", "int", "bool") and \
                    len(node.args) == 1 and \
                    isinstance(node.args[0], ast.Name) and \
                    node.args[0].id in traced:
                yield _at(ctx, node, "CB202",
                          f"{node.func.id}() on traced argument "
                          f"{node.args[0].id!r} inside {where}",
                          "keep it an array, or declare the argument "
                          "static")


@rule("CB203", "static-unhashable",
      "dict/list values for static_argnums/static_argnames are unhashable")
def check_static_unhashable(ctx: FileContext) -> Iterator[Finding]:
    # (a) call sites of same-module jit wrappers passing mutable literals
    # in static slots;
    wrappers = ctx.jit_wrappers
    for node in ctx.walk():
        if not (isinstance(node, ast.Call) and
                isinstance(node.func, ast.Name) and
                node.func.id in wrappers):
            continue
        w = wrappers[node.func.id]
        for kw in node.keywords:
            if kw.arg in w.static_names and \
                    isinstance(kw.value, _MUTABLE_LITERALS):
                yield _at(ctx, node, "CB203",
                          f"unhashable literal for static argument "
                          f"{kw.arg!r} of {w.name}",
                          "pass a tuple / frozen value; statics must hash")
        for i, arg in enumerate(node.args):
            if i in w.static_nums and isinstance(arg, _MUTABLE_LITERALS):
                yield _at(ctx, node, "CB203",
                          f"unhashable literal in static position {i} "
                          f"of {w.name}",
                          "pass a tuple / frozen value; statics must hash")
    # (b) a jit entry whose static-named parameter defaults to a mutable
    # literal — the default is what most call sites will hit.
    for scope in ctx.trace_scopes:
        if not scope.static_names:
            continue
        a = scope.node.args
        pos = [*a.posonlyargs, *a.args]
        for p, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
            if p.arg in scope.static_names and \
                    isinstance(default, _MUTABLE_LITERALS):
                yield _at(ctx, default, "CB203",
                          f"static parameter {p.arg!r} of "
                          f"{scope.node.name} defaults to an unhashable "
                          "literal",
                          "default to None or a tuple")
        for p, default in zip(a.kwonlyargs, a.kw_defaults):
            if default is not None and p.arg in scope.static_names and \
                    isinstance(default, _MUTABLE_LITERALS):
                yield _at(ctx, default, "CB203",
                          f"static parameter {p.arg!r} of "
                          f"{scope.node.name} defaults to an unhashable "
                          "literal",
                          "default to None or a tuple")
