"""CB5xx — obs metric naming convention (PR 8).

Registry instruments are named ``repro.<subsystem>.<metric>`` (see
``src/repro/obs/README.md``); off-convention names fragment the
snapshot and dodge the catalog. Checked at every literal instrument
creation site: ``obs.counter("...")`` / ``registry().gauge("...")`` /
``reg.histogram("...")`` and the ``metric=`` of ``MirroredCounter``.
f-strings are validated on their static prefix, which must at least pin
the subsystem (``f"repro.serving.{name}"`` passes, ``f"{ns}.x"`` does
not).
"""
from __future__ import annotations

import ast
import re
from typing import Iterator

from repro.analysis.context import FileContext, dotted_name
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

_NAME_RE = re.compile(r"^repro(\.[a-z0-9_]+){2,}$")
_PREFIX_RE = re.compile(r"^repro\.[a-z0-9_]+\.")
_FACTORIES = ("counter", "gauge", "histogram")
_HINT = "name instruments repro.<subsystem>.<metric> (obs/README.md)"


def _at(ctx: FileContext, node: ast.AST, message: str) -> Finding:
    return Finding(path=ctx.path, line=node.lineno, col=node.col_offset + 1,
                   code="CB501", message=message, hint=_HINT)


def _check_name_node(ctx: FileContext, node: ast.AST) -> Finding | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        if not _NAME_RE.match(node.value):
            return _at(ctx, node,
                       f"instrument name {node.value!r} is off the "
                       "repro.<subsystem>.<metric> convention")
    elif isinstance(node, ast.JoinedStr):
        prefix = ""
        for part in node.values:
            if isinstance(part, ast.Constant):
                prefix += str(part.value)
            else:
                break
        if not _PREFIX_RE.match(prefix):
            return _at(ctx, node,
                       f"f-string instrument name must pin "
                       f"'repro.<subsystem>.' statically (prefix "
                       f"{prefix!r})")
    return None


@rule("CB501", "metric-name",
      "registry instrument names follow repro.<subsystem>.<metric>")
def check_metric_names(ctx: FileContext) -> Iterator[Finding]:
    for node in ctx.walk():
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _FACTORIES and node.args:
            found = _check_name_node(ctx, node.args[0])
            if found is not None:
                yield found
        callee = dotted_name(node.func)
        if callee and callee.rsplit(".", 1)[-1] == "MirroredCounter":
            for kw in node.keywords:
                if kw.arg == "metric" and kw.value is not None:
                    found = _check_name_node(ctx, kw.value)
                    if found is not None:
                        yield found
