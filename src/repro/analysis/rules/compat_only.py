"""CB1xx — the compat-layer-only guardrail (ROADMAP, PR 1).

All JAX-version drift is funneled through ``src/repro/compat.py``; the
rest of the tree must never touch the drifting spellings directly:

  * CB101: ``pltpu.CompilerParams`` / ``pltpu.TPUCompilerParams`` —
    renamed across 0.4.x/0.6; use ``compat.tpu_compiler_params``.
  * CB102: ``pl.pallas_call`` — every TPU call site goes through
    ``compat.pallas_call_tpu`` so ``dimension_semantics``/``interpret``
    handling stays centralized.
  * CB103: ``jax.shard_map`` / ``jax.experimental.shard_map`` — the
    location and the ``check_rep``/``check_vma`` kwarg both drifted;
    use ``compat.shard_map``.
  * CB104: ``axis_types=`` — the kwarg doesn't exist on 0.4.x; use
    ``compat.make_mesh`` / ``compat.mesh_axis_types``.

``compat.py`` itself is exempt — it is the one place these spellings
are supposed to live.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.context import FileContext, dotted_name
from repro.analysis.findings import Finding
from repro.analysis.registry import rule


def _is_compat(ctx: FileContext) -> bool:
    return ctx.path.rsplit("/", 1)[-1] == "compat.py"


def _at(ctx: FileContext, node: ast.AST, code: str, message: str,
        hint: str) -> Finding:
    return Finding(path=ctx.path, line=node.lineno, col=node.col_offset + 1,
                   code=code, message=message, hint=hint)


@rule("CB101", "compat-compiler-params",
      "TPU compiler params are version-drifting; only compat.py names them")
def check_compiler_params(ctx: FileContext) -> Iterator[Finding]:
    if _is_compat(ctx):
        return
    for node in ctx.walk():
        if isinstance(node, ast.Attribute) and \
                node.attr.endswith("CompilerParams"):
            yield _at(ctx, node, "CB101",
                      f"direct {node.attr} use outside compat.py",
                      "build params via repro.compat.tpu_compiler_params")
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name.endswith("CompilerParams"):
                    yield _at(ctx, node, "CB101",
                              f"imports {alias.name} outside compat.py",
                              "build params via "
                              "repro.compat.tpu_compiler_params")


@rule("CB102", "compat-pallas-call",
      "pl.pallas_call call sites live behind compat.pallas_call_tpu")
def check_pallas_call(ctx: FileContext) -> Iterator[Finding]:
    if _is_compat(ctx):
        return
    for node in ctx.walk():
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
            yield _at(ctx, node, "CB102",
                      "direct pl.pallas_call use outside compat.py",
                      "call repro.compat.pallas_call_tpu instead")
        if isinstance(node, ast.ImportFrom) and node.module and \
                "pallas" in node.module:
            for alias in node.names:
                if alias.name == "pallas_call":
                    yield _at(ctx, node, "CB102",
                              "imports pallas_call outside compat.py",
                              "call repro.compat.pallas_call_tpu instead")


@rule("CB103", "compat-shard-map",
      "shard_map's module path and check kwarg drift; use compat.shard_map")
def check_shard_map(ctx: FileContext) -> Iterator[Finding]:
    if _is_compat(ctx):
        return
    for node in ctx.walk():
        if isinstance(node, ast.Attribute) and node.attr == "shard_map" and \
                dotted_name(node) in ("jax.shard_map",
                                      "jax.experimental.shard_map"):
            yield _at(ctx, node, "CB103",
                      f"direct {dotted_name(node)} use outside compat.py",
                      "call repro.compat.shard_map instead")
        if isinstance(node, ast.ImportFrom) and node.module and \
                node.module.startswith("jax") and (
                    "shard_map" in node.module
                    or any(a.name == "shard_map" for a in node.names)):
            yield _at(ctx, node, "CB103",
                      f"imports shard_map from {node.module} "
                      "outside compat.py",
                      "call repro.compat.shard_map instead")


@rule("CB104", "compat-axis-types",
      "axis_types= doesn't exist on JAX 0.4.x; use compat.make_mesh")
def check_axis_types(ctx: FileContext) -> Iterator[Finding]:
    if _is_compat(ctx):
        return
    for node in ctx.walk():
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "axis_types":
                    yield _at(ctx, node, "CB104",
                              "axis_types= kwarg outside compat.py",
                              "use repro.compat.make_mesh / "
                              "mesh_axis_types")
