"""cblint — repo-invariant static analysis for the CB-SpMV tree.

Zero third-party dependencies (stdlib ``ast`` only; the optional obs
hook uses the stdlib-only ``repro.obs``). The rule set encodes the
invariants earlier PRs established by convention:

  ======  ====================  =========================================
  code    name                  invariant
  ======  ====================  =========================================
  CB001   useless-suppression   pragmas must name a rule that fires
  CB002   parse-error           every linted file must parse
  CB101   compat-compiler-...   pltpu CompilerParams only in compat.py
  CB102   compat-pallas-call    pl.pallas_call only in compat.py
  CB103   compat-shard-map      jax shard_map only in compat.py
  CB104   compat-axis-types     axis_types= only in compat.py
  CB201   trace-side-effect     obs/print/RNG/clock outside jitted code
  CB202   trace-host-sync       no .item()/float(tracer) under tracing
  CB203   static-unhashable     jit statics must be hashable
  CB301   magic-block-n         block_n spelled via streams.LANE
  CB302   kernel-magic-literal  %128 / %8 arithmetic via LANE/SUBLANE
  CB401   bare-builtin-raise    library raises use repro.errors types
  CB501   metric-name           instruments named repro.<subsys>.<name>
  ======  ====================  =========================================

Entry points: ``scripts/cblint.py`` (CLI), ``tests/test_lint.py``
(pytest gate, ``lint`` marker), and ``lint_paths`` for embedding (the
bench driver records lint health onto the obs registry through it).
Full catalog with examples: ``src/repro/analysis/README.md``.
"""
from __future__ import annotations

import os

from repro.analysis.baseline import (  # noqa: F401
    load_baseline,
    save_baseline,
    subtract_baseline,
)
from repro.analysis.engine import (  # noqa: F401
    SCHEMA,
    LintResult,
    iter_python_files,
    lint_file,
    lint_paths,
    record_lint_health,
)
from repro.analysis.findings import Finding  # noqa: F401
from repro.analysis.registry import all_rules, known_codes  # noqa: F401

#: The checked-in baseline the repo gate runs against (empty by policy).
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
