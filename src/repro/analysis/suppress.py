"""Inline suppressions: ``# cblint: disable=CB101[,CB301]``.

A suppression comment silences the named codes *on its own line* (the
pragma rides the offending statement, pylint-style). Suppressions are
themselves linted: a pragma naming an unknown code, or one that silences
nothing on that line, is a ``CB001 useless-suppression`` finding — so
stale pragmas can't rot in place after the code they excused is fixed.

``CB001`` itself cannot be inline-disabled (that would make rot
self-excusing); remove the dead pragma instead.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize

# Tolerate flexible spacing; the canonical spelling in docs is
#   "cblint: disable=CB101,CB202" behind a comment hash.
_PRAGMA_RE = re.compile(
    r"#\s*cblint:\s*disable\s*=\s*(?P<codes>[A-Za-z0-9_,\s]+)"
)


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One pragma occurrence: the line it governs and the codes named."""

    line: int
    codes: tuple[str, ...]
    col: int


def parse_suppressions(source: str) -> tuple[Suppression, ...]:
    """Scan ``source`` for pragmas, one :class:`Suppression` per comment.

    Only real COMMENT tokens are considered (``tokenize``, not a text
    scan), so documentation that *mentions* the pragma syntax inside a
    docstring never registers as a suppression.
    """
    out = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA_RE.search(tok.string)
            if not m:
                continue
            codes = tuple(
                c.strip() for c in m.group("codes").split(",") if c.strip()
            )
            out.append(Suppression(line=tok.start[0], codes=codes,
                                   col=tok.start[1] + m.start() + 1))
    except (tokenize.TokenError, SyntaxError):
        # The engine reports unparseable files as CB002; no pragmas.
        return ()
    return tuple(out)
