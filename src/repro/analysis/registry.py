"""Rule registry: stable ``CBxxx`` codes -> checker callables.

Each rule is registered once at import time (``rules/`` modules run the
decorator) and carries the catalog metadata rendered into
``src/repro/analysis/README.md``. Codes are grouped by invariant family:

  * ``CB0xx`` — lint hygiene (useless suppressions, parse errors)
  * ``CB1xx`` — compat-layer-only (ROADMAP standing guardrail)
  * ``CB2xx`` — trace safety (PR 8 "instrumentation outside jit" contract)
  * ``CB3xx`` — kernel lane/sublane alignment (PR 4 lane rule)
  * ``CB4xx`` — error taxonomy (PR 7 typed errors)
  * ``CB5xx`` — obs metric naming convention

A checker is ``(FileContext) -> Iterable[Finding]``; the engine invokes
every registered checker on every file and handles suppression /
baseline subtraction itself, so rules stay pure syntax -> findings.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Iterable

from repro import errors

_CODE_RE = re.compile(r"^CB\d{3}$")


@dataclasses.dataclass(frozen=True)
class Rule:
    """Registered rule: stable code, short name, invariant, checker."""

    code: str
    name: str
    invariant: str
    checker: Callable


_RULES: dict[str, Rule] = {}

# Codes that exist but are emitted by the engine itself rather than a
# per-file checker (they still need catalog entries + suppression
# validity, so they register with ``checker=None``-style no-ops).
ENGINE_CODES = ("CB001", "CB002")


def rule(code: str, name: str, invariant: str):
    """Decorator registering ``fn`` as the checker for ``code``."""

    if not _CODE_RE.match(code):
        raise errors.InvalidArgError(f"bad rule code {code!r} (want CBxxx)")

    def register(fn: Callable) -> Callable:
        if code in _RULES:
            raise errors.InvalidArgError(f"duplicate rule code {code}")
        _RULES[code] = Rule(code=code, name=name, invariant=invariant,
                            checker=fn)
        return fn

    return register


def all_rules() -> tuple[Rule, ...]:
    """Every registered rule, sorted by code (deterministic run order)."""
    _ensure_loaded()
    return tuple(_RULES[c] for c in sorted(_RULES))


def known_codes() -> frozenset[str]:
    """Every valid code: checker rules plus the engine-emitted CB0xx."""
    _ensure_loaded()
    return frozenset(_RULES) | frozenset(ENGINE_CODES)


def get(code: str) -> Rule:
    _ensure_loaded()
    return _RULES[code]


def _ensure_loaded() -> None:
    # Import the rule modules lazily so ``registry`` itself never cycles
    # with them (they import ``rule`` from here).
    from repro.analysis import rules  # noqa: F401
