"""Checked-in baseline: grandfathered findings the gate tolerates.

The baseline is a JSON file (``src/repro/analysis/baseline.json``)
listing findings that predate a rule and are excused *by name* rather
than fixed. Matching is by ``(code, path, message)`` with multiset
semantics — one baseline entry excuses exactly one live finding — and
deliberately ignores line numbers so unrelated edits above a
grandfathered site don't un-excuse it.

Policy (ISSUE 9): the baseline for ``src/repro`` is **empty** — every
real violation was fixed rather than grandfathered — and the gate in
``tests/test_lint.py`` keeps it that way. The mechanism stays because a
future rule may land with violations too risky to fix in the same PR.
"""
from __future__ import annotations

import collections
import json
import os

from repro import errors
from repro.analysis.findings import Finding

SCHEMA = "cblint-baseline/v1"


def load_baseline(path: str) -> list[dict]:
    """Entries from ``path``; a missing file is an empty baseline."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or data.get("schema") != SCHEMA:
        raise errors.SchemaError(
            f"{path}: expected {SCHEMA!r} baseline, got "
            f"{data.get('schema') if isinstance(data, dict) else type(data)}"
        )
    entries = data.get("findings", [])
    for e in entries:
        if not {"code", "path", "message"} <= set(e):
            raise errors.ArtifactError(
                f"{path}: baseline entry missing code/path/message: {e}"
            )
    return entries


def save_baseline(path: str, findings: list[Finding]) -> None:
    """Write ``findings`` as the new baseline (sorted, stable bytes)."""
    payload = {
        "schema": SCHEMA,
        "findings": [f.to_dict() for f in sorted(findings)],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def subtract_baseline(
    findings: list[Finding], entries: list[dict]
) -> tuple[list[Finding], list[dict]]:
    """Split findings into (new, excused-entry list actually used).

    Returns the findings NOT covered by the baseline, plus the subset of
    entries that matched (callers can report stale entries as hygiene).
    """
    budget = collections.Counter(
        (e["code"], e["path"], e["message"]) for e in entries
    )
    fresh: list[Finding] = []
    used: collections.Counter = collections.Counter()
    for f in sorted(findings):
        key = f.baseline_key()
        if budget[key] > 0:
            budget[key] -= 1
            used[key] += 1
        else:
            fresh.append(f)
    used_entries = [
        {"code": c, "path": p, "message": m, "count": n}
        for (c, p, m), n in sorted(used.items())
    ]
    return fresh, used_entries
