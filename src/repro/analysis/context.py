"""Per-file analysis context: parsed AST plus cached scope maps.

``FileContext`` is what every rule checker receives. It owns the parse
(one ``ast.parse`` per file) and lazily computes the semantic maps
several rules share:

  * :meth:`jit_scopes` — function bodies that execute **under JAX
    tracing**: ``_*_jit`` entries (the PR 8 naming contract), functions
    decorated with ``jax.jit`` / ``functools.partial(jax.jit, ...)``,
    and Pallas kernel bodies (``*_kernel`` names or functions passed as
    the kernel argument of ``pallas_call_tpu`` / ``pl.pallas_call``).
    Trace-safety rules (CB2xx) scan only these subtrees, so host-side
    CLI ``print``\\ s and ``obs`` calls never false-positive.
  * :meth:`jit_wrappers` — name -> (static_argnames, static_argnums)
    for jit-wrapped callables defined in the module, used to validate
    call-site static arguments (CB203).

Also home to the small AST helpers (``dotted_name``, ``root_name``)
rules use to match ``pltpu.CompilerParams``-style attribute chains
without each reimplementing the descent.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
from typing import Iterator

from repro.analysis.suppress import Suppression, parse_suppressions

# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a pure Name/Attribute chain, else ``None``."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> str | None:
    """Base ``Name`` id of an attribute/call chain.

    Descends through both attribute access and calls, so
    ``obs.registry().counter("x")`` roots at ``obs`` — which is how the
    trace-safety rule catches registry lookups spelled either way.
    """
    while True:
        if isinstance(node, ast.Attribute):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            return node.id
        else:
            return None


def str_constants(node: ast.AST) -> tuple[str, ...]:
    """String constants inside a tuple/list/set literal (or one string)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return tuple(
            e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)
        )
    return ()


def int_constants(node: ast.AST) -> tuple[int, ...]:
    """Int constants inside a tuple/list literal (or one bare int)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(
            e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, int)
        )
    return ()


def _is_jax_jit(node: ast.AST) -> bool:
    return dotted_name(node) in ("jax.jit", "jit")


def _partial_jit_call(node: ast.AST) -> ast.Call | None:
    """Return the Call if ``node`` is ``[functools.]partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return None
    if dotted_name(node.func) not in ("functools.partial", "partial"):
        return None
    if node.args and _is_jax_jit(node.args[0]):
        return node
    return None


def _jit_call(node: ast.AST) -> ast.Call | None:
    """Return the Call if ``node`` is ``jax.jit(f, ...)``."""
    if isinstance(node, ast.Call) and _is_jax_jit(node.func):
        return node
    return None


def _static_args(call: ast.Call | None) -> tuple[frozenset[str], frozenset[int]]:
    names: frozenset[str] = frozenset()
    nums: frozenset[int] = frozenset()
    if call is not None:
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                names = frozenset(str_constants(kw.value))
            elif kw.arg == "static_argnums":
                nums = frozenset(int_constants(kw.value))
    return names, nums


# ---------------------------------------------------------------------------
# Scope records
# ---------------------------------------------------------------------------

JIT_ENTRY = "jit-entry"
KERNEL_BODY = "kernel-body"


@dataclasses.dataclass(frozen=True)
class TraceScope:
    """One function whose body runs under tracing (or inside a kernel)."""

    node: ast.FunctionDef
    kind: str  # JIT_ENTRY | KERNEL_BODY
    static_names: frozenset[str]

    def walk(self) -> Iterator[ast.AST]:
        """Every node in the body (the def's own decorators excluded)."""
        for stmt in self.node.body:
            yield from ast.walk(stmt)


@dataclasses.dataclass(frozen=True)
class JitWrapper:
    """A jit-wrapped callable reachable by name within the module."""

    name: str
    static_names: frozenset[str]
    static_nums: frozenset[int]
    line: int


# ---------------------------------------------------------------------------
# FileContext
# ---------------------------------------------------------------------------


class FileContext:
    """Everything a rule needs to lint one file."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path          # repo-relative, POSIX separators
        self.source = source
        self.tree = tree
        self.suppressions: tuple[Suppression, ...] = parse_suppressions(source)

    # -- generic traversal ------------------------------------------------

    def walk(self) -> Iterator[ast.AST]:
        return ast.walk(self.tree)

    def functions(self) -> Iterator[ast.FunctionDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node

    # -- trace-scope classification --------------------------------------

    @functools.cached_property
    def _kernel_arg_names(self) -> frozenset[str]:
        """Names passed as the kernel (first) argument of a pallas call."""
        names = set()
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            if callee is None:
                continue
            last = callee.rsplit(".", 1)[-1]
            if last in ("pallas_call_tpu", "pallas_call") and node.args:
                first = node.args[0]
                if isinstance(first, ast.Name):
                    names.add(first.id)
        return frozenset(names)

    @functools.cached_property
    def trace_scopes(self) -> tuple[TraceScope, ...]:
        scopes = []
        for fn in self.functions():
            kind = None
            static_names: frozenset[str] = frozenset()
            for deco in fn.decorator_list:
                call = _partial_jit_call(deco)
                if call is not None or _is_jax_jit(deco) or _jit_call(deco):
                    kind = JIT_ENTRY
                    static_names, _ = _static_args(call or _jit_call(deco))
                    break
            if kind is None and fn.name.startswith("_") and \
                    fn.name.endswith("_jit"):
                kind = JIT_ENTRY
            if kind is None and (fn.name.endswith("_kernel")
                                 or fn.name in self._kernel_arg_names):
                kind = KERNEL_BODY
            if kind is not None:
                scopes.append(TraceScope(node=fn, kind=kind,
                                         static_names=static_names))
        return tuple(scopes)

    # -- jit wrappers (for call-site static-arg validation) ---------------

    @functools.cached_property
    def jit_wrappers(self) -> dict[str, JitWrapper]:
        wrappers: dict[str, JitWrapper] = {}

        def add(name: str, call: ast.Call | None, line: int) -> None:
            names, nums = _static_args(call)
            if names or nums:
                wrappers[name] = JitWrapper(name=name, static_names=names,
                                            static_nums=nums, line=line)

        for node in ast.walk(self.tree):
            # f_jit = jax.jit(f, static_arg...=...)
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                call = _jit_call(node.value)
                if call is not None:
                    add(node.targets[0].id, call, node.lineno)
            # @functools.partial(jax.jit, static_arg...=...) / @jax.jit(...)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for deco in node.decorator_list:
                    call = _partial_jit_call(deco) or _jit_call(deco)
                    if call is not None:
                        add(node.name, call, node.lineno)
        return wrappers
