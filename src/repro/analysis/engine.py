"""Analyzer engine: files -> rules -> suppressions -> baseline -> report.

One pass per file: parse (a syntax error is itself a ``CB002`` finding,
never a crash), run every registered checker, apply inline suppressions
(``# cblint: disable=CBxxx``), manufacture ``CB001 useless-suppression``
findings for pragmas that silence nothing, subtract the checked-in
baseline, and return a :class:`LintResult` whose JSON rendering is
byte-deterministic (sorted findings, sorted keys, no timestamps — two
runs over the same tree must produce identical bytes).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os

from repro.analysis import baseline as baseline_mod
from repro.analysis import registry
from repro.analysis.context import FileContext
from repro.analysis.findings import Finding

SCHEMA = "cblint/v1"

# Engine-emitted codes: never inline-suppressible (a pragma excusing the
# pragma-rot detector would make rot self-excusing, and a parse error
# has no trustworthy line table to suppress against).
_UNSUPPRESSABLE = frozenset(registry.ENGINE_CODES)


@dataclasses.dataclass
class LintResult:
    """Outcome of one analyzer run."""

    findings: list[Finding]          # after suppression + baseline
    files: int
    suppressed: int                  # pragma-silenced finding count
    baseline_used: list[dict]        # baseline entries that matched

    @property
    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.code] = out.get(f.code, 0) + 1
        return dict(sorted(out.items()))

    def to_json(self) -> str:
        payload = {
            "schema": SCHEMA,
            "files": self.files,
            "counts": self.counts,
            "suppressed": self.suppressed,
            "baseline_used": self.baseline_used,
            "findings": [f.to_dict() for f in sorted(self.findings)],
        }
        return json.dumps(payload, indent=1, sort_keys=True)


def iter_python_files(paths: list[str]) -> list[str]:
    """Expand files/directories into a sorted, deduplicated .py list."""
    out: set[str] = set()
    for path in paths:
        if os.path.isdir(path):
            for dirpath, dirnames, filenames in os.walk(path):
                dirnames.sort()
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__",)]
                for name in sorted(filenames):
                    if name.endswith(".py"):
                        out.add(os.path.join(dirpath, name))
        elif path.endswith(".py"):
            out.add(path)
    return sorted(out)


def _rel(path: str, root: str) -> str:
    rel = os.path.relpath(path, root)
    return rel.replace(os.sep, "/")


def lint_file(path: str, root: str) -> tuple[list[Finding], int]:
    """All raw findings for one file plus the pragma-silenced count."""
    rel = _rel(path, root)
    with open(path, "rb") as f:
        try:
            source = f.read().decode("utf-8")
        except UnicodeDecodeError as e:
            return [Finding(path=rel, line=1, col=1, code="CB002",
                            message=f"file is not valid UTF-8: {e.reason}",
                            hint="")], 0
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return [Finding(path=rel, line=int(e.lineno or 1),
                        col=int(e.offset or 1), code="CB002",
                        message=f"syntax error: {e.msg}",
                        hint="")], 0

    ctx = FileContext(rel, source, tree)
    raw: list[Finding] = []
    for rule in registry.all_rules():
        raw.extend(rule.checker(ctx))

    # line -> codes silenced there
    silenced: dict[int, set[str]] = {}
    for s in ctx.suppressions:
        silenced.setdefault(s.line, set()).update(s.codes)

    kept: list[Finding] = []
    fired: dict[int, set[str]] = {}
    n_suppressed = 0
    for f in raw:
        fired.setdefault(f.line, set()).add(f.code)
        if f.code not in _UNSUPPRESSABLE and \
                f.code in silenced.get(f.line, ()):
            n_suppressed += 1
        else:
            kept.append(f)

    known = registry.known_codes()
    for s in ctx.suppressions:
        for code in s.codes:
            if code not in known:
                kept.append(Finding(
                    path=rel, line=s.line, col=s.col, code="CB001",
                    message=f"suppression names unknown rule {code!r}",
                    hint="fix the code or delete the pragma"))
            elif code in _UNSUPPRESSABLE:
                kept.append(Finding(
                    path=rel, line=s.line, col=s.col, code="CB001",
                    message=f"{code} cannot be inline-suppressed",
                    hint="delete the pragma"))
            elif code not in fired.get(s.line, ()):
                kept.append(Finding(
                    path=rel, line=s.line, col=s.col, code="CB001",
                    message=f"useless suppression of {code} "
                            "(nothing fires on this line)",
                    hint="delete the stale pragma"))
    return kept, n_suppressed


def lint_paths(
    paths: list[str],
    *,
    root: str | None = None,
    baseline_path: str | None = None,
    record_obs: bool = False,
) -> LintResult:
    """Lint ``paths`` (files or directories) and return the result.

    ``root`` anchors the repo-relative paths in findings (defaults to
    the current directory). ``baseline_path`` points at a
    ``cblint-baseline/v1`` JSON file; missing means empty.
    ``record_obs=True`` publishes per-rule finding counts to the obs
    registry as ``repro.analysis.findings`` gauges so ``run.py --json``
    snapshots carry lint health.
    """
    root = root or os.getcwd()
    files = iter_python_files(paths)
    findings: list[Finding] = []
    suppressed = 0
    for path in files:
        got, n = lint_file(path, root)
        findings.extend(got)
        suppressed += n

    entries = baseline_mod.load_baseline(baseline_path) \
        if baseline_path else []
    fresh, used = baseline_mod.subtract_baseline(findings, entries)
    result = LintResult(findings=sorted(fresh), files=len(files),
                        suppressed=suppressed, baseline_used=used)
    if record_obs:
        record_lint_health(result)
    return result


def record_lint_health(result: LintResult) -> None:
    """Publish per-rule counts onto the obs registry.

    Gauges, not counters: a lint run reports the *current* state of the
    tree, and re-running must not accumulate. The ``rule="total"``
    series is always set (0 when clean) so snapshots prove the pass ran.
    """
    from repro import obs

    gauge = obs.gauge("repro.analysis.findings")
    gauge.set(len(result.findings), rule="total")
    for code, n in result.counts.items():
        gauge.set(n, rule=code)
    obs.gauge("repro.analysis.files").set(result.files)
