"""Pallas TPU kernel: dense-tile CB-SpMV (paper Alg. 4, TPU-native, batched).

One grid step processes one *super-tile*: ``G`` FMT_DENSE sub-blocks
stacked vertically into a ``(G*B, B)`` value slab, each multiplied by its
own pre-gathered ``(B,)`` slice of x, producing a ``(G, B)`` stack of
partial result tiles. Partials are scatter-added into y by the jit'd
wrapper (ops.cb_spmv) — the deterministic TPU replacement for Alg. 4's
``atomicAdd`` (TPU has no atomics; XLA's scatter-add is deterministic and
the combine is order-independent, so the balanced group schedule is
preserved).

Batching G blocks per step amortizes per-step pipeline/DMA overhead — the
single-block version moved one (B, B) tile per step, far below what one
HBM->VMEM DMA can stream. The per-slot multiplies stay *separate* dots
(unrolled over the static G) because each slot contracts against its own
x slice; the slab still arrives as one contiguous DMA, which is where the
win is. Grid steps write disjoint output rows and never revisit them, so
``dimension_semantics=("parallel",)`` lets Mosaic split the grid across
megacore halves.

x is always pre-gathered through ``*_xidx`` (XLA gather), which folds the
column-aggregation ``restore_cols`` mapping or the trivial ``bcol*B + j``
mapping — Alg. 4's two x-access branches collapse into one path at
preprocessing time. (The old scalar-prefetch variant indexed x by block
column; a super-tile mixes block columns, so pre-gathering is the uniform
contract now.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_call_tpu


def _kernel_batched(tiles_ref, xg_ref, out_ref, *, group_size: int,
                    block_size: int):
    """One super-tile: G unrolled (B, B) @ (B,) matvecs, one output stack."""
    B = block_size
    for g in range(group_size):
        tile = tiles_ref[0, g * B : (g + 1) * B, :]   # (B, B)
        xb = xg_ref[0, g]                             # (B,)
        out_ref[0, g, :] = jnp.dot(
            tile.astype(jnp.float32), xb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_dense_spmv_batched(
    tiles: jax.Array,   # (gd, G*B, B) stacked super-tiles
    xg: jax.Array,      # (gd, G, B) pre-gathered x values per slot
    *,
    interpret: bool = True,
) -> jax.Array:
    """Per-slot partials for every super-tile — (gd, G, B) float32."""
    gd, G, B = xg.shape
    return pallas_call_tpu(
        functools.partial(_kernel_batched, group_size=G, block_size=B),
        grid=(gd,),
        in_specs=[
            pl.BlockSpec((1, G * B, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, G, B), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, B), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gd, G, B), jnp.float32),
        dimension_semantics=("parallel",),
        interpret=interpret,
        name="cb_block_dense_spmv_batched",
    )(tiles, xg)
