"""Pallas TPU kernel: dense-tile CB-SpMV (paper Alg. 4, TPU-native).

One grid step processes one FMT_DENSE sub-block: a (B, B) value tile
multiplied by the B-wide slice of x it touches, producing a (B,) partial
result tile. Partials are scatter-added into y by the jit'd wrapper
(ops.cb_spmv) — the deterministic TPU replacement for Alg. 4's
``atomicAdd`` (TPU has no atomics; XLA's sorted scatter-add is
deterministic and the combine is order-independent, so the paper's
load-balanced slot order is preserved).

Two x-access paths, mirroring Alg. 4's two branches:

  * no column aggregation  -> the x block at ``bcol`` is *scalar-prefetch
    indexed*: the index map reads the prefetched ``bcol`` array so the
    pipeline DMAs exactly the (1, B) slice of x into VMEM — the TPU
    analogue of "preload x into shared memory".
  * column aggregation     -> x was pre-gathered through ``restore_cols``
    (XLA gather) and arrives as the (nd, B) ``xg`` operand — the analogue
    of "load x from global memory via restore_cols".

The warp-shuffle reduction of Alg. 4 becomes a VPU lane reduction inside
``jnp.dot`` — the MXU/VPU native reduction (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_call_tpu


def _kernel_prefetched_x(bcol_ref, tiles_ref, x_ref, out_ref):
    """x block arrives via scalar-prefetch-driven DMA (non-colagg path)."""
    del bcol_ref  # consumed by the index map, not the body
    tile = tiles_ref[0]                       # (B, B)
    xb = x_ref[0]                             # (B,)
    out_ref[0, :] = jnp.dot(
        tile.astype(jnp.float32), xb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _kernel_gathered_x(tiles_ref, xg_ref, out_ref):
    """x arrives pre-gathered per block (column-aggregation path)."""
    tile = tiles_ref[0]
    xb = xg_ref[0]
    out_ref[0, :] = jnp.dot(
        tile.astype(jnp.float32), xb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_dense_spmv_prefetch(
    tiles: jax.Array,      # (nd, B, B)
    bcol: jax.Array,       # (nd,) int32
    x_blocks: jax.Array,   # (nbc, B) — x reshaped into B-wide blocks
    *,
    interpret: bool = True,
) -> jax.Array:
    """Per-block partials, x fetched by scalar-prefetched block index."""
    nd, B, _ = tiles.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda i, bcol: (i, 0, 0)),
            pl.BlockSpec((1, B), lambda i, bcol: (bcol[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i, bcol: (i, 0)),
    )
    return pallas_call_tpu(
        _kernel_prefetched_x,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, B), jnp.float32),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
        name="cb_block_dense_spmv_prefetch",
    )(bcol, tiles, x_blocks)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_dense_spmv_gathered(
    tiles: jax.Array,   # (nd, B, B)
    xg: jax.Array,      # (nd, B) pre-gathered x values
    *,
    interpret: bool = True,
) -> jax.Array:
    """Per-block partials, x pre-gathered (column-aggregation path)."""
    nd, B, _ = tiles.shape
    return pallas_call_tpu(
        _kernel_gathered_x,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nd, B), jnp.float32),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
        name="cb_block_dense_spmv_gathered",
    )(tiles, xg)
