"""Pallas TPU kernel: column-compacted micro-panel CB-SpMV.

FMT_CSR blocks (intermediate sparsity) become dense (B, K) panels after
per-block column compaction — the TPU re-expression of the paper's
block-aware column aggregation (§3.3.1): all-zero columns are dropped at
preprocessing time so every VPU lane that loads data does useful work,
the TPU analogue of the ">= 50% warp utilization" guarantee.

One grid step = one panel: a (B, Kp) dense multiply against the Kp
pre-gathered x values (gathered through ``restore_cols`` by XLA — the
Alg. 3 colagg branch). Partials combine by scatter-add in ops.cb_spmv.

The CSR row_ptr of the portable format is *dissolved* at preprocessing:
rows are materialized into the panel's row axis, so the kernel needs no
row decoding at all — row structure is positional, which is exactly what
a systolic/vector unit wants (no indirection on the critical path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_call_tpu


def _panel_kernel(panel_ref, xg_ref, out_ref):
    panel = panel_ref[0]   # (B, Kp)
    xg = xg_ref[0]         # (Kp,)
    out_ref[0, :] = jnp.dot(
        panel.astype(jnp.float32), xg.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_spmv(
    panels: jax.Array,  # (np_, B, Kp)
    xg: jax.Array,      # (np_, Kp)
    *,
    interpret: bool = True,
) -> jax.Array:
    """Per-panel partial y tiles — (np_, B) float32."""
    np_, B, Kp = panels.shape
    return pallas_call_tpu(
        _panel_kernel,
        grid=(np_,),
        in_specs=[
            pl.BlockSpec((1, B, Kp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Kp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, B), jnp.float32),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
        name="cb_colagg_panel_spmv",
    )(panels, xg)
