"""Pallas TPU kernel: column-compacted micro-panel CB-SpMV (batched).

FMT_CSR blocks (intermediate sparsity) become dense (B, k) panels after
per-block column compaction — the TPU re-expression of the paper's
block-aware column aggregation (§3.3.1): all-zero columns are dropped at
preprocessing time so every VPU lane that loads data does useful work,
the TPU analogue of the ">= 50% warp utilization" guarantee.

One grid step = one *panel group*: many panels lane-packed side by side
into a fused ``(B, W)`` slab. Lane->slot routing is positional — slot =
``lane // SUBLANE`` — because the packer rounds every panel's width to a
SUBLANE multiple (its width bucket) and lays panels at aligned offsets.
A panel wider than one slot simply owns several consecutive slots whose
partials the scatter-add combine reunites (the combine is additive, so
splitting a panel's columns across slots is exact). The whole group then
reduces with

    tmp = slab * xg                 elementwise,   (B, W)
    out = tmp.reshape(B, S, SUBLANE).sum(lanes)    (B, S) -> (S, B)

— a plain strided lane reduction, O(B*W) work with *no* data-dependent
segment contraction, so the batched step costs the same FLOPs as the
panels it fuses on any backend. Batching buys the DMA/step amortization:
one contiguous slab per step instead of one panel per step, and a wide
outlier pads only its own group instead of the global ``Kp``. Grid steps
are independent (scatter-add combine outside), so
``dimension_semantics=("parallel",)`` enables megacore partitioning.

The CSR row_ptr of the portable format is *dissolved* at preprocessing:
rows are materialized into the panel's row axis, so the kernel needs no
row decoding at all — row structure is positional, which is exactly what
a systolic/vector unit wants (no indirection on the critical path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_call_tpu
from repro.core.streams import SUBLANE
from repro import errors


def _panel_kernel_batched(panels_ref, xg_ref, out_ref, *, slots: int):
    slab = panels_ref[0].astype(jnp.float32)   # (B, W)
    xg = xg_ref[0].astype(jnp.float32)         # (W,)
    tmp = slab * xg[None, :]                   # (B, W)
    B = slab.shape[0]
    out = tmp.reshape(B, slots, SUBLANE).sum(axis=2)   # (B, S)
    out_ref[0] = out.T


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_spmv_batched(
    panels: jax.Array,  # (gp, B, W) lane-packed panel groups, W % SUBLANE == 0
    xg: jax.Array,      # (gp, W) pre-gathered x values
    *,
    interpret: bool = True,
) -> jax.Array:
    """Per-slot partial y tiles — (gp, W // SUBLANE, B) float32."""
    gp, B, W = panels.shape
    if W % SUBLANE:
        raise errors.InvalidArgError(f"packed width {W} not a multiple of {SUBLANE}")
    slots = W // SUBLANE
    return pallas_call_tpu(
        functools.partial(_panel_kernel_batched, slots=slots),
        grid=(gp,),
        in_specs=[
            pl.BlockSpec((1, B, W), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, slots, B), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, slots, B), jnp.float32),
        dimension_semantics=("parallel",),
        interpret=interpret,
        name="cb_colagg_panel_spmv_batched",
    )(panels, xg)
