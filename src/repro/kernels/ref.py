"""Pure-jnp oracles for every Pallas kernel (stream-level contracts).

Each function mirrors a kernel's exact input contract so tests can sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle. These are *also*
the portable fallback implementations used on CPU backends and inside the
dry-run lowering (`impl="reference"`), so they are written to be
XLA-efficient (vectorized, scatter-add combine), not just correct.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aggregation import coord_bits
from repro.core.streams import (
    SpMVStreams, SuperBlockStreams, SuperTileStream, TileStream,
)


def _acc_dtype(*dts) -> jnp.dtype:
    return jnp.result_type(*dts, jnp.float32)


# ---------------------------------------------------------------------------
# SpMV stream oracles
# ---------------------------------------------------------------------------

def block_dense_spmv(tiles: jax.Array, brow: jax.Array, xg: jax.Array,
                     mb: int) -> jax.Array:
    """y_blocks = scatter_add_i( tiles[i] @ xg[i] ) — (mb, B)."""
    acc = _acc_dtype(tiles.dtype, xg.dtype)
    part = jnp.einsum("brc,bc->br", tiles.astype(acc), xg.astype(acc))
    return jnp.zeros((mb, tiles.shape[1]), acc).at[brow].add(part)


def panel_spmv(panels: jax.Array, brow: jax.Array, xg: jax.Array,
               mb: int) -> jax.Array:
    """Column-compacted micro-panel SpMV: panels (np, B, K), xg (np, K)."""
    acc = _acc_dtype(panels.dtype, xg.dtype)
    part = jnp.einsum("brk,bk->br", panels.astype(acc), xg.astype(acc))
    return jnp.zeros((mb, panels.shape[1]), acc).at[brow].add(part)


def coo_spmv(codes: jax.Array, vals: jax.Array, brow: jax.Array,
             xg: jax.Array, mb: int, block_size: int) -> jax.Array:
    """Element-list SpMV with the paper's packed coords (Alg. 3 semantics).

    codes/vals/xg: (nc, E); padding has vals == 0. Decode
    ``row = code & ((1 << bits) - 1)`` (Alg. 3's ``& 15`` generalized —
    a full bit mask, since ``B - 1`` has holes for non-power-of-two B)
    and scatter-add products into the block-local row.
    """
    acc = _acc_dtype(vals.dtype, xg.dtype)
    B = block_size
    bits = coord_bits(B)
    rows = codes & ((1 << bits) - 1)
    prod = vals.astype(acc) * xg.astype(acc)
    # one-hot scatter within each block, then scatter blocks into y
    onehot = (rows[:, :, None] == jnp.arange(B, dtype=codes.dtype)).astype(acc)
    part = jnp.einsum("be,ber->br", prod, onehot)
    return jnp.zeros((mb, B), acc).at[brow].add(part)


def cb_spmv(streams: SpMVStreams, x: jax.Array) -> jax.Array:
    """Full CB-SpMV over the three streams — the ops.py contract oracle."""
    acc = _acc_dtype(streams.dense_tiles.dtype, x.dtype)
    mb, B = streams.mb, streams.block_size
    y = jnp.zeros((mb, B), acc)
    if streams.num_dense:
        y += block_dense_spmv(streams.dense_tiles, streams.dense_brow,
                              x[streams.dense_xidx], mb)
    if streams.num_panel:
        y += panel_spmv(streams.panel_vals, streams.panel_brow,
                        x[streams.panel_xidx], mb)
    if streams.num_coo:
        y += coo_spmv(streams.coo_codes, streams.coo_vals, streams.coo_brow,
                      x[streams.coo_xidx], mb, B)
    return y.reshape(-1)[: streams.m]


# ---------------------------------------------------------------------------
# Super-block (batched) stream oracle
# ---------------------------------------------------------------------------

def super_spmv(s: SuperBlockStreams, x: jax.Array) -> jax.Array:
    """CB-SpMV over packed super-block streams — the batched ops contract.

    Mirror of the batched kernels' math: slot routing is positional
    (slot = lane // SUBLANE), so splitting a fused payload into per-slot
    partials is a strided reshape-sum — O(payload) work on any backend,
    no data-dependent segment contraction. Empty slots carry zero
    payload and brow 0, so they add exact zeros.
    """
    from repro.core.streams import SUBLANE

    B, mb = s.block_size, s.mb
    acc = _acc_dtype(s.panel_vals.dtype, x.dtype)
    parts, brows = [], []
    if s.num_dense_groups:
        gd, Gd = s.dense_brow.shape
        tiles = s.dense_tiles.reshape(gd, Gd, B, B).astype(acc)
        xg = x[s.dense_xidx].astype(acc)                      # (gd, Gd, B)
        part = jnp.einsum("gsrc,gsc->gsr", tiles, xg)
        parts.append(part.reshape(-1, B))
        brows.append(s.dense_brow.reshape(-1))
    if s.num_panel_groups:
        gp, W = s.panel_xidx.shape
        S = W // SUBLANE
        xg = x[s.panel_xidx].astype(acc).reshape(gp, S, SUBLANE)
        vals = s.panel_vals.astype(acc).reshape(gp, B, S, SUBLANE)
        part = jnp.einsum("grsk,gsk->gsr", vals, xg)
        parts.append(part.reshape(-1, B))
        brows.append(s.panel_brow.reshape(-1))
    if s.num_coo_groups:
        gc, W = s.coo_codes.shape
        S = W // SUBLANE
        bits = coord_bits(B)
        rows = s.coo_codes & ((1 << bits) - 1)
        prod = (s.coo_vals.astype(acc)
                * x[s.coo_xidx].astype(acc)).reshape(gc, S, SUBLANE)
        onehot = (rows.reshape(gc, S, SUBLANE)[..., None]
                  == jnp.arange(B, dtype=rows.dtype)).astype(acc)
        part = jnp.einsum("gsk,gskr->gsr", prod, onehot)
        parts.append(part.reshape(-1, B))
        brows.append(s.coo_brow.reshape(-1))
    y = jnp.zeros((mb, B), acc)
    if parts:
        y = y.at[jnp.concatenate(brows)].add(jnp.concatenate(parts))
    return y.reshape(-1)[: s.m]


# ---------------------------------------------------------------------------
# SpMM tile-stream oracle
# ---------------------------------------------------------------------------

def cb_spmm(stream: TileStream, X: jax.Array) -> jax.Array:
    """Y = A @ X with A as a block-dense tile stream; X is (n, N)."""
    B, mb = stream.block_size, stream.mb
    acc = _acc_dtype(stream.tiles.dtype, X.dtype)
    n_pad = stream.nb * B
    Xp = jnp.pad(X.astype(acc), ((0, n_pad - X.shape[0]), (0, 0)))
    Xb = Xp.reshape(stream.nb, B, X.shape[1])
    part = jnp.einsum("trc,tcn->trn", stream.tiles.astype(acc), Xb[stream.bcol])
    Y = jnp.zeros((mb, B, X.shape[1]), acc).at[stream.brow].add(part)
    return Y.reshape(mb * B, X.shape[1])[: stream.m]


def super_spmm(s: SuperTileStream, X: jax.Array) -> jax.Array:
    """CB-SpMM over packed super-tile groups — the batched ops contract.

    Mirror of the batched kernel's math: each group slot is an
    independent (B, B) @ (B, N) product routed by the ``brow``/``bcol``
    slot maps; empty slots hold zero tiles, so they add exact zeros.
    ``cb_spmm`` above stays the *unbatched* oracle — it never sees the
    packed layout, so batched results are always checked against math
    that never touched the batching code.
    """
    B, mb = s.block_size, s.mb
    gt, Gt = s.brow.shape
    acc = _acc_dtype(s.tiles.dtype, X.dtype)
    n_pad = s.nb * B
    Xp = jnp.pad(X.astype(acc), ((0, n_pad - X.shape[0]), (0, 0)))
    Xb = Xp.reshape(s.nb, B, X.shape[1])
    tiles = s.tiles.reshape(gt * Gt, B, B).astype(acc)
    part = jnp.einsum("trc,tcn->trn", tiles, Xb[s.bcol.reshape(-1)])
    Y = jnp.zeros((mb, B, X.shape[1]), acc).at[s.brow.reshape(-1)].add(part)
    return Y.reshape(mb * B, X.shape[1])[: s.m]


def cb_spmm_dense_equiv(stream: TileStream) -> jax.Array:
    """Densify the tile stream (test utility)."""
    B = stream.block_size
    A = jnp.zeros((stream.mb * B, stream.nb * B), stream.tiles.dtype)
    for i in range(stream.num_tiles):
        r0 = int(stream.brow[i]) * B
        c0 = int(stream.bcol[i]) * B
        A = A.at[r0 : r0 + B, c0 : c0 + B].add(stream.tiles[i])
    return A[: stream.m, : stream.n]
