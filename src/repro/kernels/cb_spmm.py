"""Pallas TPU kernel: batched CB-SpMM — block-sparse weights x dense acts.

The training/prefill path of ``CBSparseLinear`` and the solver subsystem's
multi-RHS ``matmat``: Y = A @ X with A a super-tile stream (``Gt`` B x B
weight tiles stacked into one ``(Gt*B, B)`` slab per grid step) and X
dense (n, N). SpMV (decode) is memory-bound, SpMM is compute-bound, so
the adaptation goal flips from locality to MXU occupancy; batching many
tiles per step amortizes per-step pipeline/DMA overhead exactly like the
SpMV super-block engine — the single-tile version moved one (B, B) tile
per step, far below what one HBM->VMEM DMA can stream.

Group contract (mirrors ``core/streams.SuperTileStream``):

  * grid is ``(num_n_tiles, num_groups)`` with the *group* dimension
    minor; one step consumes one super-tile slab and produces a
    ``(Gt, B, bn)`` stack of per-slot partial output tiles;
  * slot ``g`` contracts sublanes ``[g*B, (g+1)*B)`` of the slab against
    the X tile of block-column ``bcol[i, g]`` — an unrolled MXU dot per
    slot, because each slot owns its own activation tile. The slab still
    arrives as ONE contiguous DMA, which is where the win is;
  * X tiles are DMA'd per slot through the scalar-prefetched ``bcol``
    slot map — the virtual-pointer idea (data location resolved from
    prefetched metadata, payload fetched with a sequential DMA) mapped
    onto Pallas's pipeline. Empty slots carry ``bcol`` 0 and a zero
    tile, so they fetch X block 0 and contribute exact zeros;
  * every output cell is written exactly once (no revisiting, no
    accumulation order), so BOTH grid dimensions are ``"parallel"`` —
    Mosaic may split steps across megacore halves freely. The per-slot
    partials are scatter-added into y by the jit'd wrapper
    (``ops.cb_spmm``) with ONE fused combine over ``brow`` — the
    deterministic TPU replacement for atomicAdd, shared with the SpMV
    engine.

The activation tile width ``block_n`` must be a LANE (128) multiple —
``core/streams.spmm_block_n`` is the one place that rounding lives; this
kernel only asserts the invariant it established.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_call_tpu
from repro.core.streams import LANE
from repro import errors


def _spmm_group_kernel(bcol_ref, tiles_ref, *refs, group_size: int,
                       block_size: int):
    """One group: a (Gt, B, B) x (Gt, B, bn) batched MXU dot, one stack."""
    del bcol_ref  # consumed by the per-slot X index maps
    B, Gt = block_size, group_size
    out_ref = refs[-1]
    x_refs = refs[:-1]
    tiles = tiles_ref[0].reshape(Gt, B, B).astype(jnp.float32)
    xs = jnp.concatenate(
        [x_refs[g][0][None] for g in range(Gt)]
    ).astype(jnp.float32)                              # (Gt, B, bn)
    out_ref[0] = jax.lax.dot_general(
        tiles, xs,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def super_tile_spmm(
    tiles: jax.Array,   # (gt, Gt*B, B) stacked super-tiles
    bcol: jax.Array,    # (gt, Gt) int32 slot -> X block-row
    Xb: jax.Array,      # (nb, B, Npad) — X reshaped into B-row blocks
    *,
    block_n: int = LANE,
    interpret: bool = True,
) -> jax.Array:
    """Per-slot partial Y tiles — (gt, Gt, B, Npad) float32, ONE pallas_call.

    ``Npad`` (= ``Xb.shape[-1]``) must divide by ``block_n`` and
    ``block_n`` by LANE — both are arranged by ``ops.cb_spmm`` through
    ``spmm_block_n``; violations here are caller bugs, not data bugs.
    """
    gt, GtB, B = tiles.shape
    Gt = GtB // B
    _, _, Npad = Xb.shape
    if block_n % LANE:
        raise errors.InvalidArgError(f"block_n {block_n} not a multiple of {LANE} lanes")
    if Npad % block_n:
        raise errors.InvalidArgError(f"padded width {Npad} not a multiple of {block_n}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Npad // block_n, gt),
        in_specs=[
            pl.BlockSpec((1, Gt * B, B), lambda j, i, bcol: (i, 0, 0)),
            *[
                pl.BlockSpec(
                    (1, B, block_n),
                    lambda j, i, bcol, g=g: (bcol[i, g], 0, j),
                )
                for g in range(Gt)
            ],
        ],
        out_specs=pl.BlockSpec(
            (1, Gt, B, block_n), lambda j, i, bcol: (i, 0, 0, j)
        ),
    )
    return pallas_call_tpu(
        functools.partial(_spmm_group_kernel, group_size=Gt, block_size=B),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((gt, Gt, B, Npad), jnp.float32),
        dimension_semantics=("parallel", "parallel"),
        interpret=interpret,
        name="cb_super_tile_spmm",
    )(bcol, tiles, *([Xb] * Gt))
