"""Pallas TPU kernel: CB-SpMM — block-sparse weights x dense activations.

The training/prefill path of ``CBSparseLinear``: Y = A @ X with A a
block-dense tile stream (B x B tiles at (brow, bcol)) and X dense (n, N).
This is where the MXU earns its keep; SpMV (decode) is memory-bound, SpMM
is compute-bound, so the adaptation goal flips from locality to MXU
occupancy (DESIGN.md §2).

Grid is (num_n_tiles, num_blocks) with the *block* dimension minor, so for
a fixed activation tile j the kernel sweeps all weight tiles in
block-row-major order. Output tile (brow[i], j) is therefore revisited in
consecutive grid steps and accumulated in VMEM — the deterministic
replacement for atomicAdd. The stream guarantees every block row owns at
least one tile (build_tile_stream pads coverage), so every output tile is
initialized.

Scalar-prefetched ``brow``/``bcol`` drive the index maps: X tiles are
DMA'd by ``bcol[i]`` and output tiles by ``brow[i]`` — the virtual-pointer
idea (data location resolved from prefetched metadata, payload fetched
with one sequential DMA) mapped onto Pallas's pipeline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_call_tpu


def _spmm_kernel(brow_ref, bcol_ref, tiles_ref, x_ref, out_ref):
    del bcol_ref  # consumed by the X index map
    i = pl.program_id(1)
    # First visit of this output tile <=> first block of a block-row run.
    is_first = (i == 0) | (brow_ref[i] != brow_ref[jnp.maximum(i - 1, 0)])

    @pl.when(is_first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = tiles_ref[0].astype(jnp.float32)   # (B, B)
    xt = x_ref[0].astype(jnp.float32)         # (B, block_n)
    out_ref[0] += jnp.dot(tile, xt, preferred_element_type=jnp.float32)


def tile_spmm(
    tiles: jax.Array,   # (nt, B, B) — block-row-major order, full row coverage
    brow: jax.Array,    # (nt,) int32 ascending
    bcol: jax.Array,    # (nt,) int32
    Xb: jax.Array,      # (nb, B, N) — X reshaped into B-row blocks
    mb: int,
    *,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Y_blocks = A @ X as (mb, B, N) float32. N must divide by block_n."""
    nt, B, _ = tiles.shape
    _, _, N = Xb.shape
    assert N % block_n == 0, (N, block_n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N // block_n, nt),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda j, i, brow, bcol: (i, 0, 0)),
            pl.BlockSpec(
                (1, B, block_n), lambda j, i, brow, bcol: (bcol[i], 0, j)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, B, block_n), lambda j, i, brow, bcol: (brow[i], 0, j)
        ),
    )
    return pallas_call_tpu(
        _spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mb, B, N), jnp.float32),
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
        name="cb_tile_spmm",
    )(brow, bcol, tiles, Xb)
