"""jit'd public entry points for the CB-SpMV / CB-SpMM kernels.

``cb_spmv(streams, x)`` dispatches each per-format stream to its Pallas
kernel (the paper's "segregated per-format streams" replacement for
intra-kernel branching — TPU cores have no divergence mechanism, uniform
kernels win) and combines partial block results with a single scatter-add.

``impl`` selects between the Pallas kernels ("pallas", interpret=True on
CPU; compiled Mosaic on TPU) and the pure-XLA reference ("reference",
kernels/ref.py) — the reference path is what the multi-pod dry-run lowers,
since Mosaic kernels cannot compile for the CPU stand-in devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.streams import SpMVStreams, TileStream

from . import cb_block_dense, cb_colagg, cb_coo, ref
from . import cb_spmm as _cb_spmm_kernel


def _x_blocks(x: jax.Array, B: int, nbc: int) -> jax.Array:
    """Reshape x into (nbc, B) blocks, zero-padding the ragged tail."""
    pad = nbc * B - x.shape[0]
    return jnp.pad(x, (0, pad)).reshape(nbc, B)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cb_spmv(
    streams: SpMVStreams,
    x: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
) -> jax.Array:
    """y = A @ x over the CB streams. x: (n,) -> y: (m,) float32."""
    if impl == "reference":
        return ref.cb_spmv(streams, x)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    interp = (not _on_tpu()) if interpret is None else interpret

    B, mb = streams.block_size, streams.mb
    y = jnp.zeros((mb, B), jnp.float32)

    if streams.num_dense:
        if streams.colagg_applied:
            part = cb_block_dense.block_dense_spmv_gathered(
                streams.dense_tiles, x[streams.dense_xidx], interpret=interp
            )
        else:
            nbc = -(-streams.n // B)
            part = cb_block_dense.block_dense_spmv_prefetch(
                streams.dense_tiles, streams.dense_bcol,
                _x_blocks(x, B, nbc), interpret=interp,
            )
        y = y.at[streams.dense_brow].add(part)

    if streams.num_panel:
        part = cb_colagg.panel_spmv(
            streams.panel_vals, x[streams.panel_xidx], interpret=interp
        )
        y = y.at[streams.panel_brow].add(part)

    if streams.num_coo:
        # The element stream always uses pre-gathered x: its xidx already
        # folds colagg restore (or the trivial mapping), and per-element
        # gathers are XLA's job either way (Alg. 3's d_x branch).
        part = cb_coo.coo_spmv_gathered(
            streams.coo_codes, streams.coo_vals, x[streams.coo_xidx],
            block_size=B, interpret=interp,
        )
        y = y.at[streams.coo_brow].add(part)

    return y.reshape(-1)[: streams.m]


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "block_n"))
def cb_spmm(
    stream: TileStream,
    X: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    block_n: int = 128,
) -> jax.Array:
    """Y = A @ X with A a block-dense tile stream. X: (n, N) -> Y: (m, N)."""
    if impl == "reference":
        return ref.cb_spmm(stream, X)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    interp = (not _on_tpu()) if interpret is None else interpret

    B, mb, nb = stream.block_size, stream.mb, stream.nb
    n, N = X.shape
    bn = min(block_n, max(8, N))
    Npad = -(-N // bn) * bn
    Xp = jnp.pad(X, ((0, nb * B - n), (0, Npad - N)))
    Xb = Xp.reshape(nb, B, Npad)
    Yb = _cb_spmm_kernel.tile_spmm(
        stream.tiles, stream.brow, stream.bcol, Xb, mb,
        block_n=bn, interpret=interp,
    )
    return Yb.reshape(mb * B, Npad)[: stream.m, :N]
