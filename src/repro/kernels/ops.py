"""jit'd public entry points for the CB-SpMV / CB-SpMM kernels.

``cb_spmv(streams, x)`` runs the batched super-block execution engine:
each per-format stream becomes at most ONE ``pallas_call`` whose grid
covers every super-block group of that format (the paper's "segregated
per-format streams" replacement for intra-kernel branching — TPU cores
have no divergence mechanism, uniform kernels win), and all per-format
partials are combined by a SINGLE fused scatter-add into the ``(mb, B)``
result — one deterministic combine instead of three.

``streams`` may be either

  * ``SuperBlockStreams`` (from ``build_super_streams``) — blocks already
    packed into width-bucketed, load-balanced groups at preprocessing
    time; ``group_size`` is baked into the stream, or
  * ``SpMVStreams`` (from ``build_streams``) — the one-block-per-row
    layout. ``group_size=G`` then regroups it on the fly with pure
    reshapes (jit-safe, no host round-trip): G rows fuse into one grid
    step. On-the-fly regrouping keeps each format's global padding width
    (only the host-side packer can shrink it), but it already buys the
    batching win: 1/G as many grid steps, G times the payload per DMA.

``cb_spmm(stream, X)`` applies the same batched contract to the multi-RHS
tile stream: ``SuperTileStream`` (host-packed, nnz-balanced) or
``TileStream`` + ``group_size=`` (jit-side regroup), ONE ``pallas_call``
for the whole stream, one fused scatter-add, and a lane-aligned
activation tile width from ``spmm_block_n``.

``impl`` selects between the Pallas kernels ("pallas", interpret=True on
CPU; compiled Mosaic on TPU) and the pure-XLA reference ("reference",
kernels/ref.py) — the reference path is what the multi-pod dry-run lowers,
since Mosaic kernels cannot compile for the CPU stand-in devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import errors, obs
from repro.core.streams import (
    LANE, SUBLANE, SpMVStreams, SuperBlockStreams, SuperTileStream,
    TileStream, even_group, spmm_block_n,
)

from . import cb_block_dense, cb_colagg, cb_coo, ref
from . import cb_spmm as _cb_spmm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(arr: jax.Array, rows: int) -> jax.Array:
    """Zero-pad axis 0 to ``rows`` (ragged tails regroup as inert slots)."""
    pad = [(0, rows - arr.shape[0])] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pad)


def _slot_brow(brow_blocks: jax.Array, width: int, groups: int) -> jax.Array:
    """Expand per-block rows to per-SUBLANE-slot rows (block-major lanes)."""
    per_block = width // SUBLANE
    if groups == 0 or per_block == 0:
        return jnp.zeros((groups, 0), jnp.int32)
    return jnp.repeat(brow_blocks.reshape(-1), per_block).reshape(groups, -1)


def _regroup(streams: SpMVStreams, G: int) -> SuperBlockStreams:
    """Fuse G one-block rows per super-block row with pure reshapes.

    Padding rows appended to ragged tails carry zero payload and brow 0,
    so they scatter-add exact zeros. The lane order of fused panel/coo
    rows is block-major (member g owns lanes [g*K, (g+1)*K)); since the
    flat stream's K is already a SUBLANE multiple, the per-slot brow
    arrays are just each block's row repeated over its K // SUBLANE
    slots. Each format uses its own evened member count.
    """
    B, mb = streams.block_size, streams.mb

    gd, Gd = even_group(streams.num_dense, G)
    d_tiles = _pad_rows(streams.dense_tiles, gd * Gd).reshape(gd, Gd * B, B)
    d_brow = _pad_rows(streams.dense_brow, gd * Gd).reshape(gd, Gd)
    d_xidx = _pad_rows(streams.dense_xidx, gd * Gd).reshape(gd, Gd, B)

    np_, Kp = streams.panel_vals.shape[0], streams.panel_vals.shape[2]
    gp, Gp = even_group(np_, G)
    p_vals = (
        _pad_rows(streams.panel_vals, gp * Gp)
        .reshape(gp, Gp, B, Kp)
        .transpose(0, 2, 1, 3)
        .reshape(gp, B, Gp * Kp)
    )
    p_xidx = _pad_rows(streams.panel_xidx, gp * Gp).reshape(gp, Gp * Kp)
    p_brow = _slot_brow(_pad_rows(streams.panel_brow, gp * Gp), Kp, gp)

    nc, Ep = streams.coo_codes.shape
    gc, Gc = even_group(nc, G)
    c_codes = _pad_rows(streams.coo_codes, gc * Gc).reshape(gc, Gc * Ep)
    c_vals = _pad_rows(streams.coo_vals, gc * Gc).reshape(gc, Gc * Ep)
    c_xidx = _pad_rows(streams.coo_xidx, gc * Gc).reshape(gc, Gc * Ep)
    c_brow = _slot_brow(_pad_rows(streams.coo_brow, gc * Gc), Ep, gc)

    return SuperBlockStreams(
        block_size=B, m=streams.m, n=streams.n, mb=mb,
        colagg_applied=streams.colagg_applied, group_size=G,
        dense_tiles=d_tiles, dense_brow=d_brow, dense_xidx=d_xidx,
        panel_vals=p_vals, panel_brow=p_brow, panel_xidx=p_xidx,
        coo_codes=c_codes, coo_vals=c_vals, coo_brow=c_brow,
        coo_xidx=c_xidx,
    )


def _super_partials_pallas(s: SuperBlockStreams, x: jax.Array, interp: bool):
    """One pallas_call per present format -> [(partials (t, B), brow (t,))].

    Slot counts are positional: the kernels derive them from the payload
    widths (``W // SUBLANE`` for panel/coo, the brow shape for dense).
    """
    B = s.block_size
    parts = []
    if s.num_dense_groups:
        part = cb_block_dense.block_dense_spmv_batched(
            s.dense_tiles, x[s.dense_xidx], interpret=interp
        )
        parts.append((part.reshape(-1, B), s.dense_brow.reshape(-1)))
    if s.num_panel_groups:
        part = cb_colagg.panel_spmv_batched(
            s.panel_vals, x[s.panel_xidx], interpret=interp,
        )
        parts.append((part.reshape(-1, B), s.panel_brow.reshape(-1)))
    if s.num_coo_groups:
        part = cb_coo.coo_spmv_batched(
            s.coo_codes, s.coo_vals, x[s.coo_xidx],
            block_size=B, interpret=interp,
        )
        parts.append((part.reshape(-1, B), s.coo_brow.reshape(-1)))
    return parts


def _resolve_plan(streams, plan, group_size):
    """Fold an autotune ``Plan`` into the effective ``group_size``.

    Duck-typed (any object with ``block_size``/``group_size``) so this
    module never imports the autotune package. The plan's block size
    must match the streams it is applied to; an explicit conflicting
    ``group_size`` is an error, matching the SuperBlockStreams contract.
    """
    if plan is None:
        return group_size
    if plan.block_size != streams.block_size:
        raise errors.InvalidArgError(
            f"plan was made for block_size={plan.block_size}; "
            f"streams carry block_size={streams.block_size}"
        )
    if group_size is not None and group_size != plan.group_size:
        raise errors.InvalidArgError(
            f"plan chose group_size={plan.group_size}; conflicting "
            f"explicit group_size={group_size}"
        )
    return plan.group_size


# ---------------------------------------------------------------------------
# Launch accounting (repro.obs): the numbers the cost model predicts,
# measured from the streams every call actually dispatches.
# ---------------------------------------------------------------------------

def spmv_launch_stats(
    streams: SpMVStreams | SuperBlockStreams, group_size: int | None = None
) -> dict:
    """Per-format grid steps / padded elements one ``cb_spmv`` call runs.

    Pure shape arithmetic (works on tracers): for a packed
    ``SuperBlockStreams`` the geometry is read off directly; for a flat
    ``SpMVStreams`` + ``group_size`` it replicates ``_regroup``'s
    ``even_group`` padding arithmetic without building anything — tested
    equal to the actually-regrouped stream. ``launches`` counts the
    ``pallas_call``s the batched engine issues: one per non-empty format.
    """
    B = streams.block_size
    if isinstance(streams, SuperBlockStreams):
        G = streams.group_size
        steps = {"dense": streams.num_dense_groups,
                 "panel": streams.num_panel_groups,
                 "coo": streams.num_coo_groups}
        padded = streams.padded_work()
    else:
        G = int(group_size or 1)
        gd, Gd = even_group(streams.num_dense, G)
        gp, Gp = even_group(streams.num_panel, G)
        gc, Gc = even_group(streams.num_coo, G)
        Kp = streams.panel_vals.shape[2]
        Ep = streams.coo_codes.shape[1]
        steps = {"dense": gd, "panel": gp, "coo": gc}
        padded = {"dense": gd * Gd * B * B, "panel": gp * B * Gp * Kp,
                  "coo": gc * Gc * Ep}
    steps = {k: int(v) for k, v in steps.items()}
    padded = {k: int(v) for k, v in padded.items()}
    return {
        "group_size": int(G),
        "steps": steps,
        "padded": padded,
        "launches": {k: int(steps[k] > 0) for k in steps},
        "steps_total": sum(steps.values()),
        "padded_total": sum(padded.values()),
    }


def spmm_launch_stats(
    stream: TileStream | SuperTileStream,
    group_size: int | None = None,
    *,
    n_cols: int | None = None,
    block_n: int = LANE,
) -> dict:
    """``cb_spmm``'s analogue of :func:`spmv_launch_stats`.

    ``steps`` is the full grid size ``tile_groups * n_tiles_of_X`` when
    the activation width is known (``n_cols``), else the weight-stream
    group count alone.
    """
    B = stream.block_size
    if isinstance(stream, SuperTileStream):
        G = stream.group_size
        gt, Gt = stream.num_groups, stream.slots
    else:
        G = int(group_size or 1)
        gt, Gt = even_group(stream.num_tiles, G)
    padded = int(gt * Gt * B * B)
    steps = int(gt)
    if n_cols is not None and gt:
        bn = spmm_block_n(int(n_cols), block_n)
        steps = gt * (-(-int(n_cols) // bn))
    return {
        "group_size": int(G),
        "steps": {"tiles": steps},
        "padded": {"tiles": padded},
        "launches": {"tiles": int(gt > 0)},
        "steps_total": steps,
        "padded_total": padded,
    }


def _record_call(entry: str, stats: dict, impl: str, plan) -> None:
    """Emit one call's launch accounting to the default registry.

    Runs outside jitted code — under an outer ``jax.jit`` this is a
    trace-time side effect, so counts are per *logical* invocation.
    Only the Pallas engine dispatches kernels; reference calls count
    calls alone.
    """
    reg = obs.registry()
    reg.counter(f"repro.ops.{entry}.calls").inc(impl=impl)
    if impl != "pallas":
        return
    launches = reg.counter(f"repro.ops.{entry}.launches")
    steps = reg.counter(f"repro.ops.{entry}.steps")
    padded = reg.counter(f"repro.ops.{entry}.padded_elems")
    for fmt, n in stats["steps"].items():
        if n:
            launches.inc(stats["launches"][fmt], format=fmt)
            steps.inc(n, format=fmt)
            padded.inc(stats["padded"][fmt], format=fmt)
    reg.gauge(f"repro.ops.{entry}.group_size").set(stats["group_size"])
    if plan is not None and entry in ("spmv", "spmv_into"):
        # measured-vs-predicted per plan: the raw material for online
        # calibration of the cost model (ROADMAP) — both sides accumulate
        # once per call, so their ratio is the per-call fidelity.
        label = plan.structure_hash[:12]
        exec_padded = reg.counter("repro.autotune.exec.padded_elems")
        exec_steps = reg.counter("repro.autotune.exec.steps")
        reg.counter("repro.autotune.exec.calls").inc(plan=label)
        exec_padded.inc(stats["padded_total"], plan=label, kind="measured")
        exec_padded.inc(plan.predicted_padded_elems, plan=label,
                        kind="predicted")
        exec_steps.inc(stats["steps_total"], plan=label, kind="measured")
        exec_steps.inc(plan.predicted_steps, plan=label, kind="predicted")


@functools.partial(
    jax.jit, static_argnames=("impl", "interpret", "group_size", "plan")
)
def _cb_spmv_jit(
    streams: SpMVStreams | SuperBlockStreams,
    x: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    group_size: int | None = None,
    plan=None,
) -> jax.Array:
    group_size = _resolve_plan(streams, plan, group_size)
    _check_group_size(streams, group_size)

    if impl == "reference":
        if isinstance(streams, SuperBlockStreams):
            return ref.super_spmv(streams, x)
        return ref.cb_spmv(streams, x)
    if impl != "pallas":
        raise errors.InvalidArgError(f"unknown impl {impl!r}")
    sup = (streams if isinstance(streams, SuperBlockStreams)
           else _regroup(streams, group_size or 1))
    interp = (not _on_tpu()) if interpret is None else interpret

    B, mb = sup.block_size, sup.mb
    y = _combine_into(jnp.zeros((mb, B), jnp.float32), sup, x, interp)
    return y.reshape(-1)[: sup.m]


def cb_spmv(
    streams: SpMVStreams | SuperBlockStreams,
    x: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    group_size: int | None = None,
    plan=None,
) -> jax.Array:
    """y = A @ x over the CB streams. x: (n,) -> y: (m,) float32.

    ``group_size`` (static) only applies to ``SpMVStreams`` input: blocks
    are fused G per grid step via ``_regroup``. ``SuperBlockStreams``
    carry their group size from the host-side packer; passing a
    conflicting value is an error. ``plan`` (static, an autotune
    ``Plan``) supplies the group size the planner chose — it must agree
    with both an explicit ``group_size`` and a packed stream's.

    ``impl="reference"`` stays an *independent* oracle: it consumes the
    stream layout as given (no regrouping), so batched Pallas results are
    always checked against math that never touched the batching code.

    The computation itself is the jitted ``_cb_spmv_jit``; this entry is
    a host-side shim that additionally records launch accounting
    (``repro.ops.spmv.*`` — see ``obs/README.md``) after a successful
    dispatch. Recording reads only static stream geometry, so results
    are bit-identical with obs enabled or disabled.
    """
    y = _cb_spmv_jit(streams, x, impl=impl, interpret=interpret,
                     group_size=group_size, plan=plan)
    if obs.is_enabled():
        g = group_size if group_size is not None else (
            plan.group_size if plan is not None else None)
        _record_call("spmv", spmv_launch_stats(streams, g), impl, plan)
    return y


def _check_group_size(streams, group_size) -> None:
    """Shared argument contract of ``cb_spmv`` / ``cb_spmv_into``."""
    if group_size is not None and group_size < 1:
        raise errors.InvalidArgError(f"group_size must be >= 1, got {group_size}")
    if isinstance(streams, SuperBlockStreams):
        if group_size is not None and group_size != streams.group_size:
            raise errors.InvalidArgError(
                f"stream was packed with group_size={streams.group_size}; "
                f"cannot re-batch to {group_size} post hoc"
            )


def _combine_into(y2d, sup: SuperBlockStreams, x: jax.Array, interp: bool):
    """Scatter every format's partials into the (mb, B) accumulator."""
    parts = _super_partials_pallas(sup, x, interp)
    if parts:
        # ONE fused scatter-add over every format's per-slot partials.
        all_parts = jnp.concatenate([p for p, _ in parts], axis=0)
        all_brow = jnp.concatenate([b for _, b in parts], axis=0)
        y2d = y2d.at[all_brow].add(all_parts)
    return y2d


@functools.partial(
    jax.jit,
    static_argnames=("impl", "interpret", "group_size", "plan"),
    donate_argnums=(0,),
)
def _cb_spmv_into_jit(
    y_acc: jax.Array,
    streams: SpMVStreams | SuperBlockStreams,
    x: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    group_size: int | None = None,
    plan=None,
) -> jax.Array:
    group_size = _resolve_plan(streams, plan, group_size)
    _check_group_size(streams, group_size)
    if impl == "reference":
        return y_acc + _cb_spmv_jit(streams, x, impl="reference")
    if impl != "pallas":
        raise errors.InvalidArgError(f"unknown impl {impl!r}")
    sup = (streams if isinstance(streams, SuperBlockStreams)
           else _regroup(streams, group_size or 1))
    interp = (not _on_tpu()) if interpret is None else interpret
    B, mb = sup.block_size, sup.mb
    y2d = jnp.pad(
        y_acc.astype(jnp.float32), (0, mb * B - y_acc.shape[0])
    ).reshape(mb, B)
    y2d = _combine_into(y2d, sup, x, interp)
    return y2d.reshape(-1)[: sup.m]


def cb_spmv_into(
    y_acc: jax.Array,
    streams: SpMVStreams | SuperBlockStreams,
    x: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    group_size: int | None = None,
    plan=None,
) -> jax.Array:
    """``y_acc + A @ x`` with the ``(m,)`` accumulator **donated**.

    The iterative-solver pattern: the same ``y`` buffer is reused across
    thousands of matvecs, so the accumulator is donated (``donate_argnums``)
    and XLA aliases the output onto the caller's buffer instead of
    allocating a fresh one per iteration (a no-op where the backend lacks
    donation, e.g. CPU — then this is just fused accumulate-SpMV). The
    caller must not reuse ``y_acc`` after the call, per donation rules.

    Like :func:`cb_spmv`, the host-side shim records launch accounting
    (``repro.ops.spmv_into.*``) around the jitted computation.
    """
    y = _cb_spmv_into_jit(y_acc, streams, x, impl=impl, interpret=interpret,
                          group_size=group_size, plan=plan)
    if obs.is_enabled():
        g = group_size if group_size is not None else (
            plan.group_size if plan is not None else None)
        _record_call("spmv_into", spmv_launch_stats(streams, g), impl, plan)
    return y


def _check_tile_group_size(stream, group_size) -> None:
    """``cb_spmm``'s group-size contract (mirrors ``_check_group_size``)."""
    if group_size is not None and group_size < 1:
        raise errors.InvalidArgError(f"group_size must be >= 1, got {group_size}")
    if isinstance(stream, SuperTileStream):
        if group_size is not None and group_size != stream.group_size:
            raise errors.InvalidArgError(
                f"tile stream was packed with group_size={stream.group_size};"
                f" cannot re-batch to {group_size} post hoc"
            )


def _regroup_tiles(ts: TileStream, G: int) -> SuperTileStream:
    """Fuse G one-tile rows per super-tile row with pure reshapes.

    The jit-safe analogue of ``build_super_tile_stream`` (no host round
    trip, no balancing): padding rows appended to ragged tails carry a
    zero tile and brow/bcol 0, so they DMA X block 0 and scatter-add
    exact zeros.
    """
    B = ts.block_size
    gt, Gt = even_group(ts.num_tiles, G)
    tiles = _pad_rows(ts.tiles, gt * Gt).reshape(gt, Gt * B, B)
    brow = _pad_rows(jnp.asarray(ts.brow), gt * Gt).reshape(gt, Gt)
    bcol = _pad_rows(jnp.asarray(ts.bcol), gt * Gt).reshape(gt, Gt)
    return SuperTileStream(
        block_size=B, m=ts.m, n=ts.n, mb=ts.mb, nb=ts.nb, group_size=G,
        tiles=tiles, brow=brow, bcol=bcol,
    )


@functools.partial(
    jax.jit,
    static_argnames=("impl", "interpret", "block_n", "group_size", "plan"),
)
def _cb_spmm_jit(
    stream: TileStream | SuperTileStream,
    X: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    block_n: int = LANE,
    group_size: int | None = None,
    plan=None,
) -> jax.Array:
    group_size = _resolve_plan(stream, plan, group_size)
    _check_tile_group_size(stream, group_size)
    if impl == "reference":
        if isinstance(stream, SuperTileStream):
            return ref.super_spmm(stream, X)
        return ref.cb_spmm(stream, X)
    if impl != "pallas":
        raise errors.InvalidArgError(f"unknown impl {impl!r}")
    sup = (stream if isinstance(stream, SuperTileStream)
           else _regroup_tiles(stream, group_size or 1))
    interp = (not _on_tpu()) if interpret is None else interpret

    B, mb, nb = sup.block_size, sup.mb, sup.nb
    n, N = X.shape
    bn = spmm_block_n(N, block_n)
    Npad = -(-N // bn) * bn
    Xp = jnp.pad(X, ((0, nb * B - n), (0, Npad - N)))
    Xb = Xp.reshape(nb, B, Npad)
    part = _cb_spmm_kernel.super_tile_spmm(
        sup.tiles, sup.bcol, Xb, block_n=bn, interpret=interp,
    )                                                  # (gt, Gt, B, Npad)
    Yb = jnp.zeros((mb, B, Npad), jnp.float32)
    Yb = Yb.at[sup.brow.reshape(-1)].add(part.reshape(-1, B, Npad))
    return Yb.reshape(mb * B, Npad)[: sup.m, :N]


def cb_spmm(
    stream: TileStream | SuperTileStream,
    X: jax.Array,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    block_n: int = LANE,
    group_size: int | None = None,
    plan=None,
) -> jax.Array:
    """Y = A @ X over the block-dense tile stream. X: (n, N) -> Y: (m, N).

    Mirrors ``cb_spmv``'s batched contract: a ``SuperTileStream`` (from
    ``build_super_tile_stream``) carries its group size from the
    host-side nnz-balancing packer; a flat ``TileStream`` is regrouped
    on the fly with pure reshapes when ``group_size=G`` is passed
    (``G=None`` keeps one tile per grid step). Either way the whole
    stream is ONE ``pallas_call`` whose per-slot partials are combined
    by a single fused scatter-add over ``brow``.

    The activation tile width is ``spmm_block_n(N, block_n)`` — always a
    LANE multiple, with X zero-padded to match (the old
    ``min(block_n, max(8, N))`` policy emitted lane-misaligned widths
    that only interpret mode accepted). ``impl="reference"`` stays an
    independent oracle on the layout as given (no regrouping). ``plan``
    (static, an autotune ``Plan``) supplies the planner's group size,
    with the same conflict rules as ``cb_spmv``.

    The host-side shim records launch accounting (``repro.ops.spmm.*``)
    around the jitted computation, mirroring :func:`cb_spmv`.
    """
    Y = _cb_spmm_jit(stream, X, impl=impl, interpret=interpret,
                     block_n=block_n, group_size=group_size, plan=plan)
    if obs.is_enabled():
        g = group_size if group_size is not None else (
            plan.group_size if plan is not None else None)
        n_cols = int(X.shape[1]) if hasattr(X, "shape") else None
        _record_call(
            "spmm",
            spmm_launch_stats(stream, g, n_cols=n_cols, block_n=block_n),
            impl, plan,
        )
    return Y
