"""Pallas TPU kernels for CB-SpMV / CB-SpMM (+ jnp oracles in ref.py)."""
from . import ref  # noqa: F401
from .ops import cb_spmm, cb_spmv, cb_spmv_into  # noqa: F401
