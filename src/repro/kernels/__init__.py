"""Pallas TPU kernels for CB-SpMV / CB-SpMM (+ jnp oracles in ref.py)."""
from . import ref  # noqa: F401
from .ops import cb_spmm, cb_spmv  # noqa: F401
