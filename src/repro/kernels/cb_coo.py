"""Pallas TPU kernel: faithful block-COO CB-SpMV (paper Alg. 3, batched).

FMT_COO blocks (super-sparse) ship as element lists with the paper's
*packed coordinates*: ``code = col << bits | row`` (Alg. 3 decodes
``row = b & 15; col = b >> 4``; we generalize the mask to the block
size). One grid step consumes one *element group*: many blocks' element
lists lane-packed into a single ``(W,)`` payload at SUBLANE-aligned
offsets, so lane->slot routing is positional (slot = ``lane // SUBLANE``;
a block with many elements owns several consecutive slots, whose partial
tiles the additive scatter combine reunites). The kernel decodes
coordinates on-chip and scatters within each slot with a one-hot product
plus a strided lane reduction:

    row      = code & mask                   block-local row (Alg. 3)
    weighted = (val * xv)[:, None] * onehot(row)        (W, B)
    out      = weighted.reshape(S, SUBLANE, B).sum(lanes)   (S, B)

The one-hot is only ``B`` wide — identical per-element work to the
unbatched kernel — and the slot split is a free reshape, so batching
costs no extra FLOPs on any backend; it buys the step/DMA amortization
and per-group (instead of global ``Ep``) padding. The reduction order is
fixed by the contraction, so the result is exact and deterministic,
unlike ``atomicAdd``. Padding lanes carry ``val == 0`` and contribute
nothing regardless of their decoded coordinates.

x arrives pre-gathered (``coo_xidx`` folds the colagg ``restore_cols``
mapping or the trivial one — Alg. 3's two x branches resolved at
preprocessing). Steps write disjoint output rows, so
``dimension_semantics=("parallel",)`` allows megacore partitioning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_call_tpu
from repro.core.aggregation import coord_bits
from repro.core.streams import SUBLANE
from repro import errors


def _decode(codes, B):
    """Alg. 3 lines 11-12, generalized: row = code & mask, col = code >> bits.

    The mask is ``(1 << bits) - 1``, NOT ``B - 1``: for non-power-of-two
    block sizes (e.g. B=24, bits=5) ``B - 1`` has holes and corrupts rows.
    """
    bits = coord_bits(B)
    rows = codes & ((1 << bits) - 1)
    cols = codes >> bits
    return rows, cols


def _coo_kernel_batched(codes_ref, vals_ref, xg_ref, out_ref, *,
                        block_size: int, slots: int):
    B = block_size
    codes = codes_ref[0]                        # (W,) int32
    vals = vals_ref[0].astype(jnp.float32)      # (W,)
    xv = xg_ref[0].astype(jnp.float32)          # (W,) pre-gathered
    rows, _ = _decode(codes, B)
    iota = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], B), 1)
    onehot = (rows[:, None] == iota).astype(jnp.float32)     # (W, B)
    weighted = (vals * xv)[:, None] * onehot                 # (W, B)
    out_ref[0] = weighted.reshape(slots, SUBLANE, B).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def coo_spmv_batched(
    codes: jax.Array,  # (gc, W) int32 lane-packed coordinates
    vals: jax.Array,   # (gc, W) values (0 on padding lanes)
    xg: jax.Array,     # (gc, W) pre-gathered x values
    *,
    block_size: int,
    interpret: bool = True,
) -> jax.Array:
    """Per-slot partial y tiles — (gc, W // SUBLANE, B) float32."""
    gc, W = codes.shape
    if W % SUBLANE:
        raise errors.InvalidArgError(f"packed width {W} not a multiple of {SUBLANE}")
    slots = W // SUBLANE
    B = block_size
    return pallas_call_tpu(
        functools.partial(_coo_kernel_batched, block_size=B, slots=slots),
        grid=(gc,),
        in_specs=[
            pl.BlockSpec((1, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, slots, B), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gc, slots, B), jnp.float32),
        dimension_semantics=("parallel",),
        interpret=interpret,
        name="cb_coo_spmv_batched",
    )(codes, vals, xg)
