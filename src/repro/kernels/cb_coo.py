"""Pallas TPU kernel: faithful block-COO CB-SpMV (paper Alg. 3).

FMT_COO blocks (super-sparse) ship as element lists with the paper's
*packed coordinates*: ``code = col << bits | row`` (Alg. 3 decodes
``row = b & 15; col = b >> 4``; we generalize the mask to the block
size). The kernel decodes coordinates on-chip and performs the
gather-multiply-scatter with two one-hot contractions:

    xv   = onehot(col) @ x_block          (the x gather)
    y    = onehot(row)^T @ (val * xv)     (the atomicAdd scatter)

Both contractions are MXU matmuls — the TPU-native way to express
data-dependent gather/scatter without atomics; the scatter is exact and
deterministic (summation order fixed by the contraction), unlike
``atomicAdd``. Padding elements carry ``val == 0`` so they contribute
nothing regardless of their decoded coordinates.

Like Alg. 3, x access has two branches: scalar-prefetched x block
(non-colagg; "preload into shared memory") or pre-gathered values
(colagg; "read d_x via restore_cols").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_call_tpu
from repro.core.aggregation import coord_bits


def _decode(codes, B):
    """Alg. 3 lines 11-12, generalized: row = code & mask, col = code >> bits.

    The mask is ``(1 << bits) - 1``, NOT ``B - 1``: for non-power-of-two
    block sizes (e.g. B=24, bits=5) ``B - 1`` has holes and corrupts rows.
    """
    bits = coord_bits(B)
    rows = codes & ((1 << bits) - 1)
    cols = codes >> bits
    return rows, cols


def _coo_kernel_prefetched_x(brow_bcol_ref, codes_ref, vals_ref, x_ref,
                             out_ref, *, block_size: int):
    del brow_bcol_ref
    B = block_size
    codes = codes_ref[0]                       # (Ep,) int32
    vals = vals_ref[0].astype(jnp.float32)     # (Ep,)
    xb = x_ref[0].astype(jnp.float32)          # (B,)
    rows, cols = _decode(codes, B)
    iota = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], B), 1)
    col_onehot = (cols[:, None] == iota).astype(jnp.float32)   # (Ep, B)
    row_onehot = (rows[:, None] == iota).astype(jnp.float32)   # (Ep, B)
    xv = jnp.dot(col_onehot, xb, preferred_element_type=jnp.float32)
    out_ref[0, :] = jnp.dot(
        row_onehot.T, vals * xv, preferred_element_type=jnp.float32
    )


def _coo_kernel_gathered_x(codes_ref, vals_ref, xg_ref, out_ref,
                           *, block_size: int):
    B = block_size
    codes = codes_ref[0]
    vals = vals_ref[0].astype(jnp.float32)
    xv = xg_ref[0].astype(jnp.float32)         # (Ep,) pre-gathered
    rows, _ = _decode(codes, B)
    iota = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], B), 1)
    row_onehot = (rows[:, None] == iota).astype(jnp.float32)
    out_ref[0, :] = jnp.dot(
        row_onehot.T, vals * xv, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def coo_spmv_prefetch(
    codes: jax.Array,     # (nc, Ep) int32
    vals: jax.Array,      # (nc, Ep)
    bcol: jax.Array,      # (nc,) int32
    x_blocks: jax.Array,  # (nbc, B)
    *,
    interpret: bool = True,
) -> jax.Array:
    nc, Ep = codes.shape
    B = x_blocks.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, Ep), lambda i, bcol: (i, 0)),
            pl.BlockSpec((1, Ep), lambda i, bcol: (i, 0)),
            pl.BlockSpec((1, B), lambda i, bcol: (bcol[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i, bcol: (i, 0)),
    )
    return pallas_call_tpu(
        functools.partial(_coo_kernel_prefetched_x, block_size=B),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nc, B), jnp.float32),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
        name="cb_coo_spmv_prefetch",
    )(bcol, codes, vals, x_blocks)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def coo_spmv_gathered(
    codes: jax.Array,  # (nc, Ep) int32
    vals: jax.Array,   # (nc, Ep)
    xg: jax.Array,     # (nc, Ep) pre-gathered x values
    *,
    block_size: int,
    interpret: bool = True,
) -> jax.Array:
    nc, Ep = codes.shape
    B = block_size
    return pallas_call_tpu(
        functools.partial(_coo_kernel_gathered_x, block_size=B),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, Ep), lambda i: (i, 0)),
            pl.BlockSpec((1, Ep), lambda i: (i, 0)),
            pl.BlockSpec((1, Ep), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, B), jnp.float32),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
        name="cb_coo_spmv_gathered",
    )(codes, vals, xg)
