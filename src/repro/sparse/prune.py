"""Block-structured magnitude pruning — produces CB-shaped weight sparsity.

Whole B x B blocks are kept or dropped by Frobenius norm, so the surviving
weight is exactly the block-sparse structure the CB kernels consume (the
``pruned_weight`` regime of data/matrices.py). This is the standard
block-pruning recipe (movement/magnitude pruning at block granularity) and
is how the paper's SpMV technique becomes a *training/serving feature*
rather than a standalone kernel demo.
"""
from __future__ import annotations

import numpy as np


def block_sparsity_pattern(
    w: np.ndarray, block_size: int, keep_fraction: float
) -> np.ndarray:
    """Boolean (mb, nb) mask of surviving blocks (top-|keep| by Fro norm)."""
    m, n = w.shape
    B = block_size
    mb, nb = -(-m // B), -(-n // B)
    wp = np.zeros((mb * B, nb * B), dtype=w.dtype)
    wp[:m, :n] = w
    norms = np.square(
        wp.reshape(mb, B, nb, B).transpose(0, 2, 1, 3)
    ).sum(axis=(2, 3))
    keep = max(1, int(round(keep_fraction * mb * nb)))
    thresh = np.partition(norms.reshape(-1), -keep)[-keep]
    mask = norms >= thresh
    # Tie-breaking can keep a few extra blocks; trim deterministically.
    extra = int(mask.sum()) - keep
    if extra > 0:
        flat = np.flatnonzero(mask.reshape(-1))
        order = np.argsort(norms.reshape(-1)[flat], kind="stable")
        mask.reshape(-1)[flat[order[:extra]]] = False
    # Every block row must keep >= 1 block (row coverage for the kernel and
    # a non-dead output row — mirrors build_tile_stream's padding).
    for rb in range(mb):
        if not mask[rb].any():
            mask[rb, int(np.argmax(norms[rb]))] = True
    return mask


def block_magnitude_prune(
    w: np.ndarray, block_size: int, keep_fraction: float
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (pruned dense weight, block mask)."""
    m, n = w.shape
    B = block_size
    mask = block_sparsity_pattern(w, block_size, keep_fraction)
    mb, nb = mask.shape
    full = np.repeat(np.repeat(mask, B, axis=0), B, axis=1)[:m, :n]
    return w * full, mask
