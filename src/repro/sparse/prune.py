"""Block-structured magnitude pruning — produces CB-shaped weight sparsity.

Whole B x B blocks are kept or dropped by Frobenius norm, so the surviving
weight is exactly the block-sparse structure the CB kernels consume (the
``pruned_weight`` regime of data/matrices.py). This is the standard
block-pruning recipe (movement/magnitude pruning at block granularity) and
is how the paper's SpMV technique becomes a *training/serving feature*
rather than a standalone kernel demo.

The refreeze machinery at the bottom makes the pattern *periodically*
dynamic: every k training steps the block mask is recomputed from the
current tile magnitudes (``refreeze_spec``). The crucial contract is that
a mask-stable refreeze returns the SAME spec object — the custom-VJP
matmul cache in ``linear.py`` keys on spec identity, so the jitted
forward/backward closures (and any autotune plan attached to the layer)
survive every step on which the structure did not actually drift. Only a
genuine mask change pays for a spec rebuild.
"""
from __future__ import annotations

import numpy as np


def block_sparsity_pattern(
    w: np.ndarray, block_size: int, keep_fraction: float
) -> np.ndarray:
    """Boolean (mb, nb) mask of surviving blocks (top-|keep| by Fro norm)."""
    m, n = w.shape
    B = block_size
    mb, nb = -(-m // B), -(-n // B)
    wp = np.zeros((mb * B, nb * B), dtype=w.dtype)
    wp[:m, :n] = w
    norms = np.square(
        wp.reshape(mb, B, nb, B).transpose(0, 2, 1, 3)
    ).sum(axis=(2, 3))
    keep = max(1, int(round(keep_fraction * mb * nb)))
    thresh = np.partition(norms.reshape(-1), -keep)[-keep]
    mask = norms >= thresh
    # Tie-breaking can keep a few extra blocks; trim deterministically.
    extra = int(mask.sum()) - keep
    if extra > 0:
        flat = np.flatnonzero(mask.reshape(-1))
        order = np.argsort(norms.reshape(-1)[flat], kind="stable")
        mask.reshape(-1)[flat[order[:extra]]] = False
    # Every block row must keep >= 1 block (row coverage for the kernel and
    # a non-dead output row — mirrors build_tile_stream's padding).
    for rb in range(mb):
        if not mask[rb].any():
            mask[rb, int(np.argmax(norms[rb]))] = True
    return mask


def block_magnitude_prune(
    w: np.ndarray, block_size: int, keep_fraction: float
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (pruned dense weight, block mask)."""
    m, n = w.shape
    B = block_size
    mask = block_sparsity_pattern(w, block_size, keep_fraction)
    mb, nb = mask.shape
    full = np.repeat(np.repeat(mask, B, axis=0), B, axis=1)[:m, :n]
    return w * full, mask


# ---------------------------------------------------------------------------
# Mask refreeze: periodically re-derive the block pattern during training.
# ---------------------------------------------------------------------------

def refreeze_due(step: int, every_k: int) -> bool:
    """Whether a mask refreeze fires on this (0-based) training step."""
    return every_k > 0 and step > 0 and step % every_k == 0


def refreeze_spec(params, spec, *, keep_fraction: float | None = None):
    """Recompute the block mask from current magnitudes; rebuild only on drift.

    Returns ``(params, spec, changed)``. When the freshly pruned mask
    equals the spec's mask, the ORIGINAL ``params`` and ``spec`` objects
    come back untouched (``changed=False``) — spec identity is what the
    matmul cache keys on, so the layer's jitted VJP closures and plan
    survive. On drift, a new spec is built through the same
    ``spec_from_mask`` constructor as ``cb_linear_init`` and the
    surviving tile values are carried over (newly admitted blocks start
    at zero and regrow).
    """
    import jax.numpy as jnp

    from . import linear as _linear  # lazy: linear imports prune at load

    kf = spec.keep_fraction if keep_fraction is None else keep_fraction
    a = np.asarray(_linear.dense_equivalent(params, spec)).T  # (out, in)
    new_mask = block_sparsity_pattern(a, spec.block_size, kf)
    if np.array_equal(new_mask, _linear.spec_block_mask(spec)):
        return params, spec, False
    new_spec = _linear.spec_from_mask(
        new_mask, spec.in_features, spec.out_features,
        block_size=spec.block_size, keep_fraction=kf,
    )
    new_params = dict(params)
    new_params["tiles"] = jnp.asarray(
        _linear.gather_tiles(a, new_spec), params["tiles"].dtype
    )
    return new_params, new_spec, True


def refreeze_training_step(
    params,
    ef,
    spec,
    x,
    y,
    *,
    step: int,
    every_k: int,
    lr: float = 1e-2,
    keep_fraction: float | None = None,
    impl: str = "reference",
    interpret: bool | None = None,
    group_size: int | None = None,
    plan=None,
):
    """One EF-int8-compressed SGD step with mask refreeze every ``every_k``.

    The dynamic-sparsity training hook: gradients ride the int8
    error-feedback wire format (``training.grad_compression``), the
    weight update is plain SGD on the tile stream, and on refreeze steps
    the mask is re-derived from the updated magnitudes. Mask-stable steps
    keep the exact same spec (and therefore the same compiled VJP and
    plan); a drifted mask rebuilds the spec and resets the EF buffers to
    match the new tile shapes.

    Returns ``(params, ef, spec, loss, changed)``.
    """
    import jax
    import jax.numpy as jnp

    from repro.training import grad_compression as _gc

    from . import linear as _linear

    def loss_fn(p):
        pred = _linear.cb_linear_apply(
            p, spec, x, impl=impl, interpret=interpret,
            group_size=group_size, plan=plan,
        )
        return jnp.mean((pred.astype(jnp.float32)
                         - y.astype(jnp.float32)) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    grads, ef = _gc.ef_compress_grads(grads, ef)
    params = jax.tree_util.tree_map(
        lambda p, g: (p - lr * g.astype(p.dtype)), params, grads
    )
    changed = False
    if refreeze_due(step, every_k):
        params, spec, changed = refreeze_spec(
            params, spec, keep_fraction=keep_fraction
        )
        if changed:
            ef = _gc.init_ef_buffers(params)
    return params, ef, spec, loss, changed
