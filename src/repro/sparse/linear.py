"""CBSparseLinear — block-sparse linear layers backed by the CB kernels.

The paper's technique as a first-class model feature: a linear layer whose
weight is magnitude-pruned to B x B blocks and stored as a CB tile stream.
Forward is CB-SpMM (prefill/training) or CB-SpMV (single-token decode);
backward is a custom VJP whose dX pass runs the *transposed* tile stream
(precomputed statically — sparsity patterns are trace-time constants) and
whose dW pass is a gathered per-tile outer product.

Sparsity metadata (brow/bcol and the transpose permutation) is static
numpy closed over by the apply function, so jit embeds it as constants —
the structure never rides the data path, exactly like the paper's
preprocessed metadata arrays.

Weight convention: the layer computes ``y = x @ W + b`` with
``W: (in, out)``; internally the tile stream stores ``A = W^T`` (out, in)
so that ``y^T = A @ x^T`` matches the kernels' row-major SpMM contract.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streams import TileStream, build_tile_stream
from repro import errors

from .prune import block_sparsity_pattern


@dataclasses.dataclass(frozen=True, eq=False)
class CBLinearSpec:
    """Static sparsity structure of one CB linear layer.

    ``eq=False`` keeps object-identity hashing (the numpy fields are
    unhashable anyway), which is what lets the matmul cache key on the
    spec itself through a ``WeakKeyDictionary`` — dropped specs evict
    their cached closures instead of accumulating forever.
    """

    in_features: int
    out_features: int
    block_size: int
    keep_fraction: float
    # A = W^T stream metadata (block-row-major, full row coverage)
    brow: Any          # (nt,) numpy int32 — static
    bcol: Any          # (nt,) numpy int32 — static
    mb: int            # ceil(out / B)
    nb: int            # ceil(in / B)
    # transposed stream: tiles_T[i] = tiles[t_perm[i]]^T at (browT, bcolT)
    t_perm: Any        # (ntT,) numpy int64 into the forward stream; -1 = zero pad
    browT: Any
    bcolT: Any

    @property
    def num_tiles(self) -> int:
        return len(self.brow)

    @property
    def density(self) -> float:
        return self.num_tiles / float(self.mb * self.nb)

    def flops_per_token(self) -> int:
        """Useful MACs per input row (2*nt*B^2) — roofline accounting."""
        return 2 * self.num_tiles * self.block_size * self.block_size


def _transpose_stream(brow: np.ndarray, bcol: np.ndarray, nb: int):
    """Static metadata for A^T's stream, with full row coverage over nb."""
    order = np.lexsort((brow, bcol))  # sort by (bcol, then brow)
    browT = bcol[order].astype(np.int32)
    bcolT = brow[order].astype(np.int32)
    perm = order.astype(np.int64)
    present = set(browT.tolist())
    pads = [rb for rb in range(nb) if rb not in present]
    if pads:
        browT = np.concatenate([browT, np.asarray(pads, np.int32)])
        bcolT = np.concatenate([bcolT, np.zeros(len(pads), np.int32)])
        perm = np.concatenate([perm, np.full(len(pads), -1, np.int64)])
        reorder = np.argsort(browT, kind="stable")
        browT, bcolT, perm = browT[reorder], bcolT[reorder], perm[reorder]
    return perm, browT, bcolT


def cb_spec_random(
    in_features: int,
    out_features: int,
    *,
    block_size: int = 128,
    keep_fraction: float = 0.25,
    seed: int = 0,
) -> CBLinearSpec:
    """Structural spec with a random block pattern (numpy-only, no tracing).

    Magnitude pruning of a fresh Gaussian init keeps a uniformly random
    block subset, so drawing the pattern directly is statistically
    equivalent and lets specs be built eagerly (model construction time)
    — required because scanned layers share one pattern and the dry-run
    never materializes weights.
    """
    B = block_size
    mb, nb = -(-out_features // B), -(-in_features // B)
    rng = np.random.default_rng(seed)
    norms = rng.random((mb, nb))
    keep = max(1, int(round(keep_fraction * mb * nb)))
    thresh = np.partition(norms.reshape(-1), -keep)[-keep]
    mask = norms >= thresh
    for rb in range(mb):
        if not mask[rb].any():
            mask[rb, int(np.argmax(norms[rb]))] = True
    brow, bcol = np.nonzero(mask)
    order = np.argsort(brow, kind="stable")
    brow = brow[order].astype(np.int32)
    bcol = bcol[order].astype(np.int32)
    t_perm, browT, bcolT = _transpose_stream(brow, bcol, nb)
    return CBLinearSpec(
        in_features=in_features, out_features=out_features,
        block_size=B, keep_fraction=keep_fraction,
        brow=brow, bcol=bcol, mb=mb, nb=nb,
        t_perm=t_perm, browT=browT, bcolT=bcolT,
    )


def spec_from_mask(
    mask: np.ndarray,
    in_features: int,
    out_features: int,
    *,
    block_size: int,
    keep_fraction: float,
) -> CBLinearSpec:
    """Build a spec straight from a boolean (mb, nb) block mask.

    The shared structural constructor behind ``cb_linear_init`` and the
    mask-refreeze path (``prune.refreeze_spec``): everything downstream of
    the mask — tile order (block-row-major), row-coverage padding, and the
    transposed-stream permutation — is derived here, so both entry points
    agree on the stream layout bit-for-bit.
    """
    B = block_size
    mb, nb = -(-out_features // B), -(-in_features // B)
    mask = np.asarray(mask, bool)
    if mask.shape != (mb, nb):
        raise errors.InvalidArgError(
            f"mask shape {mask.shape} != block grid ({mb}, {nb}) for "
            f"({out_features}, {in_features}) at B={B}"
        )
    uncovered = np.flatnonzero(~mask.any(axis=1))
    if len(uncovered):
        # coverage pad at bcol=0, mirroring build_tile_stream's padding
        mask = mask.copy()
        mask[uncovered, 0] = True
    brow, bcol = np.nonzero(mask)  # row-major == block-row-major order
    brow = brow.astype(np.int32)
    bcol = bcol.astype(np.int32)
    t_perm, browT, bcolT = _transpose_stream(brow, bcol, nb)
    return CBLinearSpec(
        in_features=in_features, out_features=out_features,
        block_size=B, keep_fraction=keep_fraction,
        brow=brow, bcol=bcol, mb=mb, nb=nb,
        t_perm=t_perm, browT=browT, bcolT=bcolT,
    )


def spec_block_mask(spec: CBLinearSpec) -> np.ndarray:
    """The spec's boolean (mb, nb) block mask (inverse of spec_from_mask)."""
    mask = np.zeros((spec.mb, spec.nb), bool)
    mask[spec.brow, spec.bcol] = True
    return mask


def gather_tiles(a: np.ndarray, spec: CBLinearSpec) -> np.ndarray:
    """Extract the (nt, B, B) tile stack of dense ``A`` (out, in) at the
    spec's block slots — entries outside the mask are dropped (pruned)."""
    B = spec.block_size
    ap = np.zeros((spec.mb * B, spec.nb * B), a.dtype)
    ap[: a.shape[0], : a.shape[1]] = a
    blocks = ap.reshape(spec.mb, B, spec.nb, B).transpose(0, 2, 1, 3)
    return blocks[spec.brow, spec.bcol]


def cb_tiles_init(key: jax.Array, spec: CBLinearSpec, dtype=jnp.float32,
                  scale: float | None = None) -> dict:
    """Draw tile values for an existing spec (vmap/scan friendly)."""
    scale = spec.in_features**-0.5 if scale is None else scale
    B = spec.block_size
    tiles = jax.random.normal(
        key, (spec.num_tiles, B, B), jnp.float32
    ) * scale
    return {"tiles": tiles.astype(dtype)}


def cb_linear_init(
    key: jax.Array,
    in_features: int,
    out_features: int,
    *,
    block_size: int = 128,
    keep_fraction: float = 0.25,
    dtype=jnp.float32,
    init_scale: float | None = None,
) -> tuple[dict, CBLinearSpec]:
    """Initialize a dense weight, block-prune it, and build the CB stream."""
    scale = init_scale if init_scale is not None else in_features**-0.5
    w = np.asarray(
        jax.random.normal(key, (in_features, out_features), jnp.float32) * scale
    )
    a = w.T  # (out, in)
    mask = block_sparsity_pattern(a, block_size, keep_fraction)
    rr, cc = np.nonzero(np.repeat(np.repeat(mask, block_size, 0), block_size, 1)[
        : a.shape[0], : a.shape[1]
    ] & (a != 0))
    stream = build_tile_stream(
        rr, cc, a[rr, cc], (out_features, in_features), block_size
    )
    t_perm, browT, bcolT = _transpose_stream(
        np.asarray(stream.brow), np.asarray(stream.bcol), stream.nb
    )
    spec = CBLinearSpec(
        in_features=in_features,
        out_features=out_features,
        block_size=block_size,
        keep_fraction=keep_fraction,
        brow=np.asarray(stream.brow),
        bcol=np.asarray(stream.bcol),
        mb=stream.mb,
        nb=stream.nb,
        t_perm=t_perm,
        browT=browT,
        bcolT=bcolT,
    )
    params = {"tiles": jnp.asarray(stream.tiles, dtype)}
    return params, spec


def make_cb_matmul(spec: CBLinearSpec, impl: str = "reference",
                   interpret: bool | None = None,
                   group_size: int | None = None):
    """Build the differentiable ``(tiles, X) -> A @ X`` for this spec.

    X: (in, N) -> Y: (out, N). The VJP's dX runs A^T's stream (same kernel,
    transposed metadata); dW gathers (dY block-row, X block-col) pairs and
    contracts per tile — both pure-XLA, so the backward pass is collective-
    and layout-friendly under GSPMD. ``group_size`` rides through BOTH
    SpMM streams (forward and the transposed dX stream) as a jit-side
    regroup — a schedule change only, so gradients stay bit-identical to
    the unbatched path's on the reference impl and allclose on Pallas.

    The returned closure captures the spec's *fields*, never the spec
    object, so the weakref-keyed matmul cache can evict entries once the
    caller drops the spec (a closure holding the key would pin it
    forever).
    """
    from repro.kernels import ops

    B = spec.block_size
    # NOTE: metadata stays numpy — creating jnp constants here would bind
    # them to whatever trace is active (this runs inside scan/grad traces).
    brow = spec.brow
    bcol = spec.bcol
    mb, nb = spec.mb, spec.nb
    in_f, out_f = spec.in_features, spec.out_features
    t_perm, browT, bcolT = spec.t_perm, spec.browT, spec.bcolT

    def _stream(tiles):
        return TileStream(block_size=B, m=out_f, n=in_f, mb=mb, nb=nb,
                          tiles=tiles, brow=brow, bcol=bcol)

    def _stream_T(tiles):
        safe = np.maximum(t_perm, 0)
        tilesT = jnp.swapaxes(tiles[safe], -1, -2)
        tilesT = jnp.where((t_perm >= 0)[:, None, None], tilesT, 0.0)
        return TileStream(block_size=B, m=in_f, n=out_f, mb=nb, nb=mb,
                          tiles=tilesT, brow=browT, bcol=bcolT)

    def fwd_compute(tiles, X):
        return ops.cb_spmm(_stream(tiles), X, impl=impl,
                           interpret=interpret, group_size=group_size)

    @jax.custom_vjp
    def matmul(tiles, X):
        return fwd_compute(tiles, X)

    def matmul_fwd(tiles, X):
        return fwd_compute(tiles, X), (tiles, X)

    def matmul_bwd(res, dY):
        tiles, X = res
        dY = dY.astype(jnp.float32)
        # dX = A^T @ dY via the transposed stream (same SpMM kernel).
        dX = ops.cb_spmm(_stream_T(tiles), dY, impl=impl,
                         interpret=interpret,
                         group_size=group_size).astype(X.dtype)
        # dA[t] = dY_blocks[brow[t]] @ X_blocks[bcol[t]]^T
        N = X.shape[1]
        Xp = jnp.pad(X.astype(jnp.float32), ((0, nb * B - X.shape[0]), (0, 0)))
        dYp = jnp.pad(dY, ((0, mb * B - dY.shape[0]), (0, 0)))
        Xb = Xp.reshape(nb, B, N)
        dYb = dYp.reshape(mb, B, N)
        d_tiles = jnp.einsum("tbn,tcn->tbc", dYb[brow], Xb[bcol])
        return d_tiles.astype(tiles.dtype), dX

    matmul.defvjp(matmul_fwd, matmul_bwd)
    return matmul


# custom_vjp closures must be constructed OUTSIDE any trace (constructing
# them inside a scanned/grad-traced body leaks trace-local constants into
# the later-staged bwd jaxpr). Cache one matmul per spec per config — the
# spec is the weak key (identity hash, see CBLinearSpec), so entries die
# with the spec instead of keeping every spec ever built alive, which is
# what the old ``id(spec)``-keyed dict deliberately (and unboundedly) did.
_MATMUL_CACHE: "weakref.WeakKeyDictionary[CBLinearSpec, dict]" = (
    weakref.WeakKeyDictionary()
)


def _cached_matmul(spec: CBLinearSpec, impl: str, interpret: bool | None,
                   group_size: int | None = None):
    per_spec = _MATMUL_CACHE.get(spec)
    if per_spec is None:
        per_spec = _MATMUL_CACHE[spec] = {}
    key = (impl, interpret, group_size)
    hit = per_spec.get(key)
    if hit is None:
        hit = per_spec[key] = make_cb_matmul(
            spec, impl=impl, interpret=interpret, group_size=group_size
        )
    return hit


def cb_linear_apply(
    params: dict,
    spec: CBLinearSpec,
    x: jax.Array,
    *,
    impl: str = "reference",
    interpret: bool | None = None,
    group_size: int | None = None,
    plan=None,
) -> jax.Array:
    """y = x @ W for x of shape (..., in_features).

    ``plan`` (an autotune ``Plan``) supplies the SpMM group size the
    planner chose — the one plan knob that applies to the layer's
    block-dense tile stream (the structural knobs are fixed by the
    spec). Conflicting explicit ``group_size`` is an error; the resolved
    value feeds the same matmul cache, so plan-carrying calls and
    explicit-group calls share closures.
    """
    if plan is not None:
        if group_size is not None and group_size != plan.group_size:
            raise errors.InvalidArgError(
                f"plan chose group_size={plan.group_size}; conflicting "
                f"explicit group_size={group_size}"
            )
        group_size = plan.group_size
    matmul = _cached_matmul(spec, impl, interpret, group_size)
    lead = x.shape[:-1]
    X = x.reshape(-1, spec.in_features).T  # (in, N)
    Y = matmul(params["tiles"], X)         # (out, N)
    return Y.T.reshape(*lead, spec.out_features).astype(x.dtype)


def dense_equivalent(params: dict, spec: CBLinearSpec) -> jax.Array:
    """Densified W (in, out) — test/debug utility."""
    B = spec.block_size
    A = jnp.zeros((spec.mb * B, spec.nb * B), params["tiles"].dtype)
    brow = jnp.asarray(spec.brow)
    bcol = jnp.asarray(spec.bcol)
    ridx = (brow[:, None] * B + jnp.arange(B)[None, :]).reshape(-1)
    out = A.at[ridx[:, None],
               (bcol[:, None] * B + jnp.arange(B)[None, :])
               .reshape(spec.num_tiles, 1, B)
               .repeat(B, 1)
               .reshape(-1, B)].add(
        params["tiles"].reshape(-1, B)
    )
    return out[: spec.out_features, : spec.in_features].T
