"""CB block-sparse weight integration for the model stack."""
from .linear import (  # noqa: F401
    CBLinearSpec,
    cb_linear_apply,
    cb_linear_init,
    dense_equivalent,
    gather_tiles,
    spec_block_mask,
    spec_from_mask,
)
from .prune import (  # noqa: F401
    block_magnitude_prune,
    block_sparsity_pattern,
    refreeze_due,
    refreeze_spec,
    refreeze_training_step,
)
