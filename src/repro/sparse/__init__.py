"""CB block-sparse weight integration for the model stack."""
from .linear import CBLinearSpec, cb_linear_apply, cb_linear_init  # noqa: F401
from .prune import block_magnitude_prune, block_sparsity_pattern  # noqa: F401
