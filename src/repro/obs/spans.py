"""Lightweight span tracing with Chrome ``trace_event`` export.

A span is a context manager timing one host-side region on the
injectable monotonic clock (``metrics.now``):

    with obs.span("robust_solve", n=4096) as sp:
        ...
        sp.set(status="ok")

Spans nest per thread (a thread-local stack records each span's depth
and parent), cost two clock reads plus one list append, and become
no-ops when obs is disabled. Completed spans accumulate in a bounded
in-process buffer on the :class:`Tracer`; ``chrome_trace()`` renders
them as Chrome ``trace_event`` *complete* events (``ph: "X"``, µs
timestamps relative to the tracer epoch) — load the exported
``.trace.json`` in ``chrome://tracing`` / Perfetto, or feed it to
``scripts/obs_report.py`` for a terminal summary.

Determinism: timestamps come only from the configured clock and thread
ids are logical (0, 1, ... in first-seen order, not OS idents), so a
fake clock reproduces byte-identical traces.
"""
from __future__ import annotations

import dataclasses
import json
import threading

from . import metrics


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span (times in clock seconds since tracer epoch)."""

    name: str
    start: float
    duration: float
    depth: int
    tid: int
    attrs: dict


class _NullSpan:
    """Returned while obs is disabled: absorbs the whole span API."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Span:
    """Context manager for one traced region; ``set()`` adds attrs."""

    __slots__ = ("name", "attrs", "_tracer", "_start", "_depth", "_tid")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict) -> None:
        self.name = name
        self.attrs = attrs
        self._tracer = tracer
        self._start = 0.0
        self._depth = 0
        self._tid = 0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._tid, stack = self._tracer._thread_state()
        self._depth = len(stack)
        stack.append(self)
        self._start = metrics.now()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        end = metrics.now()
        _, stack = self._tracer._thread_state()
        if stack and stack[-1] is self:
            stack.pop()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._record(SpanRecord(
            name=self.name,
            start=self._start - self._tracer._epoch,
            duration=end - self._start,
            depth=self._depth,
            tid=self._tid,
            attrs=dict(self.attrs),
        ))
        return False


class Tracer:
    """Bounded buffer of completed spans + Chrome trace rendering."""

    def __init__(self, max_spans: int = 100_000) -> None:
        self.max_spans = max_spans
        self._lock = threading.Lock()
        self._local = threading.local()
        self._records: list[SpanRecord] = []
        self._tids: dict[int, int] = {}
        self._epoch: float | None = None
        self.dropped = 0

    # -- recording ------------------------------------------------------
    def span(self, name: str, **attrs):
        if not metrics.is_enabled():
            return _NULL_SPAN
        if self._epoch is None:
            with self._lock:
                if self._epoch is None:
                    self._epoch = metrics.now()
        return Span(self, name, attrs)

    def _thread_state(self) -> tuple[int, list]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
            with self._lock:
                self._local.tid = self._tids.setdefault(
                    threading.get_ident(), len(self._tids))
        return self._local.tid, stack

    def _record(self, record: SpanRecord) -> None:
        with self._lock:
            if len(self._records) >= self.max_spans:
                self.dropped += 1
                return
            self._records.append(record)

    # -- reading --------------------------------------------------------
    def records(self) -> tuple[SpanRecord, ...]:
        with self._lock:
            return tuple(self._records)

    def reset(self) -> None:
        with self._lock:
            self._records.clear()
            self._tids.clear()
            self._epoch = None
            self.dropped = 0
        self._local = threading.local()

    def summary(self) -> list[dict]:
        """Per-name aggregate rows (count, total/mean/max seconds),
        sorted by total descending — the obs_report table."""
        agg: dict[str, list] = {}
        for r in self.records():
            row = agg.setdefault(r.name, [0, 0.0, 0.0])
            row[0] += 1
            row[1] += r.duration
            row[2] = max(row[2], r.duration)
        return [
            {"name": name, "count": c, "total_s": tot,
             "mean_s": tot / c, "max_s": mx}
            for name, (c, tot, mx) in sorted(
                agg.items(), key=lambda kv: -kv[1][1])
        ]

    # -- export ---------------------------------------------------------
    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON object (complete events)."""
        events = [
            {
                "name": r.name,
                "cat": "repro",
                "ph": "X",
                "ts": r.start * 1e6,        # trace_event wants microseconds
                "dur": r.duration * 1e6,
                "pid": 1,
                "tid": r.tid,
                "args": dict(r.attrs, depth=r.depth),
            }
            for r in self.records()
        ]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1, sort_keys=True)
        return str(path)


_DEFAULT_TRACER = Tracer()


def tracer() -> Tracer:
    return _DEFAULT_TRACER
