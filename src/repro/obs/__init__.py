"""Unified observability for the CB engine: metrics, spans, exports.

Zero-dependency (stdlib only). Three layers:

  * **metrics** — a process-wide :class:`MetricsRegistry` of typed,
    labeled instruments (counter / gauge / log2-bucket histogram) with
    deterministic snapshots (``obs.snapshot()``) and JSON export;
  * **spans** — ``obs.span(name, **attrs)`` context-manager tracing on
    the injectable monotonic clock, exported as Chrome ``trace_event``
    JSON (``obs.export_chrome_trace(path)``, rendered by
    ``scripts/obs_report.py``);
  * **migration shims** — :class:`MirroredCounter` keeps the historical
    private-counter APIs (``_TRACE_COUNTS``, ``PlanCache.hits``) intact
    while forwarding their increments into the registry.

A fourth layer lives in the ``repro.obs.locality`` submodule (import it
explicitly — it needs numpy, so it stays out of this package's
stdlib-only import): the vectorized reuse-distance engine and the
access-stream generators that model L1/L2 cache traffic of the planned
super-block/super-tile pipelines (``repro.locality.*`` gauges).

Everything is gated on ``obs.configure(enabled=...)`` (default ON;
disabled instruments are no-op-cheap) and timed by the injectable
``configure(clock=...)`` so tests are deterministic. Instrumentation
lives strictly *outside* jitted code: recording is a Python-level side
effect, so under an outer ``jax.jit`` it fires once per trace — by
design, launch accounting counts logical invocations, and numeric
results are bit-identical with obs on or off.

Metric naming convention: ``repro.<subsystem>.<metric>`` — the catalog
lives in ``src/repro/obs/README.md``.
"""
from __future__ import annotations

from .metrics import (  # noqa: F401
    BUCKET_EDGES,
    Counter,
    Gauge,
    Histogram,
    Instrument,
    MetricsRegistry,
    MirroredCounter,
    bucket_index,
    configure,
    is_enabled,
    now,
    registry,
)
from .spans import (  # noqa: F401
    Span,
    SpanRecord,
    Tracer,
    tracer,
)


def counter(name: str) -> Counter:
    """Shorthand for the default registry's counter."""
    return registry().counter(name)


def gauge(name: str) -> Gauge:
    return registry().gauge(name)


def histogram(name: str) -> Histogram:
    return registry().histogram(name)


def span(name: str, **attrs):
    """Start a traced region on the default tracer (context manager)."""
    return tracer().span(name, **attrs)


def snapshot() -> dict:
    """Deterministic JSON-able view of every recorded metric."""
    return registry().snapshot()


def reset() -> None:
    """Clear the default registry AND the default tracer."""
    registry().reset()
    tracer().reset()


def chrome_trace() -> dict:
    return tracer().chrome_trace()


def export_chrome_trace(path) -> str:
    """Write the default tracer's spans as Chrome trace_event JSON."""
    return tracer().export(path)
