"""Vectorized locality/traffic profiler for CB access streams.

The paper's headline empirical claim is *cache behaviour* (Fig. 10): the
contiguous one-region-per-block layout touches fewer, denser cache lines
than CSR/BSR/TileSpMV. Off-GPU there is no Nsight, so the repro models
it as a fully-associative LRU over the byte-access stream a format
generates — but the seed implementation walked every access through a
Python ``OrderedDict`` (and capped streams at 300k nnz to stay
tractable), and it measured the seed's *flat* layouts, not the
super-block streams the batched engines actually execute under a plan.

This module replaces both halves:

  * :func:`reuse_profile` — an exact, vectorized reuse-distance engine.
    For every access, the LRU *stack distance* (distinct lines touched
    since the previous access to the same line) is computed in
    O(N log^2 N) numpy passes; an access hits a cache of capacity ``C``
    lines iff its distance is ``< C``, so ONE pass prices every capacity
    (L1 and L2 come from the same distances). No per-access Python loop,
    no stream-length cap, bit-identical to the brute-force LRU
    (``tests/test_locality.py`` proves it on adversarial streams).
  * :func:`access_stream_super` / :func:`access_stream_super_tile` —
    byte-access streams derived from the **actual** kernel inputs
    (``SuperBlockStreams`` / ``SuperTileStream``): the per-grid-step
    sequential payload DMAs (values + packed coords + gather indices),
    the ``*_xidx``-driven x gathers, and optionally the scatter-add y
    traffic. Pure shape/index metadata — results are bit-deterministic
    and identical with obs enabled or disabled.

The vectorized distance algorithm: with ``prev[i]`` / ``next[i]`` the
previous/next access of access ``i``'s line (``next = N`` when none) and
``U[i]`` the number of distinct lines seen in ``[0, i)``,

    d[i] = U[i] - (prev[i] + 1) + #{t < prev[i] : next[t] < i}

(cold accesses have no ``prev`` and infinite distance). The last term
is a "count of earlier-smaller elements" over the ``next`` array —
non-sentinel ``next`` values are distinct positions, and ``i`` is
exactly ``next[prev[i]]`` — counted by a bottom-up merge (Fenwick-style
dominance count, one ``lexsort`` per level instead of one tree update
per access). Consecutive duplicate lines are collapsed first: they are
unconditional hits at any capacity and never change the miss sequence,
which shrinks sequential payload walks by ~line/element.

Numpy is imported lazily so ``repro.obs``'s stdlib-only import contract
(metrics/spans are consumed by dependency-free guard scripts) survives.

Metric naming for published results: ``repro.locality.*`` — see the
catalog in ``obs/README.md``; the guarded bench section lives in
``benchmarks/locality_bench.py``.
"""
from __future__ import annotations

import dataclasses

from repro import errors

# The cache line model shared by every stream generator and profile:
# 128-byte lines, L1/L2 capacities as v5e-ish SMEM/CMEM stand-ins.
# Relative ordering between formats is the claim under test, not the
# absolute hit rates.
LINE_BYTES = 128
L1_BYTES = 128 * 1024
L2_BYTES = 4 * 1024 * 1024

# One SpMV multiply-add per stored element.
FLOPS_PER_NNZ = 2


def _np():
    import numpy as np

    return np


# ---------------------------------------------------------------------------
# The reuse-distance engine.
# ---------------------------------------------------------------------------

def _count_prev_smaller(vals):
    """out[i] = #{j < i : vals[j] < vals[i]}, fully vectorized.

    Bottom-up divide and conquer: at level ``L`` every pair of adjacent
    runs of length ``L`` is value-sorted together (one ``lexsort`` over
    (pair-id, value)), and each right-run element receives the count of
    left-run elements preceding it in that order. Every (j < i) pair is
    counted at exactly one level — the first where j and i fall in
    different halves of the same pair — so the sum over levels is the
    exact dominance count, in ``ceil(log2 N)`` numpy passes.

    Ties are resolved right-run-first (strict ``<``: a tied left element
    must not count). Only reached through :func:`reuse_profile`, where
    non-sentinel values are distinct and sentinel positions are never
    read back, but the routine stays correct for arbitrary ties.
    """
    np = _np()
    n = len(vals)
    out = np.zeros(n, np.int64)
    if n < 2:
        return out
    idx = np.arange(n)
    L = 1
    while L < n:
        pair = idx // (2 * L)
        side = (idx // L) & 1          # 0 = left run, 1 = right run
        # sort by (pair, value, right-before-left on ties)
        order = np.lexsort((-side, vals, pair))
        left = (side[order] == 0).astype(np.int64)
        seen = np.cumsum(left) - left  # left elements before, globally
        po = pair[order]
        starts = np.flatnonzero(np.r_[True, po[1:] != po[:-1]])
        base = np.repeat(seen[starts], np.diff(np.r_[starts, n]))
        right = side[order] == 1
        out[order[right]] += (seen - base)[right]
        L *= 2
    return out


def reuse_distances(line_ids):
    """LRU stack distance per access; ``-1`` marks cold (first) accesses.

    ``d[i]`` = number of *distinct* lines accessed strictly between the
    previous access to ``line_ids[i]`` and position ``i``. An access
    hits a fully-associative LRU of capacity ``C`` lines iff
    ``0 <= d[i] < C``.
    """
    np = _np()
    lines = np.asarray(line_ids)
    if lines.ndim != 1:
        raise errors.InvalidArgError(
            f"line_ids must be 1-D, got shape {lines.shape}"
        )
    n = len(lines)
    if n == 0:
        return np.zeros(0, np.int64)

    _, codes = np.unique(lines, return_inverse=True)

    # prev[i]: previous position of the same code (-1 = first access).
    order = np.argsort(codes, kind="stable")
    sc = codes[order]
    prev_sorted = np.empty(n, np.int64)
    prev_sorted[0] = -1
    same = sc[1:] == sc[:-1]
    prev_sorted[1:] = np.where(same, order[:-1], -1)
    prev = np.empty(n, np.int64)
    prev[order] = prev_sorted

    # next[t]: the access whose prev is t (N = never reused again).
    nxt = np.full(n, n, np.int64)
    has_prev = prev >= 0
    nxt[prev[has_prev]] = np.flatnonzero(has_prev)

    first = ~has_prev
    distinct_before = np.cumsum(first) - first      # U[i]

    inv = _count_prev_smaller(nxt)                  # #{t < p : next[t] < next[p]}

    d = np.full(n, -1, np.int64)
    p = prev[has_prev]
    d[has_prev] = distinct_before[has_prev] - (p + 1) + inv[p]
    return d


@dataclasses.dataclass(frozen=True)
class ReuseProfile:
    """Reuse-distance summary of one access stream (any capacity).

    ``distances`` covers the *collapsed* stream (consecutive duplicate
    lines merged); the ``accesses - collapsed_accesses`` merged
    duplicates are unconditional hits at every capacity >= 1, so
    :meth:`hits` restores them — hit/miss counts are bit-identical to a
    brute-force LRU walk of the raw stream.
    """

    accesses: int            # raw stream length
    collapsed_accesses: int
    unique_lines: int
    distances: object        # (collapsed_accesses,) int64, -1 = cold

    def hits(self, cache_bytes: int, line_bytes: int = LINE_BYTES) -> int:
        np = _np()
        capacity = max(1, int(cache_bytes) // int(line_bytes))
        d = self.distances
        collapsed_hits = int(np.count_nonzero((d >= 0) & (d < capacity)))
        return (self.accesses - self.collapsed_accesses) + collapsed_hits

    def misses(self, cache_bytes: int, line_bytes: int = LINE_BYTES) -> int:
        return self.accesses - self.hits(cache_bytes, line_bytes)

    def hit_rate(self, cache_bytes: int, line_bytes: int = LINE_BYTES) -> float:
        return self.hits(cache_bytes, line_bytes) / max(1, self.accesses)


def reuse_profile(line_ids) -> ReuseProfile:
    """Profile an access stream of cache-line ids (see module docstring)."""
    np = _np()
    lines = np.asarray(line_ids)
    n = len(lines)
    if n == 0:
        return ReuseProfile(0, 0, 0, np.zeros(0, np.int64))
    keep = np.r_[True, lines[1:] != lines[:-1]]
    collapsed = lines[keep]
    return ReuseProfile(
        accesses=int(n),
        collapsed_accesses=int(len(collapsed)),
        unique_lines=int(len(np.unique(collapsed))),
        distances=reuse_distances(collapsed),
    )


def lru_hit_rate(line_ids, cache_bytes: int,
                 line_bytes: int = LINE_BYTES) -> float:
    """Hit rate of a fully-associative LRU over ``line_ids`` (exact)."""
    return reuse_profile(line_ids).hit_rate(cache_bytes, line_bytes)


def stream_stats(line_ids, *, nnz: int,
                 l1_bytes: int = L1_BYTES,
                 l2_bytes: int = L2_BYTES,
                 line_bytes: int = LINE_BYTES,
                 flops: int | None = None) -> dict:
    """The locality row every report/bench renders for one stream.

    ``misses/nnz`` is the format-comparable metric (hit *rate* alone
    rewards formats that simply make more redundant accesses per
    element); ``bytes_moved`` is L2-miss traffic (the DRAM side of the
    roofline) and ``arith_intensity`` divides ``flops`` (default
    ``FLOPS_PER_NNZ * nnz``) by it.
    """
    prof = reuse_profile(line_ids)
    nnz = max(1, int(nnz))
    flops = FLOPS_PER_NNZ * nnz if flops is None else int(flops)
    l1_miss = prof.misses(l1_bytes, line_bytes)
    l2_miss = prof.misses(l2_bytes, line_bytes)
    bytes_moved = l2_miss * line_bytes
    return {
        "accesses": prof.accesses,
        "unique_lines": prof.unique_lines,
        "l1_hit_rate": prof.hit_rate(l1_bytes, line_bytes),
        "l2_hit_rate": prof.hit_rate(l2_bytes, line_bytes),
        "l1_misses_per_nnz": l1_miss / nnz,
        "l2_misses_per_nnz": l2_miss / nnz,
        "bytes_moved": int(bytes_moved),
        "arith_intensity": flops / max(1, bytes_moved),
    }


# ---------------------------------------------------------------------------
# Access-stream generators over the REAL batched-engine inputs.
# ---------------------------------------------------------------------------

class _AddressSpace:
    """Line-aligned virtual layout: one region per device buffer."""

    def __init__(self, line_bytes: int = LINE_BYTES) -> None:
        self._line = int(line_bytes)
        self._top = 0

    def region(self, nbytes: int) -> int:
        base = self._top
        self._top += -(-int(nbytes) // self._line) * self._line
        return base


def _seq_lines(np, base: int, nbytes: int, line_bytes: int):
    """Line ids a sequential walk of [base, base+nbytes) touches, in order.

    One entry per line (not per element): a streaming DMA revisits a
    line only consecutively, and :func:`reuse_profile` collapses
    consecutive duplicates anyway — emitting lines directly is
    bit-equivalent and ~line/element smaller.
    """
    if nbytes <= 0:
        return np.zeros(0, np.int64)
    return np.arange(base // line_bytes,
                     (base + nbytes - 1) // line_bytes + 1, dtype=np.int64)


def access_stream_super(streams, *, include_output: bool = False,
                        line_bytes: int = LINE_BYTES):
    """Byte-access stream of one batched SpMV pass over ``streams``.

    ``streams`` is a ``core.streams.SuperBlockStreams`` (duck-typed —
    only shape/index metadata is read, never values, so the result is a
    pure function of the plan's structure). Emission follows the
    engine's execution order: one ``pallas_call`` per non-empty format
    (dense, panel, coo), and per grid step within it

      1. the gather-index row (``*_xidx``, int32) and the payload row
         (values; plus packed codes for coo) — each a single sequential
         HBM->VMEM DMA of that stream row,
      2. the x gathers the row's indices drive (one access per lane /
         tile column, in lane order — padding lanes really do gather
         ``x[0]``, so they are charged),
      3. with ``include_output=True``, the scatter-add partial rows
         (one access per output element; flat-format baselines carry no
         output traffic, so comparisons default to leaving it out).

    Returns an int64 array of cache-line ids for :func:`reuse_profile`.
    """
    np = _np()
    B = int(streams.block_size)
    vb = int(streams.val_itemsize)
    ib = 4  # int32 gather indices / packed codes

    dense_tiles = np.asarray(streams.dense_tiles)
    dense_xidx = np.asarray(streams.dense_xidx)
    panel_vals = np.asarray(streams.panel_vals)
    panel_xidx = np.asarray(streams.panel_xidx)
    coo_codes = np.asarray(streams.coo_codes)
    coo_xidx = np.asarray(streams.coo_xidx)

    space = _AddressSpace(line_bytes)
    base = {name: space.region(nbytes)
            for name, nbytes in streams.region_nbytes().items()}
    base_dt, base_dx = base["dense_tiles"], base["dense_xidx"]
    base_pv, base_px = base["panel_vals"], base["panel_xidx"]
    base_cc, base_cv, base_cx = (base["coo_codes"], base["coo_vals"],
                                 base["coo_xidx"])
    base_x, base_y = base["x"], base["y"]

    out = []

    def x_lines(idx):
        return base_x // line_bytes + (
            idx.astype(np.int64) * vb + base_x % line_bytes) // line_bytes

    def y_lines(brow_per_slot):
        rows = (brow_per_slot.astype(np.int64)[:, None] * B
                + np.arange(B, dtype=np.int64)[None, :]).reshape(-1)
        return (base_y + rows * vb) // line_bytes

    # -- dense super-tiles ------------------------------------------------
    row_dt = dense_tiles.shape[1] * dense_tiles.shape[2] * vb if \
        dense_tiles.ndim == 3 else 0
    row_dx = dense_xidx.shape[1] * dense_xidx.shape[2] * ib if \
        dense_xidx.ndim == 3 else 0
    for g in range(streams.num_dense_groups):
        out.append(_seq_lines(np, base_dx + g * row_dx, row_dx, line_bytes))
        out.append(_seq_lines(np, base_dt + g * row_dt, row_dt, line_bytes))
        out.append(x_lines(dense_xidx[g].reshape(-1)))
        if include_output:
            out.append(y_lines(np.asarray(streams.dense_brow)[g]))

    # -- lane-packed panels -----------------------------------------------
    Wp = panel_vals.shape[-1]
    row_pv = panel_vals.shape[1] * Wp * vb
    row_px = Wp * ib
    for g in range(streams.num_panel_groups):
        out.append(_seq_lines(np, base_px + g * row_px, row_px, line_bytes))
        out.append(_seq_lines(np, base_pv + g * row_pv, row_pv, line_bytes))
        out.append(x_lines(panel_xidx[g]))
        if include_output:
            out.append(y_lines(np.asarray(streams.panel_brow)[g]))

    # -- lane-packed coo --------------------------------------------------
    Wc = coo_codes.shape[-1]
    for g in range(streams.num_coo_groups):
        out.append(_seq_lines(np, base_cx + g * Wc * ib, Wc * ib, line_bytes))
        out.append(_seq_lines(np, base_cc + g * Wc * ib, Wc * ib, line_bytes))
        out.append(_seq_lines(np, base_cv + g * Wc * vb, Wc * vb, line_bytes))
        out.append(x_lines(coo_xidx[g]))
        if include_output:
            out.append(y_lines(np.asarray(streams.coo_brow)[g]))

    if not out:
        return np.zeros(0, np.int64)
    return np.concatenate(out)


def access_stream_super_tile(ts, n_cols: int | None = None, *,
                             include_output: bool = False,
                             line_bytes: int = LINE_BYTES):
    """Byte-access stream of one batched SpMM sweep over ``ts``.

    ``ts`` is a ``core.streams.SuperTileStream``. The grid is
    (activation n-tile, group): per n-tile the whole weight super-tile
    stream is re-read (the real traffic pattern the engine pays), and
    each slot DMAs its X block's ``bn``-column row segments via the
    ``bcol`` slot map. ``n_cols`` defaults to one lane tile
    (``streams.LANE``); the activation tile width comes from
    ``streams.spmm_block_n`` — the single home of the lane rule.
    """
    np = _np()
    from repro.core.streams import LANE, spmm_block_n

    B = int(ts.block_size)
    tiles = np.asarray(ts.tiles)
    vb = int(ts.val_itemsize)
    N = LANE if n_cols is None else int(n_cols)
    bn = spmm_block_n(N)
    n_tiles = -(-N // bn)
    Np = n_tiles * bn                       # padded activation width

    space = _AddressSpace(line_bytes)
    base_w = space.region(ts.region_nbytes()["tiles"])
    base_x = space.region(int(ts.nb) * B * Np * vb)
    base_y = space.region(int(ts.mb) * B * Np * vb)

    bcol = np.asarray(ts.bcol)
    brow = np.asarray(ts.brow)
    row_w = tiles.shape[1] * tiles.shape[2] * vb if tiles.ndim == 3 else 0
    col = np.arange(bn, dtype=np.int64)

    def tile_rows(base, block_rows, j):
        """Row-segment lines: B rows per slot, bn contiguous cols each."""
        rows = (block_rows.astype(np.int64)[:, None] * B
                + np.arange(B, dtype=np.int64)[None, :]).reshape(-1)
        byte = base + (rows[:, None] * Np + j * bn + col[None, :]) * vb
        return (byte // line_bytes).reshape(-1)

    out = []
    for j in range(n_tiles):
        for g in range(ts.num_groups):
            out.append(_seq_lines(np, base_w + g * row_w, row_w, line_bytes))
            out.append(tile_rows(base_x, bcol[g], j))
            if include_output:
                out.append(tile_rows(base_y, brow[g], j))
    if not out:
        return np.zeros(0, np.int64)
    return np.concatenate(out)
