"""Process-wide metrics registry with typed, labeled instruments.

Zero-dependency (stdlib only — no jax, no numpy): the registry is
imported by hot host paths (``kernels/ops``, ``autotune/plan``,
``serving/engine``) that must stay importable in the standalone guard
scripts, and snapshot values are plain Python ints/floats so
``json.dumps`` always works.

Three instrument kinds, all supporting labeled series (one independent
value per label combination):

  * :class:`Counter`   — monotonically increasing sum (``inc``);
  * :class:`Gauge`     — last-written value (``set``);
  * :class:`Histogram` — fixed **log2 buckets** (upper edges at powers
    of two), so p50/p99 are deterministic functions of the observed
    multiset: a quantile is always reported as its bucket's upper edge,
    never interpolated from machine-dependent timings.

Naming convention: ``repro.<subsystem>.<metric>`` (see ``obs/README.md``
for the catalog). All recording is gated on :func:`is_enabled` —
``configure(enabled=False)`` turns every instrument into a cheap no-op —
and timestamps come from the injectable :func:`now` clock so tests can
drive deterministic time.

:class:`MirroredCounter` is the migration shim for the pre-obs private
counters (``solvers.krylov._TRACE_COUNTS``, ``PlanCache`` hit/miss/
stale): a real ``collections.Counter`` whose increments are *also*
forwarded to a registry counter. The local dict stays the source of
truth for the legacy attribute API (correct even when obs is disabled);
the registry series is the telemetry view.
"""
from __future__ import annotations

import collections
import json
import math
import threading
import time
from repro import errors


# ---------------------------------------------------------------------------
# Process-wide configuration: the enabled flag and the injectable clock.
# ---------------------------------------------------------------------------

class _Config:
    __slots__ = ("enabled", "clock")

    def __init__(self) -> None:
        self.enabled = True
        self.clock = time.monotonic


CONFIG = _Config()


def configure(*, enabled: bool | None = None, clock=None) -> None:
    """Set the process-wide obs switches (None leaves a switch untouched).

    ``enabled=False`` turns every instrument and span into a no-op-cheap
    guard check; ``clock`` replaces the monotonic clock used for span
    timing and latency histograms (inject a fake for deterministic
    tests).
    """
    if enabled is not None:
        CONFIG.enabled = bool(enabled)
    if clock is not None:
        CONFIG.clock = clock


def is_enabled() -> bool:
    return CONFIG.enabled


def now() -> float:
    """Current time from the configured (injectable) monotonic clock."""
    return CONFIG.clock()


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------

class Instrument:
    """Base: one named metric holding independent labeled series."""

    kind = "instrument"

    def __init__(self, name: str, registry: "MetricsRegistry") -> None:
        self.name = name
        self._registry = registry
        self._series: dict[tuple, object] = {}

    def reset(self) -> None:
        with self._registry._lock:
            self._series.clear()

    def labelsets(self) -> list[dict]:
        return [dict(key) for key in sorted(self._series)]

    def _snapshot_value(self, state):  # pragma: no cover - overridden
        raise NotImplementedError

    def snapshot(self) -> list[dict]:
        with self._registry._lock:
            return [
                {"labels": dict(key), **self._snapshot_value(state)}
                for key, state in sorted(self._series.items())
            ]


class Counter(Instrument):
    """Monotonic sum. ``inc`` rejects negative deltas by contract."""

    kind = "counter"

    def inc(self, value: float = 1, **labels) -> None:
        if not CONFIG.enabled:
            return
        if value < 0:
            raise errors.InvalidArgError(
                f"counter {self.name!r}: negative increment {value!r}"
            )
        key = _label_key(labels)
        with self._registry._lock:
            self._series[key] = self._series.get(key, 0) + value

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0)

    def total(self) -> float:
        """Sum across every labeled series."""
        with self._registry._lock:
            return sum(self._series.values())

    def _snapshot_value(self, state):
        return {"value": state}


class Gauge(Instrument):
    """Last-written value per labeled series."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not CONFIG.enabled:
            return
        with self._registry._lock:
            self._series[_label_key(labels)] = value

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0)

    def _snapshot_value(self, state):
        return {"value": state}


# Fixed log2 bucket upper edges: 2^-30 (~1ns in seconds) .. 2^31. A value
# lands in the smallest bucket whose upper edge it does not exceed; the
# two sentinel buckets catch underflow (v <= 2^-30, including 0) and
# overflow (v > 2^31). Fixed edges make every percentile a deterministic
# function of the observed multiset, independent of arrival order.
_MIN_EXP = -30
_MAX_EXP = 31
BUCKET_EDGES: tuple[float, ...] = tuple(
    2.0 ** e for e in range(_MIN_EXP, _MAX_EXP + 1)
)


def bucket_index(value: float) -> int:
    """Index of the log2 bucket holding ``value`` (see BUCKET_EDGES)."""
    if not value > BUCKET_EDGES[0]:
        return 0
    if value > BUCKET_EDGES[-1]:
        return len(BUCKET_EDGES)
    m, e = math.frexp(value)          # value = m * 2^e, 0.5 <= m < 1
    exp = e - 1 if m == 0.5 else e    # ceil(log2(value))
    return exp - _MIN_EXP


class _HistState:
    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self) -> None:
        self.counts = [0] * (len(BUCKET_EDGES) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf


class Histogram(Instrument):
    """Fixed-log2-bucket histogram with deterministic percentiles."""

    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        if not CONFIG.enabled:
            return
        value = float(value)
        key = _label_key(labels)
        with self._registry._lock:
            state = self._series.get(key)
            if state is None:
                state = self._series[key] = _HistState()
            state.counts[bucket_index(value)] += 1
            state.count += 1
            state.sum += value
            state.min = min(state.min, value)
            state.max = max(state.max, value)

    def percentile(self, p: float, **labels) -> float:
        """Deterministic quantile: the upper edge of the bucket holding
        the ``ceil(p * count)``-th observation (the true max for the
        overflow bucket)."""
        state = self._series.get(_label_key(labels))
        if state is None or state.count == 0:
            return 0.0
        rank = max(1, math.ceil(p * state.count))
        seen = 0
        for i, c in enumerate(state.counts):
            seen += c
            if seen >= rank:
                return BUCKET_EDGES[i] if i < len(BUCKET_EDGES) else state.max
        return state.max  # pragma: no cover - rank <= count always hits

    def summary(self, **labels) -> dict:
        state = self._series.get(_label_key(labels))
        if state is None or state.count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p99": 0.0}
        return {
            "count": state.count,
            "sum": state.sum,
            "min": state.min,
            "max": state.max,
            "p50": self.percentile(0.50, **labels),
            "p99": self.percentile(0.99, **labels),
        }

    def _snapshot_value(self, state: _HistState):
        # recompute the percentile walk inline: snapshot() holds the lock
        summary = {"count": state.count, "sum": state.sum,
                   "min": state.min, "max": state.max}
        for tag, p in (("p50", 0.50), ("p99", 0.99)):
            rank = max(1, math.ceil(p * state.count))
            seen, val = 0, state.max
            for i, c in enumerate(state.counts):
                seen += c
                if seen >= rank:
                    val = (BUCKET_EDGES[i] if i < len(BUCKET_EDGES)
                           else state.max)
                    break
            summary[tag] = val
        return {"summary": summary}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Name -> instrument store with snapshot / reset / JSON export."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._instruments: dict[str, Instrument] = {}

    def _get(self, name: str, cls) -> Instrument:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, self)
            elif type(inst) is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}, "
                    f"requested {cls.kind}"
                )
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> dict:
        """JSON-able view: {name: {"type": kind, "series": [...]}}.

        Series with no recordings are omitted; ordering is sorted, so
        two identical recording sequences snapshot identically.
        """
        with self._lock:
            return {
                name: {"type": inst.kind, "series": inst.snapshot()}
                for name, inst in sorted(self._instruments.items())
                if inst._series
            }

    def reset(self) -> None:
        """Clear every series (instrument objects stay registered)."""
        with self._lock:
            for inst in self._instruments.values():
                inst.reset()

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True)


# The process-wide default registry: subsystem instrumentation all lands
# here so one ``snapshot()`` sees the whole engine.
_DEFAULT_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _DEFAULT_REGISTRY


class MirroredCounter(collections.Counter):
    """``collections.Counter`` whose increments also feed the registry.

    Drop-in for the historical private counters: call sites keep the
    ``counts[key] += 1`` / ``dict(counts)`` idioms (PR 6/7 tests rely on
    them), while every positive delta is forwarded to the registry
    counter ``metric`` with the key as the ``label`` value. The local
    dict stays authoritative — it keeps counting even when obs is
    disabled or the registry is reset, so the legacy attribute API never
    changes meaning.
    """

    def __init__(self, data=None, *, metric: str | None = None,
                 label: str = "key", registry: MetricsRegistry | None = None):
        self._metric = metric
        self._label = label
        self._registry = registry
        super().__init__()
        if data:
            for k, v in dict(data).items():   # seed without re-mirroring
                super().__setitem__(k, v)

    def __setitem__(self, key, value) -> None:
        if self._metric is not None:
            delta = value - self.get(key, 0)
            if delta > 0:
                reg = self._registry or _DEFAULT_REGISTRY
                reg.counter(self._metric).inc(delta, **{self._label: key})
        super().__setitem__(key, value)
