"""Version-guarded JAX compatibility shims.

The repo tracks current JAX APIs but must run on every toolchain the
container ships (currently 0.4.37). Every API whose name or signature
drifted between JAX 0.4.x and newer releases is funneled through this
module so the rest of the codebase is version-agnostic:

  * ``tpu_compiler_params``  — ``pltpu.CompilerParams`` was called
    ``TPUCompilerParams`` before jax 0.6.
  * ``pallas_call_tpu``      — one entry point for every Pallas TPU call
    site; centralizes ``dimension_semantics``/``interpret`` handling so
    kernels never touch ``compiler_params`` directly.
  * ``make_mesh`` / ``mesh_axis_types`` — ``jax.sharding.AxisType`` and
    the ``axis_types=`` kwarg of ``jax.make_mesh`` don't exist in 0.4.x.
  * ``shard_map``            — lives at ``jax.experimental.shard_map``
    with a ``check_rep`` kwarg in 0.4.x, at ``jax.shard_map`` with
    ``check_vma`` in newer releases.

Nothing here may import heavyweight repro modules; kernels and launch
code import *us*.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Sequence

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro import errors

# ---------------------------------------------------------------------------
# Pallas compiler params
# ---------------------------------------------------------------------------

# jax >= 0.6 renamed TPUCompilerParams -> CompilerParams; support both and
# prefer the modern name when present.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def tpu_compiler_params(
    *, dimension_semantics: Sequence[str] | None = None, **kwargs: Any
):
    """Build the TPU compiler-params object for this JAX version."""
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return _CompilerParams(**kwargs)


def pallas_call_tpu(
    kernel: Callable,
    *,
    out_shape,
    interpret: bool,
    grid=None,
    grid_spec=None,
    in_specs=None,
    out_specs=None,
    dimension_semantics: Sequence[str] | None = None,
    name: str | None = None,
    **kwargs: Any,
):
    """``pl.pallas_call`` with version-stable TPU compiler params.

    Exactly one of ``grid_spec`` (e.g. ``pltpu.PrefetchScalarGridSpec``)
    or the ``grid``/``in_specs``/``out_specs`` triple must be given —
    mirroring ``pl.pallas_call`` itself. Returns the callable to apply to
    the operands.

    ``interpret`` is deliberately required: our kernels default it per
    backend (interpret off-TPU, compiled on TPU) and a silent default
    here would make a future TPU call site run the interpreter — slow
    with no error. Unsupplied grid/spec arguments are left to
    ``pl.pallas_call``'s own defaults rather than forwarded as ``None``.
    """
    call_kwargs: dict[str, Any] = dict(
        out_shape=out_shape,
        compiler_params=tpu_compiler_params(
            dimension_semantics=dimension_semantics
        ),
        interpret=interpret,
        name=name,
        **kwargs,
    )
    if grid_spec is not None:
        if grid is not None or in_specs is not None or out_specs is not None:
            raise errors.InvalidArgError("pass either grid_spec or grid/in_specs/out_specs")
        call_kwargs["grid_spec"] = grid_spec
    else:
        for key, value in (("grid", grid), ("in_specs", in_specs),
                           ("out_specs", out_specs)):
            if value is not None:
                call_kwargs[key] = value
    return pl.pallas_call(kernel, **call_kwargs)


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def mesh_axis_types(num_axes: int) -> dict[str, Any]:
    """kwargs enabling explicit Auto axis types where the API supports it.

    Returns ``{}`` on JAX 0.4.x (where every mesh axis is implicitly
    Auto), so call sites can always splat the result into ``make_mesh``.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None or not _MAKE_MESH_TAKES_AXIS_TYPES:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_mesh(
    axis_shapes: Sequence[int], axis_names: Sequence[str], **kwargs: Any
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types on versions that take them."""
    return jax.make_mesh(
        axis_shapes, axis_names, **mesh_axis_types(len(axis_shapes)), **kwargs
    )


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


def shard_map(
    f: Callable,
    *,
    mesh: jax.sharding.Mesh,
    in_specs,
    out_specs,
    check_vma: bool | None = None,
    **kwargs: Any,
):
    """Version-stable ``shard_map`` (supports ``functools.partial`` use).

    ``check_vma`` follows the modern spelling; it maps onto ``check_rep``
    for JAX 0.4.x where shard_map still lives under ``jax.experimental``.
    """
    modern = getattr(jax, "shard_map", None)
    if modern is not None:
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return modern(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
    from jax.experimental.shard_map import shard_map as legacy

    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kwargs)
