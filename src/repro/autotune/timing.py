"""Measurement helpers shared by the autotuner and the benchmark sections.

Lives in-package so ``search.py``'s empirical refinement can time
candidates without reaching outside ``src/``; ``benchmarks/_timing.py``
re-exports these same helpers so every benchmark section keeps one
timing discipline.
"""
from __future__ import annotations

import time

import numpy as np


def time_min(fn, *args, reps=15):
    """Min of individually-timed calls (two warmups first): robust to
    scheduler noise at the microsecond scales the small matrices produce
    on a shared box."""
    fn(*args).block_until_ready()
    fn(*args).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def geomean(xs) -> float:
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
