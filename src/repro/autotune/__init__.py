"""Adaptive autotuning + persistent plan cache for the CB engines.

Converts the repo's hardcoded performance constants (th1/th2 format
thresholds, the th0 colagg gate, TARGET_STEP_ELEMS / MAX_GROUP_SIZE
group sizing) into per-matrix decisions: cheap feature extraction
(``features``), an analytical cost model over the stream builders
(``cost``), empirical refinement of the top-k candidates (``search``),
and a schema-versioned plan cache keyed on the canonical *structure*
hash (``plan``) so the planning cost amortizes across processes and
across value updates. See ``autotune/README.md``.
"""
from .cost import (  # noqa: F401
    CandidateConfig,
    CostEstimate,
    DEFAULT_CONFIG,
    default_candidates,
    estimate,
    rank,
)
from .features import (  # noqa: F401
    CANDIDATE_BLOCK_SIZES,
    BlockProfile,
    MatrixFeatures,
    extract_features,
    feature_vector,
    features_from_cb,
)
from .plan import (  # noqa: F401
    PLAN_SCHEMA,
    PLAN_SCHEMA_V1,
    MatrixHashes,
    Plan,
    PlanCache,
    canonical_triplets,
    legacy_content_hash,
    matrix_content_hash,
    matrix_hashes,
    structure_hash,
    value_hash,
)
from .search import (  # noqa: F401
    DEFAULT_SETTINGS,
    SearchSettings,
    plan_search,
    resolve_mode,
)
