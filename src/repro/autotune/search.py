"""Empirical refinement: build (and optionally time) the top-k candidates.

``plan_search`` is the subsystem's front door (``CBMatrix.plan_for``
delegates here):

  1. hash the matrix; a ``PlanCache`` hit returns the stored plan with
     zero work (the cross-process amortization path);
  2. extract features, rank the candidate grid with the analytical cost
     model (``cost.rank``) — no kernels run;
  3. **refine**: the top-k candidates plus the default-constants
     configuration are actually *built* (``CBMatrix.from_coo`` +
     ``build_super_streams``), giving exact padded-work and step counts
     instead of estimates. Candidates sharing a structural config
     (block size / thresholds / colagg) share one CBMatrix build — only
     the stream packing differs per group size;
  4. select: in **timed** mode the shortlist is timed through
     ``ops.cb_spmv`` (``timing.time_min``, interpret-aware — off-TPU the
     Pallas kernels run interpreted) and the fastest wins. In
     **heuristic** mode — the default off TPU, where interpret-mode wall
     time says nothing about hardware — selection minimizes
     ``padded + STEP_OVERHEAD_ELEMS * steps`` over the *measured*
     builds, restricted to candidates whose padded work does not exceed
     the default configuration's (so a tuned plan never regresses the
     guarded padded-work metric; ``allow_padded_regression=True`` lifts
     the restriction). Heuristic mode consumes no wall clock anywhere,
     so the same matrix always yields the same plan bit-for-bit.

The returned ``Plan`` records the winning configuration with its
*resolved* colagg decision plus the model's prediction and the measured
values, and is stored in the cache when one was given.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.cb_matrix import CBMatrix
from repro.core.streams import build_super_streams
from repro import errors

from . import timing
from .cost import (
    DEFAULT_CONFIG, STEP_OVERHEAD_ELEMS, CandidateConfig, default_candidates,
    estimate, rank,
)
from .features import extract_features
from .plan import Plan, PlanCache, legacy_content_hash, matrix_hashes


@dataclasses.dataclass(frozen=True)
class SearchSettings:
    """Knobs of the refinement pass (not of the candidate space)."""

    top_k: int = 3
    mode: str = "auto"              # "heuristic" | "timed" | "auto"
    timing_reps: int = 5
    allow_padded_regression: bool = False
    candidates: tuple[CandidateConfig, ...] | None = None


DEFAULT_SETTINGS = SearchSettings()


def resolve_mode(mode: str) -> str:
    """'auto' -> timed on real TPU hardware, heuristic elsewhere.

    Off TPU the Pallas kernels run in interpret mode, whose wall time
    reflects the interpreter, not the machine the plan will serve —
    timing there would tune for the wrong target (and break the
    determinism contract for no gain).
    """
    if mode in ("heuristic", "timed"):
        return mode
    if mode != "auto":
        raise errors.InvalidArgError(f"unknown search mode {mode!r}")
    import jax

    return "timed" if jax.default_backend() == "tpu" else "heuristic"


@dataclasses.dataclass
class _Refined:
    """One shortlisted candidate after the build-and-measure pass."""

    config: CandidateConfig
    cb: CBMatrix
    streams: object
    padded_elems: int
    steps: int
    t_spmv: float | None = None

    @property
    def heuristic_score(self) -> float:
        return self.padded_elems + STEP_OVERHEAD_ELEMS * self.steps


def _build_candidate(rows, cols, vals, shape, val_dtype, config,
                     cb_by_structure: dict) -> _Refined:
    skey = (config.block_size, config.thresholds, config.colagg)
    cb = cb_by_structure.get(skey)
    if cb is None:
        cb = cb_by_structure[skey] = CBMatrix.from_coo(
            rows, cols, vals, shape,
            block_size=config.block_size,
            val_dtype=val_dtype,
            thresholds=config.thresholds,
            use_column_aggregation=config.colagg,
        )
    streams = build_super_streams(cb, group_size=config.resolved_group_size())
    return _Refined(
        config=config, cb=cb, streams=streams,
        padded_elems=int(sum(streams.padded_work().values())),
        steps=int(streams.num_dense_groups + streams.num_panel_groups
                  + streams.num_coo_groups),
    )


def _time_candidate(refined: _Refined, shape, reps: int) -> float:
    from repro.kernels import ops

    x = jnp.asarray(
        np.random.default_rng(0).standard_normal(shape[1]), jnp.float32
    )
    return timing.time_min(
        lambda s, xx: ops.cb_spmv(s, xx, impl="pallas"),
        refined.streams.device_put(), x, reps=reps,
    )


def plan_search(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    *,
    val_dtype=np.float32,
    cache: PlanCache | None = None,
    settings: SearchSettings | None = None,
) -> Plan:
    """Pick a per-matrix CB configuration (see module docstring)."""
    settings = DEFAULT_SETTINGS if settings is None else settings
    val_dtype = np.dtype(val_dtype)
    hashes = matrix_hashes(rows, cols, vals, shape, val_dtype)
    if cache is not None:
        # Structure-keyed lookup: value churn reuses the plan. The v1
        # content hash rides along so pre-split plan files still hit
        # (and migrate) instead of forcing one last re-plan.
        hit = cache.get(
            hashes.structure,
            legacy_hash=legacy_content_hash(rows, cols, vals, shape,
                                            val_dtype),
            shape=shape,
            nnz=hashes.nnz,
        )
        if hit is not None:
            return hit

    mode = resolve_mode(settings.mode)
    features = extract_features(rows, cols, vals, shape)
    candidates = (default_candidates() if settings.candidates is None
                  else settings.candidates)
    ranked = rank(features, candidates)

    # shortlist: top-k by model score, default config always present
    shortlist = [c for c, _ in ranked[: max(1, settings.top_k)]]
    if DEFAULT_CONFIG not in shortlist:
        shortlist.append(DEFAULT_CONFIG)

    cb_by_structure: dict = {}
    refined = [
        _build_candidate(rows, cols, vals, shape, val_dtype, c,
                         cb_by_structure)
        for c in shortlist
    ]
    default_refined = next(r for r in refined if r.config == DEFAULT_CONFIG)

    if mode == "timed":
        for r in refined:
            r.t_spmv = _time_candidate(r, shape, settings.timing_reps)
        best = min(refined, key=lambda r: (r.t_spmv, r.padded_elems))
    else:
        pool = refined
        if not settings.allow_padded_regression:
            pool = [r for r in refined
                    if r.padded_elems <= default_refined.padded_elems]
        # min() is stable: ties keep shortlist (= model-rank) order
        best = min(pool, key=lambda r: r.heuristic_score)

    predicted = estimate(features, best.config)
    plan = Plan(
        structure_hash=hashes.structure,
        value_hash=hashes.value,
        shape=tuple(int(v) for v in shape),
        nnz=hashes.nnz,
        val_dtype=val_dtype.name,
        block_size=best.config.block_size,
        th0=best.config.thresholds.th0,
        th1=best.config.thresholds.th1,
        th2=best.config.thresholds.th2,
        colagg=bool(best.cb.colagg.applied),
        group_size=best.config.resolved_group_size(),
        mode=mode,
        predicted_padded_elems=predicted.padded_elems,
        predicted_steps=predicted.steps,
        measured_padded_elems=best.padded_elems,
        measured_steps=best.steps,
        t_spmv=best.t_spmv,
    )
    if cache is not None:
        cache.put(plan)
    return plan
