"""Analytical cost model over the stream builders — ranks plans unrun.

The model prices a candidate configuration (block size B, th1/th2
format thresholds, column-aggregation mode, group size G) by mirroring
the *arithmetic* of ``core/streams.build_super_streams`` on the block
profile from ``features.py``, without building anything:

  * **padded work** — elements the kernels would stream per SpMV pass:
    dense blocks cost ``B*B`` each (evened groups via ``even_group``,
    exactly as the packer evens slots); CSR blocks cost ``B *
    bucket(width)`` where ``width`` is the block's distinct-column
    count and ``bucket`` rounds to the SUBLANE like ``pad_width``;
    COO blocks cost ``bucket(nnz)``. Group widths assume the Alg. 2
    balancer achieves its target (max group ~= mean group), which it
    does to within a bucket on every corpus family.
  * **grid steps** — groups per format, ``ceil(count / G)``: the
    per-step dispatch overhead the batched engines amortize.
  * **scatter rows** — per-slot partial rows the fused combine adds:
    ``G`` per dense group plus ``W / SUBLANE`` per packed group.

Column aggregation is the one *estimated* quantity: a compacted panel
with ``C`` distinct nonzero columns spans ``ceil(C / B)`` blocks with
its nnz concentrated into them (paper §3.3.1). The model redistributes
each panel's nnz over that many synthetic blocks; format selection then
runs on the synthetic profile. The estimate is deliberately optimistic
about balance and pessimistic about nothing — which is fine, because
``search.py`` *builds* the top-k candidates and measures the real
streams before committing; the model only has to rank.

The score folds the three quantities into element-equivalents:
``padded + STEP_OVERHEAD_ELEMS * steps + SCATTER_ROW_ELEMS * rows``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import DEFAULT_THRESHOLDS, FormatThresholds
from repro.core.streams import (
    MAX_GROUP_SIZE, SUBLANE, TARGET_STEP_ELEMS, even_group, group_size_for,
    pad_width,
)

from .features import CANDIDATE_BLOCK_SIZES, MatrixFeatures

# Fixed cost of one grid step in payload-element equivalents: dispatch,
# DMA setup, and the per-step one-hot scratch. Calibrated against the
# spmv_batch section's interpret-mode step-count sensitivity; order of
# magnitude is what matters for ranking (G=1 must lose to G=16 on a
# 10k-block matrix, a 3-block matrix must not chase giant groups).
STEP_OVERHEAD_ELEMS = 512

# Cost of one per-slot partial row in the fused scatter-add combine.
SCATTER_ROW_ELEMS = 16


@dataclasses.dataclass(frozen=True)
class CandidateConfig:
    """One point in the planner's configuration space."""

    block_size: int = 16
    thresholds: FormatThresholds = DEFAULT_THRESHOLDS
    colagg: object = "auto"          # "auto" | True | False
    group_size: int | None = None    # None -> group_size_for(block_size)

    def resolved_group_size(self) -> int:
        if self.group_size is None:
            return group_size_for(self.block_size)
        return int(self.group_size)


DEFAULT_CONFIG = CandidateConfig()


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """The model's prediction for one candidate on one matrix."""

    padded_elems: int
    steps: int
    scatter_rows: int
    colagg_applied: bool
    score: float


def _colagg_profile(prof, B: int):
    """Synthetic (nnz, width) per block after panel compaction.

    Each panel's ``C`` distinct columns compact into ``ceil(C / B)``
    blocks; its nnz spreads evenly over them and the last block keeps
    the ragged ``C mod B`` width.
    """
    blocks_per_panel = np.maximum(1, -(-prof.panel_cols // B))
    total = int(blocks_per_panel.sum())
    nnz_est = np.repeat(prof.panel_nnz // blocks_per_panel, blocks_per_panel)
    # spread the remainder one element per leading block of each panel
    rem = np.repeat(prof.panel_nnz % blocks_per_panel, blocks_per_panel)
    first = np.repeat(
        np.cumsum(blocks_per_panel) - blocks_per_panel, blocks_per_panel
    )
    nnz_est += (np.arange(total) - first) < rem
    width_est = np.full(total, B, np.int64)
    last = np.cumsum(blocks_per_panel) - 1
    ragged = prof.panel_cols - (blocks_per_panel - 1) * B
    width_est[last] = ragged
    return nnz_est, np.minimum(width_est, np.maximum(nnz_est, 1))


def estimate(features: MatrixFeatures, config: CandidateConfig) -> CostEstimate:
    """Price one candidate configuration on one matrix's features."""
    B = config.block_size
    prof = features.profile(B)
    th1, th2 = config.thresholds.resolve(B)
    G = config.resolved_group_size()

    if config.colagg == "auto":
        applied = prof.super_sparse_fraction >= config.thresholds.th0
    else:
        applied = bool(config.colagg)

    if applied and prof.num_blocks:
        nnz_blk, width_blk = _colagg_profile(prof, B)
    else:
        nnz_blk, width_blk = prof.nnz_per_block, prof.cols_per_block

    is_coo = nnz_blk < th1
    is_dense = nnz_blk > th2
    is_csr = ~(is_coo | is_dense)

    padded = steps = rows = 0

    nd = int(is_dense.sum())
    if nd:
        gd, Gd = even_group(nd, G)
        padded += gd * Gd * B * B
        steps += gd
        rows += gd * Gd

    def _packed_cost(widths: np.ndarray) -> tuple[int, int, int]:
        """(padded_elems_per_row, groups, slot_rows) for lane packing."""
        count = len(widths)
        g, _ = even_group(count, G)
        bucketed = (-(-widths // SUBLANE)) * SUBLANE
        w = max(pad_width(int(np.ceil(bucketed.sum() / g))),
                int(bucketed.max()))
        return w, g, g * (w // SUBLANE)

    np_ = int(is_csr.sum())
    if np_:
        w, g, r = _packed_cost(width_blk[is_csr])
        padded += g * B * w
        steps += g
        rows += r

    nc = int(is_coo.sum())
    if nc:
        w, g, r = _packed_cost(nnz_blk[is_coo])
        padded += g * w
        steps += g
        rows += r

    score = (padded + STEP_OVERHEAD_ELEMS * steps
             + SCATTER_ROW_ELEMS * rows)
    return CostEstimate(
        padded_elems=int(padded), steps=int(steps), scatter_rows=int(rows),
        colagg_applied=bool(applied), score=float(score),
    )


def rank(
    features: MatrixFeatures,
    candidates: tuple[CandidateConfig, ...],
) -> list[tuple[CandidateConfig, CostEstimate]]:
    """Candidates sorted by model score (stable: ties keep input order)."""
    scored = [(c, estimate(features, c)) for c in candidates]
    return sorted(scored, key=lambda ce: ce[1].score)


def default_candidates(
    block_sizes: tuple[int, ...] = CANDIDATE_BLOCK_SIZES,
) -> tuple[CandidateConfig, ...]:
    """The stock configuration grid the planner searches.

    Per block size: the paper thresholds plus a denser-leaning and a
    sparser-leaning variant (shifting the COO/CSR/Dense boundaries by
    2x either way), colagg forced on/off/auto, and group sizes at the
    occupancy heuristic and half/double it. The default constants
    configuration is always element [0] so searches can special-case it.
    """
    out = [DEFAULT_CONFIG]
    for B in block_sizes:
        area = B * B
        ths = (
            DEFAULT_THRESHOLDS,
            FormatThresholds(th1=max(1, area // 16), th2=max(1, area // 4)),
            FormatThresholds(th1=max(1, area // 4),
                             th2=min(area, (3 * area) // 4)),
        )
        gs = group_size_for(B)
        sizes = sorted({gs, max(1, gs // 2), min(MAX_GROUP_SIZE, gs * 2)})
        for th in ths:
            for colagg in ("auto", True, False):
                for g in sizes:
                    cand = CandidateConfig(
                        block_size=B, thresholds=th, colagg=colagg,
                        group_size=g,
                    )
                    if cand != DEFAULT_CONFIG:
                        out.append(cand)
    return tuple(out)
