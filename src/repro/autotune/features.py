"""Cheap per-matrix feature extraction for the autotune cost model.

The paper's adaptivity levers — block size, th1/th2 format thresholds,
the th0 column-aggregation gate, and the batched engines' group size —
all key off *block-granular* statistics of the sparsity pattern. One
pass of vectorized numpy over the COO triplets yields, for every
candidate block size at once:

  * the per-block nnz distribution (drives format selection and the
    Alg. 2 balance story),
  * the per-block distinct-column count (the compacted panel width a
    FMT_CSR block would stream — exact, because ``_collect_blocks``
    packs exactly the unique columns),
  * per-panel (block-row) nonzero-column counts and nnz (the column-
    aggregation win estimate: a compacted panel spans
    ``ceil(cols / B)`` blocks instead of ``ceil(n / B)``),
  * the super-sparse fraction (the th0 gate input, paper Fig. 3).

Matrix-level scalars (nnz/row moments, bandwidth) ride along for
diagnostics and future learned selectors (PAPERS.md: the nonlinear-hash
SpMV work conditions on exactly these). Everything here is
O(nnz log nnz) host-side numpy — no kernels, no JAX, no wall clock, so
features (and everything derived from them in heuristic mode) are
bit-deterministic for a given matrix.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import super_sparse_fraction

# The block sizes the planner considers: the paper's 16 plus the
# neighbors the conformance grid already certifies.
CANDIDATE_BLOCK_SIZES = (8, 16, 24)


@dataclasses.dataclass
class BlockProfile:
    """Block-granular statistics of one matrix at one block size."""

    block_size: int
    num_blocks: int                 # nonzero B x B blocks
    nnz_per_block: np.ndarray       # (num_blocks,) int64
    cols_per_block: np.ndarray      # (num_blocks,) int64 distinct columns
    panel_nnz: np.ndarray           # (num_panels,) int64, nonempty panels
    panel_cols: np.ndarray          # (num_panels,) int64 distinct nonzero cols
    super_sparse_fraction: float    # th0 gate input


@dataclasses.dataclass
class MatrixFeatures:
    """Everything the cost model needs to rank candidate plans."""

    shape: tuple[int, int]
    nnz: int
    density: float
    row_nnz_mean: float
    row_nnz_cv: float               # std/mean — load-imbalance proxy (Fig. 4)
    row_nnz_max: int
    bandwidth_mean: float           # mean |r - c| — locality proxy
    bandwidth_max: int
    profiles: dict[int, BlockProfile]

    def profile(self, block_size: int) -> BlockProfile:
        prof = self.profiles.get(int(block_size))
        if prof is None:
            raise KeyError(
                f"no block profile for B={block_size}; extracted sizes: "
                f"{sorted(self.profiles)}"
            )
        return prof


def _block_profile(rows, cols, shape, block_size: int) -> BlockProfile:
    B = block_size
    nb = -(-shape[1] // B)
    bkey = (rows // B) * np.int64(nb) + cols // B

    ukeys, counts = np.unique(bkey, return_counts=True)
    # distinct columns per block: unique (block, col) pairs, counted per block
    ckey = bkey * np.int64(shape[1]) + cols
    ublocks_of_cols = np.unique(ckey) // np.int64(shape[1])
    _, col_counts = np.unique(ublocks_of_cols, return_counts=True)

    # per-panel (block-row) nnz and distinct nonzero columns
    prow = rows // B
    upanels, pnnz = np.unique(prow, return_counts=True)
    pckey = prow * np.int64(shape[1]) + cols
    upanel_of_cols = np.unique(pckey) // np.int64(shape[1])
    _, pcols = np.unique(upanel_of_cols, return_counts=True)

    return BlockProfile(
        block_size=B,
        num_blocks=len(ukeys),
        nnz_per_block=counts.astype(np.int64),
        cols_per_block=col_counts.astype(np.int64),
        panel_nnz=pnnz.astype(np.int64),
        panel_cols=pcols.astype(np.int64),
        super_sparse_fraction=super_sparse_fraction(counts, B),
    )


def extract_features(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    block_sizes: tuple[int, ...] = CANDIDATE_BLOCK_SIZES,
) -> MatrixFeatures:
    """One vectorized pass -> features at every candidate block size."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    m, n = int(shape[0]), int(shape[1])
    nnz = len(rows)

    if nnz:
        row_counts = np.bincount(rows, minlength=m).astype(np.int64)
        nz_rows = row_counts[row_counts > 0]
        band = np.abs(rows - cols)
        row_mean = float(nz_rows.mean())
        row_cv = float(nz_rows.std() / max(row_mean, 1e-12))
        row_max = int(nz_rows.max())
        band_mean, band_max = float(band.mean()), int(band.max())
    else:
        row_mean = row_cv = band_mean = 0.0
        row_max = band_max = 0

    return MatrixFeatures(
        shape=(m, n),
        nnz=nnz,
        density=nnz / max(1, m * n),
        row_nnz_mean=row_mean,
        row_nnz_cv=row_cv,
        row_nnz_max=row_max,
        bandwidth_mean=band_mean,
        bandwidth_max=band_max,
        profiles={int(B): _block_profile(rows, cols, (m, n), int(B))
                  for B in block_sizes},
    )


def feature_vector(features: MatrixFeatures) -> dict:
    """Flatten ``MatrixFeatures`` to an ordered ``{name: scalar}`` dict.

    The stable, named scalar view consumed by ``scripts/explain.py``
    (the "why this plan" report) and intended as the input row for the
    learned selector (ROADMAP): matrix-level moments first, then per
    candidate block size the distribution summaries of the block
    profile. Deterministic for a given matrix — pure arithmetic over
    :func:`extract_features` output, no wall clock.
    """
    m, n = features.shape
    out = {
        "m": float(m),
        "n": float(n),
        "nnz": float(features.nnz),
        "density": float(features.density),
        "row_nnz_mean": float(features.row_nnz_mean),
        "row_nnz_cv": float(features.row_nnz_cv),
        "row_nnz_max": float(features.row_nnz_max),
        "bandwidth_mean": float(features.bandwidth_mean),
        "bandwidth_max": float(features.bandwidth_max),
    }
    for B in sorted(features.profiles):
        prof = features.profiles[B]
        tag = f"b{B}"
        nnz_blk = prof.nnz_per_block
        cols_blk = prof.cols_per_block
        out[f"{tag}_num_blocks"] = float(prof.num_blocks)
        out[f"{tag}_nnz_per_block_mean"] = (
            float(nnz_blk.mean()) if len(nnz_blk) else 0.0)
        out[f"{tag}_nnz_per_block_max"] = (
            float(nnz_blk.max()) if len(nnz_blk) else 0.0)
        out[f"{tag}_block_fill_mean"] = (
            float(nnz_blk.mean()) / (B * B) if len(nnz_blk) else 0.0)
        out[f"{tag}_cols_per_block_mean"] = (
            float(cols_blk.mean()) if len(cols_blk) else 0.0)
        out[f"{tag}_num_panels"] = float(len(prof.panel_nnz))
        out[f"{tag}_panel_cols_mean"] = (
            float(prof.panel_cols.mean()) if len(prof.panel_cols) else 0.0)
        out[f"{tag}_super_sparse_fraction"] = float(
            prof.super_sparse_fraction)
    return out


def features_from_cb(cb) -> MatrixFeatures:
    """Features of an already-built ``CBMatrix`` (original coordinates).

    Folds column aggregation back via ``CBMatrix.to_coo`` so the planner
    sees the same matrix ``from_coo`` was given, then profiles every
    candidate block size — the plan may well move away from the build's
    current one.
    """
    rows, cols, vals = cb.to_coo()
    return extract_features(rows, cols, vals, cb.shape)
