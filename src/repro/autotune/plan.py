"""Plan — the autotuner's persistent, schema-versioned decision record.

A ``Plan`` pins every knob the planner decided for one matrix: block
size, format thresholds (th0/th1/th2), the *resolved* column-aggregation
bool, and the batched engines' group size — plus the predictions and
measurements that justified the choice. It is a frozen (hashable)
dataclass so it can ride ``jax.jit`` static arguments directly
(``ops.cb_spmv(..., plan=p)``).

Persistence mirrors ``CBMatrix.save``/``load`` (schema string checked on
load, version ``cb-plan/v2``; ``cb-plan/v1`` files remain readable) but
uses JSON — a plan is a dozen scalars, and a human should be able to
read why the planner chose what it chose.

Matrix identity is split in two:

  * ``structure_hash`` — sha256 over the *canonical* sparsity pattern:
    duplicate triplets merged, explicit zeros dropped, (row, col)-sorted
    coordinates, plus the shape. Independent of triplet order, value
    dtype, and the values themselves.
  * ``value_hash``     — sha256 over the canonical-order values in the
    plan's value dtype (dtype name included).

``PlanCache`` keys plans on ``structure_hash`` alone: every CB planning
decision (blocking, colagg, format select, Alg. 2 balance) depends only
on the pattern, so a matrix whose *values* churn every step — the
dynamic-sparsity regime — reuses its plan indefinitely. This fixes the
v1 defect where any value change re-planned from scratch, and the
explicit-zeros aliasing hazard ``CBMatrix.to_coo`` documents: the
canonicalization inside the hash makes original triplets (with explicit
zeros) and round-tripped triplets land on the same cache entry.
Cross-process amortization is the MERBIT regime (PAPERS.md) where
per-matrix planning cost divides by thousands of reuses.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import NamedTuple

import numpy as np

from repro import errors, obs
from repro.core import aggregation
from repro.core.formats import FormatThresholds

PLAN_SCHEMA = "cb-plan/v2"
PLAN_SCHEMA_V1 = "cb-plan/v1"


def canonical_triplets(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    val_dtype=np.float32,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Canonical form of a COO matrix: dedup, drop zeros, (row, col)-sort.

    Duplicate coordinates are merged by summation (matching
    ``blocking.partition_coo``) and entries whose merged value is exactly
    zero are dropped — an explicitly-stored 0.0 does not survive a CB
    round trip (``CBMatrix.to_coo``), so it must not contribute to the
    matrix identity either. The result is sorted by (row, col), the same
    order ``to_coo`` emits.
    """
    rows = np.ascontiguousarray(rows, np.int64)
    cols = np.ascontiguousarray(cols, np.int64)
    vals = np.ascontiguousarray(vals, np.dtype(val_dtype))
    n = int(shape[1])
    key = rows * n + cols
    uniq, inv = np.unique(key, return_inverse=True)
    summed = np.zeros(len(uniq), vals.dtype)
    np.add.at(summed, inv, vals)
    keep = summed != 0
    uniq, summed = uniq[keep], summed[keep]
    return uniq // n, uniq % n, summed


class MatrixHashes(NamedTuple):
    """Both halves of a matrix's identity plus its canonical nnz."""

    structure: str
    value: str
    nnz: int


def matrix_hashes(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    val_dtype=np.float32,
) -> MatrixHashes:
    """Compute (structure_hash, value_hash, canonical nnz) in one pass."""
    r, c, v = canonical_triplets(rows, cols, vals, shape, val_dtype)
    hs = hashlib.sha256()
    hs.update(b"cb-structure/v2")
    hs.update(np.asarray([shape[0], shape[1], len(r)], np.int64).tobytes())
    hs.update(r.tobytes())
    hs.update(c.tobytes())
    hv = hashlib.sha256()
    hv.update(b"cb-values/v2")
    hv.update(np.dtype(val_dtype).name.encode())
    hv.update(v.tobytes())
    return MatrixHashes(hs.hexdigest(), hv.hexdigest(), len(r))


def structure_hash(rows, cols, vals, shape, val_dtype=np.float32) -> str:
    """sha256 of the canonical sparsity *pattern* (see module docstring)."""
    return matrix_hashes(rows, cols, vals, shape, val_dtype).structure


def value_hash(rows, cols, vals, shape, val_dtype=np.float32) -> str:
    """sha256 of the canonical-order *values* in ``val_dtype``."""
    return matrix_hashes(rows, cols, vals, shape, val_dtype).value


def matrix_content_hash(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    val_dtype=np.float32,
) -> str:
    """sha256 of the full matrix *content* (structure + values).

    The combined identity: changes with the pattern, the values, or the
    value dtype, but not with triplet order, duplicate splitting, or
    explicit zeros (the canonicalization of ``canonical_triplets`` is
    applied first). Use ``structure_hash`` when only the pattern matters
    — the plan cache does.
    """
    h = matrix_hashes(rows, cols, vals, shape, val_dtype)
    return hashlib.sha256(f"{h.structure}:{h.value}".encode()).hexdigest()


def legacy_content_hash(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    val_dtype=np.float32,
) -> str:
    """The exact ``cb-plan/v1`` content hash (no canonicalization).

    Kept bit-compatible with the v1 algorithm so a v2 lookup can probe
    for plan files written by v1 processes and migrate them.
    """
    rows = np.ascontiguousarray(rows, np.int64)
    cols = np.ascontiguousarray(cols, np.int64)
    vals = np.ascontiguousarray(vals, np.dtype(val_dtype))
    order = np.lexsort((cols, rows))
    h = hashlib.sha256()
    h.update(np.asarray([shape[0], shape[1], len(rows)], np.int64).tobytes())
    h.update(np.dtype(val_dtype).name.encode())
    h.update(rows[order].tobytes())
    h.update(cols[order].tobytes())
    h.update(vals[order].tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Plan:
    """One matrix's tuned CB configuration (see module docstring)."""

    structure_hash: str
    shape: tuple[int, int]
    nnz: int                        # canonical nnz (dedup, zero-dropped)
    val_dtype: str                  # numpy dtype name the plan was tuned in
    block_size: int
    th0: float
    th1: int | None                 # None = derive from B (formats.resolve)
    th2: int | None
    colagg: bool                    # resolved decision, not the "auto" mode
    group_size: int
    mode: str                       # "heuristic" | "timed"
    predicted_padded_elems: int
    predicted_steps: int
    measured_padded_elems: int
    measured_steps: int
    t_spmv: float | None = None     # refinement timing (None in heuristic mode)
    value_hash: str | None = None   # values the measurements ran with (info)
    # sha256 over the canonical JSON payload, written by ``to_json`` and
    # verified by ``check_valid`` (None = pre-checksum file, not checked).
    # compare=False so a loaded plan still ``==`` the freshly-planned one.
    payload_checksum: str | None = dataclasses.field(
        default=None, compare=False)

    @property
    def thresholds(self) -> FormatThresholds:
        return FormatThresholds(th0=self.th0, th1=self.th1, th2=self.th2)

    # ------------------------------------------------------------------
    def check_valid(self, shape=None, nnz=None) -> str | None:
        """Validate the plan, optionally against a matrix.

        Returns a human-readable reason string when the plan is
        internally inconsistent (thresholds that do not resolve at its
        block size, nonsense block/group sizes) or does not match the
        matrix it is about to be applied to — ``None`` when it is usable.
        ``PlanCache.get`` treats a non-None reason as a stale miss;
        ``CBMatrix.from_plan`` raises it.
        """
        if (self.payload_checksum is not None
                and self.payload_checksum != self._payload_digest()):
            return errors.reason(
                errors.ARTIFACT_CORRUPT,
                "plan payload checksum mismatch — the persisted fields "
                "were altered after save",
            )
        if len(self.shape) != 2 or min(self.shape) < 1:
            return f"plan shape {self.shape!r} is not a positive 2-D shape"
        if self.block_size < 1:
            return f"plan block_size {self.block_size} < 1"
        if self.group_size < 1:
            return f"plan group_size {self.group_size} < 1"
        try:
            aggregation.coord_dtype(self.block_size)
            self.thresholds.resolve(self.block_size)
        except (ValueError, TypeError) as e:
            return f"plan thresholds/block size invalid: {e}"
        if shape is not None and tuple(int(v) for v in shape) != tuple(self.shape):
            return f"plan was made for shape {self.shape}, got {tuple(shape)}"
        if nnz is not None and int(nnz) != int(self.nnz):
            return f"plan was made for nnz {self.nnz}, got {int(nnz)}"
        return None

    # ------------------------------------------------------------------
    def _payload_digest(self) -> str:
        """sha256 over the canonical JSON form of every persisted field.

        Canonical = compact separators, sorted keys, shape as a list,
        ``payload_checksum`` itself excluded — so the digest a fresh
        ``to_json`` stamps and the one a loaded plan recomputes agree
        bit-for-bit (JSON round-trips Python ints/floats exactly).
        """
        d = dataclasses.asdict(self)
        d.pop("payload_checksum", None)
        d["shape"] = list(self.shape)
        blob = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        d["schema"] = PLAN_SCHEMA
        d["payload_checksum"] = self._payload_digest()
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        schema = d.get("schema")
        if schema == PLAN_SCHEMA_V1:
            # v1 read-compat: the single content hash becomes the
            # structure key (PlanCache re-keys migrated entries on the
            # true structure hash; see PlanCache.get).
            d = dict(d)
            d["structure_hash"] = d.pop("matrix_hash")
            d.setdefault("value_hash", None)
            d.setdefault("payload_checksum", None)
        elif schema != PLAN_SCHEMA:
            raise errors.InvalidArgError(
                f"plan schema {schema!r} is neither {PLAN_SCHEMA!r} nor "
                f"{PLAN_SCHEMA_V1!r}"
            )
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["shape"] = tuple(int(v) for v in kw["shape"])
        return cls(**kw)

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path) -> "Plan":
        with open(path) as f:
            return cls.from_json(json.load(f))


class PlanCache:
    """Directory-backed plan store keyed by **structure hash**.

    ``get`` probes the structure-keyed ``cb-plan/v2`` file first and
    falls back to a caller-supplied legacy ``cb-plan/v1`` content-hash
    key; a legacy hit is re-keyed on the structure hash and persisted
    under the v2 schema, so the old file serves exactly one migration.
    Either way a logical lookup counts **exactly one** hit or miss —
    never once per probe level.

    An unreadable or schema-mismatched file is a miss (a newer schema
    simply re-plans rather than erroring a fleet). A file that loads but
    fails ``Plan.check_valid`` against the requested matrix — wrong
    shape, wrong nnz, thresholds that no longer resolve — is a *stale*
    miss, counted separately in ``stale`` so fleets can alarm on cache
    poisoning instead of silently re-planning forever.

    Counters live on the obs registry (the process-wide counter
    ``repro.autotune.plan_cache.lookups`` labeled by outcome); the
    historical per-instance ``hits`` / ``misses`` / ``stale`` attributes
    are thin read-only views over a :class:`repro.obs.MirroredCounter`,
    so existing callers and tests see identical semantics.
    """

    def __init__(self, directory):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)
        self._counts = obs.MirroredCounter(
            metric="repro.autotune.plan_cache.lookups", label="outcome")

    @property
    def hits(self) -> int:
        return self._counts["hit"]

    @property
    def misses(self) -> int:
        return self._counts["miss"]

    @property
    def stale(self) -> int:
        return self._counts["stale"]

    def path_for(self, structure_hash: str) -> str:
        return os.path.join(self.directory, f"{structure_hash}.plan.json")

    def _load(self, key: str) -> Plan | None:
        """Load without touching counters; None on any read failure."""
        try:
            return Plan.load(self.path_for(key))
        except (OSError, ValueError, KeyError, TypeError,
                json.JSONDecodeError):
            return None

    def get(
        self,
        structure_hash: str,
        *,
        legacy_hash: str | None = None,
        shape: tuple[int, int] | None = None,
        nnz: int | None = None,
    ) -> Plan | None:
        migrated = False
        plan = self._load(structure_hash)
        if plan is not None and plan.structure_hash != structure_hash:
            plan = None  # alien payload under this file name
        if plan is None and legacy_hash and legacy_hash != structure_hash:
            legacy = self._load(legacy_hash)
            if legacy is not None:
                # Re-keying changes the payload, so the stored digest (if
                # any) no longer applies; ``put`` stamps a fresh one.
                plan = dataclasses.replace(
                    legacy, structure_hash=structure_hash,
                    payload_checksum=None,
                )
                migrated = True
        if plan is None:
            self._counts["miss"] += 1
            return None
        if plan.check_valid(shape=shape, nnz=nnz) is not None:
            self._counts["stale"] += 1
            self._counts["miss"] += 1
            return None
        if migrated:
            self.put(plan)
        self._counts["hit"] += 1
        return plan

    def put(self, plan: Plan) -> str:
        path = self.path_for(plan.structure_hash)
        plan.save(path)
        return path

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
