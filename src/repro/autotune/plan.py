"""Plan — the autotuner's persistent, schema-versioned decision record.

A ``Plan`` pins every knob the planner decided for one matrix: block
size, format thresholds (th0/th1/th2), the *resolved* column-aggregation
bool, and the batched engines' group size — plus the predictions and
measurements that justified the choice. It is a frozen (hashable)
dataclass so it can ride ``jax.jit`` static arguments directly
(``ops.cb_spmv(..., plan=p)``).

Persistence mirrors ``CBMatrix.save``/``load`` (schema string checked on
load, version ``cb-plan/v1``) but uses JSON — a plan is a dozen scalars,
and a human should be able to read why the planner chose what it chose.

``PlanCache`` is a directory of such files keyed by the **matrix content
hash** (sha256 over the canonically-sorted triplets + shape + dtype), so
planning amortizes across *processes*: a solver restart, a benchmark
rerun, or a fleet of workers sharing a filesystem all hit the same plan
without re-searching — the MERBIT regime (PAPERS.md) where per-matrix
planning cost divides by thousands of reuses.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np

from repro.core.formats import FormatThresholds

PLAN_SCHEMA = "cb-plan/v1"


def matrix_content_hash(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: tuple[int, int],
    val_dtype=np.float32,
) -> str:
    """sha256 of the matrix *content*, independent of triplet order.

    Triplets are canonically (row, col)-sorted before hashing, so the
    hash of a matrix is stable across whatever order a loader or
    ``CBMatrix.to_coo`` emitted. Values are hashed in the plan's value
    dtype — the dtype a plan executes in is part of its identity.
    """
    rows = np.ascontiguousarray(rows, np.int64)
    cols = np.ascontiguousarray(cols, np.int64)
    vals = np.ascontiguousarray(vals, np.dtype(val_dtype))
    order = np.lexsort((cols, rows))
    h = hashlib.sha256()
    h.update(np.asarray([shape[0], shape[1], len(rows)], np.int64).tobytes())
    h.update(np.dtype(val_dtype).name.encode())
    h.update(rows[order].tobytes())
    h.update(cols[order].tobytes())
    h.update(vals[order].tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Plan:
    """One matrix's tuned CB configuration (see module docstring)."""

    matrix_hash: str
    shape: tuple[int, int]
    nnz: int
    val_dtype: str                  # numpy dtype name
    block_size: int
    th0: float
    th1: int | None                 # None = derive from B (formats.resolve)
    th2: int | None
    colagg: bool                    # resolved decision, not the "auto" mode
    group_size: int
    mode: str                       # "heuristic" | "timed"
    predicted_padded_elems: int
    predicted_steps: int
    measured_padded_elems: int
    measured_steps: int
    t_spmv: float | None = None     # refinement timing (None in heuristic mode)

    @property
    def thresholds(self) -> FormatThresholds:
        return FormatThresholds(th0=self.th0, th1=self.th1, th2=self.th2)

    # ------------------------------------------------------------------
    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        d["schema"] = PLAN_SCHEMA
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        schema = d.get("schema")
        if schema != PLAN_SCHEMA:
            raise ValueError(f"plan schema {schema!r} != {PLAN_SCHEMA!r}")
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["shape"] = tuple(int(v) for v in kw["shape"])
        return cls(**kw)

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path) -> "Plan":
        with open(path) as f:
            return cls.from_json(json.load(f))


class PlanCache:
    """Directory-backed plan store keyed by matrix content hash.

    ``get`` treats an unreadable or schema-mismatched file as a miss
    (a newer schema simply re-plans rather than erroring a fleet), and
    counts hits/misses so benchmark sections can report the hit rate.
    """

    def __init__(self, directory):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)
        self.hits = 0
        self.misses = 0

    def path_for(self, matrix_hash: str) -> str:
        return os.path.join(self.directory, f"{matrix_hash}.plan.json")

    def get(self, matrix_hash: str) -> Plan | None:
        path = self.path_for(matrix_hash)
        try:
            plan = Plan.load(path)
        except (OSError, ValueError, KeyError, TypeError,
                json.JSONDecodeError):
            self.misses += 1
            return None
        if plan.matrix_hash != matrix_hash:
            self.misses += 1
            return None
        self.hits += 1
        return plan

    def put(self, plan: Plan) -> str:
        path = self.path_for(plan.matrix_hash)
        plan.save(path)
        return path

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
