"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

The InternViT frontend is a STUB: input_specs provides precomputed patch
embeddings (B, 256, d_model) — 448x448 / 14px patches after pixel-shuffle
— prepended to the text sequence. The listed transformer config is the
InternLM2-1.8B language backbone.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,           # GQA
    d_ff=8192,
    vocab_size=92553,
    num_patches=256,
)

SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, num_patches=16, attn_chunk=64, remat="none",
)
