"""mamba2-130m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]. num_heads/num_kv_heads/d_ff are unused by
the SSM family (kept at structural placeholders); the mixer is
d_inner = 2*d_model with headdim 64 -> 24 SSD heads, d_state 128.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,             # placeholder (attn-free)
    num_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
)

SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, vocab_size=512, ssm_state=32, ssm_headdim=32,
    ssm_chunk=32, remat="none",
)
