"""whisper-small [audio/encdec] — 12L encoder + 12L decoder
[arXiv:2212.04356; unverified]. The conv/mel frontend is a STUB:
input_specs provides precomputed frame embeddings (B, 1500, d_model).
Positional scheme adapted to RoPE (DESIGN.md §8).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,          # MHA
    d_ff=3072,
    vocab_size=51865,
    num_frames=1500,          # 30 s audio after conv stride 2
)

SMOKE = CONFIG.scaled(
    num_layers=2, encoder_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, num_frames=64, attn_chunk=64, remat="none",
)
