"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]. SWA (4096 window) makes decode O(window), so this
arch RUNS long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,           # GQA
    d_ff=14336,               # per-expert FFN width
    vocab_size=32000,
    num_experts=8,
    top_k=2,
    swa_window=4096,
    rope_theta=1_000_000.0,
    # group-local dispatch (capacity per group of tokens): keeps MoE
    # scatters shard-local when groups == the data-axis width (§Perf A)
    moe_groups=16,
)

SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, num_experts=4, swa_window=32, attn_chunk=64, remat="none",
)
