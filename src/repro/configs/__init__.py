"""Config registry: ``--arch <id>`` -> ModelConfig, plus shapes.

Also exposes ``cb_paper`` — the paper-representative variant (granite-8b
with CB block-sparse MLPs) used by the technique-focused dry-run cell and
examples.
"""
from __future__ import annotations

import importlib

from .base import (  # noqa: F401
    LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K, DECODE_32K,
    ModelConfig, ShapeConfig, input_specs, supports_shape,
)

_MODULES = {
    "granite-8b": "granite_8b",
    "qwen3-32b": "qwen3_32b",
    "stablelm-3b": "stablelm_3b",
    "phi3-mini-3.8b": "phi3_mini",
    "internvl2-2b": "internvl2_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-130m": "mamba2_130m",
    "whisper-small": "whisper_small",
    "zamba2-2.7b": "zamba2_2p7b",
}

ARCH_IDS = tuple(_MODULES)


def _load(arch: str):
    if arch == "cb-paper":
        mod = importlib.import_module(".granite_8b", __package__)
        cfg = mod.CONFIG.scaled(
            name="cb-paper", sparse_mlp=True, sparse_block=128, sparse_keep=0.25
        )
        smoke = mod.SMOKE.scaled(
            name="cb-paper-smoke", sparse_mlp=True, sparse_block=16,
            sparse_keep=0.5,
        )
        return cfg, smoke
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)} + ['cb-paper']")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG, mod.SMOKE


def get_config(arch: str) -> ModelConfig:
    return _load(arch)[0]


def get_smoke_config(arch: str) -> ModelConfig:
    return _load(arch)[1]


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cells() -> list[tuple[str, str]]:
    """All runnable (arch, shape) dry-run cells (skips noted by supports_shape)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = supports_shape(cfg, shape)
            out.append((arch, shape.name) if ok else (arch, shape.name))
    return out
