"""zamba2-2.7b [hybrid] — Mamba2 trunk + weight-shared attention blocks
with per-invocation LoRA [arXiv:2411.15242; hf]. 54 Mamba2 layers, one
shared attn+MLP block applied every 6 layers (9 invocations). SSM decode
is O(1)/token, so this arch RUNS long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,            # Mamba2 layers
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,               # shared-block MLP width
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
    attn_every=6,
    shared_attn_lora_rank=128,
)

SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
    vocab_size=512, ssm_state=32, ssm_headdim=32, ssm_chunk=32,
    attn_every=1, shared_attn_lora_rank=8, attn_chunk=64, remat="none",
)
