"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified].

Treated as full attention (the chunked-attention long-context variant is
not claimed here), so long_500k is skipped (DESIGN.md §6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,           # GQA
    d_ff=8192,                # per-expert FFN width
    vocab_size=202048,
    num_experts=128,
    top_k=1,
    moe_shared_expert=True,   # llama4 early-fusion shared expert
    moe_every=2,              # interleave_moe_layer_step=2 -> 400B total / 17B active
    rope_theta=500_000.0,
    moe_groups=16,            # group-local dispatch (§Perf B)
)

SMOKE = CONFIG.scaled(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, num_experts=4, attn_chunk=64, remat="none",
)
