"""phi3-mini-3.8b [dense] — RoPE + SwiGLU + GQA [arXiv:2404.14219; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
)

SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
    vocab_size=512, attn_chunk=64, remat="none",
)
