"""Config schema: architectures x input shapes.

One ``ModelConfig`` per assigned architecture (exact public configs in the
sibling modules) and one ``ShapeConfig`` per assigned input shape. A
(config, shape) pair fully determines the dry-run cell: ``input_specs``
builds the ShapeDtypeStruct stand-ins, and the launcher picks train_step
vs serve_step from ``shape.kind``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default d_model // num_heads
    qk_norm: bool = False               # qwen3-style per-head RMSNorm on q,k
    swa_window: Optional[int] = None    # sliding-window attention (mixtral)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_shared_expert: bool = False     # llama4: always-on shared expert
    moe_every: int = 1                  # MoE every k-th layer (llama4: 2)
    moe_groups: int = 1                 # GShard-style dispatch groups:
                                        # capacity is per-group, scatters
                                        # stay shard-local when groups ==
                                        # data width (see §Perf)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (zamba2) ---
    attn_every: int = 0                 # shared attn block every k SSM layers
    shared_attn_lora_rank: int = 0      # per-invocation LoRA on shared block
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    num_frames: int = 0                 # stub conv frontend output length
    # --- VLM (internvl) ---
    num_patches: int = 0                # stub ViT frontend output length
    # --- CB sparsity (the paper's technique as a model feature) ---
    sparse_mlp: bool = False
    sparse_block: int = 128
    sparse_keep: float = 0.25
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    remat: str = "full"                 # none | full | dots
    attn_chunk: int = 1024              # q-chunked attention block
    scan_layers: bool = True            # False = fully unrolled (cost probes)
    attn_unroll: bool = False           # unroll the q-chunk scan (cost probes)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding: tables padded to a multiple of 256
        so the vocab dim shards evenly over any TP width; pad logits are
        masked to -inf (never predicted, never targeted)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    # ---- parameter counting (roofline MODEL_FLOPS = 6 N D) --------------
    def param_count(self) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        dh = self.resolved_head_dim
        H, Hkv = self.num_heads, self.num_kv_heads
        attn = d * H * dh + 2 * d * Hkv * dh + H * dh * d
        mlp = 3 * d * ff
        if self.family == "moe":
            moe_mlp = 3 * d * ff * self.num_experts + d * self.num_experts
            if self.moe_shared_expert:
                moe_mlp += 3 * d * ff
            k = max(1, self.moe_every)
            # 1 MoE layer per group of k; the other k-1 are dense MLP.
            mlp = (moe_mlp + (k - 1) * 3 * d * ff) / k
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            per_layer = self._ssm_layer_params()
        if self.family == "hybrid":
            n_attn = self.num_layers // max(1, self.attn_every)
            per_layer = self._ssm_layer_params()
            extra = n_attn and (attn + 3 * d * ff + 2 * d)
            return (
                V * d * (1 if self.tie_embeddings else 2)
                + self.num_layers * per_layer
                + extra + d
            )
        total = V * d * (1 if self.tie_embeddings else 2) + self.num_layers * per_layer + d
        if self.family == "encdec":
            total += self.encoder_layers * (attn + 3 * d * ff + 2 * d)
            total += self.num_layers * (attn + 2 * d)  # cross-attn + norm
        return total

    def active_param_count(self) -> int:
        """MoE: only top-k experts' FFN params count as active."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        n_moe_layers = self.num_layers // max(1, self.moe_every)
        inactive = 3 * d * ff * (self.num_experts - self.top_k) * n_moe_layers
        return self.param_count() - inactive

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        d_in = d * self.ssm_expand
        nh = d_in // self.ssm_headdim
        # in_proj -> (z, x, B, C, dt) + conv + out_proj + norm
        return (
            d * (2 * d_in + 2 * self.ssm_state + nh)
            + self.ssm_conv_width * (d_in + 2 * self.ssm_state)
            + d_in * d
            + 2 * nh + d_in + 2 * d
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §6)."""
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or (cfg.swa_window is not None and cfg.swa_window < shape.seq_len)
        )
        if not sub_quadratic:
            return False, "pure full attention is quadratic at 500k — skipped"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f_act = cfg.activation_dtype
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((B,), i32),
        }
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), f_act
        )
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.num_frames, cfg.d_model), f_act
        )
    return specs
