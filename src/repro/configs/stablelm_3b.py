"""stablelm-3b [dense] — MHA (kv == heads) [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,          # full MHA
    d_ff=6912,
    vocab_size=50304,
)

SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
    vocab_size=512, attn_chunk=64, remat="none",
)
