"""qwen3-32b [dense] — qk_norm + GQA [hf:Qwen/Qwen3-8B family; hf].

head_dim is 128 (decoupled from d_model/num_heads = 80) per the public
Qwen3 configs.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,           # GQA
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=1, d_ff=256,
    vocab_size=512, head_dim=32, attn_chunk=64, remat="none",
)
