"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,          # GQA
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10_000_000.0,  # granite code long-context rope base
)

# Reduced same-family config for CPU smoke tests.
SMOKE = CONFIG.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=1, d_ff=256,
    vocab_size=512, attn_chunk=64, remat="none",
)
