"""Shared error taxonomy — one reason-code vocabulary for every layer.

The hardened failure model (fault-injection axis, ``runtime/faults.py``)
requires that every fault is either *detected with a structured reason*
or *tolerated with a correct result*. "Structured" means machine-
matchable: a short stable reason code attached to the exception (or
status value), never just prose. This module is the single home of
those codes so the layers agree:

  * artifact integrity  — ``CBMatrix.save/load`` checksums, plan-cache
    corruption (``autotune/plan.py``), checkpoint manifests;
  * ingestion           — MatrixMarket parsing (``data/matrices.py``);
  * payload policy      — non-finite values at ``from_coo`` /
    ``update_values`` time, structure drift in the updaters;
  * solver statuses     — the in-loop breakdown/divergence/non-finite
    flags carried by ``solvers/krylov.py`` (``SolverStatus`` is an
    ``IntEnum`` because the flag rides a ``lax.while_loop`` carry);
  * serving degradation — queue backpressure, deadlines, tick retry
    exhaustion (``serving/engine.py``);
  * runtime supervision — heartbeat loss and restart-budget exhaustion
    (``runtime/fault_tolerance.py``).

Exceptions subclass the builtin the call site historically raised
(``ValueError``/``RuntimeError``) so pre-taxonomy callers and tests
keep working; new code should match on the class or ``.code``.

This module is imported by host-side plumbing everywhere, so it must
stay dependency-free (no jax/numpy).
"""
from __future__ import annotations

import enum

# ---------------------------------------------------------------------------
# Reason codes (stable strings — logged, asserted on, and persisted).
# ---------------------------------------------------------------------------

# artifact integrity
ARTIFACT_CORRUPT = "artifact-corrupt"          # checksum / byte-level damage
ARTIFACT_SCHEMA = "artifact-schema"            # unknown or wrong schema tag
PLAN_STALE = "plan-stale"                      # plan fails check_valid

# API misuse
INVALID_ARGUMENT = "invalid-argument"          # caller-supplied value rejected

# payloads + structure
NONFINITE_PAYLOAD = "nonfinite-payload"        # NaN/Inf in matrix values
STRUCTURE_DRIFT = "structure-drift"            # update pattern != structure
INGEST_INVALID = "ingest-invalid"              # malformed external input

# serving degradation
QUEUE_FULL = "queue-full"                      # backpressure rejection
DEADLINE_EXCEEDED = "deadline-exceeded"        # per-request deadline passed
TICK_FAILED = "tick-failed"                    # decode step retries exhausted
ACCEPTED = "accepted"                          # the non-error submit status

# runtime supervision
HEARTBEAT_LOST = "heartbeat-lost"              # host missed its timeout
RESTART_BUDGET_EXHAUSTED = "restart-budget-exhausted"
INJECTED = "injected-fault"                    # deterministic test fault


def reason(code: str, message: str) -> str:
    """Format a reason string carrying its code: ``"<code>: <message>"``.

    Used where the API contract is a *string*, not an exception — e.g.
    ``Plan.check_valid`` returns these and ``PlanCache.get`` counts them
    as stale misses. ``reason_code`` recovers the code half.
    """
    return f"{code}: {message}"


def reason_code(text: str | None) -> str | None:
    """Extract the leading code from a :func:`reason`-formatted string."""
    if not text:
        return None
    head = text.split(":", 1)[0].strip()
    return head if " " not in head else None


# ---------------------------------------------------------------------------
# Exception hierarchy.
# ---------------------------------------------------------------------------

class ReproError(Exception):
    """Base of the taxonomy; every instance carries a ``.code``."""

    code: str = "error"

    def __init__(self, message: str = "", *, code: str | None = None):
        if code is not None:
            self.code = code
        super().__init__(message)


class ArtifactError(ReproError, ValueError):
    """A persisted artifact (npz/JSON/checkpoint) failed integrity checks."""

    code = ARTIFACT_CORRUPT


class SchemaError(ArtifactError):
    """An artifact carries an unknown or incompatible schema tag."""

    code = ARTIFACT_SCHEMA


class InvalidArgError(ReproError, ValueError):
    """A caller-supplied argument failed validation (API misuse).

    The taxonomy home for the historical bare ``raise ValueError`` at
    library entry points — enforced by cblint rule CB401 — so even
    plain validation failures carry a stable ``.code``.
    """

    code = INVALID_ARGUMENT


class PlanStaleError(ReproError, ValueError):
    """A plan failed ``check_valid`` against the matrix it was applied to."""

    code = PLAN_STALE


class NonFiniteError(ReproError, ValueError):
    """NaN/Inf payload rejected by the non-finite policy."""

    code = NONFINITE_PAYLOAD


class StructureDriftError(ReproError, ValueError):
    """A value update's coordinate set differs from the built structure."""

    code = STRUCTURE_DRIFT


class IngestError(ReproError, ValueError):
    """External input (e.g. a MatrixMarket file) is malformed."""

    code = INGEST_INVALID


class BackpressureError(ReproError, RuntimeError):
    """The serving queue is full (typed rejection, not unbounded growth)."""

    code = QUEUE_FULL


class TickError(ReproError, RuntimeError):
    """A serving tick kept failing after bounded retry-with-backoff."""

    code = TICK_FAILED


class RestartBudgetError(ReproError, RuntimeError):
    """The supervisor's bounded restart budget is exhausted."""

    code = RESTART_BUDGET_EXHAUSTED


class InjectedFault(ReproError, RuntimeError):
    """A deterministic fault raised by ``runtime/faults.py`` injectors."""

    code = INJECTED


# ---------------------------------------------------------------------------
# Solver statuses (lax.while_loop-carried int flags).
# ---------------------------------------------------------------------------

class SolverStatus(enum.IntEnum):
    """Terminal status of a Krylov solve (``SolveResult.status``).

    The value is carried through the solver's ``lax.while_loop`` as an
    int32, so the members are small ints; ``solver_reason`` maps them to
    the taxonomy's string codes for logs and bench rows.
    """

    OK = 0           # converged to tol
    MAXITER = 1      # ran out of iterations without a detected pathology
    BREAKDOWN = 2    # Krylov scalar collapsed (rho ~ 0, non-positive pAp)
    NONFINITE = 3    # NaN/Inf in the iterate or residual
    STAGNATION = 4   # no new best residual for `stall_limit` iterations
    DIVERGED = 5     # residual blew past divtol * ||b||


_SOLVER_REASONS = {
    SolverStatus.OK: "solver-ok",
    SolverStatus.MAXITER: "solver-maxiter",
    SolverStatus.BREAKDOWN: "solver-breakdown",
    SolverStatus.NONFINITE: "solver-nonfinite",
    SolverStatus.STAGNATION: "solver-stagnation",
    SolverStatus.DIVERGED: "solver-diverged",
}


def solver_reason(status: int) -> str:
    """Stable reason code for a ``SolverStatus`` value (host side)."""
    try:
        return _SOLVER_REASONS[SolverStatus(int(status))]
    except ValueError:
        return f"solver-unknown-{int(status)}"
