"""Unified model API: one entry point per family.

    model = Model(cfg)
    params, axes = model.init(key)          # concrete init
    shapes, axes = model.abstract_init(key) # ShapeDtypeStructs (dry-run)
    loss, metrics = model.loss(params, batch)
    state = model.init_decode_state(batch, max_len)
    logits, state = model.decode_step(params, state, tokens, pos)

``axes`` is the logical-axis pytree consumed by sharding.logical_to_sharding.
CB sparsity specs (cfg.sparse_mlp) are built eagerly at construction —
they are structural (numpy-only), shared across layers, and never traced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro import errors

from . import encdec, hybrid, transformer
from .layers import build_mlp_specs


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.specs = build_mlp_specs(cfg) if cfg.sparse_mlp else None
        if cfg.family in ("dense", "moe", "ssm", "vlm"):
            self._mod = transformer
        elif cfg.family == "hybrid":
            self._mod = hybrid
        elif cfg.family == "encdec":
            self._mod = encdec
        else:
            raise errors.InvalidArgError(f"unknown family {cfg.family!r}")

    # ------------------------------------------------------------------
    def axes(self):
        if self._mod is transformer:
            return transformer.lm_axes(self.cfg)
        if self._mod is hybrid:
            return hybrid.hybrid_axes(self.cfg)
        return encdec.encdec_axes(self.cfg)

    def init(self, key: jax.Array):
        if self._mod is transformer:
            params, axes, _ = transformer.lm_init(key, self.cfg, specs=self.specs)
        elif self._mod is hybrid:
            params, axes, _ = hybrid.hybrid_init(key, self.cfg)
        else:
            params, axes, _ = encdec.encdec_init(key, self.cfg)
        return params, axes

    def abstract_init(self, key: jax.Array):
        """Shape-only init (no allocation) — the dry-run entry point."""
        shapes = jax.eval_shape(lambda k: self.init(k)[0], key)
        return shapes, self.axes()

    # ------------------------------------------------------------------
    def forward(self, params, tokens, **kw):
        return self._mod.forward(params, self.cfg, tokens, specs=self.specs, **kw)

    def loss(self, params, batch, **kw):
        if self._mod is transformer:
            return transformer.lm_loss(params, self.cfg, batch,
                                       specs=self.specs, **kw)
        fwd_kw = {}
        if self.cfg.family == "encdec":
            fwd_kw["frames"] = batch["frames"]
        out = self.forward(params, batch["tokens"], **fwd_kw)
        logits = out.logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jax.nn.one_hot(batch["targets"], self.cfg.padded_vocab,
                             dtype=jnp.float32)
        xent = -jnp.mean(jnp.sum(logits * tgt, -1) - logz)
        return xent, {"xent": xent}

    # ------------------------------------------------------------------
    def init_decode_state(self, batch: int, max_len: int):
        return self._mod.init_decode_state(self.cfg, batch, max_len)

    def decode_state_axes(self):
        return self._mod.decode_state_axes(self.cfg)

    def decode_step(self, params, state, tokens, pos):
        return self._mod.decode_step(params, self.cfg, state, tokens, pos,
                                     specs=self.specs)
