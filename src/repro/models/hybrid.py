"""Zamba2-style hybrid: Mamba2 trunk + weight-shared attention blocks.

Every ``cfg.attn_every`` SSM layers, one *shared* transformer block
(attention + SwiGLU) is applied; its weights are shared across all G
invocations, specialized per invocation by low-rank LoRA deltas on the
q/k/v projections (stacked (G, ...) — the zamba2 recipe, arXiv:2411.15242).
The Mamba trunk is scanned in G equal slices; the G shared-block calls are
unrolled (G is small).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L
from . import ssm as ssm_mod
from .sharding import constrain


def _num_groups(cfg: ModelConfig) -> int:
    assert cfg.attn_every > 0 and cfg.num_layers % cfg.attn_every == 0
    return cfg.num_layers // cfg.attn_every


def hybrid_axes(cfg: ModelConfig) -> dict:
    prepend = lambda t: jax.tree_util.tree_map(
        lambda a: ("w_layers",) + a, t,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    return {
        "embed": ("vocab", "w_embed"),
        "mamba": {
            "mixer": prepend(ssm_mod.ssm_axes(cfg)),
            "norm1": ("w_layers", "embed"),
        },
        "shared": {
            "attn": L.attention_axes(cfg),
            "mlp": L.mlp_axes(cfg.scaled(sparse_mlp=False)),
            "norm1": ("embed",), "norm2": ("embed",),
        },
        "lora": {k: ("w_layers", None, None)
                 for k in ("qa", "qb", "ka", "kb", "va", "vb")},
        "final_norm": ("embed",),
        "unembed": ("w_embed", "vocab"),
    }


def hybrid_init(key, cfg: ModelConfig, specs=None):
    del specs
    G = _num_groups(cfg)
    d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    r = max(1, cfg.shared_attn_lora_rank)
    ks = jax.random.split(key, 8)

    embed, _ = L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model)
    mamba_keys = jax.random.split(ks[1], cfg.num_layers)

    def one_mamba(k):
        p, _ = ssm_mod.ssm_init(k, cfg)
        return {"mixer": p, "norm1": jnp.ones((d,), jnp.float32)}

    mamba = jax.vmap(one_mamba)(mamba_keys)

    p_attn, _ = L.attention_init(ks[2], cfg)
    p_mlp, _, _ = L.mlp_init(ks[3], cfg.scaled(sparse_mlp=False))
    shared = {
        "attn": p_attn, "mlp": p_mlp,
        "norm1": jnp.ones((d,), jnp.float32),
        "norm2": jnp.ones((d,), jnp.float32),
    }

    lora = {
        "qa": jax.random.normal(ks[4], (G, d, r), jnp.float32) * d**-0.5,
        "qb": jnp.zeros((G, r, H * dh), jnp.float32),
        "ka": jax.random.normal(ks[5], (G, d, r), jnp.float32) * d**-0.5,
        "kb": jnp.zeros((G, r, Hkv * dh), jnp.float32),
        "va": jax.random.normal(ks[6], (G, d, r), jnp.float32) * d**-0.5,
        "vb": jnp.zeros((G, r, Hkv * dh), jnp.float32),
    }

    params = {
        "embed": embed,
        "mamba": mamba,
        "shared": shared,
        "lora": lora,
        "final_norm": jnp.ones((d,), jnp.float32),
        "unembed": jax.random.normal(ks[7], (d, cfg.padded_vocab), jnp.float32)
        * d**-0.5,
    }
    return params, hybrid_axes(cfg), None


def _shared_block(
    params, lora_g, cfg: ModelConfig, h, positions, cache=None
):
    """The shared attention+MLP block with this invocation's LoRA delta."""
    dt = h.dtype
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    hn = L.rmsnorm(h, params["norm1"])

    # LoRA deltas fold into the attention projections by pre-computing
    # per-invocation effective weights (rank-r update; cheap at trace time).
    def delta(a, b, h_out, heads):
        return (a.astype(dt) @ b.astype(dt)).reshape(
            cfg.d_model, heads, dh
        )

    attn_p = dict(params["attn"])
    attn_p["wq"] = params["attn"]["wq"] + delta(lora_g["qa"], lora_g["qb"], None, H)
    attn_p["wk"] = params["attn"]["wk"] + delta(lora_g["ka"], lora_g["kb"], None, Hkv)
    attn_p["wv"] = params["attn"]["wv"] + delta(lora_g["va"], lora_g["vb"], None, Hkv)

    attn_out, new_cache = L.attention_apply(
        attn_p, cfg, hn, positions=positions, causal=True, cache=cache,
        window=cfg.swa_window,
    )
    h = h + attn_out
    hn2 = L.rmsnorm(h, params["norm2"])
    h = h + L.mlp_apply(params["mlp"], cfg.scaled(sparse_mlp=False), hn2)
    return h, new_cache


def _mamba_slice(params_mamba, g: int, per: int):
    return jax.tree_util.tree_map(
        lambda a: a[g * per : (g + 1) * per], params_mamba
    )


def forward(params, cfg: ModelConfig, tokens, *, specs=None,
            patch_embeds=None, last_only: bool = False):
    from .transformer import LMOutputs

    del patch_embeds
    dt = cfg.activation_dtype
    G = _num_groups(cfg)
    per = cfg.attn_every
    h = params["embed"].astype(dt)[tokens]
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.arange(h.shape[1])

    def mamba_body(h, layer_params):
        hn = L.rmsnorm(h, layer_params["norm1"])
        mix, _ = ssm_mod.ssm_apply(layer_params["mixer"], cfg, hn)
        return h + mix, None

    if cfg.remat != "none":
        mamba_body = jax.checkpoint(mamba_body)

    for g in range(G):
        h, _ = jax.lax.scan(mamba_body, h, _mamba_slice(params["mamba"], g, per),
                            unroll=not cfg.scan_layers)
        lora_g = jax.tree_util.tree_map(lambda a: a[g], params["lora"])
        blk = lambda hh: _shared_block(
            params["shared"], lora_g, cfg, hh, positions
        )[0]
        h = jax.checkpoint(blk)(h) if cfg.remat != "none" else blk(h)

    h = L.rmsnorm(h, params["final_norm"])
    if last_only:
        h = h[:, -1:, :]
    logits = L.mask_pad_logits(h @ params["unembed"].astype(dt), cfg)
    return LMOutputs(
        logits=constrain(logits, "batch", "seq", "vocab"),
        aux_loss=jnp.zeros((), jnp.float32),
    )


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    G = _num_groups(cfg)
    ssm_state = ssm_mod.ssm_state_init(cfg, batch, cfg.num_layers)
    attn_cache = L.decode_cache_init(cfg, batch, max_len, G)
    return {"ssm": ssm_state, "attn": attn_cache}


def decode_state_axes(cfg: ModelConfig):
    return {"ssm": ssm_mod.SSM_STATE_AXES, "attn": L.CACHE_AXES}


def decode_step(params, cfg: ModelConfig, state, tokens, pos, *, specs=None):
    dt = cfg.activation_dtype
    G = _num_groups(cfg)
    per = cfg.attn_every
    h = params["embed"].astype(dt)[tokens]
    positions = pos[:, None]

    new_ssd, new_conv, new_k, new_v = [], [], [], []
    for g in range(G):
        def body(h, xs):
            layer_params, ssd, conv = xs
            hn = L.rmsnorm(h, layer_params["norm1"])
            mix, ns = ssm_mod.ssm_decode_step(
                layer_params["mixer"], cfg, hn, {"ssd": ssd, "conv": conv}
            )
            return h + mix, (ns["ssd"], ns["conv"])

        sl = slice(g * per, (g + 1) * per)
        h, (ssd_g, conv_g) = jax.lax.scan(
            body, h,
            (_mamba_slice(params["mamba"], g, per),
             state["ssm"]["ssd"][sl], state["ssm"]["conv"][sl]),
            unroll=not cfg.scan_layers,
        )
        new_ssd.append(ssd_g)
        new_conv.append(conv_g)

        lora_g = jax.tree_util.tree_map(lambda a: a[g], params["lora"])
        cache = {
            "k": state["attn"]["k"][g], "v": state["attn"]["v"][g],
            "pos": state["attn"]["pos"],
        }
        h, nc = _shared_block(
            params["shared"], lora_g, cfg, h, positions, cache=cache
        )
        new_k.append(nc["k"])
        new_v.append(nc["v"])

    new_state = {
        "ssm": {"ssd": jnp.concatenate(new_ssd), "conv": jnp.concatenate(new_conv)},
        "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                 "pos": state["attn"]["pos"] + 1},
    }
    h = L.rmsnorm(h, params["final_norm"])
    logits = L.mask_pad_logits((h @ params["unembed"].astype(dt))[:, 0, :], cfg)
    return constrain(logits, "batch", "vocab"), new_state
