"""Mixture-of-Experts FFN: top-k routing with sort-based ragged dispatch.

Dispatch is the sort+gather scheme (no (tokens x experts x capacity)
one-hot tensors — those are quadratic in memory at our token counts):
token->expert assignments are sorted by expert id, each token's position
within its expert is computed from run starts, tokens beyond capacity are
dropped (standard GShard capacity discipline), and the (E, C, d) buffer is
built with one gather. Experts shard over the ``model`` axis (EP == TP
axis, DESIGN.md §5), so the scatter/gather lower to all-to-alls under
GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .sharding import constrain


def moe_axes(cfg: ModelConfig) -> dict:
    # expert weights use the dedicated "expert_mlp" logical axis for their
    # FFN dim: with EP (experts -> model) it maps to None; when the expert
    # count doesn't divide the TP width (mixtral: 8 < 16) the rule table
    # flips to experts -> None, expert_mlp -> model (plain TP inside every
    # expert). Both mappings are chosen in launch/mesh.rules_for.
    axes = {
        "router": ("w_embed", None),
        "w_gate": ("experts", "w_embed", "expert_mlp"),
        "w_up": ("experts", "w_embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "w_embed"),
    }
    if cfg.moe_shared_expert:
        axes["shared"] = {
            "w_gate": ("w_embed", "mlp"),
            "w_up": ("w_embed", "mlp"),
            "w_down": ("mlp", "w_embed"),
        }
    return axes


def moe_init(key, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    params = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * d**-0.5,
        "w_gate": jax.random.normal(ks[1], (E, d, ff), jnp.float32) * d**-0.5,
        "w_up": jax.random.normal(ks[2], (E, d, ff), jnp.float32) * d**-0.5,
        "w_down": jax.random.normal(ks[3], (E, ff, d), jnp.float32) * ff**-0.5,
    }
    if cfg.moe_shared_expert:
        params["shared"] = {
            "w_gate": jax.random.normal(ks[4], (d, ff), jnp.float32) * d**-0.5,
            "w_up": jax.random.normal(jax.random.fold_in(ks[4], 1), (d, ff), jnp.float32) * d**-0.5,
            "w_down": jax.random.normal(jax.random.fold_in(ks[4], 2), (ff, d), jnp.float32) * ff**-0.5,
        }
    return params, moe_axes(cfg)


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)


def _dispatch_one_group(params, cfg: ModelConfig, xt: jax.Array,
                        C: int) -> tuple[jax.Array, jax.Array]:
    """Sort-based top-k dispatch for ONE token group. xt (T, d)."""
    T, d = xt.shape
    E, K = cfg.num_experts, cfg.top_k
    dt = xt.dtype

    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                 # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Switch-style load-balancing auxiliary loss.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0
    )
    aux = E * jnp.sum(me * ce)

    # ---- sort-based ragged dispatch ------------------------------------
    flat_expert = expert_ids.reshape(-1)                 # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    s_expert = flat_expert[order]
    s_token = flat_token[order]
    s_gate = flat_gate[order]
    # position within expert = rank - start-of-run(expert)
    starts = jnp.searchsorted(s_expert, jnp.arange(E))   # (E,)
    pos = jnp.arange(T * K) - starts[s_expert]
    keep = pos < C

    buf_idx = jnp.where(keep, s_expert * C + pos, E * C)  # overflow slot
    buf = jnp.zeros((E * C + 1, d), dt).at[buf_idx].set(xt[s_token])
    buf = buf[:-1].reshape(E, C, d)
    return (buf, (buf_idx, s_token, s_gate, keep, aux))


def _combine_one_group(out_buf, meta, T: int, dt):
    buf_idx, s_token, s_gate, keep, _ = meta
    E_C = out_buf.shape[0] * out_buf.shape[1]
    flat_out = out_buf.reshape(E_C, -1)
    gathered = jnp.where(
        keep[:, None], flat_out[jnp.minimum(buf_idx, E_C - 1)], 0.0
    )
    return jnp.zeros((T, flat_out.shape[1]), dt).at[s_token].add(
        gathered * s_gate[:, None].astype(dt)
    )


def moe_apply(params, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out, aux_loss).

    Dispatch runs per token GROUP (cfg.moe_groups, GShard-style): capacity
    is per-group, so with groups == the batch-shard width every
    sort/scatter/combine is shard-LOCAL and the only cross-device traffic
    left is the canonical expert einsum collective (TP partial-sum
    all-reduce or EP all-to-all). groups=1 reproduces global dispatch.
    """
    B, S, d = x.shape
    T = B * S
    G = max(1, min(cfg.moe_groups, T))   # batch-1 decode: fall back to G=1
    while T % G:
        G -= 1
    dt = x.dtype
    xg = x.reshape(G, T // G, d)
    xg = constrain(xg, "batch", None, "embed")
    C = _capacity(T // G, cfg)

    buf, meta = jax.vmap(
        lambda xt: _dispatch_one_group(params, cfg, xt, C)
    )(xg)
    # buf (G, E, C, d)
    buf = constrain(buf, "batch", "experts", "expert_cap", "embed")

    # ---- expert FFN (batched over group + expert axes) -------------------
    g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "experts", "expert_cap", "expert_mlp")
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(dt))
    out_buf = constrain(out_buf, "batch", "experts", "expert_cap", "embed")

    y = jax.vmap(
        lambda ob, m: _combine_one_group(ob, m, T // G, dt)
    )(out_buf, meta)
    aux = jnp.mean(meta[4])

    y = y.reshape(T, d)
    if cfg.moe_shared_expert:
        sh = params["shared"]
        xt = x.reshape(T, d)
        gs = xt @ sh["w_gate"].astype(dt)
        us = xt @ sh["w_up"].astype(dt)
        y = y + (jax.nn.silu(gs) * us) @ sh["w_down"].astype(dt)

    return y.reshape(B, S, d), aux
