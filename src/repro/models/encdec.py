"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, num_frames, d_model). Encoder is
bidirectional self-attention; decoder is causal self-attention +
cross-attention to the encoder states. Positional scheme: RoPE on both
stacks (adaptation from Whisper's sinusoidal/learned embeddings — noted in
DESIGN.md; positional fidelity is not the paper's subject).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L
from .sharding import constrain


def _cross_attention_init(key, cfg: ModelConfig):
    return L.attention_init(key, cfg)


def _cross_attention_apply(params, cfg, x, enc_kv, positions):
    """q from decoder x; k/v precomputed from encoder states."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if cfg.qk_norm:
        q = L.rmsnorm(q, params["q_norm"])
    out = L.attention_core(q, enc_kv["k"], enc_kv["v"], causal=False,
                           chunk=cfg.attn_chunk, unroll=cfg.attn_unroll)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def cross_kv(params, cfg: ModelConfig, enc: jax.Array) -> dict:
    dt = enc.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc, params["wv"].astype(dt))
    return {"k": k, "v": v}


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    p_attn, _ = L.attention_init(ks[0], cfg)
    p_mlp, _, _ = L.mlp_init(ks[1], cfg.scaled(sparse_mlp=False))
    return {"attn": p_attn, "mlp": p_mlp,
            "norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32)}


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    p_self, _ = L.attention_init(ks[0], cfg)
    p_cross, _ = _cross_attention_init(ks[1], cfg)
    p_mlp, _, _ = L.mlp_init(ks[2], cfg.scaled(sparse_mlp=False))
    return {"self": p_self, "cross": p_cross, "mlp": p_mlp,
            "norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "norm3": jnp.ones((cfg.d_model,), jnp.float32)}


def _prepend(axes):
    return jax.tree_util.tree_map(
        lambda a: ("w_layers",) + a, axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def encdec_axes(cfg: ModelConfig) -> dict:
    mcfg = cfg.scaled(sparse_mlp=False)
    enc_axes = {"attn": L.attention_axes(cfg), "mlp": L.mlp_axes(mcfg),
                "norm1": ("embed",), "norm2": ("embed",)}
    dec_axes = {"self": L.attention_axes(cfg), "cross": L.attention_axes(cfg),
                "mlp": L.mlp_axes(mcfg),
                "norm1": ("embed",), "norm2": ("embed",), "norm3": ("embed",)}
    return {
        "embed": ("vocab", "w_embed"),
        "encoder": _prepend(enc_axes),
        "decoder": _prepend(dec_axes),
        "enc_norm": ("embed",), "final_norm": ("embed",),
        "unembed": ("w_embed", "vocab"),
    }


def encdec_init(key, cfg: ModelConfig, specs=None):
    del specs
    ks = jax.random.split(key, 4)
    embed, _ = L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
        jax.random.split(ks[1], cfg.encoder_layers)
    )
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
        jax.random.split(ks[2], cfg.num_layers)
    )
    params = {
        "embed": embed,
        "encoder": enc,
        "decoder": dec,
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": jax.random.normal(ks[3], (cfg.d_model, cfg.padded_vocab),
                                     jnp.float32) * cfg.d_model**-0.5,
    }
    return params, encdec_axes(cfg), None


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, T, d) -> encoder states (B, T, d)."""
    h = constrain(frames.astype(cfg.activation_dtype), "batch", "frames", "embed")
    positions = jnp.arange(h.shape[1])

    def body(h, lp):
        attn, _ = L.attention_apply(lp["attn"], cfg,
                                    L.rmsnorm(h, lp["norm1"]),
                                    positions=positions, causal=False)
        h = h + attn
        h = h + L.mlp_apply(lp["mlp"], cfg.scaled(sparse_mlp=False),
                            L.rmsnorm(h, lp["norm2"]))
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"],
                        unroll=not cfg.scan_layers)
    return L.rmsnorm(h, params["enc_norm"])


def forward(params, cfg: ModelConfig, tokens, *, specs=None,
            frames: jax.Array | None = None, patch_embeds=None,
            last_only: bool = False):
    from .transformer import LMOutputs

    del patch_embeds
    dt = cfg.activation_dtype
    enc = encode(params, cfg, frames)
    h = params["embed"].astype(dt)[tokens]
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.arange(h.shape[1])

    def body(h, lp):
        attn, _ = L.attention_apply(lp["self"], cfg,
                                    L.rmsnorm(h, lp["norm1"]),
                                    positions=positions, causal=True)
        h = h + attn
        kv = cross_kv(lp["cross"], cfg, enc)
        h = h + _cross_attention_apply(lp["cross"], cfg,
                                       L.rmsnorm(h, lp["norm2"]), kv, positions)
        h = h + L.mlp_apply(lp["mlp"], cfg.scaled(sparse_mlp=False),
                            L.rmsnorm(h, lp["norm3"]))
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["decoder"],
                        unroll=not cfg.scan_layers)
    h = L.rmsnorm(h, params["final_norm"])
    if last_only:
        h = h[:, -1:, :]
    logits = L.mask_pad_logits(h @ params["unembed"].astype(dt), cfg)
    return LMOutputs(
        logits=constrain(logits, "batch", "seq", "vocab"),
        aux_loss=jnp.zeros((), jnp.float32),
    )


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    dh = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    self_cache = L.decode_cache_init(cfg, batch, max_len, cfg.num_layers)
    cross = {
        "k": jnp.zeros((cfg.num_layers, batch, cfg.num_frames,
                        cfg.num_kv_heads, dh), dt),
        "v": jnp.zeros((cfg.num_layers, batch, cfg.num_frames,
                        cfg.num_kv_heads, dh), dt),
    }
    return {"self": self_cache, "cross": cross}


def decode_state_axes(cfg: ModelConfig):
    return {
        "self": L.CACHE_AXES,
        "cross": {"k": (None, "batch", "frames", "kv", None),
                  "v": (None, "batch", "frames", "kv", None)},
    }


def precompute_cross(params, cfg: ModelConfig, frames: jax.Array) -> dict:
    """Run the encoder once and cache per-layer cross k/v for decoding."""
    enc = encode(params, cfg, frames)

    def one_layer(lp):
        kv = cross_kv(lp["cross"], cfg, enc)
        return kv["k"], kv["v"]

    k, v = jax.vmap(one_layer, in_axes=0)(params["decoder"])
    return {"k": k, "v": v}


def decode_step(params, cfg: ModelConfig, state, tokens, pos, *, specs=None):
    dt = cfg.activation_dtype
    h = params["embed"].astype(dt)[tokens]
    positions = pos[:, None]

    def body(h, xs):
        lp, ck, cv, xk, xv = xs
        cache = {"k": ck, "v": cv, "pos": pos}
        attn, nc = L.attention_apply(lp["self"], cfg,
                                     L.rmsnorm(h, lp["norm1"]),
                                     positions=positions, causal=True,
                                     cache=cache)
        h = h + attn
        h = h + _cross_attention_apply(lp["cross"], cfg,
                                       L.rmsnorm(h, lp["norm2"]),
                                       {"k": xk, "v": xv}, positions)
        h = h + L.mlp_apply(lp["mlp"], cfg.scaled(sparse_mlp=False),
                            L.rmsnorm(h, lp["norm3"]))
        return h, (nc["k"], nc["v"])

    h, (ck, cv) = jax.lax.scan(
        body, h,
        (params["decoder"], state["self"]["k"], state["self"]["v"],
         state["cross"]["k"], state["cross"]["v"]),
        unroll=not cfg.scan_layers,
    )
    new_state = {
        "self": {"k": ck, "v": cv, "pos": state["self"]["pos"] + 1},
        "cross": state["cross"],
    }
    h = L.rmsnorm(h, params["final_norm"])
    logits = L.mask_pad_logits((h @ params["unembed"].astype(dt))[:, 0, :], cfg)
    return constrain(logits, "batch", "vocab"), new_state
