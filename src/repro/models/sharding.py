"""Logical-axis sharding rules (MaxText-style) for the model stack.

Model code names tensor dimensions with *logical* axes ("batch", "embed",
"heads", ...); a rule table maps them to physical mesh axes at launch.
Parameters carry a parallel pytree of logical-axis tuples produced by the
init functions; ``logical_to_sharding`` turns those into NamedShardings
for jit's in_shardings, and ``constrain`` applies activation constraints
inside the traced function.

Default rules implement Megatron-TP x FSDP x DP:
  * activations: batch -> (pod, data); model-parallel dims -> model
  * weights: the "embed" dim shards over data (ZeRO/FSDP — keeps per-chip
    parameter+optimizer bytes flat as the pod grows), TP dims over model,
    and nothing over pod (pod is pure DP: weights replicated per pod,
    gradients psum across pods).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,        # activations' model dim stays replicated
    "heads": "model",
    "kv": "model",
    "kv_seq": None,       # decode cache seq; long-context overrides to model
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,   # EP default; flipped to "model" for TP-MoE
    "expert_cap": None,
    "layers": None,
    "conv": None,
    "ssm_state": None,
    "frames": None,
    "patches": None,
    # weight-only axes
    "w_embed": "data",    # FSDP shard of the embed dim of weight matrices
    "w_layers": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict = dict(DEFAULT_RULES)


_ctx = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh | None, rules: dict | None = None):
    """Activate a mesh + rule table for model tracing."""
    old = (_ctx.mesh, _ctx.rules)
    _ctx.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _ctx.rules = merged
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = old


def _resolve(axis: str | None):
    if axis is None:
        return None
    mapped = _ctx.rules.get(axis, None)
    if mapped is None:
        return None
    mesh_axes = _ctx.mesh.axis_names if _ctx.mesh is not None else ()
    if isinstance(mapped, tuple):
        present = tuple(a for a in mapped if a in mesh_axes)
        return present if present else None
    return mapped if mapped in mesh_axes else None


def spec_for(axes: tuple) -> P:
    """Logical axis tuple -> PartitionSpec under the active rules."""
    return P(*[_resolve(a) for a in axes])


def logical_to_sharding(axes_tree, mesh: Mesh, rules: dict | None = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    with axis_rules(mesh, rules):
        return jax.tree_util.tree_map(
            lambda axes: NamedSharding(mesh, spec_for(axes)),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )


def sanitize_shardings(shapes_tree, shardings_tree, mesh: Mesh):
    """Drop sharding on any dim the mesh axes don't divide (jit inputs
    require exact divisibility). The production rule tables avoid this by
    construction (vocab padding, split projections); this is the safety
    net for residual odd dims (e.g. a 12-head model on a 16-wide axis)."""

    def fix(shape_leaf, sh: NamedSharding):
        spec = list(sh.spec) + [None] * (len(shape_leaf.shape) - len(sh.spec))
        out = []
        for dim, ax in zip(shape_leaf.shape, spec):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            width = 1
            for a in axes:
                width *= mesh.shape[a]
            out.append(ax if dim % width == 0 else None)
        return NamedSharding(mesh, P(*out))

    return jax.tree_util.tree_map(fix, shapes_tree, shardings_tree)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Apply a logical-axis sharding constraint (no-op without a mesh)."""
    if _ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ctx.mesh, spec_for(axes))
    )
