"""Decoder-only LM: the unified backbone for dense / moe / ssm / vlm archs.

Layer parameters are stacked on a leading (L,) axis and driven by
``lax.scan`` (compile-time O(1) in depth — at 64 layers x 512 devices this
is what keeps the dry-run tractable); per-layer remat policy comes from
``cfg.remat``. Hybrid (zamba2) and enc-dec (whisper) wrap this module —
see hybrid.py / encdec.py.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L
from . import moe as moe_mod
from . import ssm as ssm_mod
from .sharding import constrain


class LMOutputs(NamedTuple):
    logits: jax.Array
    aux_loss: jax.Array


def _prepend_layers_axis(axes):
    return jax.tree_util.tree_map(
        lambda a: ("w_layers",) + a,
        axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def _moe_group_size(cfg: ModelConfig) -> int | None:
    """k when MoE layers are interleaved every k layers (llama4), else None."""
    if cfg.family == "moe" and cfg.moe_every > 1:
        assert cfg.num_layers % cfg.moe_every == 0
        return cfg.moe_every
    return None


def _layer_axes(cfg: ModelConfig) -> dict:
    if cfg.family == "ssm":
        return {"mixer": ssm_mod.ssm_axes(cfg), "norm1": ("embed",)}
    k = _moe_group_size(cfg)
    if k is not None:
        dense_cfg = cfg.scaled(family="dense")
        return {
            "dense": _prepend_layers_axis(_layer_axes(dense_cfg)),
            "moe": _layer_axes(cfg.scaled(moe_every=1)),
        }
    ffn_axes = (
        moe_mod.moe_axes(cfg) if cfg.family == "moe" else L.mlp_axes(cfg)
    )
    return {
        "attn": L.attention_axes(cfg),
        "ffn": ffn_axes,
        "norm1": ("embed",),
        "norm2": ("embed",),
    }


def _layer_init(key, cfg: ModelConfig, specs=None):
    """One decoder layer's (or MoE layer-group's) params."""
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        p_mix, _ = ssm_mod.ssm_init(ks[0], cfg)
        return {"mixer": p_mix, "norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    k = _moe_group_size(cfg)
    if k is not None:
        dense_cfg = cfg.scaled(family="dense")
        dense = jax.vmap(lambda kk: _layer_init(kk, dense_cfg, specs=specs))(
            jax.random.split(ks[2], k - 1)
        )
        moe = _layer_init(ks[3], cfg.scaled(moe_every=1), specs=specs)
        return {"dense": dense, "moe": moe}
    p_attn, _ = L.attention_init(ks[0], cfg)
    if cfg.family == "moe":
        p_ffn, _ = moe_mod.moe_init(ks[1], cfg)
    else:
        p_ffn, _, _ = L.mlp_init(ks[1], cfg, specs=specs)
    return {
        "attn": p_attn,
        "ffn": p_ffn,
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
    }


def lm_axes(cfg: ModelConfig) -> dict:
    axes = {
        "embed": ("vocab", "w_embed"),
        "layers": _prepend_layers_axis(_layer_axes(cfg)),
        "final_norm": ("embed",),
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("w_embed", "vocab")
    return axes


def _num_scan_steps(cfg: ModelConfig) -> int:
    k = _moe_group_size(cfg)
    return cfg.num_layers // k if k is not None else cfg.num_layers


def lm_init(key, cfg: ModelConfig, specs=None):
    ks = jax.random.split(key, 4)
    embed, _ = L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model)
    keys = jax.random.split(ks[1], _num_scan_steps(cfg))
    lyr = jax.vmap(lambda k: _layer_init(k, cfg, specs=specs))(keys)
    params = {
        "embed": embed,
        "layers": lyr,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(ks[2], (cfg.d_model, cfg.padded_vocab), jnp.float32)
            * cfg.d_model**-0.5
        )
    return params, lm_axes(cfg), specs


def _group_body(
    params: dict, cfg: ModelConfig, h: jax.Array, positions: jax.Array,
    specs=None, caches: tuple | None = None,
):
    """One MoE layer-group: (k-1) dense layers then 1 MoE layer.

    caches: optional (k_cache, v_cache) stacked (k, ...) for decode.
    Returns (h, aux, new_caches or None).
    """
    k = _moe_group_size(cfg)
    dense_cfg = cfg.scaled(family="dense")
    new_k, new_v = [], []
    aux = jnp.zeros((), jnp.float32)
    for j in range(k - 1):
        lp = jax.tree_util.tree_map(lambda a: a[j], params["dense"])
        cache = None
        if caches is not None:
            cache = {"k": caches[0][j], "v": caches[1][j], "pos": caches[2]}
        h, _, nc = _layer_body(lp, dense_cfg, h, positions, specs=specs,
                               cache=cache)
        if nc is not None:
            new_k.append(nc["k"])
            new_v.append(nc["v"])
    cache = None
    if caches is not None:
        cache = {"k": caches[0][k - 1], "v": caches[1][k - 1], "pos": caches[2]}
    h, aux_i, nc = _layer_body(params["moe"], cfg.scaled(moe_every=1), h,
                               positions, specs=specs, cache=cache)
    aux = aux + aux_i
    if nc is not None:
        new_k.append(nc["k"])
        new_v.append(nc["v"])
    new_caches = (
        (jnp.stack(new_k), jnp.stack(new_v)) if caches is not None else None
    )
    return h, aux, new_caches


def _layer_body(
    params: dict, cfg: ModelConfig, h: jax.Array, positions: jax.Array,
    specs=None, cache: dict | None = None,
):
    """Pre-norm residual layer. Returns (h, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        mix, _ = ssm_mod.ssm_apply(params["mixer"], cfg,
                                   L.rmsnorm(h, params["norm1"]))
        return h + mix, aux, None
    attn_out, new_cache = L.attention_apply(
        params["attn"], cfg, L.rmsnorm(h, params["norm1"]),
        positions=positions, causal=True, cache=cache,
        window=cfg.swa_window,
    )
    h = h + attn_out
    hn = L.rmsnorm(h, params["norm2"])
    if cfg.family == "moe":
        ffn_out, aux = moe_mod.moe_apply(params["ffn"], cfg, hn)
    else:
        ffn_out = L.mlp_apply(params["ffn"], cfg, hn, specs=specs)
    return h + ffn_out, aux, new_cache


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full"


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,                  # (B, S)
    *,
    specs=None,
    patch_embeds: jax.Array | None = None,
    last_only: bool = False,            # prefill: only final-position logits
) -> LMOutputs:
    """Full-sequence forward -> logits (B, S_text, V) (or (B, 1, V))."""
    dt = cfg.activation_dtype
    h = params["embed"].astype(dt)[tokens]
    n_prefix = 0
    if patch_embeds is not None:
        h = jnp.concatenate([patch_embeds.astype(dt), h], axis=1)
        n_prefix = patch_embeds.shape[1]
    h = constrain(h, "batch", "seq", "embed")
    S = h.shape[1]
    positions = jnp.arange(S)

    grouped = _moe_group_size(cfg) is not None

    def body(carry, layer_params):
        h, aux = carry
        if grouped:
            h, aux_i, _ = _group_body(layer_params, cfg, h, positions,
                                      specs=specs)
        else:
            h, aux_i, _ = _layer_body(layer_params, cfg, h, positions,
                                      specs=specs)
        return (h, aux + aux_i), None

    body = _remat(body, cfg)
    (h, aux), _ = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), params["layers"],
        unroll=not cfg.scan_layers,
    )

    h = L.rmsnorm(h, params["final_norm"])
    if n_prefix:
        h = h[:, n_prefix:, :]
    if last_only:
        h = h[:, -1:, :]
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(dt)
    logits = L.mask_pad_logits(h @ unembed, cfg)
    logits = constrain(logits, "batch", "seq", "vocab")
    return LMOutputs(logits=logits, aux_loss=aux / cfg.num_layers)


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    specs=None,
    aux_weight: float = 0.01,
    z_weight: float = 1e-4,
) -> tuple[jax.Array, dict]:
    out = forward(
        params, cfg, batch["tokens"], specs=specs,
        patch_embeds=batch.get("patch_embeds"),
    )
    logits = out.logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jax.nn.one_hot(batch["targets"], cfg.padded_vocab, dtype=jnp.float32)
    ll = jnp.sum(logits * tgt, axis=-1) - logz
    xent = -jnp.mean(ll)
    zloss = jnp.mean(jnp.square(logz))
    loss = xent + aux_weight * out.aux_loss + z_weight * zloss
    return loss, {"xent": xent, "aux": out.aux_loss, "zloss": zloss}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "ssm":
        return ssm_mod.ssm_state_init(cfg, batch, cfg.num_layers)
    return L.decode_cache_init(cfg, batch, max_len, cfg.num_layers)


def decode_state_axes(cfg: ModelConfig):
    if cfg.family == "ssm":
        return ssm_mod.SSM_STATE_AXES
    return L.CACHE_AXES


def decode_step(
    params: dict,
    cfg: ModelConfig,
    state: Any,
    tokens: jax.Array,     # (B, 1)
    pos: jax.Array,        # (B,)
    *,
    specs=None,
) -> tuple[jax.Array, Any]:
    """One token for every sequence in the batch. Returns (logits, state)."""
    dt = cfg.activation_dtype
    h = params["embed"].astype(dt)[tokens]      # (B, 1, d)
    h = constrain(h, "batch", None, "embed")

    if cfg.family == "ssm":
        def body(h, xs):
            layer_params, ssd, conv = xs
            hn = L.rmsnorm(h, layer_params["norm1"])
            mix, new_state = ssm_mod.ssm_decode_step(
                layer_params["mixer"], cfg, hn, {"ssd": ssd, "conv": conv}
            )
            return h + mix, (new_state["ssd"], new_state["conv"])

        h, (ssd, conv) = jax.lax.scan(
            body, h, (params["layers"], state["ssd"], state["conv"]),
            unroll=not cfg.scan_layers,
        )
        new_state = {"ssd": ssd, "conv": conv}
    else:
        positions = pos[:, None]                 # (B, 1) absolute
        k_grp = _moe_group_size(cfg)

        if k_grp is not None:
            # caches are stacked (L, ...); regroup as (G, k, ...)
            G = cfg.num_layers // k_grp
            ck_all = state["k"].reshape((G, k_grp) + state["k"].shape[1:])
            cv_all = state["v"].reshape((G, k_grp) + state["v"].shape[1:])

            def body(h, xs):
                group_params, ck, cv = xs
                h, _, ncs = _group_body(group_params, cfg, h, positions,
                                        specs=specs, caches=(ck, cv, pos))
                return h, ncs

            h, (ck, cv) = jax.lax.scan(
                body, h, (params["layers"], ck_all, cv_all),
                unroll=not cfg.scan_layers,
            )
            ck = ck.reshape(state["k"].shape)
            cv = cv.reshape(state["v"].shape)
        else:
            def body(h, xs):
                layer_params, ck, cv = xs
                cache = {"k": ck, "v": cv, "pos": pos}
                h, _, new_cache = _layer_body(
                    layer_params, cfg, h, positions, specs=specs, cache=cache
                )
                return h, (new_cache["k"], new_cache["v"])

            h, (ck, cv) = jax.lax.scan(
                body, h, (params["layers"], state["k"], state["v"]),
                unroll=not cfg.scan_layers,
            )
        new_state = {"k": ck, "v": cv, "pos": state["pos"] + 1}

    h = L.rmsnorm(h, params["final_norm"])
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(dt)
    logits = L.mask_pad_logits((h @ unembed)[:, 0, :], cfg)
    return constrain(logits, "batch", "vocab"), new_state
