"""Shared layer library: norms, embeddings, RoPE, attention cores, MLPs.

Functional style: ``*_init(key, ...) -> (params, axes)`` where ``axes``
is a parallel pytree of logical-axis tuples (see sharding.py), and
``*_apply(params, x, ...)`` is pure. Everything composes under scan/remat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from .sharding import constrain


def _split(key, n):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, axes: tuple, scale: float | None = None):
    scale = d_in**-0.5 if scale is None else scale
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return w, axes


def rmsnorm_init(d: int):
    return jnp.ones((d,), jnp.float32), ("embed",)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def embed_init(key, vocab: int, d: int):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return w, ("vocab", "w_embed")


def vocab_logit_mask(vocab_real: int, vocab_padded: int) -> jax.Array:
    """(Vpad,) additive mask: 0 for real ids, -1e9 for padding ids."""
    ids = jnp.arange(vocab_padded)
    return jnp.where(ids < vocab_real, 0.0, -1e9).astype(jnp.float32)


def mask_pad_logits(logits: jax.Array, cfg) -> jax.Array:
    """Suppress padding-vocab logits (no-op when vocab needs no padding)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    mask = vocab_logit_mask(cfg.vocab_size, cfg.padded_vocab).astype(logits.dtype)
    return logits + mask


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions (...,) -> cos/sin (..., head_dim/2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, dh); cos/sin broadcastable (..., S, 1, dh/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core (q-chunked, memory-efficient; GQA; optional SWA window)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int | None):
    """Additive bias (q, k) in f32: 0 allowed, -inf masked."""
    if causal:
        allowed = q_pos[..., :, None] >= k_pos[..., None, :]
    else:
        allowed = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if window is not None:
        near = q_pos[..., :, None] - k_pos[..., None, :] < window
        allowed = jnp.logical_and(allowed, near)
    return jnp.where(allowed, 0.0, -jnp.inf).astype(jnp.float32)


def attention_core(
    q: jax.Array,            # (B, Sq, H, dh)
    k: jax.Array,            # (B, Sk, Hkv, dh)
    v: jax.Array,            # (B, Sk, Hkv, dh)
    *,
    causal: bool,
    window: int | None = None,
    q_offset: jax.Array | int = 0,   # absolute position of q[0]
    chunk: int = 1024,
    kv_valid_len: jax.Array | None = None,  # decode: #valid cache slots
    unroll: bool = False,
) -> jax.Array:
    """Memory-efficient attention: scan over q chunks, full-K softmax rows.

    Never materializes the (Sq, Sk) score tensor — per-step memory is
    (chunk, Sk), which is what makes prefill_32k lowerable and keeps the
    roofline memory term honest. GQA: q heads grouped onto kv heads.
    """
    B, Sq, H, dh = q.shape
    _, Sk, Hkv, _ = k.shape
    groups = H // Hkv
    scale = dh**-0.5
    qf = (q * scale).astype(jnp.bfloat16) if q.dtype == jnp.bfloat16 else q * scale
    k_pos = jnp.arange(Sk)

    # GQA as repeat-kv (Megatron TP style): broadcasting K/V to H heads lets
    # every attention tensor shard on the full `heads` axis — grouped-einsum
    # formulations force uneven kv-head shardings (kv < TP width) and make
    # GSPMD fall back to full rematerialization copies.
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    # keep the (possibly sharded) kv sequence dim pinned through the
    # repeat/blend chain — losing it makes GSPMD gather the whole cache
    k = constrain(k, "batch", "kv_seq", "heads", None)
    v = constrain(v, "batch", "kv_seq", "heads", None)

    def one_chunk(q_chunk: jax.Array, q_pos: jax.Array) -> jax.Array:
        # q_chunk (B, C, H, dh). NOTE: bf16 operands with f32 accumulation
        # via preferred_element_type — an explicit astype(f32) materializes
        # a full-cache convert+copy every layer (measured 4.3 GB/op at
        # decode_32k; §Perf cell C).
        logits = jnp.einsum(
            "bchd,bshd->bhcs", q_chunk, k,
            preferred_element_type=jnp.float32,
        )
        bias = _mask_bias(q_pos, k_pos, causal, window)  # (C, Sk)
        if kv_valid_len is not None:
            valid = k_pos[None, :] < kv_valid_len[:, None]  # (B, Sk)
            bias = bias[None, :, :] + jnp.where(valid, 0.0, -jnp.inf)[:, None, :]
            logits = logits + bias[:, None, :, :]
        else:
            logits = logits + bias[None, None, :, :]
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum(
            "bhcs,bshd->bchd", probs.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return out.astype(q.dtype)

    if Sq <= chunk:
        q_pos = q_offset + jnp.arange(Sq)
        return one_chunk(qf, q_pos)

    Sq_pad = -(-Sq // chunk) * chunk
    if Sq_pad != Sq:
        qf = jnp.pad(qf, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    n_chunks = Sq_pad // chunk
    qs = qf.reshape(B, n_chunks, chunk, H, dh)

    def body(_, qc_i):
        qc, i = qc_i
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        return None, one_chunk(qc, q_pos)

    _, outs = jax.lax.scan(
        body, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(n_chunks)),
        unroll=unroll,
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq_pad, H, dh)
    return out[:, :Sq] if Sq_pad != Sq else out


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def attention_axes(cfg: ModelConfig) -> dict:
    axes = {
        "wq": ("w_embed", "heads", None),
        "wk": ("w_embed", "kv", None),
        "wv": ("w_embed", "kv", None),
        "wo": ("heads", None, "w_embed"),
    }
    if cfg.qk_norm:
        axes["q_norm"] = (None,)
        axes["k_norm"] = (None,)
    return axes


def attention_init(key, cfg: ModelConfig):
    d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    ks = _split(key, 4)
    params = {
        "wq": jax.random.normal(ks[0], (d, H, dh), jnp.float32) * d**-0.5,
        "wk": jax.random.normal(ks[1], (d, Hkv, dh), jnp.float32) * d**-0.5,
        "wv": jax.random.normal(ks[2], (d, Hkv, dh), jnp.float32) * d**-0.5,
        "wo": jax.random.normal(ks[3], (H, dh, d), jnp.float32) * (H * dh) ** -0.5,
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((dh,), jnp.float32)
        params["k_norm"] = jnp.ones((dh,), jnp.float32)
    return params, attention_axes(cfg)


def attention_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,                     # (B, S, d)
    *,
    positions: jax.Array,             # (S,) or (B, S)
    causal: bool = True,
    cache: dict | None = None,        # decode: {"k","v","pos"}
    window: int | None = None,
) -> tuple[jax.Array, dict | None]:
    dt = x.dtype
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    cos, sin = cos[..., None, :], sin[..., None, :]   # broadcast over heads
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv", None)

    new_cache = None
    if cache is None:
        out = attention_core(
            q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk,
            unroll=cfg.attn_unroll,
        )
    else:
        # decode: append this step's k/v into the (ring) cache
        ck, cv, pos = cache["k"], cache["v"], cache["pos"]  # pos (B,)
        S_max = ck.shape[1]
        slot = (pos % S_max).astype(jnp.int32)
        ck = _scatter_step(ck, k, slot)
        cv = _scatter_step(cv, v, slot)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
        kv_len = jnp.minimum(pos + 1, S_max)
        out = attention_core(
            q, ck, cv, causal=False, window=None,
            kv_valid_len=kv_len, chunk=cfg.attn_chunk,
        )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return constrain(y, "batch", "seq", "embed"), new_cache


def _scatter_step(cache: jax.Array, kv: jax.Array, slot: jax.Array) -> jax.Array:
    """Write kv (B, 1, Hkv, dh) at per-batch slot into cache (B, S, Hkv, dh).

    Implemented as a one-hot BLEND, not a true scatter, deliberately: under
    a sequence-sharded cache (flash-decoding layout) a dynamic scatter's
    write crosses shard boundaries and GSPMD falls back to gathering the
    whole cache; the blend distributes over shards trivially. Measured in
    EXPERIMENTS.md §Perf cell C: scatter+seq-sharded = 5.3x worse memory
    term than blend+seq-sharded. (On a single device a donated true
    scatter IS cheaper — this is a sharding-driven choice.)
    """
    oh = jax.nn.one_hot(slot, cache.shape[1], dtype=cache.dtype)  # (B, S)
    return cache * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * kv


def decode_cache_init(cfg: ModelConfig, batch: int, max_len: int, n_layers: int):
    """Ring-buffer KV cache; SWA archs only keep the window."""
    window = cfg.swa_window
    S = min(max_len, window) if window else max_len
    dh = cfg.resolved_head_dim
    shape = (n_layers, batch, S, cfg.num_kv_heads, dh)
    return {
        # distinct buffers — k/v must not alias (donation safety)
        "k": jnp.zeros(shape, cfg.activation_dtype),
        "v": jnp.zeros(shape, cfg.activation_dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


CACHE_AXES = {"k": (None, "batch", "kv_seq", "kv", None),
              "v": (None, "batch", "kv_seq", "kv", None),
              "pos": ("batch",)}


# ---------------------------------------------------------------------------
# MLPs (SwiGLU; dense or CB-sparse)
# ---------------------------------------------------------------------------

def build_mlp_specs(cfg: ModelConfig, seed: int = 42):
    """CB sparsity specs for the SwiGLU projections (numpy-only).

    One pattern shared by every layer (pattern-shared block sparsity —
    required for scanned/stacked layer params; DESIGN.md §8).
    """
    if not cfg.sparse_mlp:
        return None
    from repro.sparse.linear import cb_spec_random

    d, ff = cfg.d_model, cfg.d_ff
    mk = lambda i, o, s: cb_spec_random(
        i, o, block_size=cfg.sparse_block, keep_fraction=cfg.sparse_keep, seed=s
    )
    return {"gate": mk(d, ff, seed), "up": mk(d, ff, seed + 1),
            "down": mk(ff, d, seed + 2)}


def mlp_axes(cfg: ModelConfig) -> dict:
    if cfg.sparse_mlp:
        # tiles are small and uniform; replicate (FSDP gains negligible)
        return {
            "gate": {"tiles": (None, None, None)},
            "up": {"tiles": (None, None, None)},
            "down": {"tiles": (None, None, None)},
        }
    return {
        "w_gate": ("w_embed", "mlp"),
        "w_up": ("w_embed", "mlp"),
        "w_down": ("mlp", "w_embed"),
    }


def mlp_init(key, cfg: ModelConfig, specs=None):
    d, ff = cfg.d_model, cfg.d_ff
    ks = _split(key, 3)
    if cfg.sparse_mlp:
        from repro.sparse.linear import cb_tiles_init

        assert specs is not None, "sparse_mlp requires precomputed specs"
        params = {
            "gate": cb_tiles_init(ks[0], specs["gate"]),
            "up": cb_tiles_init(ks[1], specs["up"]),
            "down": cb_tiles_init(ks[2], specs["down"]),
        }
        return params, mlp_axes(cfg), specs
    params = {
        "w_gate": jax.random.normal(ks[0], (d, ff), jnp.float32) * d**-0.5,
        "w_up": jax.random.normal(ks[1], (d, ff), jnp.float32) * d**-0.5,
        "w_down": jax.random.normal(ks[2], (ff, d), jnp.float32) * ff**-0.5,
    }
    return params, mlp_axes(cfg), None


def mlp_apply(params, cfg: ModelConfig, x: jax.Array, specs=None) -> jax.Array:
    dt = x.dtype
    if cfg.sparse_mlp:
        from repro.sparse.linear import cb_linear_apply

        g = cb_linear_apply(params["gate"], specs["gate"], x)
        u = cb_linear_apply(params["up"], specs["up"], x)
        h = jax.nn.silu(g) * u
        h = constrain(h, "batch", "seq", "mlp")
        return cb_linear_apply(params["down"], specs["down"], h)
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(h @ params["w_down"].astype(dt), "batch", "seq", "embed")
