"""Mamba2 (SSD — state-space duality) block, chunked-parallel in JAX.

Implements the SSD algorithm of Mamba2 (arXiv:2405.21060): within-chunk
interactions as dense (Q x Q) matmuls (MXU-friendly — the whole point of
SSD) and across-chunk state carried by a lax.scan recurrence. Recurrences
run in f32; inputs/outputs follow the model activation dtype.

Decode is a single-step state update: S <- exp(dt*A) S + dt * x B^T,
y = C.S — O(1) per token, which is why the ssm/hybrid archs run the
long_500k shape (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import rmsnorm
from .sharding import constrain


def _dims(cfg: ModelConfig):
    d_in = cfg.d_model * cfg.ssm_expand
    nh = d_in // cfg.ssm_headdim
    return d_in, nh, cfg.ssm_headdim, cfg.ssm_state


def ssm_axes(cfg: ModelConfig) -> dict:
    # in_proj is SPLIT into z / xBC / dt projections so each output dim
    # shards cleanly over the model axis (the fused layout's width is not
    # divisible by TP width in general — DESIGN.md hardware adaptation).
    return {
        "in_z": ("w_embed", "mlp"),
        "in_xbc": ("w_embed", "mlp"),
        "in_dt": ("w_embed", None),
        "conv_w": (None, "mlp"),
        "conv_b": ("mlp",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm_w": ("mlp",),
        "out_proj": ("mlp", "w_embed"),
    }


def ssm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, nh, hd, ds = _dims(cfg)
    conv_ch = d_in + 2 * ds
    ks = jax.random.split(key, 6)
    params = {
        "in_z": jax.random.normal(ks[0], (d, d_in), jnp.float32) * d**-0.5,
        "in_xbc": jax.random.normal(ks[4], (d, d_in + 2 * ds), jnp.float32) * d**-0.5,
        "in_dt": jax.random.normal(ks[5], (d, nh), jnp.float32) * d**-0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (d_in, d), jnp.float32) * d_in**-0.5,
    }
    return params, ssm_axes(cfg)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: x (B, L, C), w (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return out + b[None, None, :]


def _in_proj(params, x, dt_):
    """Split z / xBC / dt projections (each TP-shardable on its own)."""
    z = x @ params["in_z"].astype(dt_)
    xBC = x @ params["in_xbc"].astype(dt_)
    dt = x @ params["in_dt"].astype(dt_)
    return z, xBC, dt


def ssd_chunked(
    xh: jax.Array,    # (B, L, nh, hd)
    dt: jax.Array,    # (B, L, nh) — post-softplus
    A: jax.Array,     # (nh,) negative
    Bm: jax.Array,    # (B, L, ds)
    Cm: jax.Array,    # (B, L, ds)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, nh, hd, ds)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,L,nh,hd) f32, final_state f32)."""
    B_, L, nh, hd = xh.shape
    ds = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    xf = xh.astype(jnp.float32).reshape(B_, nc, Q, nh, hd)
    dtf = dt.astype(jnp.float32).reshape(B_, nc, Q, nh)
    Bf = Bm.astype(jnp.float32).reshape(B_, nc, Q, ds)
    Cf = Cm.astype(jnp.float32).reshape(B_, nc, Q, ds)

    da = dtf * A[None, None, None, :]               # (B, nc, Q, nh), <= 0
    cum = jnp.cumsum(da, axis=2)                     # inclusive
    total = cum[:, :, -1, :]                         # (B, nc, nh)

    # ---- intra-chunk (dense QxQ attention-like matmul) -------------------
    G = jnp.einsum("bcqs,bcks->bcqk", Cf, Bf)        # (B, nc, Q, Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B, nc, Q, K, nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    M = G[..., None] * decay * dtf[:, :, None, :, :]      # weight at key pos
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xf)

    # ---- chunk boundary states ------------------------------------------
    # contribution of chunk c to its outgoing state
    w_in = jnp.exp(total[:, :, None, :] - cum) * dtf      # (B, nc, Q, nh)
    S_in = jnp.einsum("bcks,bckhp,bckh->bchps", Bf, xf, w_in)  # (B,nc,nh,hd,ds)

    S0 = (
        jnp.zeros((B_, nh, hd, ds), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def scan_fn(S_prev, inp):
        S_c, tot_c = inp                       # (B, nh, hd, ds), (B, nh)
        S_next = jnp.exp(tot_c)[:, :, None, None] * S_prev + S_c
        return S_next, S_prev                  # emit the *incoming* state

    S_last, S_prevs = jax.lax.scan(
        scan_fn, S0,
        (jnp.moveaxis(S_in, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)      # (B, nc, nh, hd, ds)

    # ---- inter-chunk output ----------------------------------------------
    y_inter = jnp.einsum(
        "bcqs,bchps,bcqh->bcqhp", Cf, S_prevs, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(B_, L, nh, hd)
    return y, S_last


def ssm_apply(
    params, cfg: ModelConfig, x: jax.Array,
    state: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """Full-sequence forward (training/prefill). x (B, L, d)."""
    dt_ = x.dtype
    d_in, nh, hd, ds = _dims(cfg)
    z, xBC, dt_raw = _in_proj(params, x, dt_)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(dt_),
                                   params["conv_b"].astype(dt_)))
    xs = xBC[..., :d_in]
    Bm = xBC[..., d_in : d_in + ds]
    Cm = xBC[..., d_in + ds :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(*xs.shape[:-1], nh, hd)
    xh = constrain(xh, "batch", "seq", "heads", None)
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:-1], d_in).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["out_proj"].astype(dt_)
    return constrain(out, "batch", "seq", "embed"), None


def ssm_state_init(cfg: ModelConfig, batch: int, n_layers: int):
    d_in, nh, hd, ds = _dims(cfg)
    conv_ch = d_in + 2 * ds
    return {
        "ssd": jnp.zeros((n_layers, batch, nh, hd, ds), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, conv_ch),
                          jnp.float32),
    }


SSM_STATE_AXES = {"ssd": (None, "batch", "heads", None, None),
                  "conv": (None, "batch", None, "mlp")}


def ssm_decode_step(
    params, cfg: ModelConfig, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """Single-token step. x (B, 1, d); state {"ssd", "conv"} per layer slice."""
    dt_ = x.dtype
    d_in, nh, hd, ds = _dims(cfg)
    z, xBC, dt_raw = _in_proj(params, x[:, 0, :], dt_)
    # conv ring: state["conv"] (B, W-1, C) holds previous inputs
    W = cfg.ssm_conv_width
    hist = jnp.concatenate([state["conv"].astype(dt_), xBC[:, None, :]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist, params["conv_w"].astype(dt_))
    xBC_t = jax.nn.silu(conv_out + params["conv_b"].astype(dt_))
    new_conv = hist[:, 1:, :].astype(jnp.float32)

    xs = xBC_t[..., :d_in]
    Bm = xBC_t[..., d_in : d_in + ds].astype(jnp.float32)
    Cm = xBC_t[..., d_in + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, :])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(-1, nh, hd).astype(jnp.float32)

    S = state["ssd"]                                       # (B, nh, hd, ds)
    decay = jnp.exp(dt * A[None, :])                       # (B, nh)
    S_new = decay[:, :, None, None] * S + jnp.einsum(
        "bh,bhp,bs->bhps", dt, xh, Bm
    )
    y = jnp.einsum("bs,bhps->bhp", Cm, S_new)              # (B, nh, hd)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(-1, d_in).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = (y @ params["out_proj"].astype(dt_))[:, None, :]
    return out, {"ssd": S_new, "conv": new_conv}
