"""Model library: 10 assigned architectures over 6 families."""
from .model import Model  # noqa: F401
from .sharding import axis_rules, constrain, logical_to_sharding  # noqa: F401
