"""Batched autoregressive decoding on top of the models' decode_step.

Greedy + temperature sampling drivers. Prefill is performed by stepping
the prompt through decode_step (cache-filling teacher forcing) — one code
path for both phases keeps the serving state machine trivial; the
prefill-optimized path (full-sequence forward) is exercised separately by
the prefill_32k dry-run cells.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model


def build_decode_fn(model: Model) -> Callable:
    """jitted (params, state, tokens, pos) -> (logits, state)."""

    def step(params, state, tokens, pos):
        return model.decode_step(params, state, tokens, pos)

    return jax.jit(step, donate_argnums=1)


def greedy_decode(
    model: Model,
    params,
    prompts: jax.Array,        # (B, P) int32
    max_new_tokens: int,
    *,
    max_len: int | None = None,
    temperature: float = 0.0,
    key: jax.Array | None = None,
):
    """Returns generated tokens (B, max_new_tokens)."""
    B, P = prompts.shape
    max_len = max_len or (P + max_new_tokens)
    state = model.init_decode_state(B, max_len)
    step_fn = build_decode_fn(model)

    logits = None
    for t in range(P):                       # prefill (cache-filling)
        pos = jnp.full((B,), t, jnp.int32)
        logits, state = step_fn(params, state, prompts[:, t : t + 1], pos)

    outs = []
    tok = _select(logits, temperature, key, 0)
    for t in range(max_new_tokens):
        outs.append(tok)
        pos = jnp.full((B,), P + t, jnp.int32)
        logits, state = step_fn(params, state, tok[:, None], pos)
        if key is not None:
            key = jax.random.fold_in(key, t)
        tok = _select(logits, temperature, key, t + 1)
    return jnp.stack(outs, axis=1)


def _select(logits, temperature, key, t):
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        jax.random.fold_in(key, t), logits / temperature
    ).astype(jnp.int32)
