"""Continuous-batching serving engine (slot-based, vLLM-style scheduling
at toy scale).

A fixed number of batch slots share one decode cache. Each engine tick
runs ONE decode_step for the whole batch; finished/empty slots are
refilled from the request queue by resetting that slot's cache position
(per-slot ``pos`` makes mixed-depth batches correct — attention masks by
``kv_valid_len``). This is the serving shape the paper's SpMV targets:
weight-bound batched matvec at small per-step batch.

Degradation model (the fault-injection axis): the engine degrades
*gracefully* instead of growing without bound or crashing mid-batch —

  * **backpressure** — ``submit`` rejects with the typed status
    ``errors.QUEUE_FULL`` once the queue holds ``max_queue`` requests;
  * **deadlines** — a request with ``deadline_ticks`` set is expired
    (status ``errors.DEADLINE_EXCEEDED``, slot freed) when that many
    ticks pass after submission without completion;
  * **tick retry** — a failing decode step is retried up to
    ``max_step_retries`` times with ``retry_backoff_s`` backoff. The
    step function is pure (state is only assigned on success), so a
    retried tick is bit-identical to a never-failed one. Exhaustion
    raises ``errors.TickError``;
  * **health** — :meth:`health` snapshots the counters so a supervisor
    can alarm on rejection/expiry/retry rates.

Telemetry: every degradation counter also lands on the obs registry
(``repro.serving.*``, labeled per engine instance), each tick runs under
an ``obs.span("serving.tick")``, and tick latency / queue depth feed
deterministic histograms surfaced through :meth:`health` — the inputs a
supervisor needs for percentile-based alerting, not just totals.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import errors, obs
from repro.models.model import Model

from .decode import build_decode_fn

# Distinguishes concurrent engines' series on the process-wide registry.
_ENGINE_IDS = itertools.count()


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # degradation bookkeeping
    deadline_ticks: Optional[int] = None   # None = no deadline
    status: str = errors.ACCEPTED
    submitted_tick: Optional[int] = None


class ServingEngine:
    def __init__(self, model: Model, params, *, slots: int = 8,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 max_step_retries: int = 0,
                 retry_backoff_s: float = 0.0,
                 sleep=time.sleep):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.max_queue = max_queue
        self.max_step_retries = max_step_retries
        self.retry_backoff_s = retry_backoff_s
        self._sleep = sleep
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * slots
        self._remaining_prompt: list[np.ndarray] = [np.zeros(0, np.int32)] * slots
        self.state = model.init_decode_state(slots, max_len)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.next_token = np.zeros((slots,), np.int32)
        self.step_fn = build_decode_fn(model)
        self.ticks = 0
        self.completed = 0
        self.rejected = 0
        self.retries = 0
        self.deadline_expired = 0
        self.backoff_total_s = 0.0
        self.expired: list[Request] = []
        self.last_error: Optional[str] = None
        self._obs_labels = {"engine": str(next(_ENGINE_IDS))}

    def _count(self, metric: str, value: int = 1) -> None:
        obs.counter(f"repro.serving.{metric}").inc(value, **self._obs_labels)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> str:
        """Enqueue a request; returns its typed admission status.

        ``errors.ACCEPTED`` on success, ``errors.QUEUE_FULL`` when the
        bounded queue is at capacity (the request is *not* enqueued —
        typed rejection instead of unbounded growth).
        """
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.status = errors.QUEUE_FULL
            self.rejected += 1
            self._count("rejected")
            return req.status
        req.status = errors.ACCEPTED
        req.submitted_tick = self.ticks
        self.queue.append(req)
        return req.status

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                self._remaining_prompt[s] = np.asarray(req.prompt, np.int32)
                self.pos = self.pos.at[s].set(0)
                self._reset_slot_cache(s)

    def _reset_slot_cache(self, s: int) -> None:
        def zero_slot(leaf):
            # state leaves are (L, B, ...) or (B, ...); zero batch index s
            if leaf.ndim >= 2 and leaf.shape[1] == self.slots:
                return leaf.at[:, s].set(0)
            if leaf.ndim >= 1 and leaf.shape[0] == self.slots:
                return leaf.at[s].set(jnp.zeros_like(leaf[s]))
            return leaf
        self.state = jax.tree_util.tree_map(zero_slot, self.state)

    # ------------------------------------------------------------------
    def _expire(self, req: Request) -> None:
        req.status = errors.DEADLINE_EXCEEDED
        self.deadline_expired += 1
        self._count("deadline_expired")
        self.expired.append(req)

    def _expire_deadlines(self) -> None:
        """Drop queued/active requests whose deadline has passed."""
        def overdue(req: Request) -> bool:
            return (req.deadline_ticks is not None
                    and req.submitted_tick is not None
                    and self.ticks - req.submitted_tick >= req.deadline_ticks)

        if any(overdue(r) for r in self.queue):
            keep = deque()
            for req in self.queue:
                self._expire(req) if overdue(req) else keep.append(req)
            self.queue = keep
        for s, req in enumerate(self.active):
            if req is not None and overdue(req):
                self._expire(req)
                self.active[s] = None

    def _step_with_retry(self, tokens):
        """Run the decode step, retrying injected/transient failures.

        ``step_fn`` is functional — ``self.state``/``self.pos`` are only
        assigned by the caller on success — so a retry re-runs the exact
        same computation and the surviving tick is bit-identical to one
        that never failed. Raises ``errors.TickError`` when
        ``max_step_retries`` is exhausted.
        """
        attempts = self.max_step_retries + 1
        for attempt in range(attempts):
            try:
                return self.step_fn(
                    self.params, self.state,
                    jnp.asarray(tokens)[:, None], self.pos,
                )
            except Exception as e:  # noqa: BLE001 — injected faults are RuntimeErrors
                self.last_error = f"{type(e).__name__}: {e}"
                if attempt + 1 >= attempts:
                    raise errors.TickError(errors.reason(
                        errors.TICK_FAILED,
                        f"decode step failed {attempts} time(s); "
                        f"last: {self.last_error}",
                    )) from e
                self.retries += 1
                self._count("retries")
                if self.retry_backoff_s:
                    delay = self.retry_backoff_s * (2 ** attempt)
                    self.backoff_total_s += delay
                    self._sleep(delay)

    # ------------------------------------------------------------------
    def tick(self) -> list[Request]:
        """One decode step for the whole batch. Returns finished requests."""
        if not obs.is_enabled():
            return self._tick()
        with obs.span("serving.tick", tick=self.ticks,
                      queue_depth=len(self.queue)) as sp:
            t0 = obs.now()
            finished = self._tick()
            obs.histogram("repro.serving.tick_latency_s").observe(
                obs.now() - t0, **self._obs_labels)
            obs.histogram("repro.serving.queue_depth").observe(
                len(self.queue), **self._obs_labels)
            self._count("ticks")
            if finished:
                self._count("completed", len(finished))
            sp.set(finished=len(finished))
        return finished

    def _tick(self) -> list[Request]:
        self._expire_deadlines()
        self._admit()
        tokens = np.zeros((self.slots,), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if len(self._remaining_prompt[s]):
                tokens[s] = self._remaining_prompt[s][0]
            else:
                tokens[s] = self.next_token[s]

        logits, self.state = self._step_with_retry(tokens)
        self.pos = self.pos + 1
        picked = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

        finished = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if len(self._remaining_prompt[s]):
                self._remaining_prompt[s] = self._remaining_prompt[s][1:]
                if len(self._remaining_prompt[s]) == 0:
                    self.next_token[s] = picked[s]   # first generated token
                continue
            req.generated.append(int(self.next_token[s]))
            self.next_token[s] = picked[s]
            hit_eos = self.eos_id is not None and req.generated[-1] == self.eos_id
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                req.done = True
                finished.append(req)
                self.completed += 1
                self.active[s] = None
        self.ticks += 1
        return finished

    def run_until_done(self, max_ticks: int = 10_000) -> list[Request]:
        done: list[Request] = []
        while (self.queue or any(self.active)) and self.ticks < max_ticks:
            done.extend(self.tick())
        return done

    # ------------------------------------------------------------------
    def health(self) -> dict:
        """Counter snapshot for supervisors (cheap, host-only).

        Totals are cumulative over the engine's lifetime — ``retries``
        counts every retried step and ``backoff_total_s`` the summed
        backoff sleep, so a supervisor can alarm on *rates* between two
        snapshots. ``tick_latency_s`` / ``queue_depth_hist`` are
        histogram summaries (count/sum/min/max/p50/p99 from the obs
        registry); their counts stay 0 while obs is disabled.
        """
        lat = obs.histogram("repro.serving.tick_latency_s").summary(
            **self._obs_labels)
        depth = obs.histogram("repro.serving.queue_depth").summary(
            **self._obs_labels)
        return {
            "ticks": self.ticks,
            "queue_depth": len(self.queue),
            "active_slots": sum(r is not None for r in self.active),
            "completed": self.completed,
            "rejected": self.rejected,
            "retries": self.retries,
            "backoff_total_s": self.backoff_total_s,
            "deadline_expired": self.deadline_expired,
            "deadline_miss_count": self.deadline_expired,
            "tick_latency_s": lat,
            "queue_depth_hist": depth,
            "last_error": self.last_error,
        }
