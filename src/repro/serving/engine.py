"""Continuous-batching serving engine (slot-based, vLLM-style scheduling
at toy scale).

A fixed number of batch slots share one decode cache. Each engine tick
runs ONE decode_step for the whole batch; finished/empty slots are
refilled from the request queue by resetting that slot's cache position
(per-slot ``pos`` makes mixed-depth batches correct — attention masks by
``kv_valid_len``). This is the serving shape the paper's SpMV targets:
weight-bound batched matvec at small per-step batch.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

from .decode import build_decode_fn


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, *, slots: int = 8,
                 max_len: int = 512, eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * slots
        self._remaining_prompt: list[np.ndarray] = [np.zeros(0, np.int32)] * slots
        self.state = model.init_decode_state(slots, max_len)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.next_token = np.zeros((slots,), np.int32)
        self.step_fn = build_decode_fn(model)
        self.ticks = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                self._remaining_prompt[s] = np.asarray(req.prompt, np.int32)
                self.pos = self.pos.at[s].set(0)
                self._reset_slot_cache(s)

    def _reset_slot_cache(self, s: int) -> None:
        def zero_slot(leaf):
            # state leaves are (L, B, ...) or (B, ...); zero batch index s
            if leaf.ndim >= 2 and leaf.shape[1] == self.slots:
                return leaf.at[:, s].set(0)
            if leaf.ndim >= 1 and leaf.shape[0] == self.slots:
                return leaf.at[s].set(jnp.zeros_like(leaf[s]))
            return leaf
        self.state = jax.tree_util.tree_map(zero_slot, self.state)

    # ------------------------------------------------------------------
    def tick(self) -> list[Request]:
        """One decode step for the whole batch. Returns finished requests."""
        self._admit()
        tokens = np.zeros((self.slots,), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if len(self._remaining_prompt[s]):
                tokens[s] = self._remaining_prompt[s][0]
            else:
                tokens[s] = self.next_token[s]

        logits, self.state = self.step_fn(
            self.params, self.state, jnp.asarray(tokens)[:, None], self.pos
        )
        self.pos = self.pos + 1
        picked = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

        finished = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if len(self._remaining_prompt[s]):
                self._remaining_prompt[s] = self._remaining_prompt[s][1:]
                if len(self._remaining_prompt[s]) == 0:
                    self.next_token[s] = picked[s]   # first generated token
                continue
            req.generated.append(int(self.next_token[s]))
            self.next_token[s] = picked[s]
            hit_eos = self.eos_id is not None and req.generated[-1] == self.eos_id
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                req.done = True
                finished.append(req)
                self.active[s] = None
        self.ticks += 1
        return finished

    def run_until_done(self, max_ticks: int = 10_000) -> list[Request]:
        done: list[Request] = []
        while (self.queue or any(self.active)) and self.ticks < max_ticks:
            done.extend(self.tick())
        return done
