from .decode import build_decode_fn, greedy_decode  # noqa: F401
from .engine import Request, ServingEngine  # noqa: F401
