"""Krylov solvers over CBLinearOperator — single-trace ``lax.while_loop``s.

The contract (see ``solvers/README.md``): each solver is jitted ONCE per
(operator structure, maxiter, impl) and every iteration runs inside a
``lax.while_loop`` body, so a 10,000-iteration solve costs exactly one
trace and zero per-iteration dispatch overhead. The residual history is
carried *in the loop state* as a fixed ``(maxiter + 1,)`` buffer
(-1.0 marks unreached iterations) — no host round-trip, no dynamic
shapes.

All solvers stop on ``||r||_2 <= tol * ||b||_2`` (relative residual, the
same criterion the numpy/scipy references in the tests use so iteration
counts are comparable) or on ``maxiter``.

``_TRACE_COUNTS`` increments at *trace* time only — the conformance
trace-count test asserts a repeated solve re-enters the compiled
executable instead of retracing.
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from .operator import CBLinearOperator

# name -> number of times the solver (or its loop body) has been TRACED.
# Python side effects only run while tracing, so a cache hit leaves these
# untouched — the no-per-iteration-recompilation proof used by the tests.
_TRACE_COUNTS: collections.Counter = collections.Counter()


@dataclasses.dataclass
class SolveResult:
    """Solution + convergence record (a pytree; shapes fixed by maxiter)."""

    x: jax.Array           # (n,) solution estimate
    iterations: jax.Array  # () int32 — iterations actually run
    residual: jax.Array    # () f32 — final ||r||_2
    converged: jax.Array   # () bool — hit tol before maxiter
    history: jax.Array     # (maxiter + 1,) f32 — ||r_k||, -1.0 = unreached


jax.tree_util.register_dataclass(
    SolveResult,
    data_fields=["x", "iterations", "residual", "converged", "history"],
    meta_fields=[],
)


def _apply_M(M, r: jax.Array) -> jax.Array:
    return r if M is None else M.apply(r)


def _safe_div(num, den):
    """num / den with a 0 denominator mapped to 0 (post-convergence guards:
    once r == 0 every Krylov scalar degenerates 0/0; the loop predicate has
    already gone False, but while_loop still evaluates the body trace)."""
    ok = den != 0
    return jnp.where(ok, num, 0.0) / jnp.where(ok, den, 1.0)


def _norm(v: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(v * v))


def _result(x, k, rnorm, stop, hist) -> SolveResult:
    return SolveResult(
        x=x, iterations=k.astype(jnp.int32), residual=rnorm,
        converged=rnorm <= stop, history=hist,
    )


# ---------------------------------------------------------------------------
# CG
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("maxiter", "impl", "interpret")
)
def cg(
    A: CBLinearOperator,
    b: jax.Array,
    M=None,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    impl: str = "pallas",
    interpret: bool | None = None,
) -> SolveResult:
    """Preconditioned conjugate gradients for SPD ``A``."""
    _TRACE_COUNTS["cg"] += 1
    b = b.astype(jnp.float32)
    mv = lambda v: A.matvec(v, impl=impl, interpret=interpret)

    x = jnp.zeros_like(b) if x0 is None else x0.astype(jnp.float32)
    r = b if x0 is None else b - mv(x)
    z = _apply_M(M, r)
    p = z
    rz = jnp.vdot(r, z)
    rnorm = _norm(r)
    stop = tol * _norm(b)
    hist = jnp.full(maxiter + 1, -1.0, jnp.float32).at[0].set(rnorm)

    def cond(state):
        k, _x, _r, _p, _rz, rnorm, _h = state
        return (k < maxiter) & (rnorm > stop)

    def body(state):
        _TRACE_COUNTS["cg_body"] += 1
        k, x, r, p, rz, _rnorm, hist = state
        q = mv(p)
        alpha = _safe_div(rz, jnp.vdot(p, q))
        x = x + alpha * p
        r = r - alpha * q
        z = _apply_M(M, r)
        rz_new = jnp.vdot(r, z)
        p = z + _safe_div(rz_new, rz) * p
        rnorm = _norm(r)
        hist = hist.at[k + 1].set(rnorm)
        return (k + 1, x, r, p, rz_new, rnorm, hist)

    k, x, _r, _p, _rz, rnorm, hist = lax.while_loop(
        cond, body, (jnp.int32(0), x, r, p, rz, rnorm, hist)
    )
    return _result(x, k, rnorm, stop, hist)


# ---------------------------------------------------------------------------
# BiCGStab
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("maxiter", "impl", "interpret")
)
def bicgstab(
    A: CBLinearOperator,
    b: jax.Array,
    M=None,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    impl: str = "pallas",
    interpret: bool | None = None,
) -> SolveResult:
    """Preconditioned BiCGStab for general (nonsymmetric) ``A``."""
    _TRACE_COUNTS["bicgstab"] += 1
    b = b.astype(jnp.float32)
    mv = lambda v: A.matvec(v, impl=impl, interpret=interpret)

    x = jnp.zeros_like(b) if x0 is None else x0.astype(jnp.float32)
    r = b if x0 is None else b - mv(x)
    r0hat = r
    rho = jnp.float32(1.0)
    alpha = jnp.float32(1.0)
    omega = jnp.float32(1.0)
    v = jnp.zeros_like(b)
    p = jnp.zeros_like(b)
    rnorm = _norm(r)
    stop = tol * _norm(b)
    hist = jnp.full(maxiter + 1, -1.0, jnp.float32).at[0].set(rnorm)

    def cond(state):
        k = state[0]
        rnorm = state[-2]
        return (k < maxiter) & (rnorm > stop)

    def body(state):
        _TRACE_COUNTS["bicgstab_body"] += 1
        k, x, r, rho, alpha, omega, v, p, _rnorm, hist = state
        rho_new = jnp.vdot(r0hat, r)
        beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
        p = r + beta * (p - omega * v)
        phat = _apply_M(M, p)
        v = mv(phat)
        alpha = _safe_div(rho_new, jnp.vdot(r0hat, v))
        s = r - alpha * v
        shat = _apply_M(M, s)
        t = mv(shat)
        omega = _safe_div(jnp.vdot(t, s), jnp.vdot(t, t))
        x = x + alpha * phat + omega * shat
        r = s - omega * t
        rnorm = _norm(r)
        hist = hist.at[k + 1].set(rnorm)
        return (k + 1, x, r, rho_new, alpha, omega, v, p, rnorm, hist)

    state = (jnp.int32(0), x, r, rho, alpha, omega, v, p, rnorm, hist)
    state = lax.while_loop(cond, body, state)
    k, x = state[0], state[1]
    rnorm, hist = state[-2], state[-1]
    return _result(x, k, rnorm, stop, hist)


# ---------------------------------------------------------------------------
# GMRES(m)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("restart", "maxiter", "impl", "interpret")
)
def gmres(
    A: CBLinearOperator,
    b: jax.Array,
    M=None,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    restart: int = 20,
    maxiter: int = 20,
    impl: str = "pallas",
    interpret: bool | None = None,
) -> SolveResult:
    """Restarted GMRES(m) with left preconditioning.

    ``maxiter`` counts *restart cycles* (outer iterations); each cycle
    performs up to ``restart`` Arnoldi steps in fixed-shape buffers —
    ``V`` is ``(restart + 1, n)``, ``H`` is ``(restart + 1, restart)`` —
    orthogonalized by two-pass classical Gram-Schmidt (unset basis rows
    are zero, so the projection needs no masking). The residual history
    records the TRUE residual at each restart boundary.
    """
    _TRACE_COUNTS["gmres"] += 1
    b = b.astype(jnp.float32)
    n = b.shape[0]
    mv = lambda v: A.matvec(v, impl=impl, interpret=interpret)
    pmv = lambda v: _apply_M(M, mv(v))

    x = jnp.zeros_like(b) if x0 is None else x0.astype(jnp.float32)
    r = b if x0 is None else b - mv(x)
    rnorm = _norm(r)
    stop = tol * _norm(b)
    hist = jnp.full(maxiter + 1, -1.0, jnp.float32).at[0].set(rnorm)
    tiny = jnp.float32(1e-30)

    def arnoldi_step(j, carry):
        V, H = carry
        w = pmv(V[j])
        # CGS2: rows > j of V are still zero, so V @ w projects onto the
        # built basis only — no index masking needed inside the trace.
        h1 = V @ w
        w = w - V.T @ h1
        h2 = V @ w
        w = w - V.T @ h2
        hn = _norm(w)
        V = V.at[j + 1].set(jnp.where(hn > tiny, 1.0, 0.0)
                            * w / jnp.maximum(hn, tiny))
        H = H.at[:, j].set(h1 + h2)
        H = H.at[j + 1, j].set(hn)
        return V, H

    def cycle(x, r):
        z = _apply_M(M, r)
        beta = _norm(z)
        V = jnp.zeros((restart + 1, n), jnp.float32)
        V = V.at[0].set(z / jnp.maximum(beta, tiny))
        H = jnp.zeros((restart + 1, restart), jnp.float32)
        V, H = lax.fori_loop(0, restart, arnoldi_step, (V, H))
        e1 = jnp.zeros(restart + 1, jnp.float32).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(H, e1)
        return x + V[:restart].T @ y

    def cond(state):
        k, _x, _r, rnorm, _h = state
        return (k < maxiter) & (rnorm > stop)

    def body(state):
        _TRACE_COUNTS["gmres_body"] += 1
        k, x, r, _rnorm, hist = state
        x = cycle(x, r)
        # the TRUE residual, computed once and carried: it both feeds the
        # history/stopping test and seeds the next cycle's Krylov space
        r = b - mv(x)
        rnorm = _norm(r)
        hist = hist.at[k + 1].set(rnorm)
        return (k + 1, x, r, rnorm, hist)

    k, x, _r, rnorm, hist = lax.while_loop(
        cond, body, (jnp.int32(0), x, r, rnorm, hist)
    )
    return _result(x, k, rnorm, stop, hist)
