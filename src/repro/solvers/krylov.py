"""Krylov solvers over CBLinearOperator — single-trace ``lax.while_loop``s.

The contract (see ``solvers/README.md``): each solver is jitted ONCE per
(operator structure, maxiter, impl) and every iteration runs inside a
``lax.while_loop`` body, so a 10,000-iteration solve costs exactly one
trace and zero per-iteration dispatch overhead. The residual history is
carried *in the loop state* as a fixed ``(maxiter + 1,)`` buffer
(-1.0 marks unreached iterations) — no host round-trip, no dynamic
shapes.

All solvers stop on ``||r||_2 <= tol * ||b||_2`` (relative residual, the
same criterion the numpy/scipy references in the tests use so iteration
counts are comparable) or on ``maxiter``.

Breakdown awareness (the hardened failure model, ``repro.errors``): the
loop carry additionally holds an int32 ``flag`` plus best-iterate
tracking. Every iteration checks — *inside the trace, no host round
trips* —

  * **breakdown**:   a Krylov scalar denominator collapsed (|rho| at the
    dtype's tiny scale; for CG also non-positive curvature p^T A p <= 0,
    i.e. the operator is not SPD);
  * **non-finite**:  NaN/Inf reached the residual (poisoned iterate,
    corrupted payload);
  * **divergence**:  ||r|| > divtol * ||b||;
  * **stagnation**:  no new best residual for ``stall_limit``
    consecutive iterations (cycles, for GMRES).

Any flag stops the loop; ``SolveResult.status`` reports the terminal
``errors.SolverStatus``, and ``SolveResult.x`` is always the *best*
iterate seen (bit-identical to the final iterate on convergence: the
loop exits on the first sub-tolerance residual, which is therefore the
strict minimum). ``robust_solve`` chains CG -> BiCGStab -> GMRES(m) on
top, restarting each attempt from the best iterate so far.

``_TRACE_COUNTS`` increments at *trace* time only — the conformance
trace-count test asserts a repeated solve re-enters the compiled
executable instead of retracing. ``robust_solve`` preserves the
guarantee: the fallback chain only re-invokes the already-jitted
solvers with identical static arguments.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro import errors, obs
from repro.errors import SolverStatus

from .operator import CBLinearOperator

# name -> number of times the solver (or its loop body) has been TRACED.
# Python side effects only run while tracing, so a cache hit leaves these
# untouched — the no-per-iteration-recompilation proof used by the tests.
# A MirroredCounter: the local dict keeps the historical API while every
# increment also lands on the registry counter ``repro.solvers.traces``.
_TRACE_COUNTS = obs.MirroredCounter(
    metric="repro.solvers.traces", label="site")

_OK = jnp.int32(SolverStatus.OK)
_MAXITER = jnp.int32(SolverStatus.MAXITER)
_BREAKDOWN = jnp.int32(SolverStatus.BREAKDOWN)
_NONFINITE = jnp.int32(SolverStatus.NONFINITE)
_STAGNATION = jnp.int32(SolverStatus.STAGNATION)
_DIVERGED = jnp.int32(SolverStatus.DIVERGED)


@dataclasses.dataclass
class SolveResult:
    """Solution + convergence record (a pytree; shapes fixed by maxiter)."""

    x: jax.Array           # (n,) best iterate (== final iterate on success)
    iterations: jax.Array  # () int32 — iterations actually run
    residual: jax.Array    # () f32 — final ||r||_2
    converged: jax.Array   # () bool — hit tol before maxiter
    history: jax.Array     # (maxiter + 1,) f32 — ||r_k||, -1.0 = unreached
    status: jax.Array      # () int32 — errors.SolverStatus terminal code

    @property
    def reason(self) -> str:
        """Host-side reason code for ``status`` (``repro.errors``)."""
        return errors.solver_reason(int(self.status))


jax.tree_util.register_dataclass(
    SolveResult,
    data_fields=["x", "iterations", "residual", "converged", "history",
                 "status"],
    meta_fields=[],
)


def _apply_M(M, r: jax.Array) -> jax.Array:
    return r if M is None else M.apply(r)


def _guard_tiny(dtype) -> jax.Array:
    """Smallest safe denominator magnitude for ``dtype``.

    Dtype-aware on purpose: ``float16``'s smallest normal is ~6e-5 — a
    float32-scale constant (1e-30) would wave through denominators whose
    reciprocal overflows half precision to Inf. ``bfloat16`` shares
    float32's exponent range, so its guard lands at the same scale.
    """
    dt = jnp.dtype(dtype)
    if not jnp.issubdtype(dt, jnp.inexact):
        dt = jnp.dtype(jnp.float32)
    return jnp.asarray(jnp.finfo(dt).tiny, dt)


def _safe_div(num, den):
    """num / den with a collapsed denominator mapped to 0.

    Post-convergence guard (once r == 0 every Krylov scalar degenerates
    0/0 — the loop predicate has already gone False, but while_loop still
    evaluates the body trace) *and* the breakdown guard: a denominator at
    or below the dtype's tiny scale produces 0, leaving the iterate
    untouched while the body's flag logic reports BREAKDOWN. The guard
    scale follows ``den``'s dtype (see :func:`_guard_tiny`)."""
    den = jnp.asarray(den)
    ok = jnp.abs(den) > _guard_tiny(den.dtype)
    return jnp.where(ok, num, 0.0) / jnp.where(ok, den, 1.0)


def _norm(v: jax.Array) -> jax.Array:
    """||v||_2, accumulated in float32 for sub-f32 inputs.

    bf16/f16 squares lose almost all mantissa (and a long bf16 sum
    saturates once the partial sum outgrows the 8-bit mantissa's ulp),
    so low-precision iterates are upcast before the square-sum."""
    v = jnp.asarray(v)
    if jnp.issubdtype(v.dtype, jnp.inexact) and \
            jnp.finfo(v.dtype).bits < 32:
        v = v.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(v * v))


def _classify(flag, *, nonfinite, breakdown, diverged, stagnated):
    """Priority-merge the in-loop failure predicates into the carry flag.

    An already-set flag wins (the loop exits on the iteration that set
    it; this keeps the body idempotent under while_loop's trailing trace
    evaluation)."""
    new = jnp.where(
        nonfinite, _NONFINITE,
        jnp.where(breakdown, _BREAKDOWN,
                  jnp.where(diverged, _DIVERGED,
                            jnp.where(stagnated, _STAGNATION, _OK))))
    return jnp.where(flag != _OK, flag, new).astype(jnp.int32)


def _result(x, k, rnorm, stop, hist, flag) -> SolveResult:
    converged = rnorm <= stop
    status = jnp.where(
        ~jnp.isfinite(rnorm), _NONFINITE,
        jnp.where(converged, _OK,
                  jnp.where(flag != _OK, flag, _MAXITER)))
    return SolveResult(
        x=x, iterations=k.astype(jnp.int32), residual=rnorm,
        converged=converged, history=hist, status=status.astype(jnp.int32),
    )


def _track_best(x, rnorm, best_x, best, stall):
    """Best-iterate / stagnation bookkeeping shared by the loop bodies."""
    improved = rnorm < best
    best_x = jnp.where(improved, x, best_x)
    best = jnp.minimum(best, rnorm)
    stall = jnp.where(improved, 0, stall + 1).astype(jnp.int32)
    return best_x, best, stall


# ---------------------------------------------------------------------------
# CG
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("maxiter", "impl", "interpret")
)
def cg(
    A: CBLinearOperator,
    b: jax.Array,
    M=None,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    impl: str = "pallas",
    interpret: bool | None = None,
    divtol: float = 1e8,
    stall_limit: int = 50,
) -> SolveResult:
    """Preconditioned conjugate gradients for SPD ``A``.

    Breakdown flag: non-positive curvature ``p^T A p <= tiny`` (the
    operator is singular or not SPD) or a collapsed ``rho``. See the
    module docstring for the other in-loop failure flags."""
    _TRACE_COUNTS["cg"] += 1
    b = b.astype(jnp.float32)
    mv = lambda v: A.matvec(v, impl=impl, interpret=interpret)
    tiny = _guard_tiny(b.dtype)

    x = jnp.zeros_like(b) if x0 is None else x0.astype(jnp.float32)
    r = b if x0 is None else b - mv(x)
    z = _apply_M(M, r)
    p = z
    rz = jnp.vdot(r, z)
    rnorm = _norm(r)
    stop = tol * _norm(b)
    blowup = divtol * jnp.maximum(_norm(b), tiny)
    hist = jnp.full(maxiter + 1, -1.0, jnp.float32).at[0].set(rnorm)

    def cond(state):
        k, _x, _r, _p, _rz, rnorm, _h, flag, *_ = state
        return (k < maxiter) & (rnorm > stop) & (flag == _OK)

    def body(state):
        _TRACE_COUNTS["cg_body"] += 1
        k, x, r, p, rz, _rnorm, hist, flag, best_x, best, stall = state
        q = mv(p)
        den = jnp.vdot(p, q)
        alpha = _safe_div(rz, den)
        x = x + alpha * p
        r = r - alpha * q
        z = _apply_M(M, r)
        rz_new = jnp.vdot(r, z)
        p = z + _safe_div(rz_new, rz) * p
        rnorm = _norm(r)
        hist = hist.at[k + 1].set(rnorm)
        best_x, best, stall = _track_best(x, rnorm, best_x, best, stall)
        flag = _classify(
            flag,
            nonfinite=~jnp.isfinite(rnorm),
            breakdown=(den <= tiny) | (jnp.abs(rz) <= tiny),
            diverged=rnorm > blowup,
            stagnated=stall >= stall_limit,
        )
        return (k + 1, x, r, p, rz_new, rnorm, hist, flag,
                best_x, best, stall)

    state = (jnp.int32(0), x, r, p, rz, rnorm, hist, _OK,
             x, rnorm, jnp.int32(0))
    state = lax.while_loop(cond, body, state)
    k, _x, _r, _p, _rz, rnorm, hist, flag, best_x, _best, _stall = state
    return _result(best_x, k, rnorm, stop, hist, flag)


# ---------------------------------------------------------------------------
# BiCGStab
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("maxiter", "impl", "interpret")
)
def bicgstab(
    A: CBLinearOperator,
    b: jax.Array,
    M=None,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    impl: str = "pallas",
    interpret: bool | None = None,
    divtol: float = 1e8,
    stall_limit: int = 50,
) -> SolveResult:
    """Preconditioned BiCGStab for general (nonsymmetric) ``A``.

    Breakdown flag: the classic BiCGStab scalars collapsing — ``rho =
    <r0hat, r>`` or ``<r0hat, v>`` at the dtype's tiny scale."""
    _TRACE_COUNTS["bicgstab"] += 1
    b = b.astype(jnp.float32)
    mv = lambda v: A.matvec(v, impl=impl, interpret=interpret)
    tiny = _guard_tiny(b.dtype)

    x = jnp.zeros_like(b) if x0 is None else x0.astype(jnp.float32)
    r = b if x0 is None else b - mv(x)
    r0hat = r
    rho = jnp.float32(1.0)
    alpha = jnp.float32(1.0)
    omega = jnp.float32(1.0)
    v = jnp.zeros_like(b)
    p = jnp.zeros_like(b)
    rnorm = _norm(r)
    stop = tol * _norm(b)
    blowup = divtol * jnp.maximum(_norm(b), tiny)
    hist = jnp.full(maxiter + 1, -1.0, jnp.float32).at[0].set(rnorm)

    def cond(state):
        k = state[0]
        rnorm, flag = state[8], state[10]
        return (k < maxiter) & (rnorm > stop) & (flag == _OK)

    def body(state):
        _TRACE_COUNTS["bicgstab_body"] += 1
        (k, x, r, rho, alpha, omega, v, p, _rnorm, hist, flag,
         best_x, best, stall) = state
        rho_new = jnp.vdot(r0hat, r)
        beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
        p = r + beta * (p - omega * v)
        phat = _apply_M(M, p)
        v = mv(phat)
        r0v = jnp.vdot(r0hat, v)
        alpha = _safe_div(rho_new, r0v)
        s = r - alpha * v
        shat = _apply_M(M, s)
        t = mv(shat)
        omega = _safe_div(jnp.vdot(t, s), jnp.vdot(t, t))
        x = x + alpha * phat + omega * shat
        r = s - omega * t
        rnorm = _norm(r)
        hist = hist.at[k + 1].set(rnorm)
        best_x, best, stall = _track_best(x, rnorm, best_x, best, stall)
        flag = _classify(
            flag,
            nonfinite=~jnp.isfinite(rnorm),
            breakdown=(jnp.abs(rho_new) <= tiny) | (jnp.abs(r0v) <= tiny),
            diverged=rnorm > blowup,
            stagnated=stall >= stall_limit,
        )
        return (k + 1, x, r, rho_new, alpha, omega, v, p, rnorm, hist,
                flag, best_x, best, stall)

    state = (jnp.int32(0), x, r, rho, alpha, omega, v, p, rnorm, hist,
             _OK, x, rnorm, jnp.int32(0))
    state = lax.while_loop(cond, body, state)
    k = state[0]
    rnorm, hist, flag, best_x = state[8], state[9], state[10], state[11]
    return _result(best_x, k, rnorm, stop, hist, flag)


# ---------------------------------------------------------------------------
# GMRES(m)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("restart", "maxiter", "impl", "interpret")
)
def gmres(
    A: CBLinearOperator,
    b: jax.Array,
    M=None,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    restart: int = 20,
    maxiter: int = 20,
    impl: str = "pallas",
    interpret: bool | None = None,
    divtol: float = 1e8,
    stall_limit: int = 5,
) -> SolveResult:
    """Restarted GMRES(m) with left preconditioning.

    ``maxiter`` counts *restart cycles* (outer iterations); each cycle
    performs up to ``restart`` Arnoldi steps in fixed-shape buffers —
    ``V`` is ``(restart + 1, n)``, ``H`` is ``(restart + 1, restart)`` —
    orthogonalized by two-pass classical Gram-Schmidt (unset basis rows
    are zero, so the projection needs no masking). The residual history
    records the TRUE residual at each restart boundary.

    In-cycle Arnoldi breakdown (``h_{j+1,j} ~ 0``) is the *lucky* kind —
    the Krylov space closed — and is handled by zeroing the next basis
    vector, not flagged. The failure flags operate at restart
    granularity: non-finite / diverged true residual, or ``stall_limit``
    cycles without a new best (the classic GMRES(m) stall, e.g. a pure
    rotation at small ``m``)."""
    _TRACE_COUNTS["gmres"] += 1
    b = b.astype(jnp.float32)
    n = b.shape[0]
    mv = lambda v: A.matvec(v, impl=impl, interpret=interpret)
    pmv = lambda v: _apply_M(M, mv(v))

    x = jnp.zeros_like(b) if x0 is None else x0.astype(jnp.float32)
    r = b if x0 is None else b - mv(x)
    rnorm = _norm(r)
    stop = tol * _norm(b)
    hist = jnp.full(maxiter + 1, -1.0, jnp.float32).at[0].set(rnorm)
    tiny = jnp.sqrt(_guard_tiny(b.dtype))
    blowup = divtol * jnp.maximum(_norm(b), tiny)

    def arnoldi_step(j, carry):
        V, H = carry
        w = pmv(V[j])
        # CGS2: rows > j of V are still zero, so V @ w projects onto the
        # built basis only — no index masking needed inside the trace.
        h1 = V @ w
        w = w - V.T @ h1
        h2 = V @ w
        w = w - V.T @ h2
        hn = _norm(w)
        V = V.at[j + 1].set(jnp.where(hn > tiny, 1.0, 0.0)
                            * w / jnp.maximum(hn, tiny))
        H = H.at[:, j].set(h1 + h2)
        H = H.at[j + 1, j].set(hn)
        return V, H

    def cycle(x, r):
        z = _apply_M(M, r)
        beta = _norm(z)
        V = jnp.zeros((restart + 1, n), jnp.float32)
        V = V.at[0].set(z / jnp.maximum(beta, tiny))
        H = jnp.zeros((restart + 1, restart), jnp.float32)
        V, H = lax.fori_loop(0, restart, arnoldi_step, (V, H))
        e1 = jnp.zeros(restart + 1, jnp.float32).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(H, e1)
        return x + V[:restart].T @ y

    def cond(state):
        k, _x, _r, rnorm, _h, flag, *_ = state
        return (k < maxiter) & (rnorm > stop) & (flag == _OK)

    def body(state):
        _TRACE_COUNTS["gmres_body"] += 1
        k, x, r, _rnorm, hist, flag, best_x, best, stall = state
        x = cycle(x, r)
        # the TRUE residual, computed once and carried: it both feeds the
        # history/stopping test and seeds the next cycle's Krylov space
        r = b - mv(x)
        rnorm = _norm(r)
        hist = hist.at[k + 1].set(rnorm)
        best_x, best, stall = _track_best(x, rnorm, best_x, best, stall)
        flag = _classify(
            flag,
            nonfinite=~jnp.isfinite(rnorm),
            breakdown=jnp.bool_(False),
            diverged=rnorm > blowup,
            stagnated=stall >= stall_limit,
        )
        return (k + 1, x, r, rnorm, hist, flag, best_x, best, stall)

    state = (jnp.int32(0), x, r, rnorm, hist, _OK, x, rnorm, jnp.int32(0))
    state = lax.while_loop(cond, body, state)
    k, _x, _r, rnorm, hist, flag, best_x, _best, _stall = state
    return _result(best_x, k, rnorm, stop, hist, flag)


# ---------------------------------------------------------------------------
# robust_solve — the breakdown-aware fallback chain.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Attempt:
    """Host-side record of one solver attempt inside ``robust_solve``."""

    solver: str
    preconditioned: bool
    status: int                  # errors.SolverStatus value
    reason: str                  # errors.solver_reason(status)
    converged: bool
    iterations: int
    residual: float


@dataclasses.dataclass(frozen=True)
class RobustSolveResult:
    """Outcome of the fallback chain: the winning (or best) attempt."""

    x: jax.Array
    converged: bool
    status: int                  # errors.SolverStatus of the final verdict
    reason: str
    solver: str                  # solver that produced ``x``
    residual: float
    attempts: tuple[Attempt, ...]
    result: SolveResult          # full record of the decisive attempt
    sanitized_x0: bool = False   # a non-finite warm start was dropped


_CHAIN_SOLVERS = {"cg": cg, "bicgstab": bicgstab, "gmres": gmres}


def robust_solve(
    A: CBLinearOperator,
    b: jax.Array,
    M=None,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    restart: int = 20,
    methods: tuple[str, ...] = ("cg", "bicgstab", "gmres"),
    fallback_preconditioner=None,
    max_attempts: int | None = None,
    impl: str = "pallas",
    interpret: bool | None = None,
    divtol: float = 1e8,
    stall_limit: int = 50,
) -> RobustSolveResult:
    """Breakdown-aware driver: CG -> BiCGStab -> GMRES(m) with bounded retry.

    A host-level supervisor over the jitted solvers — the *solvers* stay
    single-trace (the chain re-invokes them with identical static
    arguments, so a second ``robust_solve`` call never retraces); only
    the attempt accounting runs on host.

    Policy per attempt:

      * every attempt warm-starts from the **best iterate seen so far**
        (restart-from-best), falling back to ``x0`` / zero;
      * a converged attempt short-circuits the chain;
      * after the base ladder, ``fallback_preconditioner`` (if given)
        re-runs the ladder once with the escalated preconditioner;
      * ``max_attempts`` bounds the total number of solver invocations
        (default: the full ladder, once per preconditioner level).

    Detection contract (``repro.errors``): a non-finite right-hand side
    is unsolvable and raises ``NonFiniteError`` immediately; a
    non-finite ``x0`` is *tolerated* by sanitizing to a cold start
    (recorded in ``sanitized_x0``). A chain that exhausts its attempts
    returns ``converged=False`` with the best attempt's iterate and the
    final attempt's typed status — never an untyped failure.
    """
    b = jnp.asarray(b)
    if not bool(jnp.all(jnp.isfinite(b))):
        raise errors.NonFiniteError(
            "robust_solve: right-hand side contains non-finite entries"
        )
    sanitized = False
    if x0 is not None and not bool(jnp.all(jnp.isfinite(x0))):
        x0, sanitized = None, True   # poisoned warm start -> cold start

    unknown = [m for m in methods if m not in _CHAIN_SOLVERS]
    if unknown:
        raise errors.InvalidArgError(
            f"unknown methods {unknown}; choose from "
            f"{sorted(_CHAIN_SOLVERS)}"
        )

    ladder = [(name, M, False) for name in methods]
    if fallback_preconditioner is not None:
        ladder += [(name, fallback_preconditioner, True) for name in methods]
    if max_attempts is not None:
        ladder = ladder[:max_attempts]
    if not ladder:
        raise errors.InvalidArgError("robust_solve: empty fallback ladder")

    gmres_cycles = max(1, math.ceil(maxiter / restart))
    common = dict(tol=tol, impl=impl, interpret=interpret, divtol=divtol)

    # Attempt-ladder telemetry (repro.solvers.robust.*): each attempt is
    # one span + one labeled counter bump, so a fleet can alarm on
    # fallback rates without scraping Attempt tuples.
    reg = obs.registry()
    reg.counter("repro.solvers.robust.calls").inc()
    if sanitized:
        reg.counter("repro.solvers.robust.sanitized_x0").inc()

    attempts: list[Attempt] = []
    best_x, best_rnorm = x0, float("inf")
    best_attempt: tuple[str, SolveResult] | None = None
    res = None
    name = methods[0]
    with obs.span("robust_solve", n=int(b.shape[0]),
                  methods=",".join(methods)) as root:
        for name, Mi, escalated in ladder:
            solver = _CHAIN_SOLVERS[name]
            with obs.span(f"solve:{name}", solver=name,
                          preconditioned=Mi is not None,
                          escalated=escalated) as sp:
                if name == "gmres":
                    res = solver(A, b, Mi, x0=best_x, maxiter=gmres_cycles,
                                 restart=restart, **common)
                else:
                    res = solver(A, b, Mi, x0=best_x, maxiter=maxiter,
                                 stall_limit=stall_limit, **common)
                status = int(res.status)
                rnorm = float(res.residual)
                sp.set(status=errors.solver_reason(status),
                       iterations=int(res.iterations))
            attempts.append(Attempt(
                solver=name, preconditioned=Mi is not None, status=status,
                reason=errors.solver_reason(status),
                converged=bool(res.converged),
                iterations=int(res.iterations), residual=rnorm,
            ))
            reg.counter("repro.solvers.robust.attempts").inc(
                solver=name, reason=errors.solver_reason(status))
            reg.counter("repro.solvers.robust.iterations").inc(
                int(res.iterations), solver=name)
            if math.isfinite(rnorm) and rnorm < best_rnorm:
                best_rnorm, best_x = rnorm, res.x
                best_attempt = (name, res)
            if status == SolverStatus.OK:
                root.set(outcome="converged", solver=name,
                         attempts=len(attempts))
                reg.counter("repro.solvers.robust.outcome").inc(
                    outcome="converged", solver=name)
                return RobustSolveResult(
                    x=res.x, converged=True, status=SolverStatus.OK,
                    reason=errors.solver_reason(SolverStatus.OK), solver=name,
                    residual=rnorm, attempts=tuple(attempts), result=res,
                    sanitized_x0=sanitized,
                )

        # chain exhausted: surface the best iterate with a typed verdict
        final_name, final_res = best_attempt if best_attempt else (name, res)
        status = int(attempts[-1].status)
        root.set(outcome="exhausted", solver=final_name,
                 attempts=len(attempts))
        reg.counter("repro.solvers.robust.outcome").inc(
            outcome="exhausted", solver=final_name)
        return RobustSolveResult(
            x=final_res.x, converged=False, status=status,
            reason=errors.solver_reason(status), solver=final_name,
            residual=float(final_res.residual), attempts=tuple(attempts),
            result=final_res, sanitized_x0=sanitized,
        )
