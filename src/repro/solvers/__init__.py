"""Iterative solver subsystem on the batched CB-SpMV engine.

``CBLinearOperator`` amortizes all CB preprocessing (blocking, format
selection, column aggregation, balance, super-block packing, transposed
streams, SpMM tiles) into one plan-time build; the Krylov and spectral
drivers then apply it inside single-trace ``lax.while_loop``s. See
``solvers/README.md`` for the static-metadata/while-loop contract.
"""
from .operator import CBLinearOperator  # noqa: F401
from .krylov import (  # noqa: F401
    Attempt,
    RobustSolveResult,
    SolveResult,
    SolverStatus,
    bicgstab,
    cg,
    gmres,
    robust_solve,
)
from .precond import (  # noqa: F401
    BlockJacobiPreconditioner,
    DiagScatter,
    IdentityPreconditioner,
    JacobiPreconditioner,
    block_jacobi,
    diag_scatter,
    jacobi,
)
from .eigen import (  # noqa: F401
    EigenResult,
    EvolvingPageRank,
    chebyshev_subspace,
    evolving_pagerank,
    pagerank,
    pagerank_operator,
    power_iteration,
)
