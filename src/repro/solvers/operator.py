"""CBLinearOperator — the solver subsystem's view of a CB matrix.

Iterative solvers apply the same matrix thousands of times; the whole
point of CB preprocessing (paper §3, fig. 12) is that its cost amortizes
to zero in exactly this regime. The operator therefore does ALL
preprocessing once at construction time (``from_cb``) and exposes only
jit-native applications afterwards:

  * ``matvec``  — ``A @ x``  through the batched super-block engine
    (``build_super_streams``; ``group_size`` baked into the stream);
  * ``rmatvec`` — ``A^T @ y`` through a *precomputed transposed* super
    stream (``streams.transpose_cb``): the transpose gets its own CB
    structure with formats/colagg/balance re-decided for A^T's sparsity;
  * ``matmat``  — multi-RHS ``A @ X`` through the *batched* CB-SpMM
    super-tile stream (subspace eigensolvers, blocked Krylov): tiles are
    packed ``group_size`` per grid step by the same Alg. 2 balancer as
    ``matvec``'s streams, so one ``pallas_call`` sweeps the whole
    weight stream per application.

Trace-time-constant discipline (same contract as ``sparse/linear.py``):
the operator is a registered pytree whose array leaves are the stream
payloads and whose *shape metadata is static*. Solvers take the operator
as an ordinary jit argument — one trace per (structure, shape) and pure
data-path re-execution for every new value of the payload.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.cb_matrix import CBMatrix
from repro.core.streams import (
    LANE,
    SuperBlockStreams,
    SuperStreamUpdater,
    SuperTileStream,
    SuperTileUpdater,
    build_super_streams,
    build_transposed_super_streams,
    super_stream_updater,
    super_tile_stream_from_cb,
    super_tile_updater,
    transposed_super_stream_updater,
)
from repro.kernels import ops
from repro import errors


@dataclasses.dataclass
class CBLinearOperator:
    """Preprocessed CB matrix as a (pytree) linear operator.

    ``streams_T`` / ``tiles`` are optional capabilities: ``None`` when the
    caller asked ``from_cb`` not to pay their preprocessing (pytrees treat
    ``None`` as an empty subtree, so the operator stays jit-compatible
    either way).
    """

    # -- static ----------------------------------------------------------
    shape: tuple[int, int]
    block_size: int
    nnz: int
    # -- data leaves -----------------------------------------------------
    streams: SuperBlockStreams
    streams_T: SuperBlockStreams | None = None
    tiles: SuperTileStream | None = None
    # -- static (autotune) -----------------------------------------------
    plan: object | None = None       # the Plan that shaped the streams
    # -- static (dynamic sparsity) ---------------------------------------
    # Value-scatter updaters recorded at build time (``updatable=True``).
    # They are pattern-derived constants — identity-hashed metadata, so
    # ``with_values`` copies share them and jit never retraces on update.
    updater: SuperStreamUpdater | None = None
    updater_T: SuperStreamUpdater | None = None
    tile_updater: SuperTileUpdater | None = None

    # ------------------------------------------------------------------
    @classmethod
    def from_cb(
        cls,
        cb: CBMatrix,
        *,
        group_size: int | None = None,
        with_rmatvec: bool = False,
        with_matmat: bool = False,
        plan: object | None = None,
        plan_cache=None,
        plan_settings=None,
        updatable: bool = False,
    ) -> "CBLinearOperator":
        """Build every requested stream once (host-side, plan time).

        Capabilities are pay-for-what-you-ask: ``rmatvec`` costs a full
        second CB pipeline on the transposed triplets and ``matmat``
        densifies every block into balanced SpMM super-tiles, so both
        default OFF — a plain CG/power-iteration operator should not
        triple its plan time (and skew the amortization story) for paths
        it never runs. ``group_size`` is shared by every stream built
        here, so matvec and matmat amortize per-step overhead alike.

        ``plan`` hooks in the autotune subsystem, and since the operator
        IS the amortization regime (thousands of applications of one
        matrix), construction is where planning pays for itself:

          * ``None`` — keep ``cb``'s configuration as built (default);
          * ``"auto"`` — run ``CBMatrix.plan_for`` on ``cb``'s triplets
            (consulting ``plan_cache`` when given, searching with
            ``plan_settings`` — e.g. ``SearchSettings(mode="heuristic")``
            to force determinism on TPU) and rebuild the CB structure
            with the winning configuration;
          * a ``Plan`` — apply that plan's configuration directly.

        A tuned plan owns the group-size decision, so combining ``plan``
        with an explicit ``group_size`` is an error.

        ``updatable=True`` additionally records a value-scatter updater
        per requested stream (``streams.super_stream_updater`` and
        friends), enabling :meth:`with_values` — value churn without
        re-planning. Recording costs one extra shadow build per stream
        at construction, so it defaults OFF.
        """
        if plan is not None:
            if group_size is not None:
                raise errors.InvalidArgError(
                    "pass either plan= or group_size=, not both — a plan "
                    "carries its own group size"
                )
            rows, cols, vals = cb.to_coo()
            if isinstance(plan, str):
                if plan != "auto":
                    raise errors.InvalidArgError(f"unknown plan mode {plan!r}")
                plan = CBMatrix.plan_for(
                    rows, cols, vals, cb.shape,
                    val_dtype=cb.val_dtype, cache=plan_cache,
                    settings=plan_settings,
                )
            cb = CBMatrix.from_plan(rows, cols, vals, cb.shape, plan)
            group_size = plan.group_size
        return cls(
            shape=tuple(cb.shape),
            block_size=cb.block_size,
            nnz=cb.nnz,
            streams=build_super_streams(cb, group_size=group_size),
            streams_T=(build_transposed_super_streams(cb, group_size=group_size)
                       if with_rmatvec else None),
            tiles=(super_tile_stream_from_cb(cb, group_size=group_size)
                   if with_matmat else None),
            plan=plan,
            updater=(super_stream_updater(cb, group_size=group_size)
                     if updatable else None),
            updater_T=(transposed_super_stream_updater(cb,
                                                       group_size=group_size)
                       if updatable and with_rmatvec else None),
            tile_updater=(super_tile_updater(cb, group_size=group_size)
                          if updatable and with_matmat else None),
        )

    # ------------------------------------------------------------------
    def with_values(self, canonical_vals) -> "CBLinearOperator":
        """The dynamic-sparsity fast path: same structure, fresh values.

        ``canonical_vals`` is one value per matrix element in the
        canonical ``CBMatrix.to_coo`` order. Returns an operator reusing
        every structural decision — plan, blocking, colagg, formats,
        Alg. 2 balance, stream geometry, and the updaters themselves —
        with only the stream payloads rewritten (forward, transposed and
        tile payloads alike). No re-planning or re-balancing runs, and
        because the static metadata is shared object-for-object, jitted
        solvers keep their traces across updates.
        """
        if self.updater is None:
            raise errors.InvalidArgError(
                "operator was built with updatable=False; rebuild with "
                "CBLinearOperator.from_cb(cb, updatable=True)"
            )
        return dataclasses.replace(
            self,
            streams=self.updater.apply(canonical_vals),
            streams_T=(self.updater_T.apply(canonical_vals)
                       if self.updater_T is not None else self.streams_T),
            tiles=(self.tile_updater.apply(canonical_vals)
                   if self.tile_updater is not None else self.tiles),
        )

    # ------------------------------------------------------------------
    @property
    def group_size(self) -> int:
        return self.streams.group_size

    @property
    def dtype(self):
        return jnp.float32  # the kernels' accumulate/output dtype

    def matvec(self, x: jax.Array, *, impl: str = "pallas",
               interpret: bool | None = None) -> jax.Array:
        """``A @ x`` — x: (n,) -> (m,).

        Passing ``plan`` lets obs log measured-vs-predicted launch stats
        per plan structure hash; it's static metadata already baked into
        this operator, so jit sees nothing new.
        """
        return ops.cb_spmv(self.streams, x, impl=impl, interpret=interpret,
                           plan=self.plan)

    def matvec_into(self, y_acc: jax.Array, x: jax.Array, *,
                    impl: str = "pallas",
                    interpret: bool | None = None) -> jax.Array:
        """``y_acc + A @ x`` with the accumulator donated (ops.cb_spmv_into)."""
        return ops.cb_spmv_into(y_acc, self.streams, x, impl=impl,
                                interpret=interpret, plan=self.plan)

    def rmatvec(self, y: jax.Array, *, impl: str = "pallas",
                interpret: bool | None = None) -> jax.Array:
        """``A^T @ y`` — y: (m,) -> (n,) via the precomputed transpose."""
        if self.streams_T is None:
            raise errors.InvalidArgError(
                "operator was built with with_rmatvec=False; rebuild with "
                "CBLinearOperator.from_cb(cb, with_rmatvec=True)"
            )
        return ops.cb_spmv(self.streams_T, y, impl=impl, interpret=interpret)

    def matmat(self, X: jax.Array, *, impl: str = "pallas",
               interpret: bool | None = None,
               block_n: int = LANE,
               group_size: int | None = None) -> jax.Array:
        """``A @ X`` — X: (n, N) -> (m, N) via the batched SpMM stream.

        ``group_size`` is baked into the super-tile stream at plan time;
        passing it here is only a consistency assertion (ops.cb_spmm
        rejects a conflicting value), mirroring ``cb_spmv``'s contract.
        """
        if self.tiles is None:
            raise errors.InvalidArgError(
                "operator was built with with_matmat=False; rebuild with "
                "CBLinearOperator.from_cb(cb, with_matmat=True)"
            )
        return ops.cb_spmm(self.tiles, X, impl=impl, interpret=interpret,
                           block_n=block_n, group_size=group_size)


jax.tree_util.register_dataclass(
    CBLinearOperator,
    data_fields=["streams", "streams_T", "tiles"],
    meta_fields=["shape", "block_size", "nnz", "plan",
                 "updater", "updater_T", "tile_updater"],
)
