"""Preconditioners extracted from the CB block structure (plan time).

The CB format already materializes the diagonal sub-blocks as tiles —
block-Jacobi preconditioning is therefore free structure reuse: walk the
blocks once at plan time, gather every entry whose *global* column lands
inside its own block-row's diagonal window, and invert the resulting
(B, B) diagonal blocks with numpy. The apply path is a single batched
(mb, B, B) x (mb, B) contraction — one fused einsum per iteration, no
gather/scatter, jit-native.

Rows whose diagonal block row is entirely zero get an identity row so the
block stays invertible (any nonsingular M is a valid preconditioner; for
those rows M acts as the identity).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cb_matrix import CBMatrix


@dataclasses.dataclass
class IdentityPreconditioner:
    """M = I — the no-preconditioning baseline (still a pytree)."""

    def apply(self, r: jax.Array) -> jax.Array:
        return r


@dataclasses.dataclass
class JacobiPreconditioner:
    """M^-1 = diag(A)^-1 (point Jacobi)."""

    inv_diag: jax.Array  # (m,)

    def apply(self, r: jax.Array) -> jax.Array:
        return self.inv_diag * r


@dataclasses.dataclass
class BlockJacobiPreconditioner:
    """M^-1 = blockdiag(A)^-1 at the CB block size."""

    # -- static ----------------------------------------------------------
    m: int
    block_size: int
    # -- data -------------------------------------------------------------
    inv_blocks: jax.Array  # (mb, B, B)

    def apply(self, r: jax.Array) -> jax.Array:
        B = self.block_size
        mb = self.inv_blocks.shape[0]
        rp = jnp.pad(r, (0, mb * B - r.shape[0])).reshape(mb, B)
        y = jnp.einsum(
            "brc,bc->br", self.inv_blocks.astype(rp.dtype), rp
        )
        return y.reshape(-1)[: self.m]


jax.tree_util.register_dataclass(
    IdentityPreconditioner, data_fields=[], meta_fields=[]
)
jax.tree_util.register_dataclass(
    JacobiPreconditioner, data_fields=["inv_diag"], meta_fields=[]
)
jax.tree_util.register_dataclass(
    BlockJacobiPreconditioner,
    data_fields=["inv_blocks"],
    meta_fields=["m", "block_size"],
)


def _diag_blocks(cb: CBMatrix) -> np.ndarray:
    """Accumulate the (mb, B, B) block-diagonal of A from the CB blocks.

    Works in *global* column coordinates (via ``global_x_index``) so the
    extraction is correct whether or not column aggregation moved the
    diagonal entries into different compacted block columns.
    """
    B = cb.block_size
    m = cb.shape[0]
    mb = -(-m // B)
    D = np.zeros((mb, B, B), np.float64)
    for brow, bcol, _fmt, r, c, v in cb.iter_blocks():
        gc = cb.global_x_index(brow, bcol, c)
        lo = brow * B
        sel = (gc >= lo) & (gc < lo + B)
        if not np.any(sel):
            continue
        np.add.at(
            D,
            (np.full(int(sel.sum()), brow), r[sel], (gc[sel] - lo)),
            v[sel].astype(np.float64),
        )
    return D


def jacobi(cb: CBMatrix) -> JacobiPreconditioner:
    """Point-Jacobi from the CB diagonal (zero diagonals act as identity)."""
    m = cb.shape[0]
    diag = np.einsum("bii->bi", _diag_blocks(cb)).reshape(-1)[:m]
    inv = np.where(diag != 0.0, 1.0 / np.where(diag != 0.0, diag, 1.0), 1.0)
    return JacobiPreconditioner(inv_diag=jnp.asarray(inv, jnp.float32))


def block_jacobi(cb: CBMatrix) -> BlockJacobiPreconditioner:
    """Block-Jacobi from the materialized CB diagonal tiles."""
    B = cb.block_size
    m = cb.shape[0]
    D = _diag_blocks(cb)
    # Identity rows where the block row is entirely zero (incl. the ragged
    # padding rows of the last block) keep every block invertible.
    dead = ~np.any(D != 0.0, axis=2)  # (mb, B)
    bidx, ridx = np.nonzero(dead)
    D[bidx, ridx, ridx] = 1.0
    try:
        inv = np.linalg.inv(D)
    except np.linalg.LinAlgError:
        inv = np.stack([np.linalg.pinv(blk) for blk in D])
    return BlockJacobiPreconditioner(
        m=m, block_size=B, inv_blocks=jnp.asarray(inv, jnp.float32)
    )
