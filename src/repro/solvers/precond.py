"""Preconditioners extracted from the CB block structure (plan time).

The CB format already materializes the diagonal sub-blocks as tiles —
block-Jacobi preconditioning is therefore free structure reuse: walk the
blocks once at plan time, gather every entry whose *global* column lands
inside its own block-row's diagonal window, and invert the resulting
(B, B) diagonal blocks with numpy. The apply path is a single batched
(mb, B, B) x (mb, B) contraction — one fused einsum per iteration, no
gather/scatter, jit-native.

Rows whose diagonal block row is entirely zero get an identity row so the
block stays invertible (any nonsingular M is a valid preconditioner; for
those rows M acts as the identity).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cb_matrix import CBMatrix


@dataclasses.dataclass
class IdentityPreconditioner:
    """M = I — the no-preconditioning baseline (still a pytree)."""

    def apply(self, r: jax.Array) -> jax.Array:
        return r


@dataclasses.dataclass
class JacobiPreconditioner:
    """M^-1 = diag(A)^-1 (point Jacobi)."""

    inv_diag: jax.Array  # (m,)

    def apply(self, r: jax.Array) -> jax.Array:
        return self.inv_diag * r


@dataclasses.dataclass
class BlockJacobiPreconditioner:
    """M^-1 = blockdiag(A)^-1 at the CB block size."""

    # -- static ----------------------------------------------------------
    m: int
    block_size: int
    # -- data -------------------------------------------------------------
    inv_blocks: jax.Array  # (mb, B, B)

    def apply(self, r: jax.Array) -> jax.Array:
        B = self.block_size
        mb = self.inv_blocks.shape[0]
        rp = jnp.pad(r, (0, mb * B - r.shape[0])).reshape(mb, B)
        y = jnp.einsum(
            "brc,bc->br", self.inv_blocks.astype(rp.dtype), rp
        )
        return y.reshape(-1)[: self.m]


jax.tree_util.register_dataclass(
    IdentityPreconditioner, data_fields=[], meta_fields=[]
)
jax.tree_util.register_dataclass(
    JacobiPreconditioner, data_fields=["inv_diag"], meta_fields=[]
)
jax.tree_util.register_dataclass(
    BlockJacobiPreconditioner,
    data_fields=["inv_blocks"],
    meta_fields=["m", "block_size"],
)


def _diag_blocks(cb: CBMatrix) -> np.ndarray:
    """Accumulate the (mb, B, B) block-diagonal of A from the CB blocks.

    Works in *global* column coordinates (via ``global_x_index``) so the
    extraction is correct whether or not column aggregation moved the
    diagonal entries into different compacted block columns.
    """
    B = cb.block_size
    m = cb.shape[0]
    mb = -(-m // B)
    D = np.zeros((mb, B, B), np.float64)
    for brow, bcol, _fmt, r, c, v in cb.iter_blocks():
        gc = cb.global_x_index(brow, bcol, c)
        lo = brow * B
        sel = (gc >= lo) & (gc < lo + B)
        if not np.any(sel):
            continue
        np.add.at(
            D,
            (np.full(int(sel.sum()), brow), r[sel], (gc[sel] - lo)),
            v[sel].astype(np.float64),
        )
    return D


def _jacobi_from_diag(D: np.ndarray, m: int) -> JacobiPreconditioner:
    diag = np.einsum("bii->bi", D).reshape(-1)[:m]
    inv = np.where(diag != 0.0, 1.0 / np.where(diag != 0.0, diag, 1.0), 1.0)
    return JacobiPreconditioner(inv_diag=jnp.asarray(inv, jnp.float32))


def _block_jacobi_from_diag(
    D: np.ndarray, m: int, block_size: int
) -> BlockJacobiPreconditioner:
    # Identity rows where the block row is entirely zero (incl. the ragged
    # padding rows of the last block) keep every block invertible.
    D = D.copy()
    dead = ~np.any(D != 0.0, axis=2)  # (mb, B)
    bidx, ridx = np.nonzero(dead)
    D[bidx, ridx, ridx] = 1.0
    try:
        inv = np.linalg.inv(D)
    except np.linalg.LinAlgError:
        inv = np.stack([np.linalg.pinv(blk) for blk in D])
    return BlockJacobiPreconditioner(
        m=m, block_size=block_size, inv_blocks=jnp.asarray(inv, jnp.float32)
    )


def jacobi(cb: CBMatrix) -> JacobiPreconditioner:
    """Point-Jacobi from the CB diagonal (zero diagonals act as identity)."""
    return _jacobi_from_diag(_diag_blocks(cb), cb.shape[0])


def block_jacobi(cb: CBMatrix) -> BlockJacobiPreconditioner:
    """Block-Jacobi from the materialized CB diagonal tiles."""
    return _block_jacobi_from_diag(_diag_blocks(cb), cb.shape[0],
                                   cb.block_size)


# ---------------------------------------------------------------------------
# Dynamic-sparsity path: re-invert only the diagonal payloads.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class DiagScatter:
    """Pattern-derived map: canonical values -> (mb, B, B) block diagonal.

    Which canonical elements land in the block diagonal — and where — is
    pure structure, so it is recorded once (``diag_scatter``) and a value
    update only scatters fresh payloads and re-inverts: no CB block walk
    re-runs. ``jacobi``/``block_jacobi`` on the updated values are
    bit-identical to rebuilding the preconditioner from
    ``cb.update_values(vals)``.
    """

    m: int
    block_size: int
    mb: int
    val_dtype: np.dtype
    flat_idx: np.ndarray   # (k,) int64 — flat index into (mb, B, B)
    src: np.ndarray        # (k,) int64 — canonical value index

    def _diag(self, canonical_vals) -> np.ndarray:
        B = self.block_size
        vals = np.ascontiguousarray(canonical_vals, self.val_dtype)
        D = np.zeros((self.mb, B, B), np.float64)
        D.reshape(-1)[self.flat_idx] = vals[self.src].astype(np.float64)
        return D

    def jacobi(self, canonical_vals) -> JacobiPreconditioner:
        """Point-Jacobi for fresh canonical values (structure reused)."""
        return _jacobi_from_diag(self._diag(canonical_vals), self.m)

    def block_jacobi(self, canonical_vals) -> BlockJacobiPreconditioner:
        """Block-Jacobi for fresh canonical values (re-inversion only)."""
        return _block_jacobi_from_diag(self._diag(canonical_vals), self.m,
                                       self.block_size)


def diag_scatter(cb: CBMatrix) -> DiagScatter:
    """Record once which canonical elements feed the block diagonal.

    Derived straight from the value layout's global (row, col) keys —
    coordinates are unique after CB canonicalization, so the scatter is
    a plain assignment (no accumulation), matching ``_diag_blocks``'s
    ``np.add.at`` over unique positions exactly.
    """
    layout = cb.value_layout()
    B = cb.block_size
    m, n = cb.shape
    mb = -(-m // B)
    r_g = layout.keys // n
    c_g = layout.keys % n
    brow = r_g // B
    lo = brow * B
    sel = (c_g >= lo) & (c_g < lo + B)
    src = np.flatnonzero(sel)
    flat = ((brow[sel] * B + (r_g[sel] - lo[sel])) * B + (c_g[sel] - lo[sel]))
    return DiagScatter(
        m=m, block_size=B, mb=mb, val_dtype=np.dtype(cb.val_dtype),
        flat_idx=flat.astype(np.int64), src=src.astype(np.int64),
    )
