"""Spectral workloads on the CB engine — power iteration, Chebyshev
subspace iteration, and PageRank on the power-law corpus.

Same while-loop/static-metadata contract as ``krylov.py``: the operator
is the pytree argument, every iteration lives inside ``lax.while_loop``
or ``lax.fori_loop``, shapes are fixed by static ``maxiter``/``degree``,
and nothing retraces per iteration.

The Chebyshev filter is the multi-vector showcase: it drives the block
``matmat`` path (CB-SpMM tile stream), applying a degree-``d`` polynomial
that damps the spectrum inside ``[lb, ub]`` so the subspace rotates
toward the eigenvalues *above* ``ub`` — the standard filtered subspace
iteration for large sparse spectra.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.cb_matrix import CBMatrix
from repro import errors

from .operator import CBLinearOperator


@dataclasses.dataclass
class EigenResult:
    eigenvalue: jax.Array   # () f32 Rayleigh quotient
    eigenvector: jax.Array  # (n,) unit norm
    iterations: jax.Array   # () int32
    converged: jax.Array    # () bool


jax.tree_util.register_dataclass(
    EigenResult,
    data_fields=["eigenvalue", "eigenvector", "iterations", "converged"],
    meta_fields=[],
)


@functools.partial(jax.jit, static_argnames=("maxiter", "impl", "interpret"))
def power_iteration(
    A: CBLinearOperator,
    v0: jax.Array,
    *,
    tol: float = 1e-8,
    maxiter: int = 500,
    impl: str = "pallas",
    interpret: bool | None = None,
) -> EigenResult:
    """Dominant eigenpair of square ``A`` by normalized power iteration."""
    mv = lambda v: A.matvec(v, impl=impl, interpret=interpret)
    v = v0.astype(jnp.float32)
    v = v / jnp.linalg.norm(v)

    def cond(state):
        k, _v, _lam, delta = state
        return (k < maxiter) & (delta > tol)

    def body(state):
        k, v, _lam, _delta = state
        w = mv(v)
        lam = jnp.vdot(v, w)
        wn = jnp.linalg.norm(w)
        v_new = w / jnp.where(wn > 0, wn, 1.0)
        # sign-align before measuring the step so ±v oscillation (negative
        # dominant eigenvalue) still registers as converged
        v_new = jnp.where(jnp.vdot(v_new, v) < 0, -v_new, v_new)
        delta = jnp.linalg.norm(v_new - v)
        return (k + 1, v_new, lam, delta)

    k, v, lam, delta = lax.while_loop(
        cond, body, (jnp.int32(0), v, jnp.float32(0.0), jnp.float32(jnp.inf))
    )
    return EigenResult(eigenvalue=lam, eigenvector=v,
                       iterations=k.astype(jnp.int32), converged=delta <= tol)


@functools.partial(
    jax.jit,
    static_argnames=("degree", "iters", "impl", "interpret", "group_size"),
)
def chebyshev_subspace(
    A: CBLinearOperator,
    V0: jax.Array,
    *,
    lb: float,
    ub: float,
    degree: int = 8,
    iters: int = 5,
    impl: str = "pallas",
    interpret: bool | None = None,
    group_size: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chebyshev-filtered subspace iteration for the top of the spectrum.

    ``V0``: (n, k) initial block. ``[lb, ub]`` is the *unwanted* spectral
    interval to damp (typically [lambda_min, a cut below the wanted
    eigenvalues]). Returns ``(ritz_values (k,), ritz_vectors (n, k))``
    with values ascending — the largest eigenpairs of SPD ``A`` land at
    the end. Every matrix application is a multi-RHS ``matmat`` through
    the batched CB-SpMM super-tile stream; ``group_size`` (static) is
    asserted against the operator's plan-time packing, the same contract
    as ``cb_spmv``.
    """
    mm = lambda X: A.matmat(X, impl=impl, interpret=interpret,
                            group_size=group_size)
    e = (ub - lb) / 2.0
    c = (ub + lb) / 2.0

    def filt(X):
        # T_d(( A - cI ) / e) X via the three-term recurrence.
        T0 = X
        T1 = (mm(X) - c * X) / e

        def step(_d, carry):
            T0, T1 = carry
            T2 = (2.0 / e) * (mm(T1) - c * T1) - T0
            return T1, T2

        _, Td = lax.fori_loop(0, degree - 1, step, (T0, T1))
        return Td

    def outer(_i, Q):
        X = filt(Q)
        Q, _ = jnp.linalg.qr(X)
        return Q

    Q0, _ = jnp.linalg.qr(V0.astype(jnp.float32))
    Q = lax.fori_loop(0, iters, outer, Q0)
    # Rayleigh-Ritz on the filtered subspace.
    S = Q.T @ mm(Q)
    vals, U = jnp.linalg.eigh((S + S.T) / 2.0)
    return vals, Q @ U


# ---------------------------------------------------------------------------
# PageRank — the power-law-corpus spectral demo.
# ---------------------------------------------------------------------------

def pagerank_operator(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    *,
    block_size: int = 16,
    group_size: int | None = None,
) -> tuple[CBLinearOperator, jax.Array]:
    """Preprocess a directed edge list into the PageRank operator.

    Builds ``P^T`` (column-stochastic transition matrix, transposed so
    ``matvec`` pushes rank mass forward) through the full CB pipeline.
    Duplicate edges are collapsed. Returns the operator plus the dangling
    mask (out-degree-zero nodes, whose mass is spread uniformly).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    key = src * n + dst
    uk = np.unique(key)
    src, dst = uk // n, uk % n
    outdeg = np.bincount(src, minlength=n).astype(np.float64)
    vals = 1.0 / outdeg[src]
    cb = CBMatrix.from_coo(dst, src, vals.astype(np.float32), (n, n),
                           block_size=block_size, val_dtype=np.float32)
    op = CBLinearOperator.from_cb(cb, group_size=group_size)
    dangling = jnp.asarray(outdeg == 0, jnp.float32)
    return op, dangling


# ---------------------------------------------------------------------------
# Time-evolving PageRank: fixed link structure, churning edge weights.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class EvolvingPageRank:
    """PageRank over a fixed edge set whose *weights* change per step.

    The dynamic-sparsity showcase: a web/interaction graph where links
    persist but their strengths drift (click counts, decayed activity).
    The transition structure — blocking, colagg, formats, Alg. 2 balance,
    stream packing — is preprocessed ONCE (``build``); each step only
    renormalizes the new weights into transition probabilities and
    scatters them into the operator's streams (``with_values``), so the
    per-step cost is a value scatter plus the damped power iteration,
    never a CB rebuild. Weights must stay positive: a zero weight is
    structure drift (a vanished edge) and needs a fresh ``build``.
    """

    op: CBLinearOperator      # updatable P^T operator (built once)
    dangling: jax.Array       # structural: nodes with no outgoing edges
    n: int
    edge_src: np.ndarray      # unique edge sources
    edge_dst: np.ndarray      # unique edge destinations
    edge_map: np.ndarray      # original edge index -> unique edge index
    canon_order: np.ndarray   # unique-edge order -> canonical value order

    @classmethod
    def build(
        cls,
        src: np.ndarray,
        dst: np.ndarray,
        n: int,
        *,
        block_size: int = 16,
        group_size: int | None = None,
    ) -> "EvolvingPageRank":
        """Preprocess the edge structure once (unit initial weights)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        key = src * n + dst
        uk, edge_map = np.unique(key, return_inverse=True)
        src_u, dst_u = uk // n, uk % n
        outdeg = np.bincount(src_u, minlength=n).astype(np.float64)
        vals = (1.0 / outdeg[src_u]).astype(np.float32)
        cb = CBMatrix.from_coo(dst_u, src_u, vals, (n, n),
                               block_size=block_size, val_dtype=np.float32)
        op = CBLinearOperator.from_cb(cb, group_size=group_size,
                                      updatable=True)
        # canonical (to_coo) order of the (row=dst, col=src) matrix
        canon_order = np.lexsort((src_u, dst_u))
        return cls(
            op=op, dangling=jnp.asarray(outdeg == 0, jnp.float32), n=n,
            edge_src=src_u, edge_dst=dst_u, edge_map=edge_map,
            canon_order=canon_order,
        )

    def canonical_values(self, weights: np.ndarray) -> np.ndarray:
        """Per-original-edge weights -> canonical transition values."""
        w = np.asarray(weights, np.float64)
        if w.shape != self.edge_map.shape:
            raise errors.InvalidArgError(
                f"expected one weight per original edge "
                f"({self.edge_map.shape[0]}), got shape {w.shape}"
            )
        if not np.all(w > 0):
            raise errors.InvalidArgError(
                "edge weights must stay positive — a zero weight removes "
                "the edge (structure drift); rebuild instead"
            )
        w_u = np.zeros(len(self.edge_src), np.float64)
        np.add.at(w_u, self.edge_map, w)
        outsum = np.zeros(self.n, np.float64)
        np.add.at(outsum, self.edge_src, w_u)
        vals = (w_u / outsum[self.edge_src]).astype(np.float32)
        return vals[self.canon_order]

    def step(self, weights: np.ndarray, **pagerank_kwargs) -> EigenResult:
        """Rank under fresh weights: value scatter + power iteration.

        The updated operator shares the original's static metadata
        object-for-object, so the jitted ``pagerank`` while-loop traces
        once and re-executes for every step.
        """
        op = self.op.with_values(self.canonical_values(weights))
        return pagerank(op, self.dangling, **pagerank_kwargs)


def evolving_pagerank(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    weight_steps,
    *,
    block_size: int = 16,
    group_size: int | None = None,
    **pagerank_kwargs,
) -> list[EigenResult]:
    """Run PageRank over a sequence of weight snapshots (one build)."""
    ev = EvolvingPageRank.build(src, dst, n, block_size=block_size,
                                group_size=group_size)
    return [ev.step(w, **pagerank_kwargs) for w in weight_steps]


@functools.partial(jax.jit, static_argnames=("maxiter", "impl", "interpret"))
def pagerank(
    A: CBLinearOperator,
    dangling: jax.Array,
    *,
    damping: float = 0.85,
    tol: float = 1e-7,  # L1 step; f32 iteration floors out near 1e-8
    maxiter: int = 200,
    impl: str = "pallas",
    interpret: bool | None = None,
) -> EigenResult:
    """Damped power iteration on the Google matrix (L1-normalized)."""
    n = A.shape[1]
    p = jnp.full(n, 1.0 / n, jnp.float32)

    def cond(state):
        k, _p, delta = state
        return (k < maxiter) & (delta > tol)

    def body(state):
        k, p, _delta = state
        # fused accumulate-SpMV: the dangling-mass term seeds the donated
        # accumulator and A @ p lands on top of it (ops.cb_spmv_into)
        pushed = A.matvec_into(
            jnp.full(n, jnp.vdot(dangling, p) / n), p,
            impl=impl, interpret=interpret,
        )
        p_new = damping * pushed + (1.0 - damping) / n
        p_new = p_new / jnp.sum(p_new)  # renormalize f32 drift
        delta = jnp.sum(jnp.abs(p_new - p))
        return (k + 1, p_new, delta)

    k, p, delta = lax.while_loop(
        cond, body, (jnp.int32(0), p, jnp.float32(jnp.inf))
    )
    return EigenResult(
        eigenvalue=jnp.float32(1.0), eigenvector=p,
        iterations=k.astype(jnp.int32), converged=delta <= tol,
    )
