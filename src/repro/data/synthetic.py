"""Synthetic LM token pipeline — deterministic, shardable, restartable.

Provides an infinite stream of (tokens, targets) batches derived from a
seeded PRNG. The stream is indexed by (step, host) so restart-after-failure
resumes exactly (fault tolerance depends on this determinism), and each
host generates only its shard of the global batch (no cross-host I/O).
"""
from __future__ import annotations

import dataclasses

import numpy as np
from repro import errors


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


class SyntheticTokenStream:
    """Zipf-distributed token ids (natural-language-ish marginals)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        if cfg.global_batch % num_hosts:
            raise errors.InvalidArgError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.host_batch = cfg.global_batch // num_hosts
        # Zipf weights over the vocab (truncated, normalized)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = 1.0 / ranks**1.1
        self._probs = w / w.sum()

    def batch(self, step: int) -> dict:
        """Deterministic batch for (step, host): resume == replay."""
        seed = (self.cfg.seed * 1_000_003 + step) * 4096 + self.host_id
        rng = np.random.default_rng(seed)
        toks = rng.choice(
            self.cfg.vocab_size,
            size=(self.host_batch, self.cfg.seq_len + 1),
            p=self._probs,
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
