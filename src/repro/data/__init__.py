from . import matrices, synthetic  # noqa: F401
