"""Synthetic sparse-matrix corpus generator.

The paper evaluates on 2,843 SuiteSparse matrices. Offline we reproduce the
*structural families* that collection spans — uniform random, power-law
(graph-like), banded/FEM-like, block-clustered, and diagonal-dominant —
so every benchmark sweeps matrices whose block-nnz distributions match the
paper's Fig. 3 regimes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import errors


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    family: str
    m: int
    n: int
    params: tuple = ()


def _dedup(rows, cols, m, n, rng, vals=None):
    key = rows.astype(np.int64) * n + cols
    _, idx = np.unique(key, return_index=True)
    rows, cols = rows[idx], cols[idx]
    if vals is None:
        vals = rng.standard_normal(len(rows)).astype(np.float64)
    else:
        vals = vals[idx]
    return rows.astype(np.int64), cols.astype(np.int64), vals


def uniform_random(m, n, density, seed=0):
    """Uniformly scattered non-zeros — the super-sparse COO regime."""
    rng = np.random.default_rng(seed)
    nnz = max(1, int(m * n * density))
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    return _dedup(rows, cols, m, n, rng)


def power_law(m, n, avg_deg=8, alpha=2.1, seed=0):
    """Graph-like rows: degree ~ Zipf; hub rows create dense blocks +
    extreme TB load imbalance (the Fig. 4 regime)."""
    rng = np.random.default_rng(seed)
    deg = rng.zipf(alpha, size=m).astype(np.int64)
    deg = np.minimum(deg * avg_deg // 2 + 1, n)
    rows = np.repeat(np.arange(m, dtype=np.int64), deg)
    # column popularity is itself power-law (preferential attachment)
    popularity = (1.0 / np.arange(1, n + 1)) ** 0.7
    popularity /= popularity.sum()
    cols = rng.choice(n, size=len(rows), p=popularity)
    return _dedup(rows, cols, m, n, rng)


def banded(m, n, bandwidth=9, fill=0.7, seed=0):
    """FEM/stencil-like band matrix — contiguous blocks, CSR/Dense regime."""
    rng = np.random.default_rng(seed)
    offs = np.arange(-(bandwidth // 2), bandwidth // 2 + 1)
    rows = np.repeat(np.arange(m, dtype=np.int64), len(offs))
    cols = rows + np.tile(offs, m)
    keep = (cols >= 0) & (cols < n) & (rng.random(len(rows)) < fill)
    return _dedup(rows[keep], cols[keep], m, n, rng)


def block_clustered(m, n, cluster=48, clusters_per_row=3, density=0.85, seed=0):
    """Dense clusters scattered on a sparse background (mixed regimes —
    the torso1/exdata_1 style matrices the paper's ablation highlights)."""
    rng = np.random.default_rng(seed)
    rows_l, cols_l = [], []
    n_row_clusters = max(1, m // cluster)
    for rc in range(n_row_clusters):
        r0 = rc * cluster
        for _ in range(clusters_per_row):
            c0 = int(rng.integers(0, max(1, n - cluster)))
            mask = rng.random((min(cluster, m - r0), cluster)) < density
            rr, cc = np.nonzero(mask)
            rows_l.append(r0 + rr)
            cols_l.append(c0 + cc)
    # sparse background
    bg = max(1, int(0.0005 * m * n))
    rows_l.append(rng.integers(0, m, bg))
    cols_l.append(rng.integers(0, n, bg))
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    return _dedup(rows, cols, m, n, rng)


def diagonal_dominant(m, n, extra_density=0.001, seed=0):
    rng = np.random.default_rng(seed)
    d = min(m, n)
    rows = [np.arange(d, dtype=np.int64)]
    cols = [np.arange(d, dtype=np.int64)]
    nnz = max(1, int(m * n * extra_density))
    rows.append(rng.integers(0, m, nnz))
    cols.append(rng.integers(0, n, nnz))
    return _dedup(np.concatenate(rows), np.concatenate(cols), m, n, rng)


def pruned_weight(m, n, block_size=16, block_sparsity=0.85, seed=0):
    """Magnitude-pruned NN weight style: whole blocks zeroed, survivors
    dense-ish — the regime CBSparseLinear sees in the LM integration."""
    rng = np.random.default_rng(seed)
    mb, nb = -(-m // block_size), -(-n // block_size)
    alive = rng.random((mb, nb)) > block_sparsity
    rr, cc = np.nonzero(alive)
    rows_l, cols_l = [], []
    for r0, c0 in zip(rr, cc):
        h = min(block_size, m - r0 * block_size)
        w = min(block_size, n - c0 * block_size)
        mask = rng.random((h, w)) < 0.6
        lr, lc = np.nonzero(mask)
        rows_l.append(r0 * block_size + lr)
        cols_l.append(c0 * block_size + lc)
    if not rows_l:
        rows_l, cols_l = [np.zeros(1, np.int64)], [np.zeros(1, np.int64)]
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    return _dedup(rows, cols, m, n, rng)


def spd_banded(m, n=None, bandwidth=9, fill=0.7, seed=0):
    """Symmetric positive-definite banded/FEM matrix (the solver corpus).

    Symmetrizes a :func:`banded` draw (``(A + A^T) / 2``) and then shifts
    the diagonal to ``sum_j |a_ij| + 1`` — strict diagonal dominance with
    a positive diagonal, hence SPD by Gershgorin, with a modest condition
    number so Krylov iteration counts are stable across dtypes. Always
    square: ``d = min(m, n)`` when ``n`` is given.
    """
    d = m if n is None else min(m, n)
    r, c, v = banded(d, d, bandwidth=bandwidth, fill=fill, seed=seed)
    off = r != c
    r2 = np.concatenate([r[off], c[off]])
    c2 = np.concatenate([c[off], r[off]])
    v2 = np.concatenate([v[off], v[off]]) * 0.5
    key = r2 * d + c2
    uk, inv = np.unique(key, return_inverse=True)
    vs = np.zeros(len(uk))
    np.add.at(vs, inv, v2)
    rr, cc = uk // d, uk % d
    rowsum = np.zeros(d)
    np.add.at(rowsum, rr, np.abs(vs))
    rows = np.concatenate([rr, np.arange(d)])
    cols = np.concatenate([cc, np.arange(d)])
    vals = np.concatenate([vs, rowsum + 1.0])
    return rows.astype(np.int64), cols.astype(np.int64), vals


def spd_corpus(scale: str = "small", seed: int = 0):
    """SPD matrices for the solver benchmarks/tests (same tuple layout as
    :func:`corpus`)."""
    if scale == "small":
        dims = [192, 320]
    elif scale == "bench":
        dims = [4096, 8192]
    else:
        raise errors.InvalidArgError(scale)
    out = []
    for i, d in enumerate(dims):
        r, c, v = spd_banded(d, bandwidth=9 + 2 * i, seed=seed + i)
        out.append(
            (MatrixSpec(f"spd_banded_{d}", "spd", d, d), r, c, v, (d, d))
        )
    return out


# ---------------------------------------------------------------------------
# MatrixMarket ingestion — real SuiteSparse matrices alongside the
# synthetic corpus.
# ---------------------------------------------------------------------------

_MM_FIELDS = {"real", "integer", "pattern"}
_MM_SYMMETRIES = {"general", "symmetric", "skew-symmetric"}


def load_matrix_market(path):
    """Parse a MatrixMarket ``.mtx`` file into ``(rows, cols, vals, shape)``.

    Supports the ``matrix coordinate`` object/format with ``real`` /
    ``integer`` / ``pattern`` fields (pattern entries get unit values) and
    ``general`` / ``symmetric`` / ``skew-symmetric`` storage — symmetric
    variants are expanded to the full element set (off-diagonal entries
    mirrored; negated for skew). Indices come back 0-based int64, values
    float64 — ready for ``CBMatrix.from_coo``. ``complex`` fields and
    ``array`` (dense) format raise ``errors.IngestError`` (a
    ``ValueError``), as do truncated/malformed entry lines, absurd size
    lines, and non-finite values. Duplicate coordinates are merged by
    summation — the same canonicalization ``plan.canonical_triplets``
    and ``CBMatrix.from_coo`` apply — so the triplets round-trip through
    the plan cache's structure hash unchanged.
    """
    def bad(msg):
        return errors.IngestError(
            errors.reason(errors.INGEST_INVALID, f"{path}: {msg}"))

    with open(path) as f:
        header = f.readline().split()
        if len(header) != 5 or header[0] != "%%MatrixMarket":
            raise bad("not a MatrixMarket file")
        obj, fmt, field, symmetry = (tok.lower() for tok in header[1:])
        if obj != "matrix" or fmt != "coordinate":
            raise bad(f"only 'matrix coordinate' supported, got '{obj} {fmt}'")
        if field not in _MM_FIELDS:
            raise bad(f"unsupported field '{field}'")
        if symmetry not in _MM_SYMMETRIES:
            raise bad(f"unsupported symmetry '{symmetry}'")
        line = f.readline()
        while line and line.lstrip().startswith("%"):
            line = f.readline()
        dims = line.split()
        if len(dims) != 3:
            raise bad(f"malformed size line {line!r}")
        try:
            m, n, nnz = (int(t) for t in dims)
        except ValueError:
            raise bad(f"malformed size line {line!r} (non-integer dims)")
        if m < 1 or n < 1 or nnz < 0:
            raise bad(f"malformed size line {line!r} (absurd dimensions)")
        try:
            data = np.loadtxt(f, ndmin=2, dtype=np.float64)
        except ValueError as e:
            raise bad(f"malformed entry line ({e})")
    if data.size == 0:
        data = np.zeros((0, 2 if field == "pattern" else 3))
    if len(data) != nnz:
        raise bad(f"header promises {nnz} entries, found {len(data)}")
    rows = data[:, 0].astype(np.int64) - 1
    cols = data[:, 1].astype(np.int64) - 1
    if field == "pattern":
        vals = np.ones(len(rows), np.float64)
    else:
        if data.shape[1] < 3:
            raise bad(f"'{field}' entries need a value column")
        vals = data[:, 2]
    if not np.all(np.isfinite(vals)):
        raise bad("non-finite value entries (NaN/Inf)")
    if rows.size and (
        rows.min() < 0 or rows.max() >= m or cols.min() < 0 or cols.max() >= n
    ):
        raise bad(f"coordinate out of bounds for {m}x{n}")
    if symmetry != "general":
        off = rows != cols
        sign = -1.0 if symmetry == "skew-symmetric" else 1.0
        rows, cols, vals = (
            np.concatenate([rows, cols[off]]),
            np.concatenate([cols, rows[off]]),
            np.concatenate([vals, sign * vals[off]]),
        )
    key = rows * n + cols
    uniq, inv = np.unique(key, return_inverse=True)
    if len(uniq) != len(key):
        # dedup-sum, preserving nothing but the canonical (row, col) order
        # — only taken when duplicates actually exist, so duplicate-free
        # files keep their on-disk entry order.
        summed = np.zeros(len(uniq), vals.dtype)
        np.add.at(summed, inv, vals)
        rows, cols, vals = uniq // n, uniq % n, summed
    return rows, cols, vals, (m, n)


FAMILIES = {
    "uniform": uniform_random,
    "power_law": power_law,
    "banded": banded,
    "block_clustered": block_clustered,
    "diag": diagonal_dominant,
    "pruned": pruned_weight,
}


def corpus(scale: str = "small", seed: int = 0):
    """Yield (MatrixSpec, rows, cols, vals, shape) across all families.

    scale='small' keeps preprocessing CPU-cheap for tests; 'bench' matches
    the paper's >=1e5-nnz representative-matrix regime.
    """
    if scale == "small":
        sizes = [(256, 256), (400, 320), (1024, 1024)]
    elif scale == "bench":
        sizes = [(4096, 4096), (8192, 8192), (16384, 16384)]
    else:
        raise errors.InvalidArgError(scale)
    out = []
    i = 0
    for m, n in sizes:
        for fam, fn in FAMILIES.items():
            if fam == "uniform":
                r, c, v = fn(m, n, density=0.002, seed=seed + i)
            else:
                r, c, v = fn(m, n, seed=seed + i)
            out.append((MatrixSpec(f"{fam}_{m}x{n}", fam, m, n), r, c, v, (m, n)))
            i += 1
    return out
