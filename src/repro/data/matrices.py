"""Synthetic sparse-matrix corpus generator.

The paper evaluates on 2,843 SuiteSparse matrices. Offline we reproduce the
*structural families* that collection spans — uniform random, power-law
(graph-like), banded/FEM-like, block-clustered, and diagonal-dominant —
so every benchmark sweeps matrices whose block-nnz distributions match the
paper's Fig. 3 regimes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    family: str
    m: int
    n: int
    params: tuple = ()


def _dedup(rows, cols, m, n, rng, vals=None):
    key = rows.astype(np.int64) * n + cols
    _, idx = np.unique(key, return_index=True)
    rows, cols = rows[idx], cols[idx]
    if vals is None:
        vals = rng.standard_normal(len(rows)).astype(np.float64)
    else:
        vals = vals[idx]
    return rows.astype(np.int64), cols.astype(np.int64), vals


def uniform_random(m, n, density, seed=0):
    """Uniformly scattered non-zeros — the super-sparse COO regime."""
    rng = np.random.default_rng(seed)
    nnz = max(1, int(m * n * density))
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    return _dedup(rows, cols, m, n, rng)


def power_law(m, n, avg_deg=8, alpha=2.1, seed=0):
    """Graph-like rows: degree ~ Zipf; hub rows create dense blocks +
    extreme TB load imbalance (the Fig. 4 regime)."""
    rng = np.random.default_rng(seed)
    deg = rng.zipf(alpha, size=m).astype(np.int64)
    deg = np.minimum(deg * avg_deg // 2 + 1, n)
    rows = np.repeat(np.arange(m, dtype=np.int64), deg)
    # column popularity is itself power-law (preferential attachment)
    popularity = (1.0 / np.arange(1, n + 1)) ** 0.7
    popularity /= popularity.sum()
    cols = rng.choice(n, size=len(rows), p=popularity)
    return _dedup(rows, cols, m, n, rng)


def banded(m, n, bandwidth=9, fill=0.7, seed=0):
    """FEM/stencil-like band matrix — contiguous blocks, CSR/Dense regime."""
    rng = np.random.default_rng(seed)
    offs = np.arange(-(bandwidth // 2), bandwidth // 2 + 1)
    rows = np.repeat(np.arange(m, dtype=np.int64), len(offs))
    cols = rows + np.tile(offs, m)
    keep = (cols >= 0) & (cols < n) & (rng.random(len(rows)) < fill)
    return _dedup(rows[keep], cols[keep], m, n, rng)


def block_clustered(m, n, cluster=48, clusters_per_row=3, density=0.85, seed=0):
    """Dense clusters scattered on a sparse background (mixed regimes —
    the torso1/exdata_1 style matrices the paper's ablation highlights)."""
    rng = np.random.default_rng(seed)
    rows_l, cols_l = [], []
    n_row_clusters = max(1, m // cluster)
    for rc in range(n_row_clusters):
        r0 = rc * cluster
        for _ in range(clusters_per_row):
            c0 = int(rng.integers(0, max(1, n - cluster)))
            mask = rng.random((min(cluster, m - r0), cluster)) < density
            rr, cc = np.nonzero(mask)
            rows_l.append(r0 + rr)
            cols_l.append(c0 + cc)
    # sparse background
    bg = max(1, int(0.0005 * m * n))
    rows_l.append(rng.integers(0, m, bg))
    cols_l.append(rng.integers(0, n, bg))
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    return _dedup(rows, cols, m, n, rng)


def diagonal_dominant(m, n, extra_density=0.001, seed=0):
    rng = np.random.default_rng(seed)
    d = min(m, n)
    rows = [np.arange(d, dtype=np.int64)]
    cols = [np.arange(d, dtype=np.int64)]
    nnz = max(1, int(m * n * extra_density))
    rows.append(rng.integers(0, m, nnz))
    cols.append(rng.integers(0, n, nnz))
    return _dedup(np.concatenate(rows), np.concatenate(cols), m, n, rng)


def pruned_weight(m, n, block_size=16, block_sparsity=0.85, seed=0):
    """Magnitude-pruned NN weight style: whole blocks zeroed, survivors
    dense-ish — the regime CBSparseLinear sees in the LM integration."""
    rng = np.random.default_rng(seed)
    mb, nb = -(-m // block_size), -(-n // block_size)
    alive = rng.random((mb, nb)) > block_sparsity
    rr, cc = np.nonzero(alive)
    rows_l, cols_l = [], []
    for r0, c0 in zip(rr, cc):
        h = min(block_size, m - r0 * block_size)
        w = min(block_size, n - c0 * block_size)
        mask = rng.random((h, w)) < 0.6
        lr, lc = np.nonzero(mask)
        rows_l.append(r0 * block_size + lr)
        cols_l.append(c0 * block_size + lc)
    if not rows_l:
        rows_l, cols_l = [np.zeros(1, np.int64)], [np.zeros(1, np.int64)]
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    return _dedup(rows, cols, m, n, rng)


FAMILIES = {
    "uniform": uniform_random,
    "power_law": power_law,
    "banded": banded,
    "block_clustered": block_clustered,
    "diag": diagonal_dominant,
    "pruned": pruned_weight,
}


def corpus(scale: str = "small", seed: int = 0):
    """Yield (MatrixSpec, rows, cols, vals, shape) across all families.

    scale='small' keeps preprocessing CPU-cheap for tests; 'bench' matches
    the paper's >=1e5-nnz representative-matrix regime.
    """
    if scale == "small":
        sizes = [(256, 256), (400, 320), (1024, 1024)]
    elif scale == "bench":
        sizes = [(4096, 4096), (8192, 8192), (16384, 16384)]
    else:
        raise ValueError(scale)
    out = []
    i = 0
    for m, n in sizes:
        for fam, fn in FAMILIES.items():
            if fam == "uniform":
                r, c, v = fn(m, n, density=0.002, seed=seed + i)
            else:
                r, c, v = fn(m, n, seed=seed + i)
            out.append((MatrixSpec(f"{fam}_{m}x{n}", fam, m, n), r, c, v, (m, n)))
            i += 1
    return out
