"""Train-step construction + host-side training loop.

``build_train_step`` assembles the jitted step: microbatched gradient
accumulation (lax.scan — keeps activation memory at 1/k and lets XLA
overlap each microbatch's reduce with the next one's compute), global-norm
clipping, LR schedule, AdamW/Lion update, optional int8 EF gradient
compression for the cross-pod reduce.

``run_training`` is the host loop: deterministic data stream (resume ==
replay), periodic async checkpoints, heartbeat + straggler bookkeeping
from runtime/, and crash-consistent restart.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model

from . import optimizer as opt_mod
from .grad_compression import ef_quantize
from .train_state import TrainState


def build_train_step(
    model: Model,
    optimizer: opt_mod.Optimizer,
    lr_fn: Callable,
    *,
    microbatches: int = 1,
    clip_norm: float = 1.0,
    compression: str = "none",   # none | int8_ef (simulated pre-psum quant)
):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (pure)."""

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
            return loss, metrics, grads

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mbs = jax.tree_util.tree_map(split, batch)

        def body(acc, mb):
            g_acc, l_acc = acc
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            return (g_acc, l_acc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), mbs
        )
        inv = 1.0 / microbatches
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        return loss_sum * inv, {"xent": loss_sum * inv}, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)

        ef = state.ef_buffers
        if compression == "int8_ef":
            # Simulated compressed cross-pod sum: quantize+EF happens where
            # the pod psum would run; numerics match the wire version
            # (grad_compression.compressed_cross_pod_sum) exactly.
            flat_g, tree = jax.tree_util.tree_flatten(grads)
            flat_e = jax.tree_util.tree_leaves(ef)
            qs = [ef_quantize(g, e) for g, e in zip(flat_g, flat_e)]
            grads = jax.tree_util.tree_unflatten(
                tree, [q.astype(jnp.float32) * s for q, s, _ in qs]
            )
            ef = jax.tree_util.tree_unflatten(tree, [e for _, _, e in qs])

        grads, gnorm = opt_mod.clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state.step)
        updates, opt_state = optimizer.update(
            grads, state.opt_state, state.params, lr
        )
        params = opt_mod.apply_updates(state.params, updates)
        new_state = TrainState(
            step=state.step + 1, params=params, opt_state=opt_state,
            ef_buffers=ef,
        )
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out_metrics.update({k: v for k, v in metrics.items()})
        return new_state, out_metrics

    return train_step


# ---------------------------------------------------------------------------
# host loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    microbatches: int = 1
    clip_norm: float = 1.0
    optimizer: str = "adamw"
    compression: str = "none"
    step_deadline_s: float | None = None   # straggler mitigation


def run_training(
    model: Model,
    data_stream,
    loop_cfg: TrainLoopConfig,
    *,
    checkpointer=None,
    monitor=None,
    initial_state: TrainState | None = None,
    jit: bool = True,
) -> tuple[TrainState, list[dict]]:
    """Deterministic, restartable training loop (single controller)."""
    from .schedule import warmup_cosine

    optimizer = opt_mod.OPTIMIZERS[loop_cfg.optimizer]()
    lr_fn = warmup_cosine(loop_cfg.peak_lr, loop_cfg.warmup_steps,
                          loop_cfg.total_steps)
    step_fn = build_train_step(
        model, optimizer, lr_fn,
        microbatches=loop_cfg.microbatches,
        clip_norm=loop_cfg.clip_norm,
        compression=loop_cfg.compression,
    )
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=0)

    if initial_state is None:
        params, _ = model.init(jax.random.PRNGKey(0))
        state = TrainState.create(
            params, optimizer,
            use_compression=loop_cfg.compression != "none",
        )
    else:
        state = initial_state

    history: list[dict] = []
    start = int(state.step)
    for step in range(start, loop_cfg.total_steps):
        t0 = time.monotonic()
        batch = data_stream.batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if monitor is not None:
            monitor.heartbeat(step)

        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["step_time_s"] = time.monotonic() - t0
            history.append(m)
        if (
            loop_cfg.step_deadline_s is not None
            and monitor is not None
            and (time.monotonic() - t0) > loop_cfg.step_deadline_s
        ):
            monitor.report_straggler(step, time.monotonic() - t0)

        if checkpointer is not None and (
            (step + 1) % loop_cfg.checkpoint_every == 0
            or step == loop_cfg.total_steps - 1
        ):
            checkpointer.save(state, step + 1)

    return state, history
