"""LR schedules: linear warmup + cosine decay (the LM-pretraining default)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_fraction: float = 0.1):
    """Returns step -> lr (traceable)."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
        progress = (step - warmup_steps) / jnp.maximum(
            1.0, total_steps - warmup_steps
        )
        progress = jnp.clip(progress, 0.0, 1.0)
        cos = peak_lr * (
            final_fraction
            + (1 - final_fraction) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        )
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def constant(lr_value: float):
    def lr(step):
        return jnp.full((), lr_value, jnp.float32)

    return lr
