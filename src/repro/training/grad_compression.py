"""int8 error-feedback gradient compression for cross-pod reduction.

The distributed-optimization trick of DESIGN.md §5: before the *cross-pod*
gradient sum (the slow inter-pod links), gradients are quantized to int8
with a per-tensor scale; the quantization error is kept in a local
error-feedback (EF) buffer and added back into the next step's gradient —
the standard EF-SGD recipe that keeps compressed training convergent.

Two deployment modes:

  * ``compressed_cross_pod_sum`` — under a shard_map that is *manual* over
    the ``pod`` axis: quantize, ``lax.psum`` the int8 payload as int32
    (exact — pod counts are small), dequantize. This is the real 4x
    inter-pod traffic reduction.
  * ``ef_quantize``/``ef_update`` — the building blocks, unit-tested for
    the EF contract (compressed-sum + EF ≈ exact sum over time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize(grad: jax.Array, ef: jax.Array):
    """Quantize (grad + ef); return (q, scale, new_ef)."""
    target = grad.astype(jnp.float32) + ef
    q, scale = quantize_int8(target)
    new_ef = target - dequantize_int8(q, scale)
    return q, scale, new_ef


def compressed_cross_pod_sum(grads, ef_buffers, axis_name: str = "pod"):
    """EF-int8 psum over ``axis_name`` for a gradient pytree.

    Must run inside a shard_map manual over ``axis_name``. Scales are
    reduced with max (shared scale keeps the int32 sum exact), then the
    int8 payloads are summed as int32 — the wire format is 1 byte/element.
    """

    def one(g, ef):
        target = g.astype(jnp.float32) + ef
        # shared scale across pods so the integer sum is well-defined
        amax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        new_ef = target - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (summed.astype(jnp.float32) * scale).astype(g.dtype), new_ef

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef_buffers)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    summed = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_ef = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return summed, new_ef


def ef_compress_grads(grads, ef_buffers):
    """Single-process EF-int8 round trip: the wire format without the psum.

    Used by the sparse mask-refreeze training hook
    (``sparse.prune.refreeze_training_step``): tile gradients pass through
    the same int8 quantize/dequantize as the cross-pod path, with the
    error-feedback buffers absorbing the rounding error so compressed SGD
    stays convergent. Returns ``(decompressed_grads, new_ef_buffers)``.
    """

    def one(g, e):
        q, scale, new_ef = ef_quantize(g, e)
        return dequantize_int8(q, scale).astype(g.dtype), new_ef

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef_buffers)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree_util.tree_unflatten(tree, [o[0] for o in out]),
        jax.tree_util.tree_unflatten(tree, [o[1] for o in out]),
    )


def init_ef_buffers(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
