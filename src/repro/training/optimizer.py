"""Optimizers from scratch (no optax): AdamW, Lion, + global-norm clip.

Functional API mirroring the usual gradient-transform style:

    opt = adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr)
    params = apply_updates(params, updates)

Optimizer state mirrors the parameter pytree, so it inherits the params'
NamedShardings under GSPMD (ZeRO-style sharded optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def apply_updates(params, updates):
    return _tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return _tree_map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


jax.tree_util.register_dataclass(
    AdamWState, data_fields=["mu", "nu", "count"], meta_fields=[]
)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moments_dtype=jnp.float32) -> Optimizer:
    """AdamW. ``moments_dtype=bfloat16`` halves optimizer memory (the
    quantized-optimizer-state trick needed to fit 400B-class MoE on a
    single 256-chip pod — update math still runs in f32)."""

    def init(params):
        zeros = lambda: _tree_map(
            lambda p: jnp.zeros_like(p, dtype=moments_dtype), params
        )
        return AdamWState(mu=zeros(), nu=zeros(),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state: AdamWState, params, lr):
        count = state.count + 1
        cf = count.astype(jnp.float32)
        mu32 = _tree_map(
            lambda m, g: b1 * m.astype(jnp.float32)
            + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads,
        )
        nu32 = _tree_map(
            lambda v, g: b2 * v.astype(jnp.float32)
            + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        mu_hat_scale = 1.0 / (1 - b1**cf)
        nu_hat_scale = 1.0 / (1 - b2**cf)
        updates = _tree_map(
            lambda m, v, p: -lr * (
                m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + eps)
                + weight_decay * p.astype(jnp.float32)
            ),
            mu32, nu32, params,
        )
        mu = _tree_map(lambda m: m.astype(moments_dtype), mu32)
        nu = _tree_map(lambda v: v.astype(moments_dtype), nu32)
        return updates, AdamWState(mu=mu, nu=nu, count=count)

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LionState:
    mu: Any
    count: jax.Array


jax.tree_util.register_dataclass(
    LionState, data_fields=["mu", "count"], meta_fields=[]
)


def lion(b1: float = 0.9, b2: float = 0.99,
         weight_decay: float = 0.1) -> Optimizer:
    """Lion (EvoLved Sign Momentum) — half the optimizer memory of Adam."""

    def init(params):
        return LionState(
            mu=_tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state: LionState, params, lr):
        updates = _tree_map(
            lambda m, g, p: -lr * (
                jnp.sign(b1 * m + (1 - b1) * g.astype(jnp.float32))
                + weight_decay * p.astype(jnp.float32)
            ),
            state.mu, grads, params,
        )
        mu = _tree_map(
            lambda m, g: b2 * m + (1 - b2) * g.astype(jnp.float32),
            state.mu, grads,
        )
        return updates, LionState(mu=mu, count=state.count + 1)

    return Optimizer(init=init, update=update)


OPTIMIZERS = {"adamw": adamw, "lion": lion}
