"""Training substrate: optimizers, schedules, compression, train loop."""
from .optimizer import OPTIMIZERS, adamw, apply_updates, clip_by_global_norm, lion  # noqa: F401
from .schedule import constant, warmup_cosine  # noqa: F401
from .train_loop import TrainLoopConfig, build_train_step, run_training  # noqa: F401
from .train_state import TrainState  # noqa: F401
