"""TrainState: params + optimizer state + step, as a registered pytree."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TrainState:
    step: jax.Array          # () int32
    params: Any
    opt_state: Any
    ef_buffers: Any | None = None   # int8-compression error feedback

    @classmethod
    def create(cls, params, optimizer, use_compression: bool = False):
        from .grad_compression import init_ef_buffers

        return cls(
            step=jnp.zeros((), jnp.int32),
            params=params,
            opt_state=optimizer.init(params),
            ef_buffers=init_ef_buffers(params) if use_compression else None,
        )


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=["step", "params", "opt_state", "ef_buffers"],
    meta_fields=[],
)
