"""Fault tolerance: heartbeats, failure detection, restart policy,
straggler bookkeeping.

At 1000+ nodes, *something* is always failing; the design is
checkpoint/restart with deterministic replay:

  * every host pushes a heartbeat per step into ``HeartbeatMonitor``;
  * the controller (or an external watchdog) calls ``check()``; a host
    whose last beat is older than ``timeout_s`` is declared failed;
  * ``RestartPolicy`` answers "restore from step X, replay data from X" —
    correct because the data stream is indexed by (step, host)
    (data/synthetic.py) and checkpoints are atomic (checkpoint/).

Stragglers: per-step durations feed an EWMA; a step slower than
``straggler_factor`` x EWMA is recorded. The mitigation at mesh scale is
re-balancing (core/balance device assignment) or evicting the slow host
(elastic.py re-mesh) — both decisions are surfaced, not hidden.

Everything takes an injectable ``clock`` so failure scenarios unit-test
with simulated time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro import errors


@dataclasses.dataclass
class HostStatus:
    host_id: int
    last_beat: float
    last_step: int
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, num_hosts: int = 1, timeout_s: float = 60.0,
                 straggler_factor: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.clock = clock
        now = clock()
        self.hosts = {
            h: HostStatus(host_id=h, last_beat=now, last_step=-1)
            for h in range(num_hosts)
        }
        self.step_ewma: float | None = None
        self.stragglers: list[tuple[int, float]] = []   # (step, duration)
        self._last_step_t: float | None = None

    # -- heartbeats ------------------------------------------------------
    def heartbeat(self, step: int, host_id: int = 0) -> None:
        now = self.clock()
        st = self.hosts[host_id]
        st.last_beat = now
        st.last_step = step
        st.alive = True
        if self._last_step_t is not None:
            dur = now - self._last_step_t
            self.step_ewma = (
                dur if self.step_ewma is None
                else 0.9 * self.step_ewma + 0.1 * dur
            )
            if (
                self.step_ewma is not None
                and dur > self.straggler_factor * self.step_ewma
                and dur > 0
            ):
                self.stragglers.append((step, dur))
        self._last_step_t = now

    def report_straggler(self, step: int, duration_s: float) -> None:
        self.stragglers.append((step, duration_s))

    # -- failure detection --------------------------------------------------
    def check(self) -> list[int]:
        """Returns host ids newly declared failed."""
        now = self.clock()
        failed = []
        for st in self.hosts.values():
            if st.alive and (now - st.last_beat) > self.timeout_s:
                st.alive = False
                failed.append(st.host_id)
        return failed

    @property
    def alive_hosts(self) -> list[int]:
        return [h for h, st in self.hosts.items() if st.alive]


@dataclasses.dataclass
class RestartDecision:
    restore_step: int
    replay_from_step: int
    surviving_hosts: list[int]
    needs_remesh: bool


class RestartPolicy:
    """checkpoint/restart with deterministic replay (single source of truth).

    ``max_restarts`` bounds the budget: once that many ``on_failure``
    decisions have been handed out, further failures raise
    ``errors.RestartBudgetError`` — a crash-looping job must surface to
    the operator rather than burn the fleet replaying forever.
    """

    def __init__(self, checkpointer, monitor: HeartbeatMonitor,
                 *, max_restarts: int | None = None):
        self.checkpointer = checkpointer
        self.monitor = monitor
        self.max_restarts = max_restarts
        self.restarts = 0

    def on_failure(self) -> RestartDecision:
        if self.max_restarts is not None and self.restarts >= self.max_restarts:
            raise errors.RestartBudgetError(errors.reason(
                errors.RESTART_BUDGET_EXHAUSTED,
                f"restart budget of {self.max_restarts} exhausted",
            ))
        self.restarts += 1
        step = self.checkpointer.latest_step() or 0
        surviving = self.monitor.alive_hosts
        return RestartDecision(
            restore_step=step,
            replay_from_step=step,
            surviving_hosts=surviving,
            needs_remesh=len(surviving) < len(self.monitor.hosts),
        )


def run_supervised(step_fn, init_state, *, num_steps: int,
                   checkpointer, policy: RestartPolicy,
                   checkpoint_every: int = 1, host_id: int = 0):
    """Run ``num_steps`` of ``step_fn`` under checkpoint/restart supervision.

    ``step_fn(state, step) -> state`` must be deterministic in its
    arguments — that is the replay contract: after a failure the loop
    restores the newest checkpoint and re-executes from its step, so the
    final state is bit-identical to a fault-free run. The checkpoint at
    step ``s`` holds the state *before* executing step ``s`` (step 0 is
    persisted up front so even a first-step failure has a restore
    point). Each successful step heartbeats ``policy.monitor``; each
    failure consumes one unit of the policy's restart budget
    (``errors.RestartBudgetError`` propagates when it runs out).
    """
    checkpointer.save(init_state, 0)
    checkpointer.wait()
    state = init_state
    step = 0
    while step < num_steps:
        try:
            state = step_fn(state, step)
        except errors.RestartBudgetError:
            raise
        except Exception:
            decision = policy.on_failure()   # raises when budget exhausted
            checkpointer.wait()
            state = checkpointer.restore(init_state, step=decision.restore_step)
            step = decision.replay_from_step
            continue
        policy.monitor.heartbeat(step, host_id)
        step += 1
        if step % checkpoint_every == 0 and step < num_steps:
            checkpointer.save(state, step)
    checkpointer.wait()
    return state
