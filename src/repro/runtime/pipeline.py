"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

For cross-pod scaling where the inter-pod links are too slow for FSDP-style
weight gathering, the pod axis can instead carry *pipeline stages*: each
pod owns a contiguous slice of layers; microbatches stream through stages
with ``lax.ppermute`` handoffs (DCN-friendly: one activation tensor per
microbatch per boundary, overlappable with compute).

Implementation is the classic collective-permute loop under a shard_map
that is manual over the stage axis:

    for t in 0 .. (M + S - 2):            # pipeline schedule ticks
        h_in  = ppermute(h_out, shift +1) # receive from previous stage
        h_out = stage_fn(local_params, select(t) microbatch or h_in)

Bubble fraction is the usual (S-1)/(M+S-1); the launcher picks M >= 4*S.
This module is exercised at small scale in tests (2 stages on 2 fake
devices) and is the alternative ``pod`` strategy in launch/train.py.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def pipeline_forward(
    stage_fn: Callable,            # (stage_params, h) -> h
    mesh: Mesh,
    axis: str = "pod",
):
    """Builds ``run(stacked_stage_params, microbatches) -> outputs``.

    stacked_stage_params: leaves (S, ...) — stage s uses slice s.
    microbatches: (M, mb, ...) input activations (already embedded).
    outputs: (M, mb, ...) activations out of the last stage.
    """
    S = mesh.shape[axis]

    @partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(P(axis), P()),    # params sharded by stage; data replicated
        out_specs=P(),
        check_vma=False,
    )
    def run(stage_params, mbs):
        local_params = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index(axis)
        M = mbs.shape[0]
        T = M + S - 1                     # schedule length
        mb_shape = mbs.shape[1:]

        def tick(carry, t):
            h_prev, outputs = carry
            # receive boundary activation from the previous stage
            h_recv = jax.lax.ppermute(
                h_prev, axis,
                perm=[(i, (i + 1) % S) for i in range(S)],
            )
            # stage 0 feeds fresh microbatches while they last
            mb_idx = jnp.clip(t, 0, M - 1)
            fresh = mbs[mb_idx]
            h_in = jnp.where(stage == 0, fresh, h_recv)
            h_out = stage_fn(local_params, h_in)
            # last stage commits its result for microbatch (t - (S-1))
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            commit = (stage == S - 1) & (t >= S - 1)
            outputs = jax.lax.cond(
                commit,
                lambda o: o.at[out_idx].set(h_out),
                lambda o: o,
                outputs,
            )
            return (h_out, outputs), None

        init_h = jnp.zeros(mb_shape, mbs.dtype)
        init_out = jnp.zeros_like(mbs)
        (_, outputs), _ = jax.lax.scan(
            tick, (init_h, init_out), jnp.arange(T)
        )
        # every stage computed an `outputs`; only the last stage's is real
        outputs = jax.lax.psum(
            jnp.where(stage == S - 1, outputs, jnp.zeros_like(outputs)), axis
        )
        return outputs

    return run


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
