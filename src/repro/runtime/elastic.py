"""Elastic scaling: re-mesh planning when the device pool changes.

When hosts fail (or capacity arrives), training resumes on a different
device count. Checkpoints store logical (unsharded) arrays, so elasticity
reduces to: pick a new mesh shape, rebuild NamedShardings from the same
logical-axis rules, device_put on restore (checkpoint/Checkpointer).

``plan_mesh`` chooses the largest usable (data, model) factorization:
model-parallel width is kept if possible (param layouts stay aligned);
otherwise it steps down through divisors. ``global_batch`` divisibility is
preserved by construction (batch shards over data only).
"""
from __future__ import annotations

import dataclasses
from repro import errors


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dropped_devices: int

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(
    available_devices: int,
    *,
    prefer_model: int = 16,
    global_batch: int | None = None,
    pod_size: int = 256,
) -> MeshPlan:
    """Largest (data, model) grid with model | prefer_model, data maximal.

    When the pool spans >= 2 full pods, a leading ``pod`` axis is split off
    (pure DP across pods: cross-pod traffic rides the slower DCN links).
    """
    if available_devices < 1:
        raise errors.InvalidArgError("no devices")
    model = prefer_model
    while model > 1 and available_devices % model:
        model //= 2
    data = available_devices // model
    if global_batch is not None:
        while data > 1 and global_batch % data:
            data -= 1
    used = data * model
    if used >= 2 * pod_size and used % pod_size == 0:
        pods = used // pod_size
        d = pod_size // model
        return MeshPlan((pods, d, model), ("pod", "data", "model"),
                        available_devices - used)
    return MeshPlan((data, model), ("data", "model"),
                    available_devices - used)


def reshard_instructions(old_plan: MeshPlan, new_plan: MeshPlan) -> dict:
    """Human/log-readable summary of the elastic transition."""
    return {
        "old": {"shape": old_plan.shape, "axes": old_plan.axis_names},
        "new": {"shape": new_plan.shape, "axes": new_plan.axis_names},
        "mechanism": "restore logical arrays; device_put with new NamedShardings",
        "data_replay": "stream indexed by (step, host) — replay from restore step",
    }
