"""Deterministic fault injection — the test harness for the failure model.

Every injector is seeded (``np.random.default_rng``) so a fault scenario
replays bit-identically: the same seed corrupts the same bytes, poisons
the same vector entries, and fails the same calls. The acceptance
criterion for the robustness axis is that every injector here is either
*detected with a typed reason* from :mod:`repro.errors` or *tolerated
with a correct result* — see ``tests/test_faults.py`` and the
``robustness`` bench section.

Injectors by layer:

  * :func:`flip_file_bytes`        — artifact byte-flips (npz / plan JSON);
  * :func:`corrupt_packed_values`  — NaN payloads written straight into a
    ``CBMatrix`` packed stream, bypassing the ``from_coo`` policy (what a
    DMA/memory fault looks like);
  * :func:`poison_vector`          — NaN/Inf entries in a solver operand;
  * :class:`FlakyStepFn`           — a callable wrapper that raises
    ``errors.InjectedFault`` on chosen call indices (serving ticks,
    training steps);
  * :func:`lose_host`              — rewind one host's heartbeat so the
    next ``HeartbeatMonitor.check()`` declares it failed.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro import errors


def flip_file_bytes(path, *, n: int = 1, seed: int = 0,
                    start: int = 0, stop: int | None = None):
    """Flip one random bit in each of ``n`` distinct bytes of ``path``.

    ``start``/``stop`` bound the byte range (e.g. to target a JSON value
    region rather than whitespace). Returns ``[(offset, old, new), ...]``
    so a test can assert or undo the damage. In-place, deterministic in
    ``seed``.
    """
    rng = np.random.default_rng(seed)
    with open(path, "rb") as f:
        data = bytearray(f.read())
    stop = len(data) if stop is None else min(stop, len(data))
    if start >= stop:
        raise errors.InvalidArgError(f"empty flip range [{start}, {stop}) for {path}")
    span = stop - start
    offsets = start + rng.choice(span, size=min(n, span), replace=False)
    flips = []
    for off in sorted(int(o) for o in offsets):
        old = data[off]
        new = old ^ (1 << int(rng.integers(8)))
        data[off] = new
        flips.append((off, old, new))
    tmp = f"{path}.flip.tmp"
    with open(tmp, "wb") as f:
        f.write(bytes(data))
    os.replace(tmp, path)
    return flips


def poison_vector(x, *, n: int = 1, seed: int = 0, value=np.nan):
    """Copy of ``x`` with ``n`` random entries overwritten by ``value``."""
    rng = np.random.default_rng(seed)
    out = np.array(x, copy=True)
    flat = out.reshape(-1)
    idx = rng.choice(flat.size, size=min(n, flat.size), replace=False)
    flat[idx] = value
    return out


def corrupt_packed_values(cb, *, n: int = 1, seed: int = 0, value=np.nan):
    """A copy of ``cb`` with ``n`` packed values overwritten by ``value``.

    Writes the raw bytes straight into the packed stream via the value
    layout — deliberately *bypassing* the ``update_values`` non-finite
    policy, the way a memory/DMA fault would. The structure metadata is
    untouched, so ``validate()`` passes but ``validate(check_finite=True)``
    and any SpMV/solve over the matrix see the poison.
    """
    rng = np.random.default_rng(seed)
    layout = cb.value_layout()
    if layout.count == 0:
        raise errors.InvalidArgError("matrix has no stored values to corrupt")
    vsize = cb.val_dtype.itemsize
    idx = rng.choice(layout.count, size=min(n, layout.count), replace=False)
    pos = layout.byte_pos[np.sort(idx)]
    packed = cb.packed.copy()
    bad = np.full(len(pos), value, cb.val_dtype).view(np.uint8)
    packed[pos[:, None] + np.arange(vsize, dtype=np.int64)] = (
        bad.reshape(len(pos), vsize))
    new = dataclasses.replace(cb, packed=packed)
    new._value_layout_cache = layout
    return new


class FlakyStepFn:
    """Wrap a callable; raise ``errors.InjectedFault`` on chosen calls.

    ``fail_on`` is a collection of 0-based call indices. Calls are
    counted across successes *and* failures, so ``fail_on={0, 1}`` means
    "the first two attempts fail, the third succeeds" — exactly the
    shape a bounded-retry loop must absorb.
    """

    def __init__(self, fn, *, fail_on=(0,)):
        self.fn = fn
        self.fail_on = frozenset(int(i) for i in fail_on)
        self.calls = 0
        self.failures = 0

    def __call__(self, *args, **kwargs):
        i = self.calls
        self.calls += 1
        if i in self.fail_on:
            self.failures += 1
            raise errors.InjectedFault(errors.reason(
                errors.INJECTED, f"injected failure on call {i}"))
        return self.fn(*args, **kwargs)


def lose_host(monitor, host_id: int = 0) -> None:
    """Silence one host: rewind its heartbeat past the monitor timeout.

    The next ``monitor.check()`` declares the host failed — without
    having to fast-forward the (possibly shared) injectable clock.
    """
    st = monitor.hosts[host_id]
    st.last_beat = monitor.clock() - monitor.timeout_s - 1.0
