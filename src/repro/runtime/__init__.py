from .elastic import MeshPlan, plan_mesh, reshard_instructions  # noqa: F401
from .fault_tolerance import (  # noqa: F401
    HeartbeatMonitor,
    RestartDecision,
    RestartPolicy,
    run_supervised,
)
from .faults import (  # noqa: F401
    FlakyStepFn,
    corrupt_packed_values,
    flip_file_bytes,
    lose_host,
    poison_vector,
)
from .pipeline import bubble_fraction, pipeline_forward  # noqa: F401
