from .elastic import MeshPlan, plan_mesh, reshard_instructions  # noqa: F401
from .fault_tolerance import HeartbeatMonitor, RestartPolicy  # noqa: F401
from .pipeline import bubble_fraction, pipeline_forward  # noqa: F401
