"""Sharded checkpointing: npz leaves + JSON manifest, async write, elastic
resharding on restore.

Layout:  <dir>/step_<N>/
           manifest.json   — step, leaf paths, shapes/dtypes, mesh record
           <leaf_id>.npy   — one file per pytree leaf (host order)

Writes go through a temp directory + atomic rename, so a crash mid-write
never corrupts the latest checkpoint (restart scans for the newest COMPLETE
step). ``save`` can run asynchronously (thread) — the train loop keeps
stepping while the previous state is flushed (state is fetched to host
first, so donation/aliasing is safe).

Elastic restore: leaves are stored as *logical* (unsharded) arrays; on
load they are ``device_put`` with NamedShardings built from the CURRENT
mesh + logical axis rules — so a 512-chip checkpoint restores onto 256
chips (or any other mesh) without a repartition tool.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name.replace("/", "__") or "leaf", leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------
    def save(self, state: Any, step: int) -> None:
        host_state = jax.tree_util.tree_map(np.asarray, jax.device_get(state))
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(host_state, step), daemon=True
            )
            self._thread.start()
        else:
            self._write(host_state, step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step: int) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _flatten_with_paths(host_state)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            fname = f"{i:05d}_{name[:80]}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.directory)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        example_state: Any,
        step: int | None = None,
        shardings: Any | None = None,
    ) -> Any:
        """Restore into the structure of ``example_state``.

        ``shardings``: optional pytree of NamedShardings (same structure)
        for elastic placement onto the current mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        _, treedef = _flatten_with_paths(example_state)
        arrays = [
            np.load(os.path.join(d, entry["file"]))
            for entry in manifest["leaves"]
        ]
        state = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), state, shardings
            )
        return state
