"""Launchers: production mesh, dry-run, train, serve."""
from .mesh import make_production_mesh  # noqa: F401
