"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the real training loop (synthetic token stream, checkpointing, fault
monitoring) on whatever devices exist — a single CPU device locally, the
production mesh on real pods. Mesh axes and logical rules come from
launch/mesh.py; elasticity from runtime/elastic.py.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models import Model, axis_rules, logical_to_sharding
from repro.models.sharding import sanitize_shardings
from repro.runtime import HeartbeatMonitor, plan_mesh
from repro.training import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "lion"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    plan = plan_mesh(n_dev, prefer_model=min(16, n_dev),
                     global_batch=args.global_batch)
    mesh = compat.make_mesh(plan.shape, plan.axis_names)
    print(f"mesh: {dict(zip(plan.axis_names, plan.shape))}  arch: {cfg.name}")

    model = Model(cfg)
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                   global_batch=args.global_batch)
    )
    ck = Checkpointer(f"{args.ckpt_dir}/{cfg.name}")
    monitor = HeartbeatMonitor(num_hosts=1)
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps,
        microbatches=args.microbatches,
        optimizer=args.optimizer,
        compression=args.compression,
        peak_lr=args.peak_lr,
        checkpoint_every=max(10, args.steps // 4),
        log_every=max(1, args.steps // 20),
    )

    initial_state = None
    if args.resume and ck.latest_step() is not None:
        from repro.training import OPTIMIZERS, TrainState

        params, _ = model.init(jax.random.PRNGKey(0))
        example = TrainState.create(
            params, OPTIMIZERS[args.optimizer](),
            use_compression=args.compression != "none",
        )
        initial_state = jax.tree_util.tree_map(
            jnp.asarray, ck.restore(example)
        )
        print(f"resumed from step {int(initial_state.step)}")

    with axis_rules(mesh):
        state, history = run_training(
            model, stream, loop_cfg,
            checkpointer=ck, monitor=monitor, initial_state=initial_state,
        )
    ck.wait()
    print("final:", history[-1])
    if monitor.stragglers:
        print(f"stragglers observed: {len(monitor.stragglers)}")


if __name__ == "__main__":
    main()
