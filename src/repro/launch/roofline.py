"""Roofline report: aggregate the dry-run JSONs into EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(d: str) -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def fmt_table(cells: list[dict], mesh: str) -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | bound | "
        "MODEL_FLOPS | useful | peak roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for c in sorted(
        [c for c in cells if c["mesh"] == mesh],
        key=lambda c: (c["arch"], order.get(c["shape"], 9)),
    ):
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skipped | — | — | {c['reason'][:46]} |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | FAILED: "
                        f"{c.get('error', '')[:60]} | | | | | | |")
            continue
        r = c["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom > 0 else 0.0
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['bottleneck']} | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {frac:.3f} |"
        )
    return "\n".join(rows)


def summarize(cells: list[dict]) -> dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    failed = [c for c in cells if c["status"] == "FAILED"]
    by_bound: dict[str, int] = {}
    worst = None
    most_coll = None
    for c in ok:
        r = c["roofline"]
        by_bound[r["bottleneck"]] = by_bound.get(r["bottleneck"], 0) + 1
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0
        if c["shape"] != "long_500k":  # ignore degenerate batch-1 cells
            if worst is None or frac < worst[0]:
                worst = (frac, c["arch"], c["shape"], c["mesh"])
        coll_share = r["collective_s"] / dom if dom else 0
        if most_coll is None or coll_share > most_coll[0]:
            most_coll = (coll_share, c["arch"], c["shape"], c["mesh"])
    return {
        "ok": len(ok), "skipped": len(skipped), "failed": len(failed),
        "bounds": by_bound, "worst_frac": worst, "most_collective": most_coll,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print(fmt_table(cells, args.mesh))
    print()
    print(json.dumps(summarize(cells), indent=1, default=str))


if __name__ == "__main__":
    main()
