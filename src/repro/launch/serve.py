"""Serving launcher: batched decode with the continuous-batching engine.

    python -m repro.launch.serve --arch granite-8b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import Model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    engine = ServingEngine(model, params, slots=args.slots,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(2, 12))
        engine.submit(Request(uid=uid, prompt=prompt.astype(np.int32),
                              max_new_tokens=args.max_new))

    t0 = time.monotonic()
    done = engine.run_until_done()
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests, {total_tokens} tokens, "
          f"{engine.ticks} engine ticks, {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.generated}")


if __name__ == "__main__":
    main()
