"""Production mesh construction + logical-axis rule selection.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state). Single pod = (data=16, model=16) — 256 chips; multi-pod
adds a leading ``pod`` axis (2 pods = 512 chips). ``pod`` is pure DP by
default (weights replicated per pod, gradients summed across pods);
launch/train.py can alternatively run GPipe stages over it
(runtime/pipeline.py).

``rules_for`` returns the logical->physical overrides per (cfg, shape):
  * decode shapes with batch < data width: batch unsharded, KV cache
    *sequence* sharded over model (flash-decoding style LSE combine is
    inserted by GSPMD as partial-softmax reductions);
  * small archs (whisper) replicate attention heads (TP over 16 chips of
    a 12-head model is padding waste, not parallelism).
"""
from __future__ import annotations

import jax

from repro import compat
from repro.configs.base import ModelConfig, ShapeConfig

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def data_width(mesh: jax.sharding.Mesh) -> int:
    w = mesh.shape["data"]
    if "pod" in mesh.shape:
        w *= mesh.shape["pod"]
    return w


def rules_for(cfg: ModelConfig, shape: ShapeConfig,
              mesh: jax.sharding.Mesh) -> dict:
    rules: dict = {}
    dw = data_width(mesh)

    if shape.kind == "decode":
        # Decode caches dominate memory. Shard the cache SEQUENCE dim over
        # the model axis (flash-decoding: GSPMD inserts the partial-softmax
        # LSE combine) — kv-head sharding would replicate whenever
        # kv_heads < TP width (GQA: 8 < 16), which is exactly the big-cache
        # regime. Batch rides data when divisible (decode_32k), else the
        # whole cache burden is on the seq shards (long_500k, batch 1).
        rules["kv_seq"] = "model"
        rules["kv"] = None
        # heads replicated: if q-heads shard over model, GSPMD prefers
        # h-parallel attention and ALL-GATHERS the seq-sharded cache each
        # layer (measured: 2.2 GB/layer/device). Replicated heads keep the
        # contraction s-parallel — the real flash-decoding schedule: cache
        # stays sharded, only LSE-combine psums cross devices (§Perf C3).
        rules["heads"] = None
        if shape.global_batch % dw != 0:
            rules["batch"] = None

    if cfg.num_heads < mesh.shape["model"]:
        # whisper (12 heads < 16): replicate heads, shard MLP only.
        rules["heads"] = None
        rules["kv"] = None

    if cfg.family == "moe":
        if cfg.num_experts % mesh.shape["model"] == 0:
            pass  # EP (experts -> model), the default rule table
        else:
            # too few experts for the TP width (mixtral 8 < 16): replicate
            # the expert axis and TP-shard inside each expert's FFN.
            rules["experts"] = None
            rules["expert_mlp"] = "model"
    return rules
