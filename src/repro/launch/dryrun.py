import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks the device count on first
# initialization, and the dry-run (and ONLY the dry-run) needs 512
# placeholder host devices to build the production mesh.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real step function (train_step with
optimizer update / prefill forward / decode step) against ShapeDtypeStruct
inputs under the production mesh, proving the sharding config is coherent
end-to-end, then extracts:

  * memory_analysis()  — per-device bytes (does it fit 16G HBM v5e)
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms
  * collective bytes   — parsed from the post-SPMD HLO text per op kind

Results are printed and dumped as JSON under experiments/dryrun/ for
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod both|single|multi]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs, supports_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Model, axis_rules, logical_to_sharding
from repro.models.sharding import sanitize_shardings, spec_for
from repro.training import build_train_step
from repro.training.optimizer import adamw
from repro.training.schedule import warmup_cosine
from repro.training.train_state import TrainState

from .mesh import make_production_mesh, rules_for

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (per chip) — §Roofline
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_CURLY_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_CURLY_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(1, len(ids))
    return 1


def parse_collectives(hlo_text: str) -> dict:
    """Per-device operand bytes of every collective in the compiled HLO.

    Post-optimization HLO prints operands by name only, so per-op operand
    bytes are derived from the RESULT shape and the replica-group size:
      all-reduce / all-to-all / collective-permute: operand == result
      all-gather:      operand = result / group_size
      reduce-scatter:  operand = result * group_size
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            if f" {kind}(" not in line and f" {kind}-start(" not in line:
                continue
            # result shape: first dtype[dims] after "= " (tuples: sum parts)
            eq = line.find("= ")
            if eq < 0:
                continue
            op_pos = line.find(f" {kind}", eq)   # the op, not its %name
            head = line[eq + 2 : op_pos]
            result_bytes = sum(
                _shape_bytes(m.group(1), m.group(2))
                for m in _SHAPE_RE.finditer(head)
                if m.group(1) in _DTYPE_BYTES
            )
            g = _group_size(line)
            if kind == "all-gather":
                op_bytes = result_bytes // max(1, g)
            elif kind == "reduce-scatter":
                op_bytes = result_bytes * g
            else:
                op_bytes = result_bytes
            out[kind]["count"] += 1
            out[kind]["bytes"] += op_bytes
            break
    out["total_bytes"] = sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict)
    )
    return out


# ---------------------------------------------------------------------------
# step construction per shape kind
# ---------------------------------------------------------------------------

def _batch_shardings(specs: dict, mesh, rules):
    out = {}
    for k, v in specs.items():
        if k in ("tokens", "targets"):
            axes = ("batch", "seq") if v.ndim == 2 else ("batch",)
        elif k == "pos":
            axes = ("batch",)
        elif k == "patch_embeds":
            axes = ("batch", "patches", "embed")
        elif k == "frames":
            axes = ("batch", "frames", "embed")
        else:
            axes = (None,) * v.ndim
        out[k] = NamedSharding(mesh, spec_for(axes))
    return out


def _cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
        ),
        tree,
    )


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               extra_rules: dict | None = None):
    """Returns (lower_fn) -> lowered for one dry-run cell."""
    rules = rules_for(cfg, shape, mesh)
    if extra_rules:
        rules.update(extra_rules)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)

    # NOTE: the returned lower() closures re-enter axis_rules — tracing
    # happens at .lower() time, and constrain() must see the mesh then.
    with axis_rules(mesh, rules):
        param_shapes, axes = model.abstract_init(key)
        param_sh = logical_to_sharding(axes, mesh, rules)
        param_sh = sanitize_shardings(param_shapes, param_sh, mesh)
        in_specs = input_specs(cfg, shape)
        batch_sh = _batch_shardings(in_specs, mesh, rules)

        if shape.kind == "train":
            moments = (
                jnp.bfloat16 if cfg.param_count() > 100e9 else jnp.float32
            )
            optimizer = adamw(moments_dtype=moments)
            lr_fn = warmup_cosine(3e-4, 100, 10_000)
            step = build_train_step(model, optimizer, lr_fn)
            state_shapes = jax.eval_shape(
                lambda p: TrainState.create(p, optimizer), param_shapes
            )
            # moments follow the param shardings; step/count replicated
            from repro.training.optimizer import AdamWState
            rep = NamedSharding(mesh, P())
            state_sh = TrainState(
                step=rep, params=param_sh,
                opt_state=AdamWState(mu=param_sh, nu=param_sh, count=rep),
                ef_buffers=None,
            )

            def lower():
                with axis_rules(mesh, rules):
                    return jax.jit(
                        step,
                        in_shardings=(state_sh, batch_sh),
                        out_shardings=(state_sh, None),
                        donate_argnums=0,
                    ).lower(state_shapes, in_specs)

            return lower, model

        # serving cells run bf16 params
        serve_params = _cast_tree(param_shapes, jnp.bfloat16)

        if shape.kind == "prefill":
            def fwd(params, batch):
                kw = {}
                if cfg.family == "vlm":
                    kw["patch_embeds"] = batch["patch_embeds"]
                if cfg.family == "encdec":
                    kw["frames"] = batch["frames"]
                out = model.forward(params, batch["tokens"], last_only=True,
                                    **kw)
                return out.logits

            def lower():
                with axis_rules(mesh, rules):
                    return jax.jit(
                        fwd,
                        in_shardings=(param_sh, batch_sh),
                    ).lower(serve_params, in_specs)

            return lower, model

        # decode
        state_shapes = jax.eval_shape(
            lambda: model.init_decode_state(shape.global_batch, shape.seq_len)
        )
        state_axes = model.decode_state_axes()
        state_sh = logical_to_sharding(state_axes, mesh, rules)
        state_sh = sanitize_shardings(state_shapes, state_sh, mesh)

        def decode(params, state, batch):
            return model.decode_step(params, state, batch["tokens"],
                                     batch["pos"])

        def lower():
            with axis_rules(mesh, rules):
                return jax.jit(
                    decode,
                    in_shardings=(param_sh, state_sh, batch_sh),
                    out_shardings=(None, state_sh),
                    donate_argnums=1,
                ).lower(serve_params, state_shapes, in_specs)

        return lower, model


# ---------------------------------------------------------------------------
# cost probes — exact FLOPs/bytes/collectives despite scanned layers
# ---------------------------------------------------------------------------
# XLA's HLO cost analysis counts a while-loop body ONCE, not x trip count,
# so the scanned production step under-reports layer costs. We therefore
# compile small UNROLLED probes (2 and 4 layers, all scans unrolled) at the
# full production shapes and extrapolate linearly: identical layers make
# cost(L) = a + b*L exact. zamba2 has two layer species (mamba + shared
# attn block), so it gets a third probe to separate the two slopes.

def _probe_cfgs(cfg: ModelConfig) -> list[tuple[ModelConfig, dict]]:
    base = cfg.scaled(scan_layers=False, attn_unroll=True)
    if cfg.family == "hybrid":
        return [
            (base.scaled(num_layers=2, attn_every=1), {"m": 2, "s": 2}),
            (base.scaled(num_layers=4, attn_every=1), {"m": 4, "s": 4}),
            (base.scaled(num_layers=4, attn_every=2), {"m": 4, "s": 2}),
        ]
    if cfg.family == "encdec":
        return [
            (base.scaled(num_layers=2, encoder_layers=2), {"l": 2}),
            (base.scaled(num_layers=4, encoder_layers=4), {"l": 4}),
        ]
    return [
        (base.scaled(num_layers=2), {"l": 2}),
        (base.scaled(num_layers=4), {"l": 4}),
    ]


def _extrapolate(cfg: ModelConfig, samples: list[tuple[dict, float]]) -> float:
    """Solve the per-layer-species linear model and evaluate at full depth."""
    if cfg.family == "hybrid":
        (_, m1), (_, m2), (_, m3) = samples
        bs = (m2 - m3) / 2.0
        bm = (m2 - m1) / 2.0 - bs
        a = m1 - 2 * bm - 2 * bs
        n_shared = cfg.num_layers // cfg.attn_every
        return a + cfg.num_layers * bm + n_shared * bs
    (_, m1), (_, m2) = samples
    l1, l2 = samples[0][0]["l"], samples[1][0]["l"]
    # per-LAYER slope; grouped MoE (llama4) stays linear in layers because
    # each group is a fixed layer bundle (2 layers incl. 1 MoE).
    b = (m2 - m1) / (l2 - l1)
    a = m1 - l1 * b
    return a + cfg.num_layers * b


def probe_costs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                extra_rules: dict | None = None) -> dict:
    """Compile unrolled probes and extrapolate per-device costs."""
    samples = []
    for pcfg, meta in _probe_cfgs(cfg):
        lower, _ = build_cell(pcfg, shape, mesh, extra_rules)
        compiled = lower().compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        coll = parse_collectives(compiled.as_text())
        samples.append((meta, {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
        }))
    out = {}
    for key in ("flops", "bytes", "coll"):
        series = [(m, v[key]) for m, v in samples]
        out[key] = _extrapolate(cfg, series)
    return out


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def analyze(compiled, mesh, cfg: ModelConfig, shape: ShapeConfig,
            probe: dict | None = None) -> dict:
    chips = mesh.size
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if probe is not None:
        flops_dev = probe["flops"]
        bytes_dev = probe["bytes"]
    else:
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes", "peak_memory_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # CPU backend may not implement
        mem["error"] = str(e)

    coll = parse_collectives(compiled.as_text())
    coll_dev = probe["coll"] if probe is not None else coll["total_bytes"]

    flops_global = flops_dev * chips
    bytes_global = bytes_dev * chips
    coll_global = coll_dev * chips

    t_compute = flops_global / (chips * PEAK_FLOPS)
    t_memory = bytes_global / (chips * HBM_BW)
    t_coll = coll_global / (chips * ICI_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    # MODEL_FLOPS: 6·N·D for train (fwd+bwd), 2·N·D for single forward.
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens

    return {
        "chips": chips,
        "flops_per_device": flops_dev,
        "flops_global": flops_global,
        "bytes_per_device": bytes_dev,
        "bytes_global": bytes_global,
        "collectives": coll,
        "memory": mem,
        "roofline": {
            **terms,
            "bottleneck": bottleneck.replace("_s", ""),
            "model_flops": model_flops,
            "useful_flops_ratio": (
                model_flops / flops_global if flops_global else 0.0
            ),
        },
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, extra_rules: dict | None = None,
             cfg_override: ModelConfig | None = None) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

    ok, why = supports_shape(cfg, shape)
    if not ok:
        cell["status"] = "skipped"
        cell["reason"] = why
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fname = f"{arch}_{shape_name}_{mesh_name}.json"
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(cell, f, indent=1)
        return cell

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lower, _ = build_cell(cfg, shape, mesh, extra_rules)
        lowered = lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        probe = probe_costs(cfg, shape, mesh, extra_rules)
        cell.update(analyze(compiled, mesh, cfg, shape, probe=probe))
        cell["status"] = "ok"
        cell["lower_s"] = round(t_lower, 1)
        cell["compile_s"] = round(t_compile, 1)
        cell["probe_s"] = round(time.time() - t0 - t_lower - t_compile, 1)
    except Exception as e:
        cell["status"] = "FAILED"
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-2000:]
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_name}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(cell, f, indent=1)
    return cell


def _fmt_row(c: dict) -> str:
    if c["status"] != "ok":
        return (f"{c['arch']:26s} {c['shape']:12s} {c['mesh']:8s} "
                f"{c['status']}: {c.get('reason', c.get('error', ''))[:80]}")
    r = c["roofline"]
    return (
        f"{c['arch']:26s} {c['shape']:12s} {c['mesh']:8s} ok "
        f"comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
        f"coll={r['collective_s']:.3e}s bound={r['bottleneck']:4s} "
        f"useful={r['useful_flops_ratio']:.2f} "
        f"[{c['compile_s']:.0f}s compile]"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS

    archs = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = (
        list(SHAPES) if args.all or args.shape is None else [args.shape]
    )
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multipod
    ]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                c = run_cell(arch, shape, mp, out_dir=args.out)
                print(_fmt_row(c), flush=True)
                results.append(c)
    n_ok = sum(1 for c in results if c["status"] == "ok")
    n_skip = sum(1 for c in results if c["status"] == "skipped")
    n_fail = sum(1 for c in results if c["status"] == "FAILED")
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
