"""Fig. 3 + Fig. 4 reproduction: block-nnz distribution across the corpus
and per-thread-block load stddev before/after pq balancing."""
from __future__ import annotations

import numpy as np

from repro.core import partition_coo
from repro.core.balance import tb_load_stddev
from repro.core.blocking import block_nnz_histogram
from repro.core.formats import super_sparse_fraction
from repro.data import matrices


def run(scale="small") -> dict:
    hist_total = np.zeros(8, np.int64)
    sub_total = np.zeros(4, np.int64)
    frac = []
    stds = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        part = partition_coo(r, c, v, shape, 16)
        hist_total += block_nnz_histogram(part.nnz_per_blk, 16, bins=8)
        edges = np.array([0.5, 8, 16, 24, 32])
        sub, _ = np.histogram(part.nnz_per_blk, bins=edges)
        sub_total += sub
        frac.append(super_sparse_fraction(part.nnz_per_blk, 16))
        naive, bal = tb_load_stddev(part.nnz_per_blk)
        stds.append((spec.name, naive, bal))
    return {"hist8": hist_total, "sub4": sub_total,
            "super_sparse_fraction": float(np.mean(frac)), "stds": stds}


def main(scale="small"):
    res = run(scale)
    total = res["hist8"].sum()
    print("fig3a: block-nnz histogram (ranges of 32, share of blocks)")
    for i, h in enumerate(res["hist8"]):
        print(f"  {i * 32 + 1}-{(i + 1) * 32}: {h / total:.3f}")
    sub = res["sub4"]
    print("fig3b: 1-32 subdivision (1-8, 9-16, 17-24, 25-32):",
          [f"{x / max(1, sub.sum()):.3f}" for x in sub])
    print(f"super-sparse fraction (paper: 0.819 avg): "
          f"{res['super_sparse_fraction']:.3f}")
    print("fig4: TB-load stddev naive -> balanced")
    for name, naive, bal in res["stds"]:
        print(f"  {name}: {naive:.1f} -> {bal:.1f}")
    return res


if __name__ == "__main__":
    main()
