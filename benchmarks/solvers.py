"""Iterative solvers on the CB engine vs a scipy.sparse CPU reference.

Per matrix of the SPD corpus: time-per-iteration and time-to-1e-6 of the
jit-native CG/BiCGStab solvers (single trace, batched super-block matvec)
against ``scipy.sparse.linalg`` on CSR with the *same* preconditioner and
stopping rule — plus the fig. 12 overhead story extended to solves: the
preprocessing amortization curve (what fraction of end-to-end time the
CB plan costs after k iterations) and the break-even iteration count.

Machine-independent guard signal (scripts/bench_guard.py): the
``t_per_iter / t_ref_per_iter`` ratio, geomean'd across rows — both
sides run on the same box, so machine speed cancels.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import CBMatrix
from repro.data import matrices
from repro.solvers import (
    CBLinearOperator, bicgstab, block_jacobi, cg, jacobi,
)

TOL = 1e-6


def _csr(rows, cols, vals, shape):
    import scipy.sparse as sp

    return sp.csr_matrix(
        (vals.astype(np.float32), (rows, cols)), shape=shape
    )


def _ref_solve(kind, A_csr, b, M_apply):
    """scipy CG/BiCGStab with iteration counting; returns (iters, t_total)."""
    import scipy.sparse.linalg as spla

    n = A_csr.shape[0]
    M = spla.LinearOperator((n, n), matvec=M_apply, dtype=np.float32)
    fn = {"cg": spla.cg, "bicgstab": spla.bicgstab}[kind]

    def run():
        count = [0]
        _x, info = fn(A_csr, b, rtol=TOL, atol=0.0, maxiter=500, M=M,
                      callback=lambda *_: count.__setitem__(0, count[0] + 1))
        return count[0], info

    iters, info = run()  # warm caches
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        iters, info = run()
        best = min(best, time.perf_counter() - t0)
    return iters, best, info == 0


def _time_solve(solve, *args, **kwargs):
    """Min of individually-timed solves (compile excluded) — robust to
    scheduler noise at the handful-of-iterations scale of the small
    corpus, where a single sample can jitter several-fold."""
    res = solve(*args, **kwargs)
    res.x.block_until_ready()  # compile outside the timed region
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        res = solve(*args, **kwargs)
        res.x.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return res, best


def main(scale: str = "small") -> list[dict]:
    rows_out = []
    rng = np.random.default_rng(0)
    impl = "reference"  # the CPU production lowering; pallas needs real TPU

    cases = [("cg", spec, r, c, v, shape)
             for spec, r, c, v, shape in matrices.spd_corpus(scale)]
    # one nonsymmetric system for the BiCGStab path
    d = 256 if scale == "small" else 4096
    rns, cns, vns = matrices.banded(d, d, bandwidth=9, fill=0.8, seed=3)
    diag = np.arange(d)
    rows_ns = np.concatenate([rns, diag])
    cols_ns = np.concatenate([cns, diag])
    vals_ns = np.concatenate([vns, np.full(d, 10.0)])
    cases.append(("bicgstab", matrices.MatrixSpec(f"banded_ns_{d}", "banded",
                                                  d, d),
                  rows_ns, cols_ns, vals_ns, (d, d)))

    for kind, spec, r, c, v, shape in cases:
        v32 = v.astype(np.float32)
        t0 = time.perf_counter()
        cb = CBMatrix.from_coo(r, c, v32, shape, block_size=16,
                               val_dtype=np.float32)
        op = CBLinearOperator.from_cb(cb)
        M = block_jacobi(cb) if kind == "cg" else jacobi(cb)
        t_setup = time.perf_counter() - t0

        b = rng.standard_normal(shape[0]).astype(np.float32)
        solve = cg if kind == "cg" else bicgstab
        res, t_total = _time_solve(solve, op, jnp.asarray(b), M, tol=TOL,
                                   maxiter=500, impl=impl)
        iters = int(res.iterations)
        t_per_iter = t_total / max(iters, 1)

        inv_blocks = np.asarray(M.inv_blocks) if kind == "cg" else None

        def m_apply(x, inv_blocks=inv_blocks, M=M):
            if inv_blocks is None:
                return np.asarray(M.inv_diag) * x
            mb, B, _ = inv_blocks.shape
            xp = np.pad(x, (0, mb * B - len(x))).reshape(mb, B)
            return np.einsum("brc,bc->br", inv_blocks,
                             xp).reshape(-1)[: len(x)].astype(np.float32)

        ref_iters, t_ref, ref_ok = _ref_solve(kind, _csr(r, c, v32, shape), b,
                                              m_apply)
        if not ref_ok:
            raise RuntimeError(
                f"scipy {kind} did not converge on {spec.name} — the "
                f"t_ref_per_iter guard baseline would be meaningless"
            )
        t_ref_per_iter = t_ref / max(ref_iters, 1)

        amortize = t_setup / max(t_per_iter, 1e-12)
        curve = [[k, t_setup / (t_setup + k * t_per_iter)]
                 for k in (1, 10, 100, 1000, 10000)]
        row = {
            "matrix": spec.name,
            "solver": kind,
            "n": int(shape[0]),
            "nnz": int(cb.nnz),
            "group_size": int(op.group_size),
            "iters_to_tol": iters,
            "iters_ref": int(ref_iters),
            "converged": bool(res.converged),
            "residual": float(res.residual),
            "t_setup": t_setup,
            "t_to_tol": t_total,
            "t_per_iter": t_per_iter,
            "t_ref_per_iter": t_ref_per_iter,
            "amortize_break_even_iters": amortize,
            "amortization_curve": curve,
        }
        rows_out.append(row)
        print(f"  {spec.name:>16} {kind:>8}: {iters:3d} iters "
              f"(ref {ref_iters:3d}), {t_per_iter * 1e6:8.0f} us/iter "
              f"(ref {t_ref_per_iter * 1e6:8.0f}), "
              f"setup amortized after {amortize:.0f} iters", flush=True)
    return rows_out


if __name__ == "__main__":
    main()
