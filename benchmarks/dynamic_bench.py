"""Dynamic sparsity: value churn through ``with_values`` vs full rebuilds.

The fast path's two claims, measured over a 16-step value-churn loop per
corpus matrix (structure fixed, fresh nonzero values each step — the
evolving-weights regime of ``solvers.EvolvingPageRank`` and the sparse
training refreeze):

  * **update beats rebuild** — rewriting the operator's stream payloads
    through the recorded value-scatter updaters (``with_values``) must
    cost a small fraction of rebuilding the CB matrix + streams from COO
    (``from_coo`` + ``from_cb``): the guard bounds geomean
    t_update/t_rebuild <= 0.25. The honest comparison: both sides
    produce the complete forward super-block streams for the new values.
  * **the plan survives** — re-planning each churn step through one
    per-matrix ``PlanCache`` hits the structure-keyed entry for every
    step after the first: plan_hit_rate >= 0.9 (15/16 = 0.9375 when the
    split hash works; the v1 value-coupled hash scored 0/16 here).

``streams_match`` asserts the fast path is not approximating: the
updater-rewritten streams must be bit-identical to the rebuilt ones on
every audited step.

Timings are host-side (preprocessing cost, not kernel time), so the
guard only tracks the machine-independent update/rebuild *ratio*.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.autotune import PlanCache, SearchSettings
from repro.core import CBMatrix
from repro.data import matrices
from repro.solvers import CBLinearOperator

from ._timing import geomean

CHURN_STEPS = 16
DETERMINISTIC = SearchSettings(mode="heuristic")


def _host_time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _tree_equal(a, b) -> bool:
    import jax

    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def run(scale="small") -> list[dict]:
    rows_out = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        v32 = v.astype(np.float32)
        cb = CBMatrix.from_coo(r, c, v32, shape, block_size=16,
                               val_dtype=np.float32)
        op = CBLinearOperator.from_cb(cb, updatable=True)
        rows_c, cols_c, _ = cb.to_coo()
        count = cb.value_layout().count
        rng = np.random.default_rng(1)

        t_update = float("inf")
        t_rebuild = float("inf")
        streams_match = True
        with tempfile.TemporaryDirectory(prefix="cb-dyn-cache-") as d:
            cache = PlanCache(d)
            for step in range(CHURN_STEPS):
                sign = np.where(rng.random(count) < 0.5, -1.0, 1.0)
                vals = (rng.uniform(0.5, 2.0, count) * sign).astype(
                    np.float32)
                # the per-step re-plan: structure unchanged -> cache hit
                CBMatrix.plan_for(rows_c, cols_c, vals, shape, cache=cache,
                                  settings=DETERMINISTIC)
                box = {}
                t_update = min(t_update, _host_time(
                    lambda: box.setdefault("up", op.with_values(vals))
                ))
                if step % 4 == 0:  # rebuilds are the slow side; sample them
                    t_rebuild = min(t_rebuild, _host_time(
                        lambda: box.setdefault("rb", CBLinearOperator.from_cb(
                            CBMatrix.from_coo(rows_c, cols_c, vals, shape,
                                              block_size=16,
                                              val_dtype=np.float32)))
                    ))
                    streams_match = streams_match and _tree_equal(
                        box["up"].streams, box["rb"].streams
                    )
            hit_rate = cache.hit_rate

        rows_out.append({
            "matrix": spec.name,
            "nnz": int(cb.nnz),
            "churn_steps": CHURN_STEPS,
            "t_update": t_update,
            "t_rebuild": t_rebuild,
            "update_rebuild_ratio": t_update / max(t_rebuild, 1e-12),
            "plan_hit_rate": hit_rate,
            "streams_match": bool(streams_match),
        })
    return rows_out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,churn_steps,t_update,t_rebuild,ratio,"
          "plan_hit_rate,streams_match")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['churn_steps']},"
              f"{r['t_update']*1e3:.3f}ms,{r['t_rebuild']*1e3:.3f}ms,"
              f"{r['update_rebuild_ratio']:.3f},"
              f"{r['plan_hit_rate']:.3f},{int(r['streams_match'])}")
    g = geomean([r["update_rebuild_ratio"] for r in rows])
    print(f"GEOMEAN update/rebuild: {g:.3f}x "
          f"(guard bound 0.25); plan hit rate "
          f"{rows[0]['plan_hit_rate']:.3f} (bound 0.9)")
    return rows


if __name__ == "__main__":
    main()
