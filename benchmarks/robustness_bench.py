"""Robustness smoke: injected-fault detection + solver fallback recovery.

Exercises every injector in ``repro.runtime.faults`` against the live
stack and reports one row per (matrix, fault case). The contract the
guard enforces (``benchmarks/registry.py``): every row ``ok`` and every
per-case ``rate`` exactly 1.0 — a fault is *detected with a typed
reason* from ``repro.errors`` or *tolerated with a correct result*;
``robust_solve`` recovers every seeded breakdown case plain CG fails on
the (indefinitely-perturbed) SPD corpus.

All checks are deterministic (seeded injectors, reference kernels), so
"rate" is a real acceptance bar, not a flaky statistic.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import errors
from repro.autotune import Plan, PlanCache
from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.core import CBMatrix
from repro.data import matrices
from repro.models.model import Model
from repro.runtime import (
    FlakyStepFn,
    HeartbeatMonitor,
    RestartPolicy,
    corrupt_packed_values,
    flip_file_bytes,
    lose_host,
    run_supervised,
)
from repro.serving import Request, ServingEngine
from repro.solvers import CBLinearOperator, SolverStatus, cg, robust_solve

FLIP_SEEDS = 5
PLAN_FLIP_SEEDS = 10


def _rate_row(matrix: str, case: str, hits: int, total: int) -> dict:
    rate = hits / total if total else 0.0
    return {"matrix": matrix, "case": case, "ok": rate == 1.0, "rate": rate,
            "trials": total}


def _artifact_byteflip(name: str, cb: CBMatrix) -> dict:
    """Byte-flipped npz: ArtifactError or a bit-correct load, always."""
    dense = cb.to_dense()
    good = 0
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.npz")
        for seed in range(FLIP_SEEDS):
            cb.save(path)
            flip_file_bytes(path, n=8, seed=seed)
            try:
                loaded = CBMatrix.load(path)
            except errors.ArtifactError:
                good += 1
            else:
                good += int(np.array_equal(loaded.to_dense(), dense))
    return _rate_row(f"{name}/artifact_byteflip", "byte-flipped npz "
                     "detected or bit-correct", good, FLIP_SEEDS)


def _plan_corruption(name: str, plan: Plan) -> dict:
    """Byte-flipped plan file: exactly one counted miss/hit, never a crash,
    and any returned plan equals the original."""
    good = 0
    with tempfile.TemporaryDirectory() as d:
        cache = PlanCache(d)
        for seed in range(PLAN_FLIP_SEEDS):
            cache.put(plan)
            flip_file_bytes(cache.path_for(plan.structure_hash),
                            n=1, seed=seed)
            before = cache.hits + cache.misses
            try:
                got = cache.get(plan.structure_hash,
                                shape=plan.shape, nnz=plan.nnz)
            except Exception:
                continue                     # a crash is a failed trial
            counted_once = cache.hits + cache.misses == before + 1
            benign = got is None or got == plan
            good += int(counted_once and benign)
    return _rate_row(f"{name}/plan_corruption", "plan byte-flip = one "
                     "counted lookup, never wrong", good, PLAN_FLIP_SEEDS)


def _nonfinite_policy(name: str, r, c, v, shape) -> dict:
    poisoned = np.array(v, np.float32)
    poisoned[0] = np.nan
    try:
        CBMatrix.from_coo(r, c, poisoned, shape, block_size=16,
                          val_dtype=np.float32)
        hits = 0
    except errors.NonFiniteError:
        hits = 1
    return _rate_row(f"{name}/nonfinite_payload",
                     "NaN payload rejected at from_coo", hits, 1)


def _corrupt_payload_solver(name: str, cb: CBMatrix, b) -> dict:
    bad = CBLinearOperator.from_cb(corrupt_packed_values(cb, n=3, seed=0))
    res = cg(bad, b, tol=1e-6, maxiter=100, impl="reference")
    ok = int(res.status) == SolverStatus.NONFINITE
    return _rate_row(f"{name}/corrupt_payload_solver",
                     "NaN stream payload flagged NONFINITE in-loop",
                     int(ok), 1)


def _poisoned_rhs(name: str, op, d: int) -> dict:
    try:
        robust_solve(op, jnp.full(d, np.nan, jnp.float32), impl="reference")
        hits = 0
    except errors.NonFiniteError:
        hits = 1
    return _rate_row(f"{name}/poisoned_rhs",
                     "non-finite rhs rejected with typed reason", hits, 1)


def _solver_fallback(name: str, r, c, v, shape) -> dict:
    """Negate one diagonal entry: plain CG must fail, robust_solve must
    recover through the fallback chain."""
    d = shape[0]
    dense = np.zeros(shape, np.float32)
    np.add.at(dense, (r, c), v.astype(np.float32))
    rr, cc = np.nonzero(dense)
    vv = dense[rr, cc].copy()
    vv[(rr == d - 1) & (cc == d - 1)] = -50.0
    cb = CBMatrix.from_coo(rr, cc, vv, shape, block_size=16,
                           val_dtype=np.float32)
    op = CBLinearOperator.from_cb(cb)
    b = jnp.asarray(
        np.random.default_rng(0).standard_normal(d).astype(np.float32))
    plain = cg(op, b, tol=1e-6, maxiter=300, impl="reference")
    res = robust_solve(op, b, tol=1e-6, maxiter=300, impl="reference")
    ok = (not bool(plain.converged)) and res.converged
    row = _rate_row(f"{name}/solver_fallback",
                    "robust_solve recovers seeded CG breakdown", int(ok), 1)
    row["fallback_solver"] = res.solver
    row["attempts"] = len(res.attempts)
    return row


def _tiny_model():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
                      attn_chunk=32, remat="none", dtype="float32")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def _serving_tick_retry() -> dict:
    model, params = _tiny_model()
    prompt = np.array([3, 14, 15], np.int32)
    ref = ServingEngine(model, params, slots=2, max_len=64)
    ref.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    baseline = ref.run_until_done()[0].generated

    eng = ServingEngine(model, params, slots=2, max_len=64,
                        max_step_retries=2, sleep=lambda s: None)
    eng.step_fn = FlakyStepFn(eng.step_fn, fail_on={1, 3})
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    out = eng.run_until_done()[0].generated
    ok = out == baseline and eng.retries == 2
    return _rate_row("serving/tick_retry",
                     "retried ticks bit-identical to fault-free", int(ok), 1)


def _heartbeat_loss() -> dict:
    clock = [0.0]
    mon = HeartbeatMonitor(num_hosts=4, timeout_s=10.0,
                           clock=lambda: clock[0])
    clock[0] = 5.0
    for h in range(4):
        mon.heartbeat(0, host_id=h)
    lose_host(mon, 2)
    ok = mon.check() == [2] and mon.alive_hosts == [0, 1, 3]
    return _rate_row("hosts/heartbeat_loss",
                     "silent host detected by monitor", int(ok), 1)


def _checkpoint_restart() -> dict:
    def step(state, step_idx):
        return state * 2 + step_idx

    with tempfile.TemporaryDirectory() as d:
        ckpt = Checkpointer(d, async_write=False)
        mon = HeartbeatMonitor(num_hosts=1, timeout_s=1e9, clock=lambda: 0.0)
        policy = RestartPolicy(ckpt, mon, max_restarts=3)
        injected = run_supervised(
            FlakyStepFn(step, fail_on={5}), np.asarray(1, np.int64),
            num_steps=8, checkpointer=ckpt, policy=policy,
            checkpoint_every=2)
    fault_free = np.asarray(1, np.int64)
    for i in range(8):
        fault_free = step(fault_free, i)
    ok = int(injected) == int(fault_free) and policy.restarts == 1
    return _rate_row("supervisor/checkpoint_restart",
                     "failed step replays bit-identically", int(ok), 1)


def main(scale: str = "small") -> list[dict]:
    rows = []
    for spec, r, c, v, shape in matrices.spd_corpus("small"):
        cb = CBMatrix.from_coo(r, c, v.astype(np.float32), shape,
                               block_size=16, val_dtype=np.float32)
        op = CBLinearOperator.from_cb(cb)
        b = jnp.asarray(np.random.default_rng(1)
                        .standard_normal(shape[0]).astype(np.float32))
        rows.append(_artifact_byteflip(spec.name, cb))
        rows.append(_nonfinite_policy(spec.name, r, c, v, shape))
        rows.append(_corrupt_payload_solver(spec.name, cb, b))
        rows.append(_poisoned_rhs(spec.name, op, shape[0]))
        rows.append(_solver_fallback(spec.name, r, c, v, shape))

    plan = Plan(
        structure_hash="b" * 64, shape=(192, 192), nnz=100,
        val_dtype="float32", block_size=16, th0=0.15, th1=4, th2=32,
        colagg=False, group_size=4, mode="heuristic",
        predicted_padded_elems=10, predicted_steps=2,
        measured_padded_elems=10, measured_steps=2,
    )
    rows.append(_plan_corruption("plan_cache", plan))
    rows.append(_serving_tick_retry())
    rows.append(_heartbeat_loss())
    rows.append(_checkpoint_restart())
    return rows
