"""Locality: the cache-friendliness claim on the real planned pipeline.

The paper's Fig. 10 argument — CB's one-contiguous-region-per-block
layout touches fewer, denser cache lines than CSR/BSR/TileSpMV — tested
where it actually matters: on the **planned super-block streams** the
batched engine executes (PR 2's layouts under PR 5's per-matrix plans),
not on the seed's flat format walk. Per corpus matrix:

  * plan the matrix (heuristic mode: bit-deterministic), build the
    super streams, derive the byte-access stream from the real stream
    metadata (``obs.locality.access_stream_super``), and model L1/L2
    LRU hit rates / misses-per-nnz with the vectorized reuse-distance
    engine — no per-access Python loop, no nnz cap;
  * the same model over the flat CSR/BSR/TileSpMV streams at matching
    element width (float32) is the competitor baseline; the row's
    ``*_baseline`` columns are the per-matrix geomean of the three.

Guard (registry ``geomean_max``): the corpus geomean of CB-over-
baseline misses/nnz stays <= 0.85 at both cache levels — the paper's
ordering claim with margin. Individual matrices may lose (a perfectly
banded pattern streams near-optimally in CSR while CB pays block
padding); the corpus-level geomean is the claim.

Every column is pure shape/index arithmetic: deterministic across
machines and identical with obs enabled or disabled. Corpus-level
aggregates are published as ``repro.locality.*`` gauges so ``run.py
--json`` snapshots (and the bench history) carry them.
"""
from __future__ import annotations

import numpy as np

from repro import obs
from repro.autotune import SearchSettings
from repro.core import CBMatrix
from repro.core.streams import build_super_streams
from repro.data import matrices
from repro.obs import locality as loc

from . import formats as F
from ._timing import geomean

DETERMINISTIC = SearchSettings(mode="heuristic")

COMPETITORS = ("csr", "bsr", "tile")


def _flat_stream(name: str, r, c, v, shape):
    gen = {"csr": F.access_stream_csr, "bsr": F.access_stream_bsr,
           "tile": F.access_stream_tile}[name]
    lines, _ = gen(r, c, v, shape, vbytes=4)  # float32, like the planned build
    return np.asarray(lines)


def run(scale="small") -> list[dict]:
    rows_out = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        nnz = len(v)
        v32 = v.astype(np.float32)
        plan = CBMatrix.plan_for(r, c, v32, shape, settings=DETERMINISTIC)
        cb = CBMatrix.from_plan(r, c, v32, shape, plan)
        streams = build_super_streams(cb, group_size=plan.group_size)

        stats = {"cb": loc.stream_stats(
            loc.access_stream_super(streams), nnz=nnz)}
        for name in COMPETITORS:
            stats[name] = loc.stream_stats(
                _flat_stream(name, r, c, v, shape), nnz=nnz)

        row = {
            "matrix": spec.name,
            "nnz": nnz,
            "block_size": int(plan.block_size),
            "group_size": int(plan.group_size),
            "accesses_cb": stats["cb"]["accesses"],
            "bytes_moved_cb": stats["cb"]["bytes_moved"],
            "arith_intensity_cb": stats["cb"]["arith_intensity"],
        }
        for name, st in stats.items():
            row[f"l1_hit_{name}"] = st["l1_hit_rate"]
            row[f"l2_hit_{name}"] = st["l2_hit_rate"]
            row[f"l1_misses_per_nnz_{name}"] = st["l1_misses_per_nnz"]
            row[f"l2_misses_per_nnz_{name}"] = st["l2_misses_per_nnz"]
            row[f"unique_lines_{name}"] = st["unique_lines"]
        for lvl in ("l1", "l2"):
            row[f"{lvl}_misses_per_nnz_baseline"] = geomean(
                [max(row[f"{lvl}_misses_per_nnz_{n}"], 1e-12)
                 for n in COMPETITORS])
        rows_out.append(row)

    # corpus-level aggregates on the obs registry (gauges: a re-run
    # reports the current state, it must not accumulate)
    for lvl in ("l1", "l2"):
        for name in ("cb",) + COMPETITORS:
            obs.gauge("repro.locality.misses_per_nnz").set(
                geomean([max(r[f"{lvl}_misses_per_nnz_{name}"], 1e-12)
                         for r in rows_out]),
                format=name, level=lvl)
        obs.gauge("repro.locality.cb_vs_baseline").set(
            geomean([max(r[f"{lvl}_misses_per_nnz_cb"], 1e-12)
                     / r[f"{lvl}_misses_per_nnz_baseline"]
                     for r in rows_out]),
            level=lvl)
    obs.gauge("repro.locality.arith_intensity").set(
        geomean([max(r["arith_intensity_cb"], 1e-12) for r in rows_out]),
        format="cb")
    return rows_out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,B,G,l1miss/nnz cb|base,l2miss/nnz cb|base,"
          "l1hit_cb,l2hit_cb,AI_cb")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['block_size']},"
              f"{r['group_size']},"
              f"{r['l1_misses_per_nnz_cb']:.4f}|"
              f"{r['l1_misses_per_nnz_baseline']:.4f},"
              f"{r['l2_misses_per_nnz_cb']:.4f}|"
              f"{r['l2_misses_per_nnz_baseline']:.4f},"
              f"{r['l1_hit_cb']:.3f},{r['l2_hit_cb']:.3f},"
              f"{r['arith_intensity_cb']:.2f}")
    for lvl in ("l1", "l2"):
        g = geomean([max(r[f"{lvl}_misses_per_nnz_cb"], 1e-12)
                     / r[f"{lvl}_misses_per_nnz_baseline"] for r in rows])
        print(f"GEOMEAN {lvl} cb/baseline misses-per-nnz: {g:.3f}x "
              f"(<1 = CB touches fewer lines per element)")
    return rows


if __name__ == "__main__":
    main()
