"""Fig. 12 reproduction: storage bytes + preprocessing time per format."""
from __future__ import annotations

import time

import numpy as np

from repro.core import CBMatrix
from repro.data import matrices

from . import formats as F


def run(scale="small") -> list[dict]:
    out = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        m, n = shape
        nnz = len(v)
        v64 = v.astype(np.float64)

        t0 = time.perf_counter()
        F.to_csr(r, c, v64, shape)
        t_csr = time.perf_counter() - t0

        t0 = time.perf_counter()
        ts = F.to_bsr(r, c, v64, shape, 16)
        t_bsr = time.perf_counter() - t0

        t0 = time.perf_counter()
        cb = CBMatrix.from_coo(r, c, v64, shape, block_size=16,
                               val_dtype=np.float64)
        t_cb = time.perf_counter() - t0

        # storage (paper §4.4.1 models: int32 idx, FP64 vals)
        csr_bytes = (m + 1) * 4 + nnz * 4 + nnz * 8
        nnzb = int((np.asarray(ts.brow) >= 0).sum())
        bsr_bytes = 256 * 8 * nnzb + (-(-m // 16) + 1) * 4 + nnzb * 4
        cb_bytes = cb.nbytes_structure()["total"]

        out.append({
            "matrix": spec.name, "nnz": nnz,
            "csr_bytes": csr_bytes, "bsr_bytes": bsr_bytes,
            "cb_bytes": cb_bytes,
            "t_pre_csr_ms": t_csr * 1e3, "t_pre_bsr_ms": t_bsr * 1e3,
            "t_pre_cb_ms": t_cb * 1e3,
        })
    return out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,cb_bytes/csr,cb_bytes/bsr,pre_cb_ms,pre_csr_ms,pre_bsr_ms")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},"
              f"{r['cb_bytes'] / r['csr_bytes']:.2f},"
              f"{r['cb_bytes'] / r['bsr_bytes']:.3f},"
              f"{r['t_pre_cb_ms']:.1f},{r['t_pre_csr_ms']:.1f},"
              f"{r['t_pre_bsr_ms']:.1f}")
    ratio = np.mean([r["cb_bytes"] / r["csr_bytes"] for r in rows])
    print(f"MEAN cb/csr storage ratio: {ratio:.2f} (paper: ~CSR parity)")
    return rows


if __name__ == "__main__":
    main()
