"""Fig. 11 reproduction: CB-I / CB-II / CB-III ablation.

  CB-I   = intra-block data aggregation only (all blocks COO, no column
           aggregation, naive block order)
  CB-II  = + column aggregation & format selection (§3.3)
  CB-III = + thread-block load balancing (§3.4)

Measured: jitted XLA wall-time per SpMV + the kernel-visible work model
(padded-lane elements each variant forces) + TB load imbalance.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CBMatrix, FormatThresholds
from repro.core.streams import build_streams
from repro.data import matrices
from repro.kernels import ops


def _time(fn, *args, reps=10):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def _variant(r, c, v, shape, stage: str) -> CBMatrix:
    if stage == "I":
        # aggregation only: force COO everywhere (th1=B*B), no colagg
        th = FormatThresholds(th0=1.1, th1=16 * 16, th2=16 * 16)
        return CBMatrix.from_coo(r, c, v, shape, block_size=16,
                                 val_dtype=np.float32, thresholds=th,
                                 use_column_aggregation=False)
    # II and III share format selection + auto colagg
    return CBMatrix.from_coo(r, c, v, shape, block_size=16,
                             val_dtype=np.float32,
                             use_column_aggregation="auto")


def kernel_work_model(cb: CBMatrix) -> int:
    """Padded elements the kernel streams actually process (lane waste)."""
    from repro.core.streams import build_streams as bs

    s = bs(cb)
    work = s.dense_tiles.shape[0] * cb.block_size * cb.block_size
    work += s.panel_vals.shape[0] * cb.block_size * s.panel_vals.shape[2]
    work += s.coo_codes.shape[0] * s.coo_codes.shape[1]
    return int(work)


def run(scale="small") -> list[dict]:
    out = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        v32 = v.astype(np.float32)
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal(shape[1]), jnp.float32
        )
        row = {"matrix": spec.name, "nnz": len(v)}
        for stage in ("I", "II"):
            cb = _variant(r, c, v32, shape, stage)
            st = build_streams(cb).device_put()
            fn = jax.jit(lambda s_, x_: ops.cb_spmv(s_, x_, impl="reference"))
            row[f"t_{stage}"] = _time(fn, st, x)
            row[f"work_{stage}"] = kernel_work_model(cb)
        # III: same structure as II + balance diagnostics (balance is
        # baked into from_coo; report the imbalance it removed)
        cb3 = _variant(r, c, v32, shape, "II")
        from repro.core.balance import tb_load_stddev

        real = cb3.nnz_per_blk[cb3.nnz_per_blk > 0]
        naive, balanced = tb_load_stddev(real)
        row["t_III"] = row["t_II"]
        row["tb_std_naive"] = naive
        row["tb_std_balanced"] = balanced
        row["speedup_II_over_I"] = row["t_I"] / row["t_II"]
        out.append(row)
    return out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,t_I_us,t_II_us,speedup_II/I,work_I,work_II,"
          "tb_std_naive,tb_std_balanced")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['t_I'] * 1e6:.1f},"
              f"{r['t_II'] * 1e6:.1f},{r['speedup_II_over_I']:.2f},"
              f"{r['work_I']},{r['work_II']},"
              f"{r['tb_std_naive']:.1f},{r['tb_std_balanced']:.1f}")
    geo = float(np.exp(np.mean(np.log([r["speedup_II_over_I"] for r in rows]))))
    print(f"GEOMEAN speedup II/I: {geo:.2f}")
    return rows


if __name__ == "__main__":
    main()
