"""Single data-driven registry of benchmark sections + their guard schemas.

One ``Section`` record per benchmark: the human title and runner module
consumed by ``benchmarks/run.py``, and the *declarative* guard schema
consumed by ``scripts/bench_guard.py`` — required row keys, per-row
minimum bounds, machine-independent timing-ratio pairs, keys that must
be ``True``, and geomean upper bounds between two row keys. PRs 2-4
each grew a copy-pasted per-section block in both files; new sections
now add exactly one record here.

This module is imported by the standalone guard script, so it must stay
dependency-free (no jax/numpy): runner modules are resolved lazily by
name via :func:`runner`.
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class Section:
    """One benchmark section and its guard contract."""

    title: str
    module: str                  # dotted module with a ``main(scale)`` entry
    # -- guard schema (all optional; empty = section is not guarded) ------
    required_keys: tuple = ()    # every row must carry these, finite
    timing_pairs: tuple = ()     # (num, den): relative drift vs baseline
    require_true: tuple = ()     # row keys that must be exactly True
    min_values: tuple = ()       # (key, bound): row[key] >= bound
    geomean_max: tuple = ()      # (num, den, bound): geomean(num/den) <= bound

    @property
    def guarded(self) -> bool:
        return bool(self.required_keys)


_BATCH_KEYS = (
    "matrix", "nnz", "group_size", "steps_unbatched", "steps_batched",
    "padded_elems_unbatched", "padded_elems_batched",
    "padded_ratio_unbatched", "padded_ratio_batched",
    "t_unbatched", "t_batched",
)

SECTIONS: dict[str, Section] = {
    "fig9": Section("Fig. 9 — SpMV perf vs CSR/COO/BSR",
                    "benchmarks.fig9_perf"),
    "fig10": Section("Fig. 10 — cache hit-rate model",
                     "benchmarks.fig10_locality"),
    "fig11": Section("Fig. 11 — ablation CB-I/II/III",
                     "benchmarks.fig11_ablation"),
    "fig12": Section("Fig. 12 — storage + preprocessing",
                     "benchmarks.fig12_overhead"),
    "fig34": Section("Fig. 3/4 — distribution + balance",
                     "benchmarks.fig34_distribution"),
    "spmv_batch": Section(
        "Batched super-block engine vs unbatched",
        "benchmarks.spmv_batch",
        required_keys=_BATCH_KEYS,
        timing_pairs=(("t_batched", "t_unbatched"),
                      ("t_ref_batched", "t_ref_unbatched")),
    ),
    # the SpMM section mirrors spmv_batch's schema exactly (same batched-
    # engine claims: step shrink, padded weight stream, kernel-path timing)
    "spmm": Section(
        "Batched SpMM super-tile engine vs flat tile stream",
        "benchmarks.spmm_batch",
        required_keys=_BATCH_KEYS,
        timing_pairs=(("t_batched", "t_unbatched"),
                      ("t_ref_batched", "t_ref_unbatched")),
    ),
    "solvers": Section(
        "Iterative solvers vs scipy.sparse CPU reference",
        "benchmarks.solvers",
        required_keys=("matrix", "solver", "n", "nnz", "iters_to_tol",
                       "iters_ref", "converged", "t_per_iter",
                       "t_ref_per_iter"),
        timing_pairs=(("t_per_iter", "t_ref_per_iter"),),
        require_true=("converged",),
    ),
    "autotune": Section(
        "Autotuned plans vs default constants (cost model + cache)",
        "benchmarks.autotune_bench",
        required_keys=(
            "matrix", "nnz", "block_size_planned", "group_size_planned",
            "steps_default", "steps_planned",
            "predicted_padded_elems", "predicted_steps",
            "padded_elems_default", "padded_elems_planned",
            "plan_hit_rate",
        ),
        min_values=(("plan_hit_rate", 0.5),),
        # the acceptance bar: tuned plans never regress padded work
        geomean_max=(("padded_elems_planned", "padded_elems_default", 1.0),),
    ),
    "dynamic": Section(
        "Dynamic sparsity: value churn via with_values vs rebuild",
        "benchmarks.dynamic_bench",
        required_keys=(
            "matrix", "nnz", "churn_steps", "t_update", "t_rebuild",
            "update_rebuild_ratio", "plan_hit_rate", "streams_match",
        ),
        timing_pairs=(("t_update", "t_rebuild"),),
        require_true=("streams_match",),
        # 15/16 churn steps must hit the structure-keyed plan cache
        min_values=(("plan_hit_rate", 0.9),),
        # the acceptance bar: payload rewrite at <= 1/4 of a full rebuild
        geomean_max=(("t_update", "t_rebuild", 0.25),),
    ),
    "obs": Section(
        "Observability: instrumentation overhead + accounting fidelity",
        "benchmarks.obs_bench",
        required_keys=(
            "matrix", "nnz", "t_enabled", "t_disabled", "overhead_ratio",
            "padded_elems_measured", "padded_elems_predicted",
            "steps_measured", "steps_predicted", "metrics_present",
        ),
        timing_pairs=(("t_enabled", "t_disabled"),),
        require_true=("metrics_present",),
        # the acceptance bars: recording costs <= 5% of the kernel path,
        # and the registry's measured totals stay inside the same 2x
        # cost-model envelope the autotune section holds predictions to
        geomean_max=(("t_enabled", "t_disabled", 1.05),
                     ("padded_elems_measured", "padded_elems_predicted", 2.0)),
    ),
    "locality": Section(
        "Locality: modeled cache traffic, planned CB vs flat formats",
        "benchmarks.locality_bench",
        required_keys=(
            "matrix", "nnz", "block_size", "group_size",
            "accesses_cb", "unique_lines_cb",
            "bytes_moved_cb", "arith_intensity_cb",
            "l1_hit_cb", "l2_hit_cb",
            "l1_misses_per_nnz_cb", "l2_misses_per_nnz_cb",
            "l1_misses_per_nnz_csr", "l2_misses_per_nnz_csr",
            "l1_misses_per_nnz_bsr", "l2_misses_per_nnz_bsr",
            "l1_misses_per_nnz_tile", "l2_misses_per_nnz_tile",
            "l1_misses_per_nnz_baseline", "l2_misses_per_nnz_baseline",
        ),
        # the paper's Fig. 10 ordering claim on the real planned
        # pipeline: corpus geomean of CB misses/nnz over the
        # CSR/BSR/tile geomean, with margin (0.75 at both levels today)
        geomean_max=(
            ("l1_misses_per_nnz_cb", "l1_misses_per_nnz_baseline", 0.85),
            ("l2_misses_per_nnz_cb", "l2_misses_per_nnz_baseline", 0.85),
        ),
    ),
    "robustness": Section(
        "Fault injection: typed detection + solver fallback recovery",
        "benchmarks.robustness_bench",
        required_keys=("matrix", "case", "ok", "rate"),
        require_true=("ok",),
        # the acceptance bar: every injected fault detected (or tolerated
        # with a bit-correct result) and every seeded breakdown recovered
        min_values=(("rate", 1.0),),
    ),
}


def runner(name: str):
    """Resolve a section's ``main(scale)`` runner (lazy import)."""
    return importlib.import_module(SECTIONS[name].module).main
