"""Batched super-block engine: padded-work + wall-time vs the unbatched path.

The batching PR's two measurable claims, per corpus matrix:

  * **padded-FLOP ratio** — elements the kernels actually stream divided
    by real nnz. The one-block-per-step stream pads every panel/COO row
    to the *global* max width; the super-block packer pads each block to
    its own width bucket and lane-packs groups, so one wide outlier no
    longer taxes the whole stream. Pure preprocessing arithmetic —
    deterministic, hardware-independent.
  * **per-call wall time of the kernel path** (``t_unbatched`` /
    ``t_batched``) — the Pallas engine end-to-end (interpret mode off
    TPU), where per-grid-step overhead is real and batching is designed
    to amortize it: G blocks per step means 1/G as many step dispatches
    and one fused combine. This is the guarded metric.

``t_ref_*`` columns record the same layouts through the pure-XLA
reference lowering (the CPU production fallback) for context: the flat
reference stays the default CPU path precisely because slot-granular
combines don't pay off under XLA's scalar scatter; compiled-TPU numbers
are a ROADMAP item.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CBMatrix
from repro.core.streams import build_streams, build_super_streams
from repro.data import matrices
from repro.kernels import ops

from ._timing import geomean, time_min


def run(scale="small", group_size=None) -> list[dict]:
    rows_out = []
    kernel = jax.jit(lambda s, x: ops.cb_spmv(s, x, impl="pallas"))
    reference = jax.jit(lambda s, x: ops.cb_spmv(s, x, impl="reference"))
    for spec, r, c, v, shape in matrices.corpus(scale):
        v32 = v.astype(np.float32)
        cb = CBMatrix.from_coo(r, c, v32, shape, block_size=16,
                               val_dtype=np.float32)
        flat = build_streams(cb)
        packed = build_super_streams(cb, group_size=group_size)
        flat_d, packed_d = flat.device_put(), packed.device_put()
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal(shape[1]), jnp.float32
        )

        uw, sw = flat.padded_work(), packed.padded_work()
        nnz = max(1, cb.nnz)
        rows_out.append({
            "matrix": spec.name,
            "nnz": int(cb.nnz),
            "group_size": int(packed.group_size),
            "steps_unbatched": int(
                flat.num_dense + flat.num_panel + flat.num_coo
            ),
            "steps_batched": int(
                packed.num_dense_groups + packed.num_panel_groups
                + packed.num_coo_groups
            ),
            "padded_elems_unbatched": int(sum(uw.values())),
            "padded_elems_batched": int(sum(sw.values())),
            "padded_ratio_unbatched": sum(uw.values()) / nnz,
            "padded_ratio_batched": sum(sw.values()) / nnz,
            "t_unbatched": time_min(kernel, flat_d, x),
            "t_batched": time_min(kernel, packed_d, x),
            "t_ref_unbatched": time_min(reference, flat_d, x),
            "t_ref_batched": time_min(reference, packed_d, x),
        })
    return rows_out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,G,steps_un,steps_b,padded_ratio_un,padded_ratio_b,"
          "t_un_ms,t_b_ms,t_ref_un_us,t_ref_b_us")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['group_size']},"
              f"{r['steps_unbatched']},{r['steps_batched']},"
              f"{r['padded_ratio_unbatched']:.2f},"
              f"{r['padded_ratio_batched']:.2f},"
              f"{r['t_unbatched'] * 1e3:.2f},{r['t_batched'] * 1e3:.2f},"
              f"{r['t_ref_unbatched'] * 1e6:.0f},"
              f"{r['t_ref_batched'] * 1e6:.0f}")
    print(f"GEOMEAN kernel-path speedup (un/b): "
          f"{geomean([r['t_unbatched'] / r['t_batched'] for r in rows]):.2f}x; "
          f"step shrink: "
          f"{geomean([r['steps_unbatched'] / max(1, r['steps_batched']) for r in rows]):.2f}x; "
          f"padded-work shrink: "
          f"{geomean([r['padded_elems_unbatched'] / max(1, r['padded_elems_batched']) for r in rows]):.2f}x")
    return rows


if __name__ == "__main__":
    main()
