"""Benchmark driver: one section per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--scale small|bench]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "bench"])
    ap.add_argument("--only", default=None,
                    help="comma list: fig9,fig10,fig11,fig12,fig34")
    args = ap.parse_args()

    from . import fig9_perf, fig10_locality, fig11_ablation, fig12_overhead
    from . import fig34_distribution

    sections = {
        "fig9": ("Fig. 9 — SpMV perf vs CSR/COO/BSR", fig9_perf.main),
        "fig10": ("Fig. 10 — cache hit-rate model", fig10_locality.main),
        "fig11": ("Fig. 11 — ablation CB-I/II/III", fig11_ablation.main),
        "fig12": ("Fig. 12 — storage + preprocessing", fig12_overhead.main),
        "fig34": ("Fig. 3/4 — distribution + balance", fig34_distribution.main),
    }
    chosen = args.only.split(",") if args.only else list(sections)
    for key in chosen:
        title, fn = sections[key]
        print(f"\n===== {title} =====", flush=True)
        t0 = time.time()
        fn()
        print(f"[{key} done in {time.time() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
