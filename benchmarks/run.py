"""Benchmark driver: one section per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--scale small|bench]
                                            [--only fig9,spmv_batch,...]
                                            [--json BENCH_spmv.json]

Sections, titles, runner modules, and guard schemas all live in ONE
place — ``benchmarks/registry.py`` — consumed here and by
``scripts/bench_guard.py``. ``--json`` writes every executed section's
row dicts (timings, bytes, padded-work ratios) to one machine-readable
file so the perf trajectory is tracked across PRs; the guard script
diffs such a file against the checked-in ``benchmarks/BENCH_spmv.json``
baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from . import history
from .registry import SECTIONS, runner


def _jsonable(obj):
    """Coerce numpy scalars/arrays so ``json.dump`` accepts section rows."""
    import numpy as np

    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "bench"])
    ap.add_argument("--only", default=None,
                    help="comma list: " + ",".join(SECTIONS))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write executed sections' rows to PATH as JSON")
    args = ap.parse_args()

    chosen = args.only.split(",") if args.only else list(SECTIONS)
    unknown = [k for k in chosen if k not in SECTIONS]
    if unknown:
        ap.error(f"unknown sections {unknown}; known: {','.join(SECTIONS)}")

    results: dict[str, object] = {}
    for key in chosen:
        print(f"\n===== {SECTIONS[key].title} =====", flush=True)
        t0 = time.time()
        rows = runner(key)(args.scale)
        results[key] = _jsonable(rows)
        print(f"[{key} done in {time.time() - t0:.1f}s]", flush=True)

    if args.json:
        # attach the obs registry's view of everything the run recorded
        # (kernel-launch accounting, plan-cache rates, solver ladders) —
        # lazy import keeps the standalone guard script jax-free
        from repro import analysis, obs

        # lint health rides the same snapshot: repro.analysis.findings
        # gauges (per rule + total) so the JSON artifact records whether
        # the tree was invariant-clean when the numbers were taken.
        if os.path.isdir(os.path.join("src", "repro")):
            analysis.lint_paths(
                [os.path.join("src", "repro")],
                baseline_path=analysis.DEFAULT_BASELINE,
                record_obs=True,
            )

        payload = {
            "schema": "cb-spmv-bench/v1",
            "scale": args.scale,
            "git_sha": history.git_sha(),
            "sections": results,
            "metrics": obs.snapshot(),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"[wrote {args.json}]", flush=True)

        # every artifact run also extends the persistent trajectory
        # (benchmarks/history/history.jsonl, or $REPRO_BENCH_HISTORY)
        record = history.record_from_payload(
            payload, sha=payload["git_sha"])
        hist_path = history.append_record(record)
        print(f"[appended history record to {hist_path}]", flush=True)


if __name__ == "__main__":
    main()
