"""Benchmark driver: one section per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--scale small|bench]
                                            [--only fig9,spmv_batch,...]
                                            [--json BENCH_spmv.json]

``--json`` writes every executed section's row dicts (timings, bytes,
padded-work ratios) to one machine-readable file so the perf trajectory
is tracked across PRs; ``scripts/bench_guard.py`` diffs such a file
against the checked-in ``benchmarks/BENCH_spmv.json`` baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _jsonable(obj):
    """Coerce numpy scalars/arrays so ``json.dump`` accepts section rows."""
    import numpy as np

    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "bench"])
    ap.add_argument("--only", default=None,
                    help="comma list: fig9,fig10,fig11,fig12,fig34,"
                         "spmv_batch,spmm,solvers")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write executed sections' rows to PATH as JSON")
    args = ap.parse_args()

    from . import fig9_perf, fig10_locality, fig11_ablation, fig12_overhead
    from . import fig34_distribution, solvers, spmm_batch, spmv_batch

    sections = {
        "fig9": ("Fig. 9 — SpMV perf vs CSR/COO/BSR", fig9_perf.main),
        "fig10": ("Fig. 10 — cache hit-rate model", fig10_locality.main),
        "fig11": ("Fig. 11 — ablation CB-I/II/III", fig11_ablation.main),
        "fig12": ("Fig. 12 — storage + preprocessing", fig12_overhead.main),
        "fig34": ("Fig. 3/4 — distribution + balance", fig34_distribution.main),
        "spmv_batch": ("Batched super-block engine vs unbatched",
                       spmv_batch.main),
        "spmm": ("Batched SpMM super-tile engine vs flat tile stream",
                 spmm_batch.main),
        "solvers": ("Iterative solvers vs scipy.sparse CPU reference",
                    solvers.main),
    }
    chosen = args.only.split(",") if args.only else list(sections)
    results: dict[str, object] = {}
    for key in chosen:
        title, fn = sections[key]
        print(f"\n===== {title} =====", flush=True)
        t0 = time.time()
        rows = fn(args.scale)
        results[key] = _jsonable(rows)
        print(f"[{key} done in {time.time() - t0:.1f}s]", flush=True)

    if args.json:
        payload = {
            "schema": "cb-spmv-bench/v1",
            "scale": args.scale,
            "sections": results,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"[wrote {args.json}]", flush=True)


if __name__ == "__main__":
    main()
