"""Autotuned plans vs the default constants on the fig9 corpus.

Three claims, all deterministic — planning is pinned to heuristic mode
(``SearchSettings(mode="heuristic")``), which uses no wall clock, so
this section is exactly reproducible and machine-independent *even on
TPU* (where mode="auto" would switch to nondeterministic timed search
and drift against the checked-in baseline):

  * **padded work** — ``CBLinearOperator.from_cb(cb, plan="auto")``'s
    streams must not stream more padded elements than the
    default-constants operator's; the guard enforces geomean
    planned/default <= 1.0 across the corpus (the acceptance bar: tuning
    may trade *within* that envelope, never regress it).
  * **cost-model fidelity** — predicted padded-work/steps from the
    analytical model vs the measured values of the built streams
    (``predicted_*`` columns); ranking quality, not exactness, is the
    requirement, but large systematic drift shows up here first.
  * **plan-cache hit rate** — every matrix is planned through one shared
    ``PlanCache`` and then re-planned: the second pass must hit. The
    reported rate over both passes is 0.5 exactly when the cache works
    (guarded as ``plan_hit_rate >= 0.5``).
"""
from __future__ import annotations

import tempfile

import numpy as np

from repro.autotune import PlanCache, SearchSettings
from repro.core import CBMatrix
from repro.solvers import CBLinearOperator

from repro.data import matrices

from ._timing import geomean


def _stream_stats(streams) -> tuple[int, int]:
    padded = int(sum(streams.padded_work().values()))
    steps = int(streams.num_dense_groups + streams.num_panel_groups
                + streams.num_coo_groups)
    return padded, steps


DETERMINISTIC = SearchSettings(mode="heuristic")


def run(scale="small") -> list[dict]:
    rows_out = []
    with tempfile.TemporaryDirectory(prefix="cb-plan-cache-") as cache_dir:
        cache = PlanCache(cache_dir)
        corpus = list(matrices.corpus(scale))
        for spec, r, c, v, shape in corpus:
            v32 = v.astype(np.float32)
            cb = CBMatrix.from_coo(r, c, v32, shape, block_size=16,
                                   val_dtype=np.float32)
            op_default = CBLinearOperator.from_cb(cb)
            op_planned = CBLinearOperator.from_cb(cb, plan="auto",
                                                  plan_cache=cache,
                                                  plan_settings=DETERMINISTIC)
            plan = op_planned.plan
            padded_d, steps_d = _stream_stats(op_default.streams)
            padded_p, steps_p = _stream_stats(op_planned.streams)
            rows_out.append({
                "matrix": spec.name,
                "nnz": int(cb.nnz),
                "block_size_planned": int(plan.block_size),
                "group_size_planned": int(plan.group_size),
                "colagg_planned": bool(plan.colagg),
                "steps_default": steps_d,
                "steps_planned": steps_p,
                "predicted_padded_elems": int(plan.predicted_padded_elems),
                "predicted_steps": int(plan.predicted_steps),
                "padded_elems_default": padded_d,
                "padded_elems_planned": padded_p,
            })
        # second pass: every plan must come back from the cache
        for spec, r, c, v, shape in corpus:
            CBMatrix.plan_for(r, c, v.astype(np.float32), shape, cache=cache,
                              settings=DETERMINISTIC)
        hit_rate = cache.hit_rate
    for row in rows_out:
        row["plan_hit_rate"] = hit_rate
    return rows_out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,B,G,colagg,steps_def,steps_plan,"
          "padded_def,padded_plan,predicted_plan")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['block_size_planned']},"
              f"{r['group_size_planned']},{int(r['colagg_planned'])},"
              f"{r['steps_default']},{r['steps_planned']},"
              f"{r['padded_elems_default']},{r['padded_elems_planned']},"
              f"{r['predicted_padded_elems']}")
    g_pad = geomean([r["padded_elems_planned"] / max(1, r["padded_elems_default"])
                     for r in rows])
    g_steps = geomean([r["steps_planned"] / max(1, r["steps_default"])
                       for r in rows])
    g_model = geomean([r["predicted_padded_elems"]
                       / max(1, r["padded_elems_planned"]) for r in rows])
    print(f"GEOMEAN planned/default padded work: {g_pad:.3f}x; "
          f"steps: {g_steps:.3f}x; "
          f"model predicted/measured padded: {g_model:.3f}x; "
          f"plan-cache hit rate: {rows[0]['plan_hit_rate']:.2f}")
    return rows


if __name__ == "__main__":
    main()
