"""Batched SpMM super-tile engine: grid-step shrink + wall-time vs flat.

The SpMM batching PR's measurable claims, per corpus matrix:

  * **grid-step shrink** (``steps_unbatched`` / ``steps_batched``) — the
    flat tile stream runs one B x B weight tile per grid step (per
    activation n-tile); the super-tile packer fuses up to G per step, so
    the step count drops by ~G. Pure preprocessing arithmetic —
    deterministic, hardware-independent. The acceptance bar is >= 4x at
    G=16 across the corpus.
  * **streamed weight elements** (``padded_elems_*``) — the packed
    stream pads only the ragged tail group's empty slots, so the
    overhead over the flat stream stays a few percent.
  * **per-call wall time of the kernel path** (``t_unbatched`` /
    ``t_batched``) — the Pallas engine end-to-end (interpret mode off
    TPU). Unlike SpMV, interpret mode *understates* the SpMM batching
    win: the interpreter emulates each of the G per-slot X fetches at
    the same cost as a full grid step, so the batched step pays ~G fetch
    emulations and the ratio hovers near (or slightly below) 1x off-TPU.
    The metric is guarded as a ratio against the checked-in baseline to
    catch the engine getting *relatively* slower; the amortization claim
    itself is a compiled-TPU measurement (ROADMAP perf-headroom item).
    ``t_ref_*`` records the pure-XLA reference lowering for context, as
    in ``spmv_batch``.

SpMM per-call FLOPs are ~N (=128 lanes) times SpMV's, so interpret-mode
timing prices out the small corpus's largest size class: at
``scale="small"`` rows are restricted to matrices with
m <= MAX_TIMED_ROWS (the step/padded metrics are identical arithmetic at
any size, so nothing is lost but wall-clock noise). ``scale="bench"``
runs its full corpus like ``spmv_batch`` — that scale targets compiled
TPU hardware, where the per-call cost is not interpreter-bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CBMatrix
from repro.core.streams import (
    build_super_tile_stream, spmm_block_n, tile_stream_from_cb,
)
from repro.data import matrices
from repro.kernels import ops

from ._timing import geomean, time_min

N_RHS = 128           # one full lane tile of right-hand sides
MAX_TIMED_ROWS = 512  # scale="small" interpret-mode budget (see module doc)
TIMING_REPS = 7       # SpMM calls are ~N_RHS x costlier than SpMV's


def run(scale="small", group_size=None) -> list[dict]:
    rows_out = []
    kernel = jax.jit(lambda s, x: ops.cb_spmm(s, x, impl="pallas"))
    reference = jax.jit(lambda s, x: ops.cb_spmm(s, x, impl="reference"))
    for spec, r, c, v, shape in matrices.corpus(scale):
        if scale == "small" and shape[0] > MAX_TIMED_ROWS:
            continue
        cb = CBMatrix.from_coo(r, c, v.astype(np.float32), shape,
                               block_size=16, val_dtype=np.float32)
        flat = tile_stream_from_cb(cb)
        packed = build_super_tile_stream(flat, group_size=group_size)
        flat_d = jax.tree_util.tree_map(jnp.asarray, flat)
        packed_d = jax.tree_util.tree_map(jnp.asarray, packed)
        X = jnp.asarray(
            np.random.default_rng(0).standard_normal((shape[1], N_RHS)),
            jnp.float32,
        )

        n_tiles = -(-N_RHS // spmm_block_n(N_RHS))
        B = cb.block_size
        nnz = max(1, cb.nnz)
        rows_out.append({
            "matrix": spec.name,
            "nnz": int(cb.nnz),
            "group_size": int(packed.group_size),
            "steps_unbatched": int(n_tiles * flat.num_tiles),
            "steps_batched": int(n_tiles * packed.num_groups),
            "padded_elems_unbatched": int(flat.num_tiles * B * B),
            "padded_elems_batched": int(packed.padded_work()["tiles"]),
            "padded_ratio_unbatched": flat.num_tiles * B * B / nnz,
            "padded_ratio_batched": packed.padded_work()["tiles"] / nnz,
            "t_unbatched": time_min(kernel, flat_d, X, reps=TIMING_REPS),
            "t_batched": time_min(kernel, packed_d, X, reps=TIMING_REPS),
            "t_ref_unbatched": time_min(reference, flat_d, X,
                                        reps=TIMING_REPS),
            "t_ref_batched": time_min(reference, packed_d, X,
                                      reps=TIMING_REPS),
        })
    return rows_out


def main(scale="small"):
    rows = run(scale)
    if not rows:
        print("no matrices in scope at this scale")
        return rows
    print("matrix,nnz,G,steps_un,steps_b,padded_ratio_un,padded_ratio_b,"
          "t_un_ms,t_b_ms,t_ref_un_us,t_ref_b_us")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['group_size']},"
              f"{r['steps_unbatched']},{r['steps_batched']},"
              f"{r['padded_ratio_unbatched']:.2f},"
              f"{r['padded_ratio_batched']:.2f},"
              f"{r['t_unbatched'] * 1e3:.2f},{r['t_batched'] * 1e3:.2f},"
              f"{r['t_ref_unbatched'] * 1e6:.0f},"
              f"{r['t_ref_batched'] * 1e6:.0f}")
    print(f"GEOMEAN kernel-path speedup (un/b): "
          f"{geomean([r['t_unbatched'] / r['t_batched'] for r in rows]):.2f}x; "
          f"step shrink: "
          f"{geomean([r['steps_unbatched'] / max(1, r['steps_batched']) for r in rows]):.2f}x; "
          f"padded-work growth: "
          f"{geomean([r['padded_elems_batched'] / max(1, r['padded_elems_unbatched']) for r in rows]):.2f}x")
    return rows


if __name__ == "__main__":
    main()
