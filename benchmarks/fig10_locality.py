"""Fig. 10 reproduction: cache hit rates per format (LRU line model).

No Nsight on CPU, so the paper's L1/L2 measurements become a
fully-associative LRU model over the byte-access streams each format
generates during one SpMV traversal: L1 = 128 KB, L2 = 4 MB per-core
slice (v5e-ish SMEM/CMEM stand-ins; relative ordering is the claim
under test — CB's single-region-per-block layout touches fewer, denser
lines).

The ``cb`` column measures the **planned super-block pipeline** — the
streams the batched engine actually executes under a heuristic-mode
plan — via ``repro.obs.locality``'s vectorized reuse-distance engine
(which also retired the old per-access Python LRU and its 300k-nnz
skip). ``cb_flat`` keeps the seed's flat block-walk layout for
continuity with the paper's figure; CSR/BSR/TileSpMV are the
comparison baseline, all at float32 element width.
"""
from __future__ import annotations

import numpy as np

from repro.autotune import SearchSettings
from repro.core import CBMatrix
from repro.core.streams import build_super_streams
from repro.data import matrices
from repro.obs import locality as loc

from . import formats as F

L1_BYTES = loc.L1_BYTES
L2_BYTES = loc.L2_BYTES

DETERMINISTIC = SearchSettings(mode="heuristic")


def run(scale="small") -> list[dict]:
    out = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        v32 = v.astype(np.float32)
        plan = CBMatrix.plan_for(r, c, v32, shape, settings=DETERMINISTIC)
        cb = CBMatrix.from_plan(r, c, v32, shape, plan)
        super_streams = build_super_streams(cb, group_size=plan.group_size)
        streams = {
            "csr": np.asarray(F.access_stream_csr(r, c, v, shape,
                                                  vbytes=4)[0]),
            "bsr": np.asarray(F.access_stream_bsr(r, c, v, shape,
                                                  vbytes=4)[0]),
            "tile": np.asarray(F.access_stream_tile(r, c, v, shape,
                                                    vbytes=4)[0]),
            "cb_flat": np.asarray(F.access_stream_cb(r, c, v, shape,
                                                     vbytes=4)[0]),
            "cb": loc.access_stream_super(super_streams),
        }
        row = {"matrix": spec.name, "nnz": len(v)}
        for name, s in streams.items():
            prof = loc.reuse_profile(s)
            hr1 = prof.hit_rate(L1_BYTES)
            hr2 = prof.hit_rate(L2_BYTES)
            row[f"l1_{name}"] = hr1
            row[f"l2_{name}"] = hr2
            # misses per nnz — the format-comparable locality metric:
            # hit RATE alone rewards formats that simply make more
            # (redundant) accesses per element.
            row[f"m1_{name}"] = prof.misses(L1_BYTES) / max(1, len(v))
            row[f"m2_{name}"] = prof.misses(L2_BYTES) / max(1, len(v))
            row[f"lines_{name}"] = prof.unique_lines
        out.append(row)
    return out


def main(scale="small"):
    rows = run(scale)
    print("matrix,l1miss/nnz_cb,cb_flat,tile,bsr,csr,"
          "l2miss/nnz_cb,cb_flat,tile,bsr,csr")
    for r in rows:
        print(f"{r['matrix']},{r['m1_cb']:.3f},{r['m1_cb_flat']:.3f},"
              f"{r['m1_tile']:.3f},{r['m1_bsr']:.3f},{r['m1_csr']:.3f},"
              f"{r['m2_cb']:.3f},{r['m2_cb_flat']:.3f},"
              f"{r['m2_tile']:.3f},{r['m2_bsr']:.3f},{r['m2_csr']:.3f}")
    mean = lambda k: float(np.mean([r[k] for r in rows]))  # noqa: E731
    print(f"MEAN,{mean('m1_cb'):.3f},{mean('m1_cb_flat'):.3f},"
          f"{mean('m1_tile'):.3f},{mean('m1_bsr'):.3f},{mean('m1_csr'):.3f},"
          f"{mean('m2_cb'):.3f},{mean('m2_cb_flat'):.3f},"
          f"{mean('m2_tile'):.3f},{mean('m2_bsr'):.3f},{mean('m2_csr'):.3f}")
    print("(lower is better; hit rates retained in the row dicts; "
          "cb = planned super-block pipeline, cb_flat = seed layout)")
    return rows


if __name__ == "__main__":
    main()
