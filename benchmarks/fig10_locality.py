"""Fig. 10 reproduction: cache hit rates per format (LRU line model).

No Nsight on CPU, so the paper's L1/L2 measurements become a
fully-associative LRU simulation over the byte-access streams each format
generates during one SpMV traversal: L1 = 128 KB, L2 = 4 MB per-core slice
(v5e-ish SMEM/CMEM stand-ins; relative ordering is the claim under test —
CB's single-region-per-block layout touches fewer, denser lines).
"""
from __future__ import annotations

import numpy as np

from repro.data import matrices

from . import formats as F

L1_BYTES = 128 * 1024
L2_BYTES = 4 * 1024 * 1024


def run(scale="small") -> list[dict]:
    out = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        if len(v) > 300_000:   # keep the python LRU tractable
            continue
        streams = {
            "csr": F.access_stream_csr(r, c, v, shape)[0],
            "bsr": F.access_stream_bsr(r, c, v, shape)[0],
            "tile": F.access_stream_tile(r, c, v, shape)[0],
            "cb": F.access_stream_cb(r, c, v, shape)[0],
        }
        row = {"matrix": spec.name, "nnz": len(v)}
        for name, s in streams.items():
            hr1 = F.lru_hit_rate(s, L1_BYTES)
            hr2 = F.lru_hit_rate(s, L2_BYTES)
            row[f"l1_{name}"] = hr1
            row[f"l2_{name}"] = hr2
            # misses per nnz — the format-comparable locality metric:
            # hit RATE alone rewards formats that simply make more
            # (redundant) accesses per element.
            row[f"m1_{name}"] = (1 - hr1) * len(s) / len(v)
            row[f"m2_{name}"] = (1 - hr2) * len(s) / len(v)
            row[f"lines_{name}"] = int(len(np.unique(s)))
        out.append(row)
    return out


def main(scale="small"):
    rows = run(scale)
    print("matrix,l1miss/nnz_cb,tile,bsr,csr,l2miss/nnz_cb,tile,bsr,csr")
    for r in rows:
        print(f"{r['matrix']},{r['m1_cb']:.3f},{r['m1_tile']:.3f},"
              f"{r['m1_bsr']:.3f},{r['m1_csr']:.3f},"
              f"{r['m2_cb']:.3f},{r['m2_tile']:.3f},"
              f"{r['m2_bsr']:.3f},{r['m2_csr']:.3f}")
    mean = lambda k: float(np.mean([r[k] for r in rows]))
    print(f"MEAN,{mean('m1_cb'):.3f},{mean('m1_tile'):.3f},"
          f"{mean('m1_bsr'):.3f},{mean('m1_csr'):.3f},"
          f"{mean('m2_cb'):.3f},{mean('m2_tile'):.3f},"
          f"{mean('m2_bsr'):.3f},{mean('m2_csr'):.3f}")
    print("(lower is better; hit rates retained in the row dicts)")
    return rows


if __name__ == "__main__":
    main()
