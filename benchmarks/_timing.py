"""Shared measurement helpers for the benchmark sections.

The implementations live in ``repro.autotune.timing`` so the autotuner's
empirical refinement and the benchmark sections share one timing
discipline; this module re-exports them for the sections' existing
imports.
"""
from __future__ import annotations

from repro.autotune.timing import geomean, time_min  # noqa: F401
