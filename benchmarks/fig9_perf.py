"""Fig. 9 reproduction: CB-SpMV vs CSR / COO / BSR across the matrix corpus.

The paper reports GPU Gflops; offline the comparable signal is (a) CPU
wall-time of the jitted XLA implementation of each format (directional —
same compiler, same machine) and (b) the modeled HBM traffic per SpMV
(bytes that must move for one y = A x pass), which is what determines GPU
SpMV performance (it is bandwidth-bound). Speedup columns are vs CB.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CBMatrix
from repro.core.streams import build_streams
from repro.data import matrices

from . import formats as F


def _time(fn, *args, reps=20):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def modeled_bytes(rows, cols, vals, shape, fmt: str, B=16, vbytes=8) -> int:
    """One-pass traffic model: every stored byte read once + x gathers +
    y writes (line-granular x traffic is fig10's job; this is the raw
    footprint the formats force through HBM)."""
    nnz = len(vals)
    m, n = shape
    if fmt == "csr":
        return (m + 1) * 4 + nnz * 4 + nnz * vbytes + nnz * vbytes + m * vbytes
    if fmt == "coo":
        return nnz * (8 + vbytes) + nnz * vbytes + m * vbytes
    if fmt == "bsr":
        ts = F.to_bsr(rows, cols, vals, shape, B)
        return (ts.num_tiles * B * B * vbytes + ts.num_tiles * 8
                + ts.num_tiles * B * vbytes + m * vbytes)
    if fmt == "cb":
        cb = CBMatrix.from_coo(rows, cols, vals, shape, block_size=B,
                               val_dtype=np.float64 if vbytes == 8 else np.float32)
        meta = cb.nbytes_structure()
        return (meta["packed_data"] + meta["high_level_metadata"]
                + meta["column_agg_maps"] + cb.nnz * vbytes + m * vbytes)
    raise ValueError(fmt)


def run(scale="small") -> list[dict]:
    rows_out = []
    for spec, r, c, v, shape in matrices.corpus(scale):
        m, n = shape
        v32 = v.astype(np.float32)
        x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
        xj = jnp.asarray(x)

        # CSR
        rp, ci, cv = F.to_csr(r, c, v32, shape)
        csr_fn = jax.jit(lambda rp, ci, cv, x: F.csr_spmv(rp, ci, cv, x, m))
        t_csr = _time(csr_fn, jnp.asarray(rp), jnp.asarray(ci),
                      jnp.asarray(cv), xj)

        # COO
        coo_fn = jax.jit(lambda r_, c_, v_, x: F.coo_spmv(r_, c_, v_, x, m))
        t_coo = _time(coo_fn, jnp.asarray(r), jnp.asarray(c),
                      jnp.asarray(v32), xj)

        # BSR (dense blocks)
        ts = F.to_bsr(r, c, v32, shape, 16)
        ts_j = jax.tree_util.tree_map(jnp.asarray, ts)
        t_bsr = _time(jax.jit(F.bsr_spmv), ts_j, xj)

        # CB
        cb = CBMatrix.from_coo(r, c, v32, shape, block_size=16,
                               val_dtype=np.float32)
        st = build_streams(cb).device_put()
        t_cb = _time(jax.jit(F.cb_spmv_jit), st, xj)

        gflop = 2 * len(v) / 1e9
        row = {
            "matrix": spec.name, "nnz": len(v),
            "cb_gflops": gflop / t_cb,
            "speedup_vs_csr": t_csr / t_cb,
            "speedup_vs_coo": t_coo / t_cb,
            "speedup_vs_bsr": t_bsr / t_cb,
            "bytes_cb": modeled_bytes(r, c, v, shape, "cb"),
            "bytes_csr": modeled_bytes(r, c, v, shape, "csr"),
            "bytes_bsr": modeled_bytes(r, c, v, shape, "bsr"),
        }
        rows_out.append(row)
    return rows_out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,cb_gflops,speed_vs_csr,speed_vs_coo,speed_vs_bsr,"
          "bytes_cb_over_csr,bytes_cb_over_bsr")
    geo = lambda xs: float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['cb_gflops']:.3f},"
              f"{r['speedup_vs_csr']:.2f},{r['speedup_vs_coo']:.2f},"
              f"{r['speedup_vs_bsr']:.2f},"
              f"{r['bytes_cb'] / r['bytes_csr']:.2f},"
              f"{r['bytes_cb'] / r['bytes_bsr']:.2f}")
    print(f"GEOMEAN,,,{geo([r['speedup_vs_csr'] for r in rows]):.2f},"
          f"{geo([r['speedup_vs_coo'] for r in rows]):.2f},"
          f"{geo([r['speedup_vs_bsr'] for r in rows]):.2f},"
          f"{geo([r['bytes_cb'] / r['bytes_csr'] for r in rows]):.2f},"
          f"{geo([r['bytes_cb'] / r['bytes_bsr'] for r in rows]):.2f}")
    return rows


if __name__ == "__main__":
    main()
