"""Observability: instrumentation overhead + launch-accounting fidelity.

Two claims, per corpus matrix:

  * **overhead** — instrumenting the engine must leave the guarded
    kernel-path timings untouched: ``t_enabled`` / ``t_disabled`` time
    the spmv_batch workload (a jitted ``ops.cb_spmv`` closure, freshly
    traced per side) with obs on and off, guarded as geomean
    t_enabled/t_disabled <= 1.05. Recording is a *trace-time* Python
    side effect, so the steady-state compiled path is identical by
    construction — the guard catches any future change that leaks
    recording (or a host sync) into the dispatch path. The eager
    per-call shim cost is µs-scale and reported as ``t_record_us``
    (informational, machine-dependent).
  * **accounting fidelity** — after one planned ``matvec``, the registry
    series ``repro.autotune.exec.{padded_elems,steps}`` must carry both
    a ``kind=measured`` total (what the built streams actually run) and
    a ``kind=predicted`` total (the plan cost model), and their ratio is
    the per-call model fidelity — guarded at the same 2x envelope the
    autotune section uses. ``metrics_present`` asserts every required
    ``repro.ops.spmv.*`` key landed in the snapshot.

Determinism: planning is pinned to heuristic mode and the accounting
columns are pure preprocessing arithmetic; only the ``t_*`` columns are
machine-dependent (and guarded as a ratio).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.autotune import SearchSettings
from repro.core import CBMatrix
from repro.data import matrices
from repro.kernels import ops
from repro.solvers import CBLinearOperator

from ._timing import geomean, time_min

DETERMINISTIC = SearchSettings(mode="heuristic")

# Every snapshot produced by a planned pallas cb_spmv must carry these.
REQUIRED_METRICS = (
    "repro.ops.spmv.calls",
    "repro.ops.spmv.launches",
    "repro.ops.spmv.steps",
    "repro.ops.spmv.padded_elems",
    "repro.autotune.exec.calls",
    "repro.autotune.exec.padded_elems",
    "repro.autotune.exec.steps",
)


def _series_total(snap: dict, name: str, **labels) -> int:
    """Sum a counter's series filtered by a label subset."""
    entry = snap.get(name)
    if not entry:
        return 0
    want = {str(k): str(v) for k, v in labels.items()}
    return int(sum(
        s["value"] for s in entry["series"]
        if all(s["labels"].get(k) == v for k, v in want.items())
    ))


def run(scale="small") -> list[dict]:
    rows_out = []
    was_enabled = obs.is_enabled()
    try:
        for spec, r, c, v, shape in matrices.corpus(scale):
            v32 = v.astype(np.float32)
            cb = CBMatrix.from_coo(r, c, v32, shape, block_size=16,
                                   val_dtype=np.float32)
            op = CBLinearOperator.from_cb(cb, plan="auto",
                                          plan_settings=DETERMINISTIC)
            x = jnp.asarray(
                np.random.default_rng(0).standard_normal(shape[1]),
                jnp.float32,
            )

            # -- accounting fidelity: one planned matvec, read the registry
            obs.configure(enabled=True)
            obs.reset()
            op.matvec(x).block_until_ready()
            snap = obs.snapshot()
            row = {
                "matrix": spec.name,
                "nnz": int(cb.nnz),
                "padded_elems_measured": _series_total(
                    snap, "repro.autotune.exec.padded_elems",
                    kind="measured"),
                "padded_elems_predicted": _series_total(
                    snap, "repro.autotune.exec.padded_elems",
                    kind="predicted"),
                "steps_measured": _series_total(
                    snap, "repro.autotune.exec.steps", kind="measured"),
                "steps_predicted": _series_total(
                    snap, "repro.autotune.exec.steps", kind="predicted"),
                "metrics_present": all(m in snap for m in REQUIRED_METRICS),
            }

            # -- overhead: the spmv_batch workload, obs on vs off. Fresh
            # jit closures per side force a retrace, so each side pays
            # (or skips) recording at trace time; the timed steady state
            # must be identical.
            streams = op.streams.device_put()
            kernel_on = jax.jit(lambda s, xx: ops.cb_spmv(s, xx))
            kernel_off = jax.jit(lambda s, xx: ops.cb_spmv(s, xx))
            row["t_enabled"] = time_min(kernel_on, streams, x)
            obs.configure(enabled=False)
            row["t_disabled"] = time_min(kernel_off, streams, x)
            obs.configure(enabled=True)
            row["overhead_ratio"] = row["t_enabled"] / row["t_disabled"]

            # eager per-call recording cost, µs (informational)
            t0 = time.perf_counter()
            reps = 50
            for _ in range(reps):
                ops.spmv_launch_stats(streams)
            row["t_record_us"] = (time.perf_counter() - t0) / reps * 1e6
            rows_out.append(row)
    finally:
        obs.configure(enabled=was_enabled)
    return rows_out


def main(scale="small"):
    rows = run(scale)
    print("matrix,nnz,t_on_ms,t_off_ms,overhead,t_record_us,"
          "padded_meas,padded_pred,steps_meas,steps_pred,metrics_ok")
    for r in rows:
        print(f"{r['matrix']},{r['nnz']},{r['t_enabled'] * 1e3:.2f},"
              f"{r['t_disabled'] * 1e3:.2f},{r['overhead_ratio']:.3f},"
              f"{r['t_record_us']:.1f},"
              f"{r['padded_elems_measured']},{r['padded_elems_predicted']},"
              f"{r['steps_measured']},{r['steps_predicted']},"
              f"{int(r['metrics_present'])}")
    g_over = geomean([r["overhead_ratio"] for r in rows])
    g_model = geomean([r["padded_elems_measured"]
                       / max(1, r["padded_elems_predicted"]) for r in rows])
    print(f"GEOMEAN obs-on/obs-off: {g_over:.3f}x; "
          f"measured/predicted padded elems: {g_model:.3f}x")
    return rows


if __name__ == "__main__":
    main()
