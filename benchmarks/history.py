"""Persistent bench-run history: append-only JSONL across PRs.

``BENCH_spmv.json`` is a single snapshot — it guards the *latest* run
against the checked-in baseline but says nothing about the trajectory.
This module gives every ``benchmarks/run.py --json`` run a durable
record: one schema-versioned JSONL line per run (git sha, scale,
section rows, the full ``obs.snapshot()`` including the lint-health
gauges) appended to ``benchmarks/history/history.jsonl``, plus the
trajectory/regression analysis that ``scripts/bench_trend.py`` renders.

Regression detection is deliberately restricted to **deterministic,
lower-is-better** scalars (padded work, grid steps, solver iterations,
modeled cache misses, lint findings): those are pure preprocessing
arithmetic, so any uptick is a real code change, never machine noise.
Wall-clock timings ride along in the records for trajectory plots but
are never flagged — history files travel across machines.

Like ``benchmarks/registry.py`` this module is imported by standalone
scripts and must stay dependency-free (stdlib only).
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import time

HISTORY_SCHEMA = "cb-bench-history/v1"

# Where records land; the env var reroutes (scripts/check.sh points it
# at a scratch copy so CI runs never dirty the checked-in history).
ENV_VAR = "REPRO_BENCH_HISTORY"
DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "history", "history.jsonl")

# Row keys whose per-section totals are deterministic and lower-is-
# better — the only metrics --check flags. Superset of the bench
# guard's ROW_GUARDED_PREFIXES plus the locality model's outputs.
DETERMINISTIC_PREFIXES = (
    "padded_elems_", "padded_ratio_", "steps_", "iters_",
    "l1_misses_per_nnz_", "l2_misses_per_nnz_", "bytes_moved_",
)


def history_path(path: str | None = None) -> str:
    return path or os.environ.get(ENV_VAR) or DEFAULT_PATH


def git_sha(cwd: str | None = None) -> str:
    """HEAD sha of the working tree (``unknown`` outside a checkout)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))),
            capture_output=True, text=True, timeout=10,
        )
    except (OSError, subprocess.SubprocessError):
        return "unknown"
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else "unknown"


def record_from_payload(payload: dict, *, sha: str | None = None,
                        timestamp: float | None = None) -> dict:
    """Wrap one ``run.py --json`` payload as a history record."""
    return {
        "schema": HISTORY_SCHEMA,
        "git_sha": sha if sha is not None else git_sha(),
        "time": time.time() if timestamp is None else float(timestamp),
        "scale": payload.get("scale"),
        "sections": payload.get("sections", {}),
        "metrics": payload.get("metrics", {}),
    }


def validate_record(record: object) -> list[str]:
    """Schema problems of one record ([] = valid)."""
    problems = []
    if not isinstance(record, dict):
        return [f"record is {type(record).__name__}, not a dict"]
    if record.get("schema") != HISTORY_SCHEMA:
        problems.append(
            f"schema is {record.get('schema')!r}, expected {HISTORY_SCHEMA}")
    for key, typ in (("git_sha", str), ("time", (int, float)),
                     ("sections", dict), ("metrics", dict)):
        if not isinstance(record.get(key), typ):
            problems.append(f"'{key}' missing or wrong type")
    return problems


def append_record(record: dict, path: str | None = None) -> str:
    """Validate + append one record; returns the file written."""
    problems = validate_record(record)
    if problems:
        raise ValueError("invalid history record: " + "; ".join(problems))
    path = history_path(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    return path


def read_history(path: str | None = None) -> list[dict]:
    """All records, oldest first; malformed lines raise."""
    path = history_path(path)
    if not os.path.exists(path):
        return []
    records = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                record = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: bad JSON ({e})") from e
            problems = validate_record(record)
            if problems:
                raise ValueError(
                    f"{path}:{lineno}: " + "; ".join(problems))
            records.append(record)
    return records


# ---------------------------------------------------------------------------
# Trajectories + regression detection.
# ---------------------------------------------------------------------------

def scalar_metrics(record: dict) -> dict:
    """Flatten one record to ``{metric_name: float}``.

    Per guarded-style section key matching :data:`DETERMINISTIC_PREFIXES`,
    the total across rows (totals, not means, so a new corpus matrix
    shows up as a visible step rather than silently reweighting); plus
    the lint-health gauges from the metrics snapshot.
    """
    out: dict[str, float] = {}
    for name, rows in sorted(record.get("sections", {}).items()):
        if not isinstance(rows, list):
            continue
        totals: dict[str, float] = {}
        for row in rows:
            if not isinstance(row, dict):
                continue
            for key, val in row.items():
                if (isinstance(val, (int, float)) and math.isfinite(val)
                        and key.startswith(DETERMINISTIC_PREFIXES)):
                    totals[key] = totals.get(key, 0.0) + float(val)
        for key, val in sorted(totals.items()):
            out[f"{name}.{key}"] = val
    findings = record.get("metrics", {}).get("repro.analysis.findings")
    if isinstance(findings, dict):
        for series in findings.get("series", []):
            if series.get("labels", {}).get("rule") == "total":
                out["lint.findings_total"] = float(series["value"])
    return out


def trajectories(records: list[dict]) -> dict:
    """``{metric: [(sha, value), ...]}`` oldest->newest; gaps skipped."""
    out: dict[str, list[tuple[str, float]]] = {}
    for record in records:
        sha = str(record.get("git_sha", "unknown"))[:12]
        for name, val in scalar_metrics(record).items():
            out.setdefault(name, []).append((sha, val))
    return out


def detect_regressions(records: list[dict], *, last_k: int = 5,
                       rtol: float = 0.05,
                       atol: float = 1e-9) -> list[str]:
    """Deterministic metrics where the newest run regressed.

    The newest record's value is compared against the **best** (lowest)
    value over the preceding ``last_k`` records that carry the metric;
    a value more than ``rtol`` above that best is flagged. Metrics only
    the newest record has (a brand-new section) have no baseline and
    pass. Fewer than two records -> nothing to compare, [].
    """
    if len(records) < 2:
        return []
    latest = scalar_metrics(records[-1])
    window = records[-1 - last_k:-1]
    problems = []
    for name, value in sorted(latest.items()):
        prior = [m[name] for r in window
                 if name in (m := scalar_metrics(r))]
        if not prior:
            continue
        best = min(prior)
        if value > best * (1 + rtol) + atol:
            problems.append(
                f"{name}: {value:g} vs best {best:g} over last "
                f"{len(prior)} record(s) (+{(value / best - 1) * 100:.1f}%"
                f" > {rtol * 100:.0f}% tolerance)"
                if best > 0 else
                f"{name}: {value:g} vs best {best:g} over last "
                f"{len(prior)} record(s)")
    return problems
