"""Reference SpMV implementations for the baseline formats the paper
compares against (CSR, COO, BSR, TileSpMV-style) — all in JAX so wall-time
comparisons on CPU are apples-to-apples, plus byte-level access-stream
generators for the cache-locality model (benchmarks/fig10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CBMatrix, partition_coo, select_formats
from repro.core.streams import build_streams, build_tile_stream


# ---------------------------------------------------------------------------
# format builders (host-side preprocessing, like the paper's conversion step)
# ---------------------------------------------------------------------------

def to_csr(rows, cols, vals, shape):
    m, n = shape
    order = np.lexsort((cols, rows))
    r, c, v = rows[order], cols[order], vals[order]
    row_ptr = np.zeros(m + 1, np.int64)
    np.add.at(row_ptr, r + 1, 1)
    row_ptr = np.cumsum(row_ptr)
    return row_ptr.astype(np.int32), c.astype(np.int32), v


def to_bsr(rows, cols, vals, shape, B=16):
    """Dense B x B blocks incl. zeros (the BSR storage the paper critiques)."""
    return build_tile_stream(rows, cols, vals, shape, B)


# ---------------------------------------------------------------------------
# jitted SpMV per format
# ---------------------------------------------------------------------------

def csr_spmv(row_ptr, col_idx, csr_val, x, m):
    """Row-segment SpMV (jax: segment_sum over row ids)."""
    row_ids = jnp.repeat(
        jnp.arange(m), jnp.diff(row_ptr), total_repeat_length=len(col_idx)
    )
    prod = csr_val * x[col_idx]
    return jax.ops.segment_sum(prod, row_ids, num_segments=m)


def coo_spmv(rows, cols, vals, x, m):
    return jnp.zeros(m, vals.dtype).at[rows].add(vals * x[cols])


def bsr_spmv(stream, x):
    """Dense-block SpMV: every stored zero costs real bandwidth/FLOPs."""
    from repro.kernels import ref

    B, mb, nb = stream.block_size, stream.mb, stream.nb
    xp = jnp.pad(x, (0, nb * B - x.shape[0])).reshape(nb, B)
    return ref.block_dense_spmv(
        stream.tiles, stream.brow, xp[stream.bcol], mb
    ).reshape(-1)[: stream.m]


def cb_spmv_jit(streams, x):
    from repro.kernels import ops

    return ops.cb_spmv(streams, x, impl="reference")


# ---------------------------------------------------------------------------
# access-stream generation for the cache model (fig10)
# ---------------------------------------------------------------------------

LINE = 128  # bytes per cache line


def _lines(base: int, offsets_bytes: np.ndarray) -> np.ndarray:
    return (base + offsets_bytes) // LINE


def access_stream_csr(rows, cols, vals, shape, vbytes=8):
    """Interleaved (col_idx[j], val[j], x[col]) accesses, row-major —
    the paper's Fig. 1 traversal. Arrays live in separate regions."""
    m, n = shape
    row_ptr, c, v = to_csr(rows, cols, vals, shape)
    nnz = len(c)
    base_col = 0
    base_val = base_col + nnz * 4
    base_x = base_val + nnz * vbytes
    j = np.arange(nnz)
    tri = np.empty(3 * nnz, np.int64)
    tri[0::3] = _lines(base_col, j * 4)
    tri[1::3] = _lines(base_val, j * vbytes)
    tri[2::3] = _lines(base_x, c.astype(np.int64) * vbytes)
    return tri, base_x + n * vbytes


def access_stream_bsr(rows, cols, vals, shape, B=16, vbytes=8):
    """Block-dense traversal: all B*B values of every non-zero block."""
    stream = to_bsr(rows, cols, vals, shape, B)
    brow = np.asarray(stream.brow)
    bcol = np.asarray(stream.bcol)
    nblk = len(brow)
    base_val = 0
    base_x = nblk * B * B * vbytes
    out = []
    elem = np.arange(B * B, dtype=np.int64)
    xcol = np.arange(B, dtype=np.int64)
    for i in range(nblk):
        out.append(_lines(base_val, (i * B * B + elem) * vbytes))
        out.append(_lines(base_x, (bcol[i] * B + xcol) * vbytes))
    return np.concatenate(out), base_x + shape[1] * vbytes


def access_stream_tile(rows, cols, vals, shape, B=16, vbytes=8):
    """TileSpMV-style: per-block compressed storage but coordinates and
    values in SEPARATE arrays (the locality gap CB closes)."""
    part = partition_coo(rows, cols, vals, shape, B)
    nnz = part.nnz
    base_idx = 0
    base_val = nnz * 1            # packed uint8 coords
    base_x = base_val + nnz * vbytes
    out = []
    for i in range(part.num_blocks):
        s, e = part.blk_ptr[i], part.blk_ptr[i + 1]
        j = np.arange(s, e, dtype=np.int64)
        iv = np.empty(2 * len(j), np.int64)
        iv[0::2] = _lines(base_idx, j)
        iv[1::2] = _lines(base_val, j * vbytes)
        out.append(iv)
        lc = part.local_cols[s:e].astype(np.int64)
        out.append(_lines(base_x, (part.blk_col_idx[i] * B + lc) * vbytes))
    return np.concatenate(out), base_x + shape[1] * vbytes


def access_stream_cb(rows, cols, vals, shape, B=16, vbytes=8,
                     use_colagg="auto"):
    """CB: ONE contiguous region per block (coords+pad+values via VP)."""
    cb = CBMatrix.from_coo(rows, cols, vals, shape, block_size=B,
                           val_dtype=np.float64 if vbytes == 8 else np.float32,
                           use_column_aggregation=use_colagg)
    base_pack = 0
    base_x = len(cb.packed)
    out = []
    from repro.core.aggregation import unpack_block
    from repro.core.formats import FMT_DENSE

    # Walk blocks row-major: the locality claim is about the intra-block
    # layout, not the balance permutation (which serves the *parallel*
    # scheduler; a sequential LRU walk must not be charged for it).
    order = np.lexsort((cb.blk_col_idx, cb.blk_row_idx))
    for slot in order:
        nnz = int(cb.nnz_per_blk[slot])
        if nnz == 0:
            continue
        vp = int(cb.vp_per_blk[slot])
        fmt = int(cb.type_per_blk[slot])
        # one sequential walk of the block's contiguous packed region (VP)
        if fmt == FMT_DENSE:
            span = B * B * vbytes
        else:
            span = nnz * (1 + vbytes) + vbytes  # coords + pad + values
        out.append(
            _lines(base_pack, vp + np.arange(0, span, 16, dtype=np.int64))
        )
        # x accesses for this block
        brow = int(cb.blk_row_idx[slot])
        bcol = int(cb.blk_col_idx[slot])
        r, c, v = unpack_block(cb.packed, vp, fmt, nnz,
                               cb.block_size, cb.val_dtype)
        gx = cb.global_x_index(brow, bcol, c)
        out.append(_lines(base_x, gx * vbytes))
    return np.concatenate(out), base_x + shape[1] * vbytes


# ---------------------------------------------------------------------------
# LRU cache simulator
# ---------------------------------------------------------------------------

def lru_hit_rate(line_stream: np.ndarray, cache_bytes: int) -> float:
    """Fully-associative LRU over cache lines — the locality model.

    Thin wrapper over the vectorized reuse-distance engine
    (``repro.obs.locality``): bit-identical hit counts to the retired
    per-access ``OrderedDict`` walk, without the per-access Python loop
    that forced fig10's 300k-nnz cap.
    """
    from repro.obs import locality

    return locality.lru_hit_rate(line_stream, cache_bytes, line_bytes=LINE)
