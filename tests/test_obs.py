"""Observability subsystem: instruments, spans, exports, and the
instrumented hot paths (ops launch accounting, PlanCache/solver
mirrors, serving histograms, obs_report smoke)."""
import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.cb_matrix import CBMatrix
from repro.core.streams import build_streams, build_super_streams
from repro.data import matrices
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.solvers import CBLinearOperator, robust_solve


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts enabled on the real clock with empty stores."""
    obs.configure(enabled=True, clock=time.monotonic)
    obs.reset()
    yield
    obs.configure(enabled=True, clock=time.monotonic)
    obs.reset()


class FakeClock:
    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        t, self.t = self.t, self.t + self.step
        return t


def _spd_op(d=96, seed=3, plan=None):
    r, c, v = matrices.spd_banded(d, bandwidth=7, seed=seed)
    cb = CBMatrix.from_coo(r, c, v.astype(np.float32), (d, d),
                           block_size=16, val_dtype=np.float32)
    return cb, CBLinearOperator.from_cb(cb, plan=plan)


# -- counters ---------------------------------------------------------------

def test_counter_monotonic_and_labeled():
    ctr = obs.counter("t.count")
    ctr.inc()
    ctr.inc(2, solver="cg")
    ctr.inc(3, solver="cg")
    ctr.inc(5, solver="gmres")
    assert ctr.value() == 1
    assert ctr.value(solver="cg") == 5
    assert ctr.value(solver="gmres") == 5
    assert ctr.total() == 11


def test_counter_rejects_negative_increment():
    with pytest.raises(ValueError, match="negative"):
        obs.counter("t.neg").inc(-1)


def test_counter_label_isolation():
    ctr = obs.counter("t.iso")
    ctr.inc(1, a="x")
    ctr.inc(1, a="y")
    assert ctr.value(a="x") == 1  # series never bleed into each other
    assert ctr.value(a="y") == 1
    assert ctr.value() == 0


def test_registry_kind_conflict_raises():
    obs.counter("t.kind")
    with pytest.raises(TypeError, match="already registered"):
        obs.gauge("t.kind")


def test_gauge_last_write_wins():
    g = obs.gauge("t.gauge")
    g.set(3)
    g.set(7)
    assert g.value() == 7


# -- histograms -------------------------------------------------------------

def test_histogram_bucket_edges_are_log2():
    # exact powers of two land in the bucket they bound from above
    for e in (-3, 0, 5):
        idx = obs_metrics.bucket_index(2.0 ** e)
        assert obs_metrics.BUCKET_EDGES[idx] == 2.0 ** e
    # a value just above an edge falls in the next bucket
    assert (obs_metrics.bucket_index(1.0001)
            == obs_metrics.bucket_index(1.0) + 1)
    # underflow (incl. 0) and overflow go to the sentinel buckets
    assert obs_metrics.bucket_index(0.0) == 0
    assert obs_metrics.bucket_index(-5.0) == 0
    assert (obs_metrics.bucket_index(2.0 ** 40)
            == len(obs_metrics.BUCKET_EDGES))


def test_histogram_deterministic_percentiles():
    h = obs.histogram("t.hist")
    for v in (0.3, 0.4, 0.6, 0.9, 100.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5
    assert s["min"] == 0.3
    assert s["max"] == 100.0
    # rank-3 of 5 observations: 0.6 lives in the (0.5, 1.0] bucket
    assert s["p50"] == 1.0
    # p99 -> rank 5 -> the 100.0 observation, bucket edge 128
    assert s["p99"] == 128.0
    # identical multiset in any order -> identical summary
    h2 = obs.histogram("t.hist2")
    for v in (100.0, 0.9, 0.3, 0.6, 0.4):
        h2.observe(v)
    assert h2.summary() == s


def test_histogram_empty_summary_is_zero():
    assert obs.histogram("t.empty").summary()["count"] == 0


# -- snapshot / reset -------------------------------------------------------

def test_snapshot_roundtrips_json_and_sorts():
    obs.counter("t.b").inc(2, z="1", a="2")
    obs.counter("t.a").inc()
    obs.gauge("t.g").set(1.5)
    obs.histogram("t.h").observe(0.25)
    snap = obs.snapshot()
    assert list(snap) == sorted(snap)
    again = json.loads(json.dumps(snap))
    assert again == snap
    assert snap["t.b"]["series"][0]["labels"] == {"a": "2", "z": "1"}
    assert snap["t.h"]["series"][0]["summary"]["count"] == 1


def test_reset_clears_series_keeps_instruments():
    ctr = obs.counter("t.reset")
    ctr.inc(4)
    obs.reset()
    assert ctr.value() == 0
    assert obs.counter("t.reset") is ctr
    assert "t.reset" not in obs.snapshot()  # empty series omitted


# -- disabled mode ----------------------------------------------------------

def test_disabled_mode_is_a_noop():
    obs.configure(enabled=False)
    obs.counter("t.off").inc(5)
    obs.gauge("t.off.g").set(1)
    obs.histogram("t.off.h").observe(2.0)
    with obs.span("t.off.span") as sp:
        sp.set(k=1)
    assert obs.snapshot() == {}
    assert obs.tracer().records() == ()
    obs.configure(enabled=True)
    obs.counter("t.off").inc()
    assert obs.counter("t.off").value() == 1


# -- spans ------------------------------------------------------------------

def test_span_nesting_depth_and_attrs():
    clock = FakeClock()
    obs.configure(clock=clock)
    with obs.span("outer", phase="a"):
        with obs.span("inner") as sp:
            sp.set(status="ok")
    recs = {r.name: r for r in obs.tracer().records()}
    assert recs["outer"].depth == 0
    assert recs["inner"].depth == 1
    assert recs["inner"].attrs == {"status": "ok"}
    assert recs["inner"].start >= recs["outer"].start


def test_span_records_error_attr():
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (rec,) = obs.tracer().records()
    assert rec.attrs["error"] == "RuntimeError"


def test_injectable_clock_makes_traces_deterministic():
    def run():
        obs.reset()
        obs.configure(clock=FakeClock())
        with obs.span("a"):
            with obs.span("b"):
                pass
        return obs.chrome_trace()

    assert run() == run()


def test_chrome_trace_schema(tmp_path):
    obs.configure(clock=FakeClock())
    with obs.span("work", n=3):
        pass
    path = obs.export_chrome_trace(tmp_path / "t.trace.json")
    with open(path) as f:
        trace = json.load(f)
    assert isinstance(trace["traceEvents"], list)
    (ev,) = trace["traceEvents"]
    assert ev["ph"] == "X"
    assert isinstance(ev["ts"], (int, float))
    assert isinstance(ev["dur"], (int, float))
    assert ev["name"] == "work"
    assert ev["args"] == {"n": 3, "depth": 0}


def test_tracer_bounded_buffer_counts_drops():
    t = obs.Tracer(max_spans=2)
    for _ in range(4):
        with t.span("s"):
            pass
    assert len(t.records()) == 2
    assert t.dropped == 2


# -- MirroredCounter --------------------------------------------------------

def test_mirrored_counter_feeds_registry_and_stays_local():
    mc = obs.MirroredCounter(metric="t.mirror", label="site")
    mc["cg"] += 1
    mc["cg"] += 1
    mc["gmres"] += 1
    assert dict(mc) == {"cg": 2, "gmres": 1}
    assert obs.counter("t.mirror").value(site="cg") == 2
    # registry reset does not disturb the local (legacy API) view
    obs.reset()
    mc["cg"] += 1
    assert mc["cg"] == 3
    assert obs.counter("t.mirror").value(site="cg") == 1
    # disabled: local keeps counting, registry frozen
    obs.configure(enabled=False)
    mc["cg"] += 1
    assert mc["cg"] == 4
    obs.configure(enabled=True)
    assert obs.counter("t.mirror").value(site="cg") == 1


# -- ops launch accounting --------------------------------------------------

def _small_cb(d=64, seed=2):
    r, c, v = matrices.banded(d, d, bandwidth=5, fill=0.8, seed=seed)
    return CBMatrix.from_coo(r, c, v.astype(np.float32), (d, d),
                             block_size=16, val_dtype=np.float32)


def test_launch_stats_match_built_streams():
    # flat-stream arithmetic must replicate the jit-side ``_regroup``
    # path exactly (that is what ``cb_spmv`` runs on SpMVStreams input);
    # packed-stream stats must agree with the stream's own padded_work.
    cb = _small_cb()
    flat = build_streams(cb)
    for G in (1, 2, 4):
        regrouped = ops._regroup(flat, G)
        from_flat = ops.spmv_launch_stats(flat, G)
        from_regrouped = ops.spmv_launch_stats(regrouped)
        assert from_flat["padded"] == from_regrouped["padded"]
        assert from_flat["steps"] == from_regrouped["steps"]
        packed = build_super_streams(cb, group_size=G)
        assert (ops.spmv_launch_stats(packed)["padded_total"]
                == sum(packed.padded_work().values()))


def test_cb_spmv_bit_identical_with_obs_on_and_off():
    cb = _small_cb()
    streams = build_super_streams(cb, group_size=2)
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(cb.shape[1]).astype(np.float32))
    y_on = np.asarray(ops.cb_spmv(streams, x))
    obs.configure(enabled=False)
    y_off = np.asarray(ops.cb_spmv(streams, x))
    np.testing.assert_array_equal(y_on, y_off)


def test_cb_spmv_records_per_format_accounting():
    cb = _small_cb()
    streams = build_super_streams(cb, group_size=2)
    x = jnp.zeros(cb.shape[1], jnp.float32)
    ops.cb_spmv(streams, x)
    stats = ops.spmv_launch_stats(streams)
    snap = obs.snapshot()
    for fmt, steps in stats["steps"].items():
        if not steps:
            continue
        series = {tuple(sorted(s["labels"].items())): s["value"]
                  for s in snap["repro.ops.spmv.steps"]["series"]}
        assert series[(("format", fmt),)] == steps
        padded = {tuple(sorted(s["labels"].items())): s["value"]
                  for s in snap["repro.ops.spmv.padded_elems"]["series"]}
        assert padded[(("format", fmt),)] == stats["padded"][fmt]
    assert snap["repro.ops.spmv.calls"]["series"][0]["value"] == 1


def test_planned_matvec_records_measured_vs_predicted():
    _cb, op = _spd_op(plan="auto")
    x = jnp.zeros(op.shape[1], jnp.float32)
    op.matvec(x)
    snap = obs.snapshot()
    label = op.plan.structure_hash[:12]
    padded = {tuple(sorted(s["labels"].items())): s["value"]
              for s in snap["repro.autotune.exec.padded_elems"]["series"]}
    measured = padded[(("kind", "measured"), ("plan", label))]
    predicted = padded[(("kind", "predicted"), ("plan", label))]
    assert measured == ops.spmv_launch_stats(op.streams)["padded_total"]
    assert predicted == op.plan.predicted_padded_elems
    steps = {tuple(sorted(s["labels"].items())): s["value"]
             for s in snap["repro.autotune.exec.steps"]["series"]}
    assert (steps[(("kind", "measured"), ("plan", label))]
            == ops.spmv_launch_stats(op.streams)["steps_total"])


# -- migrated counters ------------------------------------------------------

def test_plan_cache_counters_mirror_to_registry(tmp_path):
    from repro.autotune import PlanCache, SearchSettings

    cache = PlanCache(tmp_path)
    settings = SearchSettings(mode="heuristic")
    r, c, v = matrices.spd_banded(96, bandwidth=7, seed=3)
    CBMatrix.plan_for(r, c, v.astype(np.float32), (96, 96), cache=cache,
                      settings=settings)
    CBMatrix.plan_for(r, c, v.astype(np.float32), (96, 96), cache=cache,
                      settings=settings)
    assert (cache.hits, cache.misses) == (1, 1)
    ctr = obs.counter("repro.autotune.plan_cache.lookups")
    assert ctr.value(outcome="hit") >= 1
    assert ctr.value(outcome="miss") >= 1


def test_trace_counts_mirror_to_registry():
    from repro.solvers import krylov as krylov_mod

    before = dict(krylov_mod._TRACE_COUNTS)
    _cb, op = _spd_op(seed=5)
    b = jnp.asarray(np.random.default_rng(1)
                    .standard_normal(96).astype(np.float32))
    res = robust_solve(op, b, tol=1e-6, maxiter=300, impl="reference")
    assert res.converged
    after = dict(krylov_mod._TRACE_COUNTS)
    assert after["cg"] >= before.get("cg", 0)
    assert isinstance(krylov_mod._TRACE_COUNTS, obs.MirroredCounter)


def test_robust_solve_emits_attempt_metrics():
    _cb, op = _spd_op(seed=7)
    b = jnp.asarray(np.random.default_rng(2)
                    .standard_normal(96).astype(np.float32))
    res = robust_solve(op, b, tol=1e-6, maxiter=300, impl="reference")
    assert res.converged
    assert obs.counter("repro.solvers.robust.calls").total() == 1
    attempts = obs.counter("repro.solvers.robust.attempts")
    assert attempts.total() == len(res.attempts)
    outcome = obs.counter("repro.solvers.robust.outcome")
    assert outcome.value(outcome="converged", solver=res.solver) == 1
    names = [r.name for r in obs.tracer().records()]
    assert "robust_solve" in names
    assert f"solve:{res.solver}" in names


# -- serving ----------------------------------------------------------------

def _tiny_engine(**kw):
    import jax

    from repro.configs.base import ModelConfig
    from repro.models.model import Model
    from repro.serving import ServingEngine

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
                      attn_chunk=32, remat="none", dtype="float32")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return ServingEngine(model, params, slots=2, max_len=64, **kw)


def test_serving_health_histograms_and_backoff():
    from repro.serving import Request

    sleeps = []
    eng = _tiny_engine(max_step_retries=2, retry_backoff_s=0.5,
                       sleep=sleeps.append)
    fail = {"n": 2}
    orig = eng.step_fn

    def flaky(params, state, tokens, pos):
        if fail["n"]:
            fail["n"] -= 1
            raise RuntimeError("injected step fault")
        return orig(params, state, tokens, pos)

    eng.step_fn = flaky
    eng.submit(Request(uid=0, prompt=np.array([1], np.int32),
                       max_new_tokens=2))
    eng.run_until_done(max_ticks=16)
    h = eng.health()
    assert h["retries"] == 2
    # exponential backoff: 0.5 * 2^0 + 0.5 * 2^1, accumulated exactly
    assert h["backoff_total_s"] == pytest.approx(1.5)
    assert sleeps == [0.5, 1.0]
    assert h["deadline_miss_count"] == h["deadline_expired"] == 0
    assert h["tick_latency_s"]["count"] == h["ticks"] > 0
    assert h["queue_depth_hist"]["count"] == h["ticks"]
    assert obs.counter("repro.serving.ticks").total() == h["ticks"]
    names = [r.name for r in obs.tracer().records()]
    assert "serving.tick" in names


def test_serving_health_keeps_legacy_keys_when_disabled():
    from repro.serving import Request

    obs.configure(enabled=False)
    eng = _tiny_engine()
    eng.submit(Request(uid=0, prompt=np.array([1], np.int32),
                       max_new_tokens=1))
    eng.run_until_done(max_ticks=8)
    h = eng.health()
    for key in ("ticks", "queue_depth", "active_slots", "completed",
                "rejected", "retries", "deadline_expired", "last_error"):
        assert key in h
    assert h["completed"] == 1
    assert h["tick_latency_s"]["count"] == 0
    assert obs.snapshot() == {}


# -- obs_report smoke (tier-1) ----------------------------------------------

def test_obs_report_exports_valid_chrome_trace(tmp_path, capsys):
    import sys

    sys.path.insert(0, "scripts")
    try:
        import obs_report
    finally:
        sys.path.pop(0)
    out = tmp_path / "demo.trace.json"
    payload = obs_report.main(["--out", str(out)])
    with open(out) as f:
        trace = json.load(f)
    assert trace == payload["trace"]
    events = trace["traceEvents"]
    assert isinstance(events, list) and events
    for ev in events:
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], (int, float))
        assert isinstance(ev["dur"], (int, float))
    names = {ev["name"] for ev in events}
    assert "robust_solve" in names
    assert "serving.tick" in names
    snap = payload["snapshot"]
    assert "repro.ops.spmv.calls" in snap
    assert "repro.autotune.exec.padded_elems" in snap
    text = capsys.readouterr().out
    assert "plan accounting" in text
