"""Locality profiler + bench history: engine exactness, generators,
history round-trip, trend regression detection, explain schema.

The load-bearing property: the vectorized reuse-distance engine's
hit/miss counts are **bit-identical** to a brute-force fully-associative
LRU walk, across random and adversarial (streaming / cyclic / blocked /
capacity-boundary) streams at several capacities — that equivalence is
what lets the guarded ``locality`` bench model unbounded streams with
no per-access Python loop.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from collections import OrderedDict

import numpy as np
import pytest

from repro.obs import locality as loc


def brute_lru_hits(stream, capacity: int) -> int:
    """The retired per-access OrderedDict LRU — the reference."""
    cache: OrderedDict[int, None] = OrderedDict()
    hits = 0
    for line in stream:
        if line in cache:
            cache.move_to_end(line)
            hits += 1
        else:
            cache[line] = None
            if len(cache) > capacity:
                cache.popitem(last=False)
    return hits


CAPACITIES = (1, 2, 7, 64, 1000)


def _adversarial_streams():
    rng = np.random.default_rng(7)
    C = 64  # exercised against capacity 64 below
    return {
        "streaming": np.arange(500),                       # all cold
        "cyclic_fits": np.tile(np.arange(C - 1), 6),       # all hits after cold
        "cyclic_thrash": np.tile(np.arange(C + 1), 6),     # LRU worst case
        "blocked": np.repeat(np.arange(40), 9),            # long runs
        "boundary_hit": np.r_[np.arange(C), 0],            # d = C-1 -> hit@C
        "boundary_miss": np.r_[np.arange(C + 1), 0],       # d = C   -> miss@C
        "random_small": rng.integers(0, 10, 400),
        "random_wide": rng.integers(0, 5000, 3000),
        "zipf": rng.zipf(1.5, 2000) % 499,
        "single": np.zeros(100, np.int64),
        "one": np.array([42]),
        "interleave": np.arange(600) % 3 * 1000 + np.arange(600) // 3,
    }


@pytest.mark.parametrize("name", sorted(_adversarial_streams()))
def test_engine_bitmatches_brute_force(name):
    stream = _adversarial_streams()[name]
    prof = loc.reuse_profile(stream)
    for cap in CAPACITIES:
        expect = brute_lru_hits(stream.tolist(), cap)
        got = prof.hits(cap * loc.LINE_BYTES)
        assert got == expect, (name, cap)
        assert prof.misses(cap * loc.LINE_BYTES) == len(stream) - expect


@pytest.mark.parametrize("seed", range(5))
def test_engine_bitmatches_random(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 800))
    stream = rng.integers(0, max(2, n // 3), n)
    prof = loc.reuse_profile(stream)
    for cap in CAPACITIES:
        assert prof.hits(cap * loc.LINE_BYTES) == \
            brute_lru_hits(stream.tolist(), cap)


def test_reuse_distances_known_values():
    # d[i] = distinct lines between consecutive accesses to i's line
    assert loc.reuse_distances(np.array([0, 1, 0])).tolist() == [-1, -1, 1]
    assert loc.reuse_distances(
        np.array([0, 1, 2, 0, 1, 2])).tolist() == [-1, -1, -1, 2, 2, 2]
    # line ids need not be dense or sorted
    assert loc.reuse_distances(
        np.array([900, -3, 900])).tolist() == [-1, -1, 1]


def test_empty_and_degenerate_streams():
    prof = loc.reuse_profile(np.zeros(0, np.int64))
    assert prof.accesses == 0 and prof.unique_lines == 0
    assert prof.hits(loc.L1_BYTES) == 0
    assert loc.lru_hit_rate(np.zeros(0, np.int64), loc.L1_BYTES) == 0.0
    with pytest.raises(Exception):
        loc.reuse_distances(np.zeros((2, 2), np.int64))


def test_duplicate_collapse_is_exact():
    # runs of the same line are unconditional hits: the collapsed
    # profile + restored duplicates must equal the raw walk exactly
    stream = np.repeat(np.array([5, 9, 5, 5, 9, 1]), [4, 1, 3, 2, 5, 1])
    prof = loc.reuse_profile(stream)
    assert prof.accesses == len(stream)
    assert prof.collapsed_accesses == 5  # 5,9,5,9,1 (5,5 runs merge)
    for cap in (1, 2, 3):
        assert prof.hits(cap * loc.LINE_BYTES) == \
            brute_lru_hits(stream.tolist(), cap)


def test_formats_wrapper_matches_brute_force():
    from benchmarks import formats as F

    rng = np.random.default_rng(3)
    stream = rng.integers(0, 300, 1500)
    cap_bytes = 128 * F.LINE
    assert F.lru_hit_rate(stream, cap_bytes) == pytest.approx(
        brute_lru_hits(stream.tolist(), 128) / len(stream))


def test_stream_stats_schema():
    st = loc.stream_stats(np.arange(100), nnz=50)
    for key in ("accesses", "unique_lines", "l1_hit_rate", "l2_hit_rate",
                "l1_misses_per_nnz", "l2_misses_per_nnz", "bytes_moved",
                "arith_intensity"):
        assert key in st
        assert np.isfinite(st[key])
    assert st["accesses"] == 100
    # 100 distinct lines, all cold at any capacity
    assert st["unique_lines"] == 100
    assert st["bytes_moved"] == 100 * loc.LINE_BYTES
    assert st["arith_intensity"] == pytest.approx(
        2 * 50 / (100 * loc.LINE_BYTES))


# ---------------------------------------------------------------------------
# Generators over the real stream metadata.
# ---------------------------------------------------------------------------

def _small_planned_streams():
    from repro.autotune import SearchSettings
    from repro.core import CBMatrix
    from repro.core.streams import build_super_streams
    from repro.data import matrices

    r, c, v = matrices.spd_banded(96, bandwidth=7, seed=3)
    v32 = v.astype(np.float32)
    plan = CBMatrix.plan_for(r, c, v32, (96, 96),
                             settings=SearchSettings(mode="heuristic"))
    cb = CBMatrix.from_plan(r, c, v32, (96, 96), plan)
    return build_super_streams(cb, group_size=plan.group_size)


def test_access_stream_super_deterministic_and_obs_invariant():
    from repro import obs

    streams = _small_planned_streams()
    a = loc.access_stream_super(streams)
    assert len(a) > 0 and a.dtype == np.int64
    was = obs.is_enabled()
    try:
        obs.configure(enabled=False)
        b = loc.access_stream_super(streams)
    finally:
        obs.configure(enabled=was)
    np.testing.assert_array_equal(a, b)
    # y-scatter traffic only appears when asked, and only adds accesses
    with_y = loc.access_stream_super(streams, include_output=True)
    assert len(with_y) > len(a)


def test_access_stream_super_covers_all_regions():
    streams = _small_planned_streams()
    a = loc.access_stream_super(streams)
    reg = streams.region_nbytes()
    # regions are laid out line-aligned, y last: without output traffic
    # every touched line lies inside the x-and-payload address space
    lines_before_y = sum(-(-v // loc.LINE_BYTES)
                         for k, v in reg.items() if k != "y")
    assert int(a.max()) < lines_before_y
    payload_keys = [k for k in reg
                    if k not in ("x", "y") and reg[k] > 0]
    assert payload_keys  # the build produced at least one format


def test_access_stream_super_tile_deterministic():
    from repro.core.streams import super_tile_stream_from_cb

    from repro.autotune import SearchSettings
    from repro.core import CBMatrix
    from repro.data import matrices

    r, c, v = matrices.spd_banded(96, bandwidth=7, seed=3)
    cb = CBMatrix.from_coo(r, c, v.astype(np.float32), (96, 96),
                           block_size=16, val_dtype=np.float32)
    ts = super_tile_stream_from_cb(cb)
    a = loc.access_stream_super_tile(ts)
    b = loc.access_stream_super_tile(ts)
    np.testing.assert_array_equal(a, b)
    assert len(a) > 0
    st = loc.stream_stats(a, nnz=int(cb.nnz))
    assert 0.0 <= st["l1_hit_rate"] <= 1.0


# ---------------------------------------------------------------------------
# Bench history + trend.
# ---------------------------------------------------------------------------

def _payload(padded=100, lint_total=0):
    return {
        "scale": "small",
        "sections": {"autotune": [
            {"matrix": "a", "padded_elems_planned": padded,
             "steps_planned": 5, "t_solve": 1.23},
        ]},
        "metrics": {"repro.analysis.findings": {"series": [
            {"labels": {"rule": "total"}, "value": lint_total}]}},
    }


def test_history_roundtrip(tmp_path):
    from benchmarks import history

    path = str(tmp_path / "h.jsonl")
    rec = history.record_from_payload(_payload(), sha="abc", timestamp=1.0)
    assert history.validate_record(rec) == []
    history.append_record(rec, path)
    history.append_record(
        history.record_from_payload(_payload(90), sha="def", timestamp=2.0),
        path)
    out = history.read_history(path)
    assert [r["git_sha"] for r in out] == ["abc", "def"]
    assert out[0]["schema"] == history.HISTORY_SCHEMA
    assert out[0]["sections"]["autotune"][0]["padded_elems_planned"] == 100


def test_history_env_override(tmp_path, monkeypatch):
    from benchmarks import history

    path = str(tmp_path / "env.jsonl")
    monkeypatch.setenv(history.ENV_VAR, path)
    assert history.history_path() == path
    history.append_record(
        history.record_from_payload(_payload(), sha="x", timestamp=0.0))
    assert len(history.read_history()) == 1


def test_history_rejects_bad_records(tmp_path):
    from benchmarks import history

    assert history.validate_record({"schema": "nope"})
    with pytest.raises(ValueError):
        history.append_record({"schema": "nope"}, str(tmp_path / "x.jsonl"))
    bad = tmp_path / "bad.jsonl"
    bad.write_text("not json\n")
    with pytest.raises(ValueError):
        history.read_history(str(bad))


def test_trend_regression_detection():
    from benchmarks import history

    def rec(i, padded, lint=0):
        return history.record_from_payload(
            _payload(padded, lint), sha=f"sha{i}", timestamp=float(i))

    # improving trajectory: clean
    recs = [rec(i, p) for i, p in enumerate([120, 110, 100])]
    assert history.detect_regressions(recs) == []
    # >5% uptick vs the best of the window: flagged
    recs = [rec(0, 100), rec(1, 120)]
    probs = history.detect_regressions(recs)
    assert len(probs) == 1 and "padded_elems_planned" in probs[0]
    # within tolerance: clean
    assert history.detect_regressions([rec(0, 100), rec(1, 104)]) == []
    # timings never flagged
    recs = [rec(0, 100), rec(1, 100)]
    recs[1]["sections"]["autotune"][0]["t_solve"] = 99.0
    assert history.detect_regressions(recs) == []
    # lint findings are guarded
    probs = history.detect_regressions([rec(0, 100, 0), rec(1, 100, 3)])
    assert any("lint.findings_total" in p for p in probs)
    # a brand-new metric has no baseline -> passes
    recs = [rec(0, 100), rec(1, 100)]
    recs[1]["sections"]["locality"] = [
        {"matrix": "a", "l2_misses_per_nnz_cb": 0.5}]
    assert history.detect_regressions(recs) == []
    # single record -> nothing to compare
    assert history.detect_regressions([rec(0, 100)]) == []


def test_bench_trend_cli(tmp_path):
    from benchmarks import history

    path = str(tmp_path / "h.jsonl")
    history.append_record(
        history.record_from_payload(_payload(100), sha="a", timestamp=1.0),
        path)
    history.append_record(
        history.record_from_payload(_payload(130), sha="b", timestamp=2.0),
        path)
    sys.path.insert(0, "scripts")
    try:
        import bench_trend
        assert bench_trend.main(["--history", path]) == 0       # report only
        assert bench_trend.main(["--history", path, "--check"]) == 1
    finally:
        sys.path.pop(0)
        sys.modules.pop("bench_trend", None)


def test_run_json_appends_history_record(tmp_path, monkeypatch):
    """run.py --json end-to-end: artifact has git_sha+scale, history
    gains a valid record, and bench_trend --check accepts it."""
    hist = str(tmp_path / "hist.jsonl")
    out = str(tmp_path / "bench.json")
    env = dict(os.environ)
    env["REPRO_BENCH_HISTORY"] = hist
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--scale", "small",
         "--only", "fig34", "--json", out],
        capture_output=True, text=True, env=env, timeout=580)
    assert r.returncode == 0, r.stderr[-2000:]
    payload = json.load(open(out))
    assert payload["schema"] == "cb-spmv-bench/v1"
    assert payload["scale"] == "small"
    assert isinstance(payload.get("git_sha"), str) and payload["git_sha"]

    from benchmarks import history
    records = history.read_history(hist)
    assert len(records) == 1
    assert records[0]["git_sha"] == payload["git_sha"]
    assert "fig34" in records[0]["sections"]

    r = subprocess.run(
        [sys.executable, "scripts/bench_trend.py", "--history", hist,
         "--check"],
        capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# explain.py schema smoke.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_explain_schema(capsys):
    sys.path.insert(0, "scripts")
    try:
        import explain
        rep = explain.main(["--matrix", "banded_256x256", "--top-k", "3"])
    finally:
        sys.path.pop(0)
        sys.modules.pop("explain", None)
    assert rep["schema"] == "cb-explain/v1"
    assert rep["matrix"] == "banded_256x256"
    for key in ("features", "decision", "plan", "locality", "roofline"):
        assert key in rep
    assert len(rep["decision"]) == 3
    assert rep["decision"][0]["rank"] == 0
    assert {"cb", "csr", "bsr", "tile"} <= set(rep["locality"])
    roof = rep["roofline"]
    assert roof["bound"] in ("memory", "compute")
    assert roof["arith_intensity"] > 0
    # the whole report must be JSON-serializable (the --json contract)
    json.dumps(rep)
    out = capsys.readouterr().out
    assert "cost-model ranking" in out and "roofline" in out
