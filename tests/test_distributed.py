"""Distribution tests that need >1 device run in a subprocess with
xla_force_host_platform_device_count (the main test process must keep the
default single CPU device — see the dry-run contract).
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=520,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_distributed_spmv_4dev():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core.cb_matrix import CBMatrix
from repro.core import distributed as dist
from repro.core.spmv_ref import dense_oracle
from repro.data import matrices

m, n = 160, 160
r, c, v = matrices.power_law(m, n, seed=7)
cb = CBMatrix.from_coo(r, c, v, (m, n), block_size=16, val_dtype=np.float32)
sh = dist.shard_streams(cb, 4)
assert sh.load_imbalance < 1.2, sh.device_nnz
mesh = compat.make_mesh((4,), ("model",))
x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
y0 = dense_oracle(r, c, v.astype(np.float32), (m, n), x)
for impl in ("pallas", "reference"):
    y = dist.distributed_spmv(sh, jnp.asarray(x), mesh, impl=impl, interpret=True)
    np.testing.assert_allclose(np.asarray(y), y0, rtol=3e-4, atol=3e-4)
print("OK")
""")


def test_distributed_spmv_combine_modes():
    """psum_scatter (sharded y) and legacy psum agree with the oracle; an
    axis-divisible m keeps the scatter output sharded end to end."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.cb_matrix import CBMatrix
from repro.core import distributed as dist
from repro.core.spmv_ref import dense_oracle
from repro.data import matrices

mesh = compat.make_mesh((4,), ("model",))
for m, n in ((160, 160), (150, 144)):  # divisible / ragged over D=4
    r, c, v = matrices.power_law(m, n, seed=7)
    cb = CBMatrix.from_coo(r, c, v, (m, n), block_size=16, val_dtype=np.float32)
    sh = dist.shard_streams(cb, 4)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    y0 = dense_oracle(r, c, v.astype(np.float32), (m, n), x)
    for combine in ("psum", "psum_scatter"):
        y = dist.distributed_spmv(sh, jnp.asarray(x), mesh, impl="reference",
                                  combine=combine)
        assert y.shape == (m,), (combine, y.shape)
        np.testing.assert_allclose(np.asarray(y), y0, rtol=3e-4, atol=3e-4)
        if combine == "psum_scatter" and m % 4 == 0:
            assert y.sharding.spec == P("model"), y.sharding
try:
    dist.distributed_spmv(sh, jnp.asarray(x), mesh, combine="bogus")
except ValueError:
    pass
else:
    raise AssertionError("bogus combine accepted")
print("OK")
""")


def test_sharded_train_step_matches_single_device():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.configs.base import ModelConfig
from repro.models import Model, axis_rules, logical_to_sharding
from repro.models.sharding import sanitize_shardings
from repro.training import build_train_step, TrainState, OPTIMIZERS, warmup_cosine

cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=256,
                  attn_chunk=32, remat="none", dtype="float32")
model = Model(cfg)
opt = OPTIMIZERS["adamw"]()
lr = warmup_cosine(1e-3, 2, 100)
step = build_train_step(model, opt, lr)
params, axes = model.init(jax.random.PRNGKey(0))
state = TrainState.create(params, opt)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 256)
batch = {"tokens": toks, "targets": toks}

# single-device result
s_plain, m_plain = jax.jit(step)(state, batch)

# sharded: data x model mesh
mesh = compat.make_mesh((2, 2), ("data", "model"))
with axis_rules(mesh):
    psh = sanitize_shardings(jax.eval_shape(lambda: params),
                             logical_to_sharding(axes, mesh), mesh)
    from repro.training.optimizer import AdamWState
    rep = NamedSharding(mesh, P())
    ssh = TrainState(step=rep, params=psh,
                     opt_state=AdamWState(mu=psh, nu=psh, count=rep),
                     ef_buffers=None)
    bsh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    f = jax.jit(step, in_shardings=(ssh, bsh), out_shardings=(ssh, None))
    s_shard, m_shard = f(state, batch)

assert abs(float(m_plain["loss"]) - float(m_shard["loss"])) < 1e-4
for a, b in zip(jax.tree_util.tree_leaves(s_plain.params),
                jax.tree_util.tree_leaves(s_shard.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
print("OK")
""")


def test_compressed_cross_pod_sum():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.training.grad_compression import compressed_cross_pod_sum, init_ef_buffers

mesh = compat.make_mesh((2, 2), ("pod", "data"))
g_local = {"w": jnp.arange(8.0).reshape(2, 4) / 7.0}
ef = init_ef_buffers(g_local)

@partial(compat.shard_map, mesh=mesh, in_specs=(P(), P()),
         out_specs=(P(), P()), check_vma=False)
def run(g, e):
    s, ne = compressed_cross_pod_sum(g, e, axis_name="pod")
    return s, ne

summed, new_ef = run(g_local, ef)
# both pods contributed identical grads -> sum == 2x
np.testing.assert_allclose(np.asarray(summed["w"]), 2 * np.asarray(g_local["w"]),
                           rtol=0.02, atol=0.02)
print("OK")
""")


def test_pipeline_two_stages():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.runtime.pipeline import pipeline_forward

mesh = compat.make_mesh((2,), ("pod",))
# stage s applies ws[s]: y = x @ w
ws = jnp.stack([jnp.eye(8) * 2.0, jnp.eye(8) * 3.0])  # (S, 8, 8)

def stage_fn(w, h):
    return h @ w

run = pipeline_forward(stage_fn, mesh, axis="pod")
mbs = jnp.ones((4, 2, 8))   # 4 microbatches of (2, 8)
out = run(ws, mbs)
np.testing.assert_allclose(np.asarray(out), 6.0 * np.ones((4, 2, 8)), rtol=1e-5)
print("OK")
""", devices=2)
