"""Scenario grid for the CB-SpMV conformance harness.

A *scenario* is one fully-specified CB preprocessing configuration over
one structural sparsity regime: (structure family, matrix shape, block
size, column-aggregation mode, value dtype, format thresholds). SpMV
correctness is regime-dependent — uniform, power-law, banded and
clustered sparsity drive different block formats, balance behaviour and
colagg decisions — so the grid sweeps the regimes instead of point
examples. Tests parametrize over ``spmv_scenarios()`` (or the smaller
``SPMM`` selection) and get a ready-built matrix via ``Scenario.build``
/ ``build_cb``.

Structures beyond the synthetic corpus families:

  * ``empty_rows_cols``  — bands of fully-empty rows AND columns (empty
    block-row panels; compacted widths of zero under colagg);
  * ``single_element``   — one nnz in a ragged corner block;
  * ``ragged_tail``      — dense-ish band on a shape not divisible by B;
  * ``spd``              — symmetrized banded + diagonal shift (the
    solver subsystem's SPD regime).

Matrices are kept small (~150 rows) so the whole grid runs in interpret
mode in seconds per case.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import CBMatrix
from repro.core.formats import FormatThresholds
from repro.data import matrices

BLOCK_SIZES = (8, 16, 24)
COLAGG_MODES = ("auto", True, False)


# ---------------------------------------------------------------------------
# structure builders: name -> (rows, cols, vals, shape)
# ---------------------------------------------------------------------------

def _uniform(seed=0):
    return (*matrices.uniform_random(152, 136, density=0.02, seed=seed),
            (152, 136))


def _power_law(seed=0):
    return (*matrices.power_law(144, 144, seed=seed), (144, 144))


def _banded(seed=0):
    return (*matrices.banded(160, 128, seed=seed), (160, 128))


def _block_clustered(seed=0):
    return (*matrices.block_clustered(144, 120, seed=seed), (144, 120))


def _empty_rows_cols(seed=0):
    """Nonzeros confined to scattered row/col stripes: whole block-row
    panels and whole column blocks stay empty."""
    rng = np.random.default_rng(seed)
    m, n = 160, 144
    live_rows = np.r_[np.arange(0, 24), np.arange(96, 120)]
    live_cols = np.r_[np.arange(8, 40), np.arange(120, 136)]
    nnz = 220
    rows = rng.choice(live_rows, nnz)
    cols = rng.choice(live_cols, nnz)
    key = rows * n + cols
    _, idx = np.unique(key, return_index=True)
    rows, cols = rows[idx], cols[idx]
    vals = rng.standard_normal(len(rows))
    return rows.astype(np.int64), cols.astype(np.int64), vals, (m, n)


def _single_element(seed=0):
    """One nnz, placed in the ragged bottom-right corner block."""
    del seed
    m, n = 90, 70
    return (np.array([m - 1], np.int64), np.array([n - 1], np.int64),
            np.array([2.5]), (m, n))


def _ragged_tail(seed=0):
    """Band structure on dimensions that are not multiples of any B."""
    return (*matrices.banded(131, 93, bandwidth=11, fill=0.8, seed=seed),
            (131, 93))


def _bucket_widths(seed=0):
    """Row bands whose distinct-column counts straddle the sublane (8)
    boundary: per-block compacted widths land on 1/7/8/9/15/16/17 — the
    exact edges the width-bucketed super-block packer must round and pack
    without losing or double-counting lanes."""
    rng = np.random.default_rng(seed)
    m, n = 136, 128
    rows_l, cols_l = [], []
    for i, k in enumerate((1, 7, 8, 9, 15, 16, 17)):
        rband = np.arange(i * 18, min(i * 18 + 12, m))
        csel = (np.arange(k) * 5 + i * 11) % n
        for rr in rband[::2]:
            rows_l.append(np.full(len(csel), rr))
            cols_l.append(csel)
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    key = rows * n + cols
    _, idx = np.unique(key, return_index=True)
    rows, cols = rows[idx], cols[idx]
    vals = rng.standard_normal(len(rows))
    return rows.astype(np.int64), cols.astype(np.int64), vals, (m, n)


def _spd(seed=0):
    """Symmetrized banded/FEM matrix with a diagonal-dominance shift —
    the SPD regime the Krylov solver subsystem runs on (CG assumes it)."""
    r, c, v = matrices.spd_banded(144, bandwidth=9, fill=0.75, seed=seed)
    return r, c, v, (144, 144)


STRUCTURES = {
    "uniform": _uniform,
    "power_law": _power_law,
    "banded": _banded,
    "block_clustered": _block_clustered,
    "empty_rows_cols": _empty_rows_cols,
    "single_element": _single_element,
    "ragged_tail": _ragged_tail,
    "bucket_widths": _bucket_widths,
    "spd": _spd,
}


# ---------------------------------------------------------------------------
# forced-format thresholds
# ---------------------------------------------------------------------------

def forced_thresholds(fmt: str, block_size: int) -> FormatThresholds:
    """Thresholds steering (nearly) every block into one intra-block format.

    Exact at the boundaries that matter: under ``coo`` only a completely
    full block escapes to CSR; under ``dense`` only single-element blocks
    stay CSR (``select_formats`` requires th1 >= 1).
    """
    area = block_size * block_size
    if fmt == "coo":
        return FormatThresholds(th1=area, th2=area)
    if fmt == "csr":
        return FormatThresholds(th1=1, th2=area)
    if fmt == "dense":
        return FormatThresholds(th1=1, th2=1)
    raise ValueError(f"unknown forced format {fmt!r}")


# ---------------------------------------------------------------------------
# the scenario record + grids
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    structure: str
    block_size: int
    colagg: object = "auto"        # "auto" | True | False
    dtype: str = "float32"         # numpy dtype name
    forced_fmt: str | None = None  # None = paper thresholds
    seed: int = 11

    @property
    def name(self) -> str:
        colagg = {True: "on", False: "off"}.get(self.colagg, "auto")
        parts = [self.structure, f"B{self.block_size}", f"colagg_{colagg}"]
        if self.dtype != "float32":
            parts.append(self.dtype)
        if self.forced_fmt:
            parts.append(f"force_{self.forced_fmt}")
        return "-".join(parts)

    def build_coo(self):
        rows, cols, vals, shape = STRUCTURES[self.structure](seed=self.seed)
        return rows, cols, vals.astype(self.dtype), shape

    def thresholds(self) -> FormatThresholds:
        if self.forced_fmt is None:
            return FormatThresholds()
        return forced_thresholds(self.forced_fmt, self.block_size)

    def build(self) -> CBMatrix:
        rows, cols, vals, shape = self.build_coo()
        return CBMatrix.from_coo(
            rows, cols, vals, shape,
            block_size=self.block_size,
            val_dtype=np.dtype(self.dtype),
            thresholds=self.thresholds(),
            use_column_aggregation=self.colagg,
        )


def spmv_scenarios() -> list[Scenario]:
    """The conformance grid for cb_spmv.

    Full structure x block-size x colagg sweep at float32 with the paper
    thresholds, plus forced-format and float64 slices so every
    intra-block format x colagg x B cell is exercised without blowing up
    the cross product.
    """
    grid: list[Scenario] = []
    for structure in STRUCTURES:
        for B in BLOCK_SIZES:
            for colagg in COLAGG_MODES:
                grid.append(Scenario(structure, B, colagg))
    # forced formats: every format x colagg on/off x every block size
    for fmt in ("coo", "csr", "dense"):
        for B in BLOCK_SIZES:
            for colagg in (True, False):
                grid.append(Scenario("uniform", B, colagg, forced_fmt=fmt))
    # float64 values through the full pipeline
    for B in BLOCK_SIZES:
        grid.append(Scenario("power_law", B, "auto", dtype="float64"))
    return grid


GROUP_SIZES = (1, 4, 16)


def batched_scenarios() -> list[tuple[Scenario, int]]:
    """The group-size axis for the batched super-block engine.

    A curated slice — structures that stress grouping (ragged block
    counts, width buckets, single blocks) crossed with ``GROUP_SIZES``,
    plus forced-format cells so every kernel sees every group size. The
    full structure grid already runs at group_size=1 via
    ``spmv_scenarios``; this axis covers what batching adds.
    """
    grid: list[tuple[Scenario, int]] = []
    for G in GROUP_SIZES:
        for structure in STRUCTURES:
            for B in (8, 16):
                grid.append((Scenario(structure, B, "auto"), G))
        # every intra-block format x colagg at one B, every group size
        for fmt in ("coo", "csr", "dense"):
            for colagg in (True, False):
                grid.append(
                    (Scenario("uniform", 16, colagg, forced_fmt=fmt), G)
                )
        # non-power-of-two block size through the batched decode path
        grid.append((Scenario("power_law", 24, "auto"), G))
        grid.append((Scenario("bucket_widths", 24, True), G))
    return grid


def batched_ids(grid: list[tuple[Scenario, int]]) -> list[str]:
    return [f"{s.name}-G{g}" for s, g in grid]


def planned_scenarios() -> list[Scenario]:
    """The autotune axis: one scenario per structure, planner decides.

    Block size / thresholds / colagg / group size in the scenario are
    ignored by the planned tests — the autotuner chooses them from the
    raw COO triplets; the scenario only contributes the structure.
    """
    return [Scenario(structure, 16, "auto") for structure in STRUCTURES]


def scenario_ids(scenarios: list[Scenario]) -> list[str]:
    return [s.name for s in scenarios]
