"""Structural invariants of the CB pipeline, checked across the grid.

Stream invariants: the kernel-facing typed streams must encode exactly
the same matrix as the portable CBMatrix — in particular every stream's
``*_xidx`` gather indices must fold the column-aggregation restore maps
(``cb.global_x_index``) element-for-element, and padding slots must
carry zero values so they cannot contribute.

Balance invariants: the Alg. 2 slot permutation is only a *schedule* —
it must preserve the nnz multiset, place every real block exactly once,
keep group sizes uniform, and its reported group loads must match the
slot assignment.
"""
import numpy as np
import pytest

from repro.core import balance
from repro.core.aggregation import coord_bits
from repro.core.formats import FMT_COO, FMT_CSR, FMT_DENSE
from repro.core.streams import build_streams

from .scenarios import Scenario, scenario_ids

pytestmark = pytest.mark.conformance

# A structural slice of the grid is enough here: these checks are about
# metadata plumbing, not numerics, so one dtype and auto thresholds.
INVARIANT_SCENARIOS = [
    Scenario(structure, B, colagg)
    for structure in ("uniform", "power_law", "empty_rows_cols",
                      "single_element", "ragged_tail")
    for B in (8, 16, 24)
    for colagg in (True, False)
]
_IDS = scenario_ids(INVARIANT_SCENARIOS)


@pytest.mark.parametrize("scn", INVARIANT_SCENARIOS, ids=_IDS)
def test_stream_xidx_folds_restore_cols(scn):
    cb = scn.build()
    s = build_streams(cb)
    B = cb.block_size
    bits = coord_bits(B)
    mask = (1 << bits) - 1

    di = pi = ci = 0
    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        gidx = cb.global_x_index(brow, bcol, c)
        if fmt == FMT_DENSE:
            assert int(s.dense_brow[di]) == brow
            # every element's column maps to the same global x index the
            # stream's per-tile gather row carries
            np.testing.assert_array_equal(s.dense_xidx[di][c], gidx)
            di += 1
        elif fmt == FMT_CSR:
            assert int(s.panel_brow[pi]) == brow
            ucols, rank = np.unique(c, return_inverse=True)
            np.testing.assert_array_equal(s.panel_xidx[pi][rank], gidx)
            pi += 1
        elif fmt == FMT_COO:
            assert int(s.coo_brow[ci]) == brow
            codes = np.asarray(s.coo_codes[ci][: len(c)])
            np.testing.assert_array_equal(codes & mask, r)
            np.testing.assert_array_equal(codes >> bits, c)
            np.testing.assert_array_equal(s.coo_xidx[ci][: len(c)], gidx)
            ci += 1
    assert (di, pi, ci) == (s.num_dense, s.num_panel, s.num_coo)


@pytest.mark.parametrize("scn", INVARIANT_SCENARIOS, ids=_IDS)
def test_stream_padding_is_inert(scn):
    """Padded tails of panel/coo rows must hold zero values."""
    cb = scn.build()
    s = build_streams(cb)
    widths = {}
    for brow, bcol, fmt, r, c, v in cb.iter_blocks():
        if fmt == FMT_CSR:
            widths.setdefault("panel", []).append(len(np.unique(c)))
        elif fmt == FMT_COO:
            widths.setdefault("coo", []).append(len(v))
    for i, k in enumerate(widths.get("panel", [])):
        assert np.all(np.asarray(s.panel_vals[i])[:, k:] == 0)
    for i, e in enumerate(widths.get("coo", [])):
        assert np.all(np.asarray(s.coo_vals[i])[e:] == 0)


@pytest.mark.parametrize("scn", INVARIANT_SCENARIOS, ids=_IDS)
def test_balance_slot_permutation_preserves_nnz_multiset(scn):
    rows, cols, vals, shape = scn.build_coo()
    cb = scn.build()
    from repro.core.blocking import partition_coo

    agg_cols = cb.colagg.new_cols if cb.colagg.applied else cols
    part = partition_coo(rows, agg_cols, vals, shape, cb.block_size)

    real = cb.nnz_per_blk[cb.nnz_per_blk > 0]
    # the permuted metadata holds exactly the partition's nnz multiset
    assert sorted(real.tolist()) == sorted(part.nnz_per_blk.tolist())
    assert int(real.sum()) == part.nnz == cb.nnz

    res = cb.balance_result
    assert len(cb.blk_row_idx) == res.num_groups * res.group_size
    # every real block placed exactly once
    placed = res.slots[res.slots >= 0]
    assert sorted(placed.tolist()) == list(range(part.num_blocks))
    # reported group loads match the slot assignment
    for g in range(res.num_groups):
        slot = res.slots[g * res.group_size : (g + 1) * res.group_size]
        got = part.nnz_per_blk[slot[slot >= 0]].sum()
        assert int(got) == int(res.group_loads[g])
    # greedy LPT bound: max load <= optimal-lower-bound + max block
    if part.num_blocks:
        bound = part.nnz_per_blk.sum() / res.num_groups + part.nnz_per_blk.max()
        assert res.group_loads.max() <= bound


def test_apply_balance_pads_with_sentinels():
    res = balance.tb_load_balance(np.array([5, 3, 1]), warps_per_tb=4)
    brow, fmtcode = balance.apply_balance(
        res, np.array([7, 8, 9]), np.array([0, 1, 2], np.uint8),
        pad_values=(0, FMT_COO),
    )
    assert len(brow) == 4
    pad_mask = res.slots < 0
    assert np.all(fmtcode[pad_mask] == FMT_COO)
    assert sorted(brow[~pad_mask].tolist()) == [7, 8, 9]
