"""SpMM conformance: tile-stream path, reference vs Pallas, the full-CB
densification path (``tile_stream_from_cb``), and the batched super-tile
engine (host-packed / jit-regrouped / reference, G ∈ {1, 4, 16}, odd
activation widths, bf16 tiles, packing bit-equality)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.streams import (
    LANE,
    build_super_tile_stream,
    build_tile_stream,
    spmm_block_n,
    tile_stream_from_cb,
)
import importlib

from repro.data import matrices
from repro.kernels import ops

# the package re-exports ops.cb_spmm under the kernel module's name, so
# reach the module itself through importlib
cb_spmm_kernel = importlib.import_module("repro.kernels.cb_spmm")

from .scenarios import Scenario, scenario_ids

pytestmark = pytest.mark.conformance


def _dense_of(rows, cols, vals, shape):
    d = np.zeros(shape, np.float32)
    np.add.at(d, (rows, cols), np.asarray(vals, np.float32))
    return d


@pytest.mark.parametrize("B", [8, 16, 24])
@pytest.mark.parametrize("N", [1, 8, 24])
def test_tile_stream_reference_vs_pallas(B, N):
    m, n = 120, 104
    r, c, v = matrices.pruned_weight(m, n, block_size=B, seed=5)
    ts = build_tile_stream(r, c, v.astype(np.float32), (m, n), B)
    ts = jax.tree_util.tree_map(jnp.asarray, ts)
    X = np.random.default_rng(2).standard_normal((n, N)).astype(np.float32)

    y_ref = np.asarray(ops.cb_spmm(ts, jnp.asarray(X), impl="reference"))
    y_pl = np.asarray(
        ops.cb_spmm(ts, jnp.asarray(X), impl="pallas", interpret=True)
    )
    np.testing.assert_allclose(y_pl, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        y_ref, _dense_of(r, c, v, (m, n)) @ X, rtol=3e-4, atol=3e-4
    )


SPMM_CB_SCENARIOS = [
    Scenario("banded", 8, False),
    Scenario("power_law", 16, True),
    Scenario("block_clustered", 16, "auto"),
    Scenario("ragged_tail", 24, True),
    Scenario("empty_rows_cols", 16, "auto"),
]


@pytest.mark.parametrize(
    "scn", SPMM_CB_SCENARIOS, ids=scenario_ids(SPMM_CB_SCENARIOS)
)
def test_cb_densified_spmm_matches_dense(scn):
    """Full CB pipeline -> tile stream -> SpMM == dense matmul, so the
    training path sees exactly the matrix the SpMV path encodes."""
    rows, cols, vals, shape = scn.build_coo()
    cb = scn.build()
    ts = tile_stream_from_cb(cb)
    ts = jax.tree_util.tree_map(jnp.asarray, ts)
    X = np.random.default_rng(4).standard_normal(
        (shape[1], 8)
    ).astype(np.float32)
    expected = _dense_of(rows, cols, vals, shape) @ X
    for impl in ("reference", "pallas"):
        got = np.asarray(
            ops.cb_spmm(ts, jnp.asarray(X), impl=impl, interpret=True)
        )
        np.testing.assert_allclose(got, expected, rtol=3e-4, atol=3e-4,
                                   err_msg=impl)


# ---------------------------------------------------------------------------
# batched super-tile engine
# ---------------------------------------------------------------------------

# Odd activation widths straddling the 128-lane tile: 1 (degenerate),
# 20 (sub-lane), 100 (the historical misaligned pick), 129 (lane + 1).
ODD_NS = (1, 20, 100, 129)

SPMM_BATCHED = [
    (scn, G, ODD_NS[(i + gi) % len(ODD_NS)])
    for gi, G in enumerate((1, 4, 16))
    for i, scn in enumerate(SPMM_CB_SCENARIOS)
]


@pytest.mark.parametrize(
    "scn,G,N", SPMM_BATCHED,
    ids=[f"{s.name}-G{g}-N{n}" for s, g, n in SPMM_BATCHED],
)
def test_batched_spmm_agrees_with_unbatched_reference(scn, G, N):
    """Host-packed, jit-regrouped, and super reference all ≤1e-5 vs the
    flat ``ref.cb_spmm`` oracle — batching is a schedule change, never a
    numerics change (same contract as the SpMV engine)."""
    rows, cols, vals, shape = scn.build_coo()
    cb = scn.build()
    ts = jax.tree_util.tree_map(jnp.asarray, tile_stream_from_cb(cb))
    sts = jax.tree_util.tree_map(
        jnp.asarray, build_super_tile_stream(tile_stream_from_cb(cb), G)
    )
    X = np.random.default_rng(7).standard_normal(
        (shape[1], N)
    ).astype(np.float32)
    Xj = jnp.asarray(X)

    y_ref = np.asarray(ops.cb_spmm(ts, Xj, impl="reference"))
    y_packed = np.asarray(ops.cb_spmm(sts, Xj, impl="pallas", interpret=True))
    y_regroup = np.asarray(
        ops.cb_spmm(ts, Xj, impl="pallas", interpret=True, group_size=G)
    )
    y_super_ref = np.asarray(ops.cb_spmm(sts, Xj, impl="reference"))

    np.testing.assert_allclose(y_packed, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_regroup, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_super_ref, y_ref, rtol=1e-5, atol=1e-5)

    expected = _dense_of(rows, cols, vals.astype(np.float32), shape) @ X
    np.testing.assert_allclose(y_packed, expected, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B", [8, 16])
@pytest.mark.parametrize("G", [4, 16])
def test_batched_spmm_bf16_tiles(B, G):
    """bf16 weight tiles through the batched path: the kernel and the
    reference both cast tile values to f32 before the MXU dot, so they
    stay within 1e-5 of each other on the same bf16 stream."""
    m, n = 120, 104
    r, c, v = matrices.pruned_weight(m, n, block_size=B, seed=9)
    ts = build_tile_stream(r, c, v.astype(np.float32), (m, n), B)
    ts_bf16 = jax.tree_util.tree_map(jnp.asarray, ts)
    ts_bf16.tiles = ts_bf16.tiles.astype(jnp.bfloat16)
    sts = build_super_tile_stream(
        jax.tree_util.tree_map(np.asarray, ts_bf16), G
    )
    assert np.asarray(sts.tiles).dtype == np.asarray(ts_bf16.tiles).dtype
    sts = jax.tree_util.tree_map(jnp.asarray, sts)
    X = jnp.asarray(
        np.random.default_rng(3).standard_normal((n, 20)), jnp.float32
    )
    y_ref = np.asarray(ops.cb_spmm(ts_bf16, X, impl="reference"))
    y_packed = np.asarray(ops.cb_spmm(sts, X, impl="pallas", interpret=True))
    np.testing.assert_allclose(y_packed, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B", [8, 16, 24])
def test_batched_spmm_packing_bit_equality(B):
    """Integer-exact data: every grouping (flat, jit-regroup, host-packed)
    must be BIT-identical — reordering exact sums cannot change a ULP, so
    any difference is a lost/duplicated/misrouted tile."""
    rng = np.random.default_rng(B)
    m, n = 136, 120
    nnz = 700
    r = rng.integers(0, m, nnz)
    c = rng.integers(0, n, nnz)
    key = r * n + c
    _, idx = np.unique(key, return_index=True)
    r, c = r[idx], c[idx]
    v = rng.integers(1, 8, len(r)).astype(np.float32)
    X = rng.integers(-4, 5, (n, 20)).astype(np.float32)

    ts = build_tile_stream(r, c, v, (m, n), B)
    tsd = jax.tree_util.tree_map(jnp.asarray, ts)
    Xj = jnp.asarray(X)
    y_flat = np.asarray(ops.cb_spmm(tsd, Xj, impl="pallas", interpret=True))
    for G in (1, 4, 16):
        sts = jax.tree_util.tree_map(
            jnp.asarray, build_super_tile_stream(ts, G)
        )
        y_packed = np.asarray(
            ops.cb_spmm(sts, Xj, impl="pallas", interpret=True)
        )
        y_regroup = np.asarray(
            ops.cb_spmm(tsd, Xj, impl="pallas", interpret=True, group_size=G)
        )
        np.testing.assert_array_equal(y_packed, y_flat, err_msg=f"G={G}")
        np.testing.assert_array_equal(y_regroup, y_flat, err_msg=f"G={G}")


def test_super_tile_packing_invariants():
    """Structure of the packed stream, independent of numerics."""
    scn = Scenario("power_law", 16, "auto")
    ts = tile_stream_from_cb(scn.build())
    for G in (1, 4, 16):
        sts = build_super_tile_stream(ts, G)
        assert sts.group_size == G
        assert sts.brow.shape == sts.bcol.shape == (sts.num_groups, sts.slots)
        assert sts.num_groups * sts.slots >= ts.num_tiles
        # value mass conserved exactly (permutation, never arithmetic)
        np.testing.assert_array_equal(
            np.sort(np.asarray(sts.tiles).ravel()[
                np.asarray(sts.tiles).ravel() != 0]),
            np.sort(np.asarray(ts.tiles).ravel()[
                np.asarray(ts.tiles).ravel() != 0]),
        )
        assert np.asarray(sts.brow).max() < ts.mb
        assert np.asarray(sts.bcol).max() < ts.nb


# ---------------------------------------------------------------------------
# canonical (brow, bcol) ordering: both builders, bit-identical streams
# ---------------------------------------------------------------------------

BUILDER_SCENARIOS = [
    Scenario("banded", 8, False),
    Scenario("uniform", 16, False),
    Scenario("uniform", 16, True),
    Scenario("ragged_tail", 24, False),
    Scenario("empty_rows_cols", 16, "auto"),
]


@pytest.mark.parametrize(
    "scn", BUILDER_SCENARIOS, ids=scenario_ids(BUILDER_SCENARIOS)
)
def test_tile_stream_builders_bit_identical(scn):
    """``build_tile_stream`` (raw COO) and ``tile_stream_from_cb`` (full
    CB pipeline, colagg folded back) must emit the SAME stream: canonical
    (brow, bcol) order, identical tiles to the bit. Historically the COO
    builder sorted by brow only while the CB builder sorted by
    (brow, bcol) — the streams held the same tiles in different orders.
    """
    rows, cols, vals, shape = scn.build_coo()
    ts_coo = build_tile_stream(
        rows, cols, vals.astype(np.float32), shape, scn.block_size
    )
    ts_cb = tile_stream_from_cb(scn.build())
    np.testing.assert_array_equal(np.asarray(ts_coo.brow),
                                  np.asarray(ts_cb.brow))
    np.testing.assert_array_equal(np.asarray(ts_coo.bcol),
                                  np.asarray(ts_cb.bcol))
    np.testing.assert_array_equal(np.asarray(ts_coo.tiles),
                                  np.asarray(ts_cb.tiles))
    # canonical order: strictly increasing (brow, bcol) pairs
    keys = (np.asarray(ts_coo.brow).astype(np.int64) * ts_coo.nb
            + np.asarray(ts_coo.bcol))
    assert np.all(np.diff(keys) > 0)


# ---------------------------------------------------------------------------
# lane-alignment regression (the compiled-shape invariant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N", ODD_NS)
def test_spmm_block_n_is_lane_multiple(N):
    """``spmm_block_n`` must emit a LANE (128) multiple for every N —
    the compiled Mosaic pipeline rejects lane-misaligned block widths;
    the old ``min(block_n, max(8, N))`` policy handed N=100 straight
    through and only survived because tests run interpreted."""
    bn = spmm_block_n(N)
    assert bn % LANE == 0
    assert spmm_block_n(N, 256) % LANE == 0


def test_spmm_block_n_validates_block_n():
    with pytest.raises(ValueError, match="multiple of 128"):
        spmm_block_n(100, block_n=100)
    with pytest.raises(ValueError, match="multiple of 128"):
        cb_spmm_kernel.super_tile_spmm(
            jnp.zeros((1, 8, 8), jnp.float32),
            jnp.zeros((1, 1), jnp.int32),
            jnp.zeros((1, 8, 100), jnp.float32),
            block_n=100, interpret=True,
        )


@pytest.mark.parametrize("N", ODD_NS)
def test_spmm_odd_widths_end_to_end(N):
    """The full entry point at every odd width: the kernel must see a
    lane-aligned tile and the result must still match dense math."""
    B, m, n = 16, 96, 80
    r, c, v = matrices.pruned_weight(m, n, block_size=B, seed=1)
    ts = jax.tree_util.tree_map(
        jnp.asarray, build_tile_stream(r, c, v.astype(np.float32), (m, n), B)
    )
    X = np.random.default_rng(N).standard_normal((n, N)).astype(np.float32)
    got = np.asarray(
        ops.cb_spmm(ts, jnp.asarray(X), impl="pallas", interpret=True,
                    group_size=4)
    )
    assert got.shape == (m, N)
    np.testing.assert_allclose(
        got, _dense_of(r, c, v, (m, n)) @ X, rtol=3e-4, atol=3e-4
    )


def test_spmm_single_pallas_call_per_stream(monkeypatch):
    """At group_size > 1 the whole tile stream is ONE ``pallas_call``
    whose grid has ``ceil(nt / G)`` steps per n-tile — the batching
    claim, asserted at the call boundary."""
    calls = []
    real = cb_spmm_kernel.pallas_call_tpu

    def spy(kernel, **kwargs):
        calls.append(kwargs["grid_spec"].grid)
        return real(kernel, **kwargs)

    monkeypatch.setattr(cb_spmm_kernel, "pallas_call_tpu", spy)
    B, m, n = 8, 104, 88   # unique shape so the jit cache cannot elide
    r, c, v = matrices.pruned_weight(m, n, block_size=B, seed=2)
    ts = build_tile_stream(r, c, v.astype(np.float32), (m, n), B)
    sts = jax.tree_util.tree_map(jnp.asarray, build_super_tile_stream(ts, 4))
    X = jnp.asarray(
        np.random.default_rng(0).standard_normal((n, 150)), jnp.float32
    )
    ops.cb_spmm(sts, X, impl="pallas", interpret=True).block_until_ready()
    assert len(calls) == 1
    (grid,) = calls
    assert grid == (2, sts.num_groups)          # ceil(150/128) n-tiles
    assert sts.num_groups * 4 <= ts.num_tiles + 4  # >= 4x fewer steps
