"""SpMM conformance: tile-stream path, reference vs Pallas, plus the
full-CB densification path (``tile_stream_from_cb``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.streams import build_tile_stream, tile_stream_from_cb
from repro.data import matrices
from repro.kernels import ops

from .scenarios import Scenario, scenario_ids

pytestmark = pytest.mark.conformance


def _dense_of(rows, cols, vals, shape):
    d = np.zeros(shape, np.float32)
    np.add.at(d, (rows, cols), np.asarray(vals, np.float32))
    return d


@pytest.mark.parametrize("B", [8, 16, 24])
@pytest.mark.parametrize("N", [1, 8, 24])
def test_tile_stream_reference_vs_pallas(B, N):
    m, n = 120, 104
    r, c, v = matrices.pruned_weight(m, n, block_size=B, seed=5)
    ts = build_tile_stream(r, c, v.astype(np.float32), (m, n), B)
    ts = jax.tree_util.tree_map(jnp.asarray, ts)
    X = np.random.default_rng(2).standard_normal((n, N)).astype(np.float32)

    y_ref = np.asarray(ops.cb_spmm(ts, jnp.asarray(X), impl="reference"))
    y_pl = np.asarray(
        ops.cb_spmm(ts, jnp.asarray(X), impl="pallas", interpret=True)
    )
    np.testing.assert_allclose(y_pl, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        y_ref, _dense_of(r, c, v, (m, n)) @ X, rtol=3e-4, atol=3e-4
    )


SPMM_CB_SCENARIOS = [
    Scenario("banded", 8, False),
    Scenario("power_law", 16, True),
    Scenario("block_clustered", 16, "auto"),
    Scenario("ragged_tail", 24, True),
    Scenario("empty_rows_cols", 16, "auto"),
]


@pytest.mark.parametrize(
    "scn", SPMM_CB_SCENARIOS, ids=scenario_ids(SPMM_CB_SCENARIOS)
)
def test_cb_densified_spmm_matches_dense(scn):
    """Full CB pipeline -> tile stream -> SpMM == dense matmul, so the
    training path sees exactly the matrix the SpMV path encodes."""
    rows, cols, vals, shape = scn.build_coo()
    cb = scn.build()
    ts = tile_stream_from_cb(cb)
    ts = jax.tree_util.tree_map(jnp.asarray, ts)
    X = np.random.default_rng(4).standard_normal(
        (shape[1], 8)
    ).astype(np.float32)
    expected = _dense_of(rows, cols, vals, shape) @ X
    for impl in ("reference", "pallas"):
        got = np.asarray(
            ops.cb_spmm(ts, jnp.asarray(X), impl=impl, interpret=True)
        )
        np.testing.assert_allclose(got, expected, rtol=3e-4, atol=3e-4,
                                   err_msg=impl)
