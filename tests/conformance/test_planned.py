"""Conformance for the autotune axis: planned execution must stay exact.

For every structure in the grid the autotuner picks a full configuration
(block size, thresholds, colagg, group size) from the raw triplets; the
planned pipeline must then:

  * agree with the flat (unbatched) reference lowering of the SAME
    planned CB structure to <= 1e-5 — the cross-implementation contract
    every perf feature is held to;
  * agree with the CB-independent dense oracle on the ORIGINAL triplets
    — tuning must not change the math;
  * execute **bit-identically** after a plan-cache round trip: plan ->
    save -> load -> rebuild -> run equals the freshly-planned run
    exactly (the cross-process amortization story is only safe if a
    cached plan reproduces the run, not just approximates it);
  * be deterministic: planning the same matrix twice (heuristic mode —
    no wall-clock inputs) yields the same ``Plan``, field for field.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune import Plan, PlanCache, SearchSettings, plan_search
from repro.core import CBMatrix
from repro.core.spmv_ref import dense_oracle
from repro.core.streams import build_streams, build_super_streams
from repro.kernels import ops

from .scenarios import planned_scenarios, scenario_ids

pytestmark = pytest.mark.conformance

SCENARIOS = planned_scenarios()

# Pin heuristic mode so bit-equality and determinism hold on EVERY
# backend — mode="auto" would switch to wall-clock-driven timed search
# on a TPU host and break both.
DETERMINISTIC = SearchSettings(mode="heuristic")


def _planned_spmv(plan, rows, cols, vals, shape, x) -> np.ndarray:
    """The full planned pipeline: rebuild + pack + batched Pallas run."""
    cb = CBMatrix.from_plan(rows, cols, vals, shape, plan)
    streams = build_super_streams(cb, group_size=plan.group_size)
    return np.asarray(
        ops.cb_spmv(streams.device_put(), x, impl="pallas", interpret=True)
    )


@pytest.mark.parametrize("scn", SCENARIOS, ids=scenario_ids(SCENARIOS))
def test_planned_agreement_and_cache_bit_equality(scn, tmp_path):
    rows, cols, vals, shape = scn.build_coo()
    vals = vals.astype(np.float32)
    x = jnp.asarray(
        np.random.default_rng(7).standard_normal(shape[1]), jnp.float32
    )

    cache = PlanCache(tmp_path / "plans")
    plan = plan_search(rows, cols, vals, shape, cache=cache,
                       settings=DETERMINISTIC)
    y_planned = _planned_spmv(plan, rows, cols, vals, shape, x)

    # --- planned Pallas vs flat reference of the planned structure -------
    cb = CBMatrix.from_plan(rows, cols, vals, shape, plan)
    y_ref = np.asarray(
        ops.cb_spmv(build_streams(cb).device_put(), x, impl="reference")
    )
    np.testing.assert_allclose(y_planned, y_ref, rtol=1e-5, atol=1e-5)

    # --- tuning must not change the math ---------------------------------
    expected = dense_oracle(rows, cols, vals, shape, np.asarray(x))
    np.testing.assert_allclose(y_ref, expected, rtol=3e-4, atol=3e-4)

    # --- cache round trip executes bit-identically -----------------------
    loaded = Plan.load(cache.path_for(plan.structure_hash))
    assert loaded == plan
    y_loaded = _planned_spmv(loaded, rows, cols, vals, shape, x)
    np.testing.assert_array_equal(y_loaded, y_planned)

    # --- and a cache *hit* returns that exact plan -----------------------
    hit = plan_search(rows, cols, vals, shape, cache=cache,
                      settings=DETERMINISTIC)
    assert hit == plan
    assert cache.hits >= 1


@pytest.mark.parametrize("scn", SCENARIOS[:3], ids=scenario_ids(SCENARIOS[:3]))
def test_plan_determinism(scn):
    """Same matrix -> same plan: heuristic mode has no wall-clock inputs."""
    rows, cols, vals, shape = scn.build_coo()
    vals = vals.astype(np.float32)
    p1 = plan_search(rows, cols, vals, shape, settings=DETERMINISTIC)
    p2 = plan_search(rows, cols, vals, shape, settings=DETERMINISTIC)
    assert p1 == p2
    assert p1.mode == "heuristic"
    assert p1.t_spmv is None  # no timing ran
