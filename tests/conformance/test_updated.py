"""Conformance for the dynamic-sparsity value-update path.

Contract, per (scenario, group_size) cell: an in-place value rewrite of a
built CB matrix — ``CBMatrix.update_values`` on the packed payload, and
the recorded stream updaters (``super_stream_updater`` and friends) on
the super-block / super-tile streams — is BIT-identical to throwing the
matrix away and rebuilding it from COO with the new values under the
same configuration. Structure is untouched by construction, so every
byte that is not a value payload must be byte-equal, and every value
payload must land exactly where a fresh build would put it. The sweep
covers colagg modes, forced intra-block formats, non-power-of-two block
sizes and every batched group size.

The property layer checks the other half of the contract: a value
rewrite never changes stream *shapes* or padded work — the whole point
of the fast path is that the Alg. 2 balance and packing decisions are
frozen with the structure.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import forall, integers, sampled_from

from repro.core import CBMatrix
from repro.core.streams import (
    build_super_streams,
    build_transposed_super_streams,
    super_stream_updater,
    super_tile_stream_from_cb,
    super_tile_updater,
    transposed_super_stream_updater,
)
from repro.kernels import ops

from .scenarios import GROUP_SIZES, STRUCTURES, Scenario, batched_ids

pytestmark = pytest.mark.conformance


def updated_scenarios() -> list[tuple[Scenario, int]]:
    """The update axis: structures x formats x colagg x group sizes."""
    grid: list[tuple[Scenario, int]] = []
    for G in GROUP_SIZES:
        for structure in STRUCTURES:
            grid.append((Scenario(structure, 16, "auto"), G))
        for fmt in ("coo", "csr", "dense"):
            for colagg in (True, False):
                grid.append(
                    (Scenario("uniform", 16, colagg, forced_fmt=fmt), G)
                )
        grid.append((Scenario("power_law", 24, "auto"), G))
        grid.append((Scenario("bucket_widths", 8, True), G))
    return grid


UPDATED = updated_scenarios()


def _fresh_values(cb: CBMatrix, seed: int) -> np.ndarray:
    """New canonical values, bounded away from zero (exact zeros are
    structure drift — outside the fast path's bit-identity contract)."""
    rng = np.random.default_rng(seed)
    count = cb.value_layout().count
    mag = rng.uniform(0.5, 2.0, count)
    sign = np.where(rng.random(count) < 0.5, -1.0, 1.0)
    return (mag * sign).astype(cb.val_dtype)


def _rebuild(cb: CBMatrix, scn: Scenario, new_vals: np.ndarray) -> CBMatrix:
    rows, cols, _ = cb.to_coo()
    return CBMatrix.from_coo(
        rows, cols, new_vals, cb.shape,
        block_size=scn.block_size,
        val_dtype=np.dtype(scn.dtype),
        thresholds=scn.thresholds(),
        use_column_aggregation=scn.colagg,
    )


def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


@pytest.mark.parametrize("scn,G", UPDATED, ids=batched_ids(UPDATED))
def test_update_bit_identical_to_fresh_build(scn, G):
    cb = scn.build()
    new_vals = _fresh_values(cb, seed=hash((scn.name, G)) % 2**31)
    cb_up = cb.update_values(new_vals)
    cb_fresh = _rebuild(cb, scn, new_vals)

    # packed payload + every metadata array byte-equal
    assert np.array_equal(cb_up.packed, cb_fresh.packed)
    assert np.array_equal(cb_up.nnz_per_blk, cb_fresh.nnz_per_blk)
    assert np.array_equal(cb_up.vp_per_blk, cb_fresh.vp_per_blk)
    assert np.array_equal(cb_up.type_per_blk, cb_fresh.type_per_blk)

    # updater-rewritten streams == streams of the fresh build
    upd = super_stream_updater(cb, group_size=G)
    assert _tree_equal(upd.apply(new_vals),
                       build_super_streams(cb_fresh, group_size=G))

    tupd = super_tile_updater(cb, group_size=G)
    assert _tree_equal(tupd.apply(new_vals),
                       super_tile_stream_from_cb(cb_fresh, group_size=G))


@pytest.mark.parametrize(
    "scn,G",
    [(Scenario("power_law", 16, "auto"), 4),
     (Scenario("uniform", 16, True, forced_fmt="coo"), 4),
     (Scenario("banded", 8, "auto"), 1)],
    ids=["power_law-B16-G4", "force_coo-B16-G4", "banded-B8-G1"],
)
def test_updated_spmv_spmm_execute_identically(scn, G):
    """The rewritten streams also *execute* bit-identically (reference)."""
    cb = scn.build()
    new_vals = _fresh_values(cb, seed=7)
    cb_fresh = _rebuild(cb, scn, new_vals)

    x = jnp.asarray(
        np.random.default_rng(1).standard_normal(cb.shape[1]), jnp.float32
    )
    X = jnp.asarray(
        np.random.default_rng(2).standard_normal((cb.shape[1], 8)),
        jnp.float32,
    )
    s_up = super_stream_updater(cb, group_size=G).apply(new_vals)
    s_fresh = build_super_streams(cb_fresh, group_size=G)
    np.testing.assert_array_equal(
        np.asarray(ops.cb_spmv(s_up, x, impl="reference")),
        np.asarray(ops.cb_spmv(s_fresh, x, impl="reference")),
    )
    t_up = super_tile_updater(cb, group_size=G).apply(new_vals)
    t_fresh = super_tile_stream_from_cb(cb_fresh, group_size=G)
    np.testing.assert_array_equal(
        np.asarray(ops.cb_spmm(t_up, X, impl="reference")),
        np.asarray(ops.cb_spmm(t_fresh, X, impl="reference")),
    )
    # transposed stream: forward-canonical values, transposed structure
    st_up = transposed_super_stream_updater(cb, group_size=G).apply(new_vals)
    st_fresh = build_transposed_super_streams(cb_fresh, group_size=G)
    y = jnp.asarray(
        np.random.default_rng(3).standard_normal(cb.shape[0]), jnp.float32
    )
    np.testing.assert_array_equal(
        np.asarray(ops.cb_spmv(st_up, y, impl="reference")),
        np.asarray(ops.cb_spmv(st_fresh, y, impl="reference")),
    )


@forall(integers(0, 2**31 - 1), sampled_from([8, 16, 24]),
        sampled_from(list(STRUCTURES)), examples=12, seed=5)
def test_value_rewrite_never_changes_shapes_or_padded_work(seed, B, structure):
    """Property: updates rewrite payload bytes only — stream geometry,
    padded work and step counts are invariant under any value rewrite."""
    scn = Scenario(structure, B, "auto", seed=seed % 7)
    cb = scn.build()
    cb_up = cb.update_values(_fresh_values(cb, seed))

    s0 = build_super_streams(cb)
    s1 = build_super_streams(cb_up)
    assert s0.padded_work() == s1.padded_work()
    l0 = jax.tree_util.tree_leaves(s0)
    l1 = jax.tree_util.tree_leaves(s1)
    assert [np.shape(a) for a in l0] == [np.shape(a) for a in l1]

    t0 = super_tile_stream_from_cb(cb)
    t1 = super_tile_stream_from_cb(cb_up)
    assert t0.padded_work() == t1.padded_work()
    assert [np.shape(a) for a in jax.tree_util.tree_leaves(t0)] == \
           [np.shape(a) for a in jax.tree_util.tree_leaves(t1)]
