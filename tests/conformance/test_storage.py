"""CBMatrix storage accounting and ``stats()`` across the scenario grid.

``nbytes_structure`` feeds the paper's §4.4.1 storage comparison and the
benchmarks; if its totals drift from the real array sizes, every storage
figure lies. ``stats()`` drives format/balance reporting.
"""
import numpy as np
import pytest

from repro.core.formats import FMT_COO, FMT_CSR, FMT_DENSE

from .scenarios import Scenario, scenario_ids

pytestmark = pytest.mark.conformance

STORAGE_SCENARIOS = [
    Scenario(structure, B, colagg, dtype=dtype)
    for structure in ("uniform", "power_law", "banded", "empty_rows_cols",
                      "single_element")
    for B in (8, 16, 24)
    for colagg, dtype in (("auto", "float32"), (True, "float32"),
                          (False, "float64"))
]
_IDS = scenario_ids(STORAGE_SCENARIOS)


@pytest.mark.parametrize("scn", STORAGE_SCENARIOS, ids=_IDS)
def test_nbytes_structure_accounts_every_byte(scn):
    cb = scn.build()
    sizes = cb.nbytes_structure()

    meta = (cb.blk_row_idx.nbytes + cb.blk_col_idx.nbytes
            + cb.nnz_per_blk.nbytes + cb.type_per_blk.nbytes
            + cb.vp_per_blk.nbytes)
    assert sizes["high_level_metadata"] == meta
    assert sizes["packed_data"] == cb.packed.nbytes
    if cb.colagg.applied:
        assert sizes["column_agg_maps"] == (
            cb.colagg.restore_cols.nbytes + cb.colagg.cols_offset.nbytes
        )
    else:
        assert sizes["column_agg_maps"] == 0
    assert sizes["total"] == (
        sizes["high_level_metadata"] + sizes["column_agg_maps"]
        + sizes["packed_data"]
    )
    # every virtual-pointer region lives inside the packed buffer
    real = cb.nnz_per_blk > 0
    assert np.all(cb.vp_per_blk[real] >= 0)
    assert np.all(cb.vp_per_blk[real] < max(1, cb.packed.nbytes))
    # packed data can never undercut the raw values it stores
    assert sizes["packed_data"] >= cb.nnz * cb.val_dtype.itemsize


@pytest.mark.parametrize("scn", STORAGE_SCENARIOS, ids=_IDS)
def test_stats_consistency(scn):
    cb = scn.build()
    st = cb.stats()

    assert st["nnz"] == cb.nnz > 0
    assert st["block_size"] == scn.block_size
    assert st["num_blocks"] == cb.num_blocks
    # format counts partition the real blocks
    assert (st["fmt_coo"] + st["fmt_csr"] + st["fmt_dense"]
            == st["num_blocks"])
    for key, code in (("fmt_coo", FMT_COO), ("fmt_csr", FMT_CSR),
                      ("fmt_dense", FMT_DENSE)):
        real = cb.nnz_per_blk > 0
        assert st[key] == int(np.sum(cb.type_per_blk[real] == code))
    assert 0.0 <= st["super_sparse_fraction"] <= 1.0
    assert st["tb_load_std"] >= 0.0
    # max/mean >= 1 by definition; bounded by the LPT guarantee
    res = cb.balance_result
    assert st["tb_load_imbalance"] >= 1.0 or st["num_blocks"] == 0
    if res.group_loads.sum() > 0:
        mean = res.group_loads.mean()
        real_nnz = cb.nnz_per_blk[cb.nnz_per_blk > 0]
        assert st["tb_load_imbalance"] <= (mean + real_nnz.max()) / mean

    if scn.colagg is True:
        assert st["column_aggregated"]
    if scn.colagg is False:
        assert not st["column_aggregated"]
