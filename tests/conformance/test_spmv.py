"""SpMV conformance: every execution path must agree on every scenario.

For each scenario in the grid:
  * ``CBMatrix.to_dense()`` must round-trip the COO input exactly
    (the preprocessing pipeline is lossless);
  * ``impl="reference"`` (pure XLA) and ``impl="pallas"`` (interpret)
    must agree to <= 1e-5 relative tolerance — the cross-implementation
    contract every later perf PR is verified against;
  * both must match the independent dense oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spmv_ref import dense_oracle, spmv_ref
from repro.core.streams import build_streams
from repro.kernels import ops

from .scenarios import scenario_ids, spmv_scenarios

pytestmark = pytest.mark.conformance

SCENARIOS = spmv_scenarios()


def _dense_of(rows, cols, vals, shape):
    d = np.zeros(shape, np.asarray(vals).dtype)
    np.add.at(d, (rows, cols), vals)
    return d


@pytest.mark.parametrize("scn", SCENARIOS, ids=scenario_ids(SCENARIOS))
def test_cb_roundtrip_and_impl_agreement(scn):
    rows, cols, vals, shape = scn.build_coo()
    cb = scn.build()

    # --- lossless preprocessing: CB -> dense == COO -> dense -------------
    np.testing.assert_allclose(
        cb.to_dense(), _dense_of(rows, cols, vals, shape),
        rtol=1e-6, atol=1e-6,
    )

    # --- cross-implementation agreement ----------------------------------
    streams = build_streams(cb).device_put()
    x = np.random.default_rng(3).standard_normal(shape[1]).astype(np.float32)
    y_ref = np.asarray(ops.cb_spmv(streams, jnp.asarray(x), impl="reference"))
    y_pl = np.asarray(
        ops.cb_spmv(streams, jnp.asarray(x), impl="pallas", interpret=True)
    )
    np.testing.assert_allclose(y_pl, y_ref, rtol=1e-5, atol=1e-5)

    # --- both match the CB-independent oracle ----------------------------
    expected = dense_oracle(rows, cols, vals.astype(np.float32), shape, x)
    np.testing.assert_allclose(y_ref, expected, rtol=3e-4, atol=3e-4)

    # --- the numpy Alg. 3/4 walker agrees too ----------------------------
    np.testing.assert_allclose(
        spmv_ref(cb, x), expected, rtol=3e-4, atol=3e-4
    )


def test_grid_covers_all_formats_and_modes():
    """The grid itself must exercise every format x colagg x block size."""
    from repro.core.formats import FMT_COO, FMT_CSR, FMT_DENSE

    seen: set[tuple[int, bool, int]] = set()
    for scn in SCENARIOS:
        cb = scn.build()
        fmts = cb.type_per_blk[cb.nnz_per_blk > 0]
        for fmt in np.unique(fmts):
            seen.add((int(fmt), bool(cb.colagg.applied), cb.block_size))
    for fmt in (FMT_COO, FMT_CSR, FMT_DENSE):
        for colagg in (True, False):
            for B in (8, 16, 24):
                assert (fmt, colagg, B) in seen, (
                    f"grid gap: fmt={fmt} colagg={colagg} B={B}"
                )
