"""Conformance for the batched super-block execution engine.

Contract, per (scenario, group_size) cell:

  * the host-packed ``SuperBlockStreams`` Pallas path, the jit-side
    ``group_size=G`` regroup path, and the super-stream reference oracle
    all agree with the *unbatched* reference to <= 1e-5 — batching is a
    schedule change, never a numerics change;
  * with integer-valued data (every product/sum exactly representable in
    float32) the batched and unbatched results are BIT-equal: the fused
    scatter-add combine may reorder additions, and reordering exact sums
    must not change a single ULP;
  * packing invariants: every real block lands in exactly one group
    slot, segment ids stay inside the group, and the bucketed payload
    never exceeds the global-max-padded payload it replaces.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CBMatrix
from repro.core.spmv_ref import dense_oracle
from repro.core.streams import build_streams, build_super_streams, pad_width
from repro.kernels import ops

from .scenarios import Scenario, batched_ids, batched_scenarios

pytestmark = pytest.mark.conformance

BATCHED = batched_scenarios()


@pytest.mark.parametrize("scn,G", BATCHED, ids=batched_ids(BATCHED))
def test_batched_agrees_with_unbatched_reference(scn, G):
    rows, cols, vals, shape = scn.build_coo()
    cb = scn.build()
    streams = build_streams(cb).device_put()
    sbs = build_super_streams(cb, group_size=G).device_put()
    x = np.random.default_rng(3).standard_normal(shape[1]).astype(np.float32)
    xj = jnp.asarray(x)

    y_ref = np.asarray(ops.cb_spmv(streams, xj, impl="reference"))
    y_packed = np.asarray(
        ops.cb_spmv(sbs, xj, impl="pallas", interpret=True)
    )
    y_regroup = np.asarray(
        ops.cb_spmv(streams, xj, impl="pallas", interpret=True, group_size=G)
    )
    y_super_ref = np.asarray(ops.cb_spmv(sbs, xj, impl="reference"))

    np.testing.assert_allclose(y_packed, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_regroup, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_super_ref, y_ref, rtol=1e-5, atol=1e-5)

    expected = dense_oracle(rows, cols, vals.astype(np.float32), shape, x)
    np.testing.assert_allclose(y_packed, expected, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B", [8, 16, 24])
@pytest.mark.parametrize("G", [1, 4, 16])
def test_batched_combine_bit_equality(B, G):
    """Batched vs unbatched must be bit-identical on exact arithmetic.

    Integer-valued matrix and x keep every product and partial sum
    exactly representable in float32, so the only way batched output can
    differ is a real packing bug (lost/duplicated/misrouted block), not
    floating-point reassociation.
    """
    rng = np.random.default_rng(B * 100 + G)
    m, n = 144, 136
    nnz = 900
    r = rng.integers(0, m, nnz)
    c = rng.integers(0, n, nnz)
    key = r * n + c
    _, idx = np.unique(key, return_index=True)
    r, c = r[idx], c[idx]
    v = rng.integers(1, 8, len(r)).astype(np.float32)
    x = rng.integers(-4, 5, n).astype(np.float32)

    cb = CBMatrix.from_coo(r, c, v, (m, n), block_size=B,
                           val_dtype=np.float32)
    streams = build_streams(cb).device_put()
    sbs = build_super_streams(cb, group_size=G).device_put()
    xj = jnp.asarray(x)

    y_unbatched = np.asarray(
        ops.cb_spmv(streams, xj, impl="pallas", interpret=True)
    )
    y_packed = np.asarray(
        ops.cb_spmv(sbs, xj, impl="pallas", interpret=True)
    )
    y_regroup = np.asarray(
        ops.cb_spmv(streams, xj, impl="pallas", interpret=True, group_size=G)
    )
    np.testing.assert_array_equal(y_packed, y_unbatched)
    np.testing.assert_array_equal(y_regroup, y_unbatched)


@pytest.mark.parametrize("G", [4, 16])
def test_single_block_matrix(G):
    """One real block, group size far larger: all pad slots stay inert."""
    scn = Scenario("single_element", 16)
    rows, cols, vals, shape = scn.build_coo()
    cb = scn.build()
    sbs = build_super_streams(cb, group_size=G).device_put()
    x = np.random.default_rng(0).standard_normal(shape[1]).astype(np.float32)
    y = np.asarray(ops.cb_spmv(sbs, jnp.asarray(x), impl="pallas",
                               interpret=True))
    expected = dense_oracle(rows, cols, vals.astype(np.float32), shape, x)
    np.testing.assert_allclose(y, expected, rtol=3e-4, atol=3e-4)


def test_group_size_not_dividing_block_count():
    """Ragged tail groups (num_blocks % G != 0) must pack without loss."""
    scn = Scenario("uniform", 8)
    cb = scn.build()
    num_blocks = cb.num_blocks
    G = 7
    assert num_blocks % G != 0, "pick a G that leaves a ragged tail"
    _, _, _, shape = scn.build_coo()
    rows, cols, vals, _ = scn.build_coo()
    sbs = build_super_streams(cb, group_size=G).device_put()
    x = np.random.default_rng(1).standard_normal(shape[1]).astype(np.float32)
    y = np.asarray(ops.cb_spmv(sbs, jnp.asarray(x), impl="pallas",
                               interpret=True))
    expected = dense_oracle(rows, cols, vals.astype(np.float32), shape, x)
    np.testing.assert_allclose(y, expected, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("G", [1, 4, 16])
def test_super_stream_packing_invariants(G):
    """Structure of the packed streams, independent of numerics."""
    scn = Scenario("bucket_widths", 8, True)
    cb = scn.build()
    streams = build_streams(cb)
    sbs = build_super_streams(cb, group_size=G)
    B = cb.block_size

    assert sbs.group_size == G
    # block-count conservation: slots with a nonzero brow or payload
    # cover every real block exactly once per format
    assert (sbs.num_dense_groups * G >= streams.num_dense
            and sbs.num_panel_groups * G >= streams.num_panel
            and sbs.num_coo_groups * G >= streams.num_coo)
    # value mass is conserved exactly (permutation, never arithmetic)
    for packed, flat in (
        (sbs.dense_tiles, streams.dense_tiles),
        (sbs.panel_vals, streams.panel_vals),
        (sbs.coo_vals, streams.coo_vals),
    ):
        np.testing.assert_array_equal(
            np.sort(np.asarray(packed).ravel()[np.asarray(packed).ravel() != 0]),
            np.sort(np.asarray(flat).ravel()[np.asarray(flat).ravel() != 0]),
        )
    # slot structure: one brow entry per SUBLANE lane chunk, rows in range
    from repro.core.streams import SUBLANE
    if sbs.num_panel_groups:
        assert sbs.panel_brow.shape[1] == sbs.panel_vals.shape[-1] // SUBLANE
        assert np.asarray(sbs.panel_brow).max() < cb.shape[0]
    if sbs.num_coo_groups:
        assert sbs.coo_brow.shape[1] == sbs.coo_codes.shape[-1] // SUBLANE
        assert np.asarray(sbs.coo_brow).max() < cb.shape[0]
    # bucketed padding never exceeds the global-max padding it replaces
    uw = streams.padded_work()
    sw = sbs.padded_work()
    Kp = streams.panel_vals.shape[-1]
    Ep = streams.coo_codes.shape[-1]
    if streams.num_panel:
        regroup_panel = -(-streams.num_panel // G) * B * G * Kp
        assert sw["panel"] <= regroup_panel
    if streams.num_coo:
        regroup_coo = -(-streams.num_coo // G) * G * Ep
        assert sw["coo"] <= regroup_coo
    assert uw["dense"] <= sw["dense"]  # dense pads empty slots only


def test_empty_streams_have_zero_width():
    """The padding policy: absent formats allocate nothing (no phantom
    (0, B, 8) buffers from a silent minimum)."""
    m = n = 32
    rr, cc = np.meshgrid(np.arange(m), np.arange(n), indexing="ij")
    v = np.ones(m * n, np.float32)
    cb = CBMatrix.from_coo(rr.ravel(), cc.ravel(), v, (m, n), block_size=16,
                           val_dtype=np.float32)
    s = build_streams(cb)
    assert s.num_dense == 4
    assert s.num_panel == 0 and s.panel_vals.shape == (0, 16, 0)
    assert s.num_coo == 0 and s.coo_codes.shape == (0, 0)
    # and the widths that DO exist are sublane-aligned
    scn2 = Scenario("uniform", 16, True)
    s2 = build_streams(scn2.build())
    if s2.num_panel:
        assert s2.panel_vals.shape[-1] == pad_width(s2.panel_vals.shape[-1])
    if s2.num_coo:
        assert s2.coo_codes.shape[-1] == pad_width(s2.coo_codes.shape[-1])
