"""Per-architecture smoke tests: REDUCED same-family configs, one
forward/train step on CPU, output shapes + no NaNs + decode consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ModelConfig
from repro.models import Model
from repro.models import ssm as ssm_mod


def _batch_for(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), cfg.activation_dtype
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_frames, cfg.d_model), cfg.activation_dtype
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + ("cb-paper",))
def test_arch_smoke(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params, axes = model.init(key)
    # axes tree matches params tree structurally
    jax.tree_util.tree_map(
        lambda p, a: None, params, axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    batch = _batch_for(cfg, key)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))

    # one train-ish step: grads exist and are finite
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(t))) for t in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0, arch

    # decode one token
    st = model.init_decode_state(2, 64)
    logits, st2 = model.decode_step(
        params, st, batch["tokens"][:, :1], jnp.zeros((2,), jnp.int32)
    )
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x7b", "mamba2-130m",
                                  "zamba2-2.7b", "whisper-small"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full forward logits (f32 numerics)."""
    cfg = get_smoke_config(arch).scaled(dtype="float32")
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = model.init(key)
    B, S = 2, 8
    batch = _batch_for(cfg, key, B=B, S=S)
    toks = batch["tokens"]

    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = batch["frames"]
    out = model.forward(params, toks, **kw)

    st = model.init_decode_state(B, S + 4)
    if cfg.family == "encdec":
        from repro.models import encdec
        st["cross"] = encdec.precompute_cross(params, cfg, batch["frames"])
    dec_logits = []
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, st = model.decode_step(params, st, toks[:, t : t + 1], pos)
        dec_logits.append(lg)
    dec = jnp.stack(dec_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(out.logits), rtol=2e-3, atol=2e-3
    )


def test_ssd_chunked_matches_sequential():
    """SSD chunked scan == step-by-step recurrence (the duality claim)."""
    cfg = get_smoke_config("mamba2-130m").scaled(dtype="float32")
    rng = jax.random.PRNGKey(3)
    B, L, nh, hd, ds = 2, 32, 4, 16, 8
    ks = jax.random.split(rng, 4)
    xh = jax.random.normal(ks[0], (B, L, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 9), (B, L, ds))

    y_chunk, S_last = ssm_mod.ssd_chunked(xh, dt, A, Bm, Cm, chunk=8)

    # sequential reference
    S = jnp.zeros((B, nh, hd, ds))
    ys = []
    for t in range(L):
        decay = jnp.exp(dt[:, t] * A[None, :])
        S = decay[:, :, None, None] * S + jnp.einsum(
            "bh,bhp,bs->bhps", dt[:, t], xh[:, t], Bm[:, t]
        )
        ys.append(jnp.einsum("bs,bhps->bhp", Cm[:, t], S))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_last), np.asarray(S),
                               rtol=1e-4, atol=1e-4)


def test_swa_masks_distant_keys():
    """Sliding-window attention must ignore keys outside the window.

    Uses a dense arch: in MoE, capacity clipping legitimately couples
    distant tokens through the router, which would mask the SWA property.
    """
    cfg = get_smoke_config("granite-8b").scaled(dtype="float32",
                                                swa_window=32)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 1, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    out1 = model.forward(params, toks)
    # perturb a token far outside the window of the last position
    w = cfg.swa_window
    assert S - 1 - 0 >= w, "test requires seq > window"
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    out2 = model.forward(params, toks2)
    # last position logits unchanged (token 0 is > window away)
    np.testing.assert_allclose(
        np.asarray(out1.logits[0, -1]), np.asarray(out2.logits[0, -1]),
        rtol=1e-4, atol=1e-4,
    )
    # but nearby positions DO change
    assert not np.allclose(np.asarray(out1.logits[0, 1]),
                           np.asarray(out2.logits[0, 1]), atol=1e-5)


def test_vocab_padding_never_predicted():
    cfg = get_smoke_config("granite-8b").scaled(vocab_size=500)  # pads to 512
    assert cfg.padded_vocab == 512
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 500)
    out = model.forward(params, toks)
    logits = np.asarray(out.logits, np.float32)
    assert logits.shape[-1] == 512
    assert (logits[..., 500:] < -1e8).all()


def test_scan_vs_unrolled_same_result():
    cfg = get_smoke_config("granite-8b").scaled(dtype="float32")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    m1 = Model(cfg)
    params, _ = m1.init(jax.random.PRNGKey(0))
    out1 = m1.forward(params, toks)
    m2 = Model(cfg.scaled(scan_layers=False, attn_unroll=True))
    out2 = m2.forward(params, toks)
    np.testing.assert_allclose(np.asarray(out1.logits), np.asarray(out2.logits),
                               rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and perfect balance, few tokens drop; the
    layer must stay finite and near-dense quality on random inputs."""
    cfg = get_smoke_config("mixtral-8x7b").scaled(dtype="float32")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size)
    out = model.forward(params, toks)
    assert np.isfinite(np.asarray(out.logits)).all()
    assert float(out.aux_loss) > 0
