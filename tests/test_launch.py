"""Launch-layer unit tests: collective parser, probe extrapolation, rules.

These run WITHOUT the 512-device flag (pure functions) — the compile-level
behaviour is covered by the dry-run sweep itself (experiments/).
"""
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import input_specs, supports_shape


def test_parse_collectives_kinds_and_groups():
    from repro.launch.dryrun import parse_collectives

    hlo = "\n".join([
        # all-reduce: operand == result
        "%all-reduce.1 = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add",
        # all-gather over 4: operand = result / 4
        "%all-gather.1 = bf16[4,256]{1,0} all-gather(%p1), replica_groups=[2,4]<=[8], dimensions={0}",
        # reduce-scatter over 2: operand = result * 2
        "%reduce-scatter.5 = f32[128]{0} reduce-scatter(%p2), replica_groups={{0,1}}, to_apply=%add",
        "%collective-permute.2 = bf16[64]{0} collective-permute(%p3), source_target_pairs={{0,1}}",
        "%fusion.9 = f32[9]{0} fusion(%x), kind=kLoop",   # not a collective
    ])
    out = parse_collectives(hlo)
    assert out["all-reduce"] == {"count": 1, "bytes": 4096}
    assert out["all-gather"] == {"count": 1, "bytes": 4 * 256 * 2 // 4 * 4 // 4 * 1 or 512}
    assert out["all-gather"]["bytes"] == 4 * 256 * 2 // 4  # 2048/4=512
    assert out["reduce-scatter"]["bytes"] == 128 * 4 * 2
    assert out["collective-permute"]["bytes"] == 64 * 2
    assert out["total_bytes"] == (
        4096 + 512 + 1024 + 128
    )


def test_probe_extrapolation_linear():
    from repro.launch.dryrun import _extrapolate

    cfg = get_config("granite-8b")  # 36 layers
    # cost(L) = 100 + 7L
    samples = [({"l": 2}, 114.0), ({"l": 4}, 128.0)]
    assert abs(_extrapolate(cfg, samples) - (100 + 7 * 36)) < 1e-6


def test_probe_extrapolation_hybrid_two_species():
    from repro.launch.dryrun import _extrapolate

    cfg = get_config("zamba2-2.7b")  # 54 mamba layers, attn every 6 -> 9
    a, bm, bs = 50.0, 3.0, 11.0
    samples = [
        ({"m": 2, "s": 2}, a + 2 * bm + 2 * bs),
        ({"m": 4, "s": 4}, a + 4 * bm + 4 * bs),
        ({"m": 4, "s": 2}, a + 4 * bm + 2 * bs),
    ]
    expected = a + 54 * bm + 9 * bs
    assert abs(_extrapolate(cfg, samples) - expected) < 1e-6


def test_supports_shape_matrix():
    runs_long = {"mixtral-8x7b", "mamba2-130m", "zamba2-2.7b"}
    from repro.configs import ARCH_IDS

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, why = supports_shape(cfg, SHAPES["long_500k"])
        assert ok == (arch in runs_long), (arch, why)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports_shape(cfg, SHAPES[s])[0]


def test_input_specs_cover_all_cells():
    from repro.configs import ARCH_IDS

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            assert specs["tokens"].shape[0] == shape.global_batch
            if shape.kind == "decode":
                assert specs["tokens"].shape[1] == 1
                assert "pos" in specs
            if cfg.family == "encdec":
                assert specs["frames"].shape[1] == cfg.num_frames
            if cfg.family == "vlm" and shape.kind != "decode":
                assert specs["patch_embeds"].shape[1] == cfg.num_patches


def test_rules_for_decode_and_moe():
    import jax

    from repro.launch.mesh import make_production_mesh, rules_for

    # rules logic is mesh-shape-dependent only; a tiny stand-in mesh with
    # the same axis NAMES would need 256 devices — use the shape API via a
    # mock object instead.
    class M:
        shape = {"data": 16, "model": 16}
        size = 256

    granite = get_config("granite-8b")
    r = rules_for(granite, SHAPES["decode_32k"], M())
    assert r["kv_seq"] == "model" and r["kv"] is None and r["heads"] is None
    r = rules_for(granite, SHAPES["long_500k"], M())
    assert r["batch"] is None

    mixtral = get_config("mixtral-8x7b")   # 8 experts < 16
    r = rules_for(mixtral, SHAPES["train_4k"], M())
    assert r["experts"] is None and r["expert_mlp"] == "model"

    llama4 = get_config("llama4-maverick-400b-a17b")  # 128 % 16 == 0
    r = rules_for(llama4, SHAPES["train_4k"], M())
    assert "experts" not in r  # EP default kept

    whisper = get_config("whisper-small")  # 12 heads < 16
    r = rules_for(whisper, SHAPES["train_4k"], M())
    assert r["heads"] is None


def test_vocab_padding_values():
    assert get_config("mamba2-130m").padded_vocab == 50432   # 50280 -> 197*256
    assert get_config("qwen3-32b").padded_vocab == 152064    # 151936 -> 594*256
    assert get_config("granite-8b").padded_vocab == 49152    # already a multiple
    assert get_config("whisper-small").padded_vocab % 256 == 0


def test_spec_for_under_rules():
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.models.sharding import axis_rules, spec_for

    mesh = compat.make_mesh((1,), ("model",))
    with axis_rules(mesh, {"mlp": "model"}):
        assert spec_for(("batch", "mlp")) == P(None, "model")
        assert spec_for((None, "embed")) == P(None, None)
