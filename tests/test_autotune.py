"""Unit tests for the autotune subsystem + its threading through the stack."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune import (
    CANDIDATE_BLOCK_SIZES,
    CandidateConfig,
    DEFAULT_CONFIG,
    Plan,
    PlanCache,
    SearchSettings,
    default_candidates,
    estimate,
    extract_features,
    features_from_cb,
    matrix_content_hash,
    plan_search,
    rank,
)
from repro.core import CBMatrix
from repro.core.formats import (
    DEFAULT_THRESHOLDS, FormatThresholds, coerce_thresholds, select_formats,
)
from repro.core.streams import (
    MAX_GROUP_SIZE,
    TARGET_STEP_ELEMS,
    build_super_streams,
    build_super_tile_stream,
    group_size_for,
    tile_stream_from_cb,
)
from repro.data import matrices
from repro.kernels import ops
from repro.solvers import CBLinearOperator


def _coo(seed=0, m=160, n=144):
    r, c, v = matrices.power_law(m, n, seed=seed)
    return r, c, v.astype(np.float32), (m, n)


def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# group_size_for — the deduplicated occupancy rule (satellite)
# ---------------------------------------------------------------------------

def test_group_size_for_matches_legacy_rule():
    for B in (8, 16, 24, 32, 64):
        legacy = int(min(max(TARGET_STEP_ELEMS // (B * B), 1), MAX_GROUP_SIZE))
        assert group_size_for(B) == legacy


def test_group_size_for_overridable_knobs():
    assert group_size_for(16, target_step_elems=256) == 1
    assert group_size_for(8, max_group=4) == 4
    assert group_size_for(128) == 1  # clamps up to 1


@pytest.mark.parametrize("B", [8, 16, 24])
def test_builders_route_through_group_size_for(B):
    """group_size=None and group_size=group_size_for(B) are bit-identical."""
    r, c, v, shape = _coo(seed=3)
    cb = CBMatrix.from_coo(r, c, v, shape, block_size=B,
                           val_dtype=np.float32)
    auto_s = build_super_streams(cb)
    expl_s = build_super_streams(cb, group_size=group_size_for(B))
    assert auto_s.group_size == group_size_for(B)
    assert _tree_equal(auto_s, expl_s)

    ts = tile_stream_from_cb(cb)
    auto_t = build_super_tile_stream(ts)
    expl_t = build_super_tile_stream(ts, group_size=group_size_for(B))
    assert auto_t.group_size == group_size_for(B)
    assert _tree_equal(auto_t, expl_t)


# ---------------------------------------------------------------------------
# formats: named constraint errors + Plan acceptance (satellite)
# ---------------------------------------------------------------------------

def test_resolve_errors_name_the_offending_constraint():
    with pytest.raises(ValueError, match="th1 must be >= 1"):
        FormatThresholds(th1=0).resolve(16)
    with pytest.raises(ValueError, match="th2 must be >= th1"):
        FormatThresholds(th1=100, th2=50).resolve(16)
    with pytest.raises(ValueError, match="th2 must be <= B\\*B"):
        FormatThresholds(th2=257).resolve(16)


def _mini_plan(**overrides):
    kw = dict(
        structure_hash="0" * 64, shape=(16, 16), nnz=4, val_dtype="float32",
        block_size=16, th0=0.15, th1=4, th2=32, colagg=False, group_size=4,
        mode="heuristic", predicted_padded_elems=100, predicted_steps=2,
        measured_padded_elems=90, measured_steps=2,
    )
    kw.update(overrides)
    return Plan(**kw)


def test_select_formats_accepts_plan():
    plan = _mini_plan()
    nnz = np.array([1, 10, 200])
    np.testing.assert_array_equal(
        select_formats(nnz, 16, plan),
        select_formats(nnz, 16, FormatThresholds(th1=4, th2=32)),
    )
    with pytest.raises(TypeError, match="FormatThresholds"):
        coerce_thresholds(42)


def test_from_coo_accepts_plan_as_thresholds():
    r, c, v, shape = _coo(seed=5)
    plan = _mini_plan(shape=shape)
    cb = CBMatrix.from_coo(r, c, v, shape, block_size=16,
                           val_dtype=np.float32, thresholds=plan)
    assert cb.thresholds == FormatThresholds(th0=0.15, th1=4, th2=32)


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------

def test_features_exact_on_handmade_matrix():
    # two blocks at B=8 in a 16x16 matrix: block (0,0) holds 3 elements in
    # 2 distinct columns; block (1,1) holds 1 element.
    rows = np.array([0, 1, 2, 9])
    cols = np.array([0, 0, 3, 10])
    vals = np.ones(4, np.float32)
    f = extract_features(rows, cols, vals, (16, 16), block_sizes=(8,))
    p = f.profile(8)
    assert p.num_blocks == 2
    np.testing.assert_array_equal(np.sort(p.nnz_per_block), [1, 3])
    np.testing.assert_array_equal(np.sort(p.cols_per_block), [1, 2])
    np.testing.assert_array_equal(np.sort(p.panel_nnz), [1, 3])
    np.testing.assert_array_equal(np.sort(p.panel_cols), [1, 2])
    assert f.nnz == 4
    assert f.row_nnz_max == 1
    assert p.super_sparse_fraction == 1.0  # all blocks < 16 nnz
    with pytest.raises(KeyError, match="no block profile"):
        f.profile(16)


def test_features_from_cb_match_raw_triplets():
    r, c, v, shape = _coo(seed=8)
    cb = CBMatrix.from_coo(r, c, v, shape, block_size=16,
                           val_dtype=np.float32)
    f_raw = extract_features(r, c, v, shape)
    f_cb = features_from_cb(cb)
    assert f_cb.nnz == f_raw.nnz
    for B in CANDIDATE_BLOCK_SIZES:
        np.testing.assert_array_equal(
            np.sort(f_cb.profile(B).nnz_per_block),
            np.sort(f_raw.profile(B).nnz_per_block),
        )


def test_to_coo_roundtrip():
    r, c, v, shape = _coo(seed=9)
    for colagg in (True, False):
        cb = CBMatrix.from_coo(r, c, v, shape, block_size=16,
                               val_dtype=np.float32,
                               use_column_aggregation=colagg)
        r2, c2, v2 = cb.to_coo()
        dense = np.zeros(shape, np.float32)
        dense[r2, c2] = v2
        np.testing.assert_array_equal(dense, cb.to_dense())
        # canonical order: strictly increasing (row, col) keys
        key = r2 * shape[1] + c2
        assert np.all(np.diff(key) > 0)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_estimate_exact_without_colagg():
    """For colagg=False the model's padded work is exact stream arithmetic
    whenever the balancer hits its target width (single-group case)."""
    r, c, v, shape = _coo(seed=4)
    cfg = CandidateConfig(colagg=False, group_size=16)
    f = extract_features(r, c, v, shape)
    est = estimate(f, cfg)
    cb = CBMatrix.from_coo(r, c, v, shape, block_size=16,
                           val_dtype=np.float32,
                           use_column_aggregation=False)
    s = build_super_streams(cb, group_size=16)
    measured = sum(s.padded_work().values())
    steps = (s.num_dense_groups + s.num_panel_groups + s.num_coo_groups)
    assert est.steps == steps
    # balancing can cost up to one extra width bucket per group
    assert measured <= est.padded_elems * 1.25 + 1024
    assert est.padded_elems <= measured * 1.25 + 1024


def test_rank_is_deterministic_and_default_first_on_ties():
    r, c, v, shape = _coo(seed=6)
    f = extract_features(r, c, v, shape)
    cands = default_candidates()
    assert cands[0] == DEFAULT_CONFIG
    r1 = rank(f, cands)
    r2 = rank(f, cands)
    assert [c for c, _ in r1] == [c for c, _ in r2]
    assert all(a[1].score <= b[1].score for a, b in zip(r1, r1[1:]))


def test_group_size_tradeoff_visible_to_model():
    """G=1 must lose to the occupancy heuristic on a many-block matrix
    (step overhead), even though it minimizes padding."""
    r, c, v, shape = _coo(seed=2, m=512, n=512)
    f = extract_features(r, c, v, shape)
    small_g = estimate(f, CandidateConfig(group_size=1))
    auto_g = estimate(f, CandidateConfig())
    assert small_g.steps > auto_g.steps
    assert small_g.score > auto_g.score


# ---------------------------------------------------------------------------
# plan + cache
# ---------------------------------------------------------------------------

def test_plan_save_load_roundtrip(tmp_path):
    plan = _mini_plan(t_spmv=1.5e-4, th1=None, th2=None)
    path = tmp_path / "p.json"
    plan.save(path)
    assert Plan.load(path) == plan
    # schema rejection
    d = plan.to_json()
    assert d["schema"] == "cb-plan/v2"
    d["schema"] = "cb-plan/v0"
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="neither"):
        Plan.load(bad)


def test_plan_is_hashable_static_arg():
    p1, p2 = _mini_plan(), _mini_plan()
    assert hash(p1) == hash(p2)
    assert len({p1, p2}) == 1


def test_content_hash_canonicalization():
    r = np.array([3, 1, 2])
    c = np.array([0, 1, 2])
    v = np.array([1.0, 2.0, 3.0], np.float32)
    h1 = matrix_content_hash(r, c, v, (4, 4))
    perm = np.array([2, 0, 1])
    h2 = matrix_content_hash(r[perm], c[perm], v[perm], (4, 4))
    assert h1 == h2  # order-invariant
    v2 = v.copy()
    v2[0] = 9.0
    assert matrix_content_hash(r, c, v2, (4, 4)) != h1   # value-sensitive
    assert matrix_content_hash(r, c, v, (4, 5)) != h1    # shape-sensitive
    assert matrix_content_hash(r, c, v, (4, 4),
                               val_dtype=np.float64) != h1  # dtype-sensitive


def test_plan_cache_miss_put_hit_and_corruption(tmp_path):
    cache = PlanCache(tmp_path / "plans")
    plan = _mini_plan(structure_hash="a" * 64)
    assert cache.get(plan.structure_hash) is None
    cache.put(plan)
    assert cache.get(plan.structure_hash) == plan
    assert (cache.hits, cache.misses) == (1, 1)
    assert cache.hit_rate == 0.5

    # corrupted file = miss, not crash
    with open(cache.path_for("b" * 64), "w") as f:
        f.write("{ not json")
    assert cache.get("b" * 64) is None

    # hash mismatch inside the file = miss (stale/renamed entry)
    other = _mini_plan(structure_hash="c" * 64)
    other.save(cache.path_for("d" * 64))
    assert cache.get("d" * 64) is None
    assert (cache.hits, cache.misses, cache.stale) == (1, 3, 0)


def test_plan_cache_stale_validation(tmp_path):
    """A plan that loads but fails check_valid is a counted stale miss."""
    cache = PlanCache(tmp_path / "plans")
    plan = _mini_plan(structure_hash="a" * 64, shape=(16, 16), nnz=4)
    cache.put(plan)
    # wrong shape -> stale miss, not a crash and not a hit
    assert cache.get("a" * 64, shape=(32, 32)) is None
    assert (cache.hits, cache.misses, cache.stale) == (0, 1, 1)
    # wrong nnz -> stale miss
    assert cache.get("a" * 64, shape=(16, 16), nnz=99) is None
    assert (cache.hits, cache.misses, cache.stale) == (0, 2, 2)
    # matching matrix -> clean hit
    assert cache.get("a" * 64, shape=(16, 16), nnz=4) == plan
    assert (cache.hits, cache.misses, cache.stale) == (1, 2, 2)


def test_plan_check_valid_reasons():
    assert _mini_plan().check_valid() is None
    assert "shape" in _mini_plan(shape=(0, 4)).check_valid()
    assert "block_size" in _mini_plan(block_size=0).check_valid()
    assert "group_size" in _mini_plan(group_size=0).check_valid()
    # thresholds that cannot resolve at the plan's block size
    assert "thresholds" in _mini_plan(th1=100, th2=50).check_valid()
    r = _mini_plan().check_valid(shape=(99, 99))
    assert "plan was made for shape" in r
    assert _mini_plan().check_valid(shape=(16, 16), nnz=4) is None


def test_plan_cache_v1_migration_single_hit(tmp_path):
    """A v1 plan file read through the legacy probe = exactly one hit,
    and the entry is re-keyed under the structure hash (v2 schema)."""
    from repro.autotune import PLAN_SCHEMA_V1

    cache = PlanCache(tmp_path / "plans")
    legacy_key = "e" * 64
    struct_key = "f" * 64
    # fabricate the file a v1 process would have written
    v1 = _mini_plan(structure_hash=legacy_key)
    d = v1.to_json()
    d["schema"] = PLAN_SCHEMA_V1
    d["matrix_hash"] = d.pop("structure_hash")
    d.pop("value_hash")
    with open(cache.path_for(legacy_key), "w") as f:
        json.dump(d, f)

    got = cache.get(struct_key, legacy_hash=legacy_key,
                    shape=(16, 16), nnz=4)
    assert got is not None
    assert got.structure_hash == struct_key
    assert got.value_hash is None
    assert (cache.hits, cache.misses, cache.stale) == (1, 0, 0)

    # migration persisted: the v2 probe now hits directly
    with open(cache.path_for(struct_key)) as f:
        assert json.load(f)["schema"] == "cb-plan/v2"
    assert cache.get(struct_key, shape=(16, 16), nnz=4) == got
    assert (cache.hits, cache.misses) == (2, 0)


def test_structure_hash_ignores_values_and_dtype():
    from repro.autotune import matrix_hashes, structure_hash, value_hash

    r = np.array([3, 1, 2])
    c = np.array([0, 1, 2])
    v = np.array([1.0, 2.0, 3.0], np.float32)
    h = matrix_hashes(r, c, v, (4, 4))
    assert h.nnz == 3
    v2 = v.copy(); v2[0] = 9.0
    h2 = matrix_hashes(r, c, v2, (4, 4))
    assert h2.structure == h.structure      # pattern unchanged
    assert h2.value != h.value              # values changed
    # dtype rides the value hash only
    h3 = matrix_hashes(r, c, v, (4, 4), val_dtype=np.float64)
    assert h3.structure == h.structure
    assert h3.value != h.value
    # shape is structural
    assert matrix_hashes(r, c, v, (4, 5)).structure != h.structure
    # thin wrappers agree
    assert structure_hash(r, c, v, (4, 4)) == h.structure
    assert value_hash(r, c, v, (4, 4)) == h.value


def test_hash_explicit_zero_and_duplicate_aliasing():
    """Original triplets (explicit zeros, split duplicates) and their CB
    round trip hash identically — the v1 aliasing defect."""
    from repro.autotune import matrix_hashes

    rows = np.array([0, 0, 2, 5, 5])
    cols = np.array([1, 3, 2, 4, 4])
    vals = np.array([1.0, 0.0, 3.0, 2.0, 2.5], np.float32)  # dup + zero
    cb = CBMatrix.from_coo(rows, cols, vals, (8, 8), block_size=8,
                           val_dtype=np.float32)
    r2, c2, v2 = cb.to_coo()
    assert len(r2) < len(rows)  # the round trip really canonicalized
    h_orig = matrix_hashes(rows, cols, vals, (8, 8))
    h_rt = matrix_hashes(r2, c2, v2, (8, 8))
    assert h_orig == h_rt


def test_plan_cache_aliasing_regression(tmp_path):
    """plan_search on original vs round-tripped triplets shares ONE cache
    entry: second lookup is a hit, and only one plan file exists."""
    import os

    rows = np.array([0, 0, 2, 5, 5, 9])
    cols = np.array([1, 3, 2, 4, 4, 9])
    vals = np.array([1.0, 0.0, 3.0, 2.0, 2.5, -1.0], np.float32)
    shape = (16, 16)
    cb = CBMatrix.from_coo(rows, cols, vals, shape, block_size=16,
                           val_dtype=np.float32)
    r2, c2, v2 = cb.to_coo()

    cache = PlanCache(tmp_path / "plans")
    p1 = plan_search(rows, cols, vals, shape, cache=cache)
    assert (cache.hits, cache.misses) == (0, 1)
    p2 = plan_search(r2, c2, v2, shape, cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)
    assert p1 == p2
    files = [f for f in os.listdir(cache.directory)
             if f.endswith(".plan.json")]
    assert len(files) == 1


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def test_search_never_regresses_padded_work_vs_default():
    for seed in range(4):
        r, c, v, shape = _coo(seed=seed)
        plan = plan_search(r, c, v, shape)
        cb_def = CBMatrix.from_coo(r, c, v, shape, block_size=16,
                                   val_dtype=np.float32)
        default_padded = sum(
            build_super_streams(cb_def).padded_work().values()
        )
        assert plan.measured_padded_elems <= default_padded
        assert plan.mode == "heuristic"


def test_search_settings_thread_through():
    r, c, v, shape = _coo(seed=1)
    only_default = SearchSettings(candidates=(DEFAULT_CONFIG,), top_k=1)
    plan = plan_search(r, c, v, shape, settings=only_default)
    assert plan.block_size == 16
    assert plan.group_size == group_size_for(16)
    with pytest.raises(ValueError, match="unknown search mode"):
        plan_search(r, c, v, shape,
                    settings=SearchSettings(mode="warp-speed"))


def test_search_single_element_matrix():
    rows = np.array([5]); cols = np.array([3])
    vals = np.array([2.5], np.float32)
    plan = plan_search(rows, cols, vals, (9, 7))
    cb = CBMatrix.from_plan(rows, cols, vals, (9, 7), plan)
    np.testing.assert_allclose(cb.to_dense()[5, 3], 2.5)


# ---------------------------------------------------------------------------
# plan threading: ops / operator / sparse linear
# ---------------------------------------------------------------------------

def _planned_setup(seed=11):
    r, c, v, shape = _coo(seed=seed)
    plan = plan_search(r, c, v, shape)
    cb = CBMatrix.from_plan(r, c, v, shape, plan)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(shape[1]),
                    jnp.float32)
    return r, c, v, shape, plan, cb, x


def test_cb_spmv_plan_equals_group_size():
    from repro.core.streams import build_streams

    _, _, _, _, plan, cb, x = _planned_setup()
    flat = build_streams(cb).device_put()
    y_plan = ops.cb_spmv(flat, x, impl="reference", plan=plan)
    y_group = ops.cb_spmv(flat, x, impl="reference",
                          group_size=plan.group_size)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_group))
    with pytest.raises(ValueError, match="conflicting"):
        ops.cb_spmv(flat, x, plan=plan, group_size=plan.group_size + 1)


def test_cb_spmv_plan_block_size_mismatch():
    from repro.core.streams import build_streams

    r, c, v, shape, plan, cb, x = _planned_setup()
    other_B = 8 if plan.block_size != 8 else 16
    cb_other = CBMatrix.from_coo(r, c, v, shape, block_size=other_B,
                                 val_dtype=np.float32)
    flat = build_streams(cb_other).device_put()
    with pytest.raises(ValueError, match="block_size"):
        ops.cb_spmv(flat, x, plan=plan)


def test_cb_spmm_plan_equals_group_size():
    _, _, _, shape, plan, cb, _ = _planned_setup()
    ts = tile_stream_from_cb(cb)
    ts = jax.tree_util.tree_map(jnp.asarray, ts)
    X = jnp.asarray(
        np.random.default_rng(1).standard_normal((shape[1], 8)), jnp.float32
    )
    y_plan = ops.cb_spmm(ts, X, impl="reference", plan=plan)
    y_group = ops.cb_spmm(ts, X, impl="reference",
                          group_size=plan.group_size)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_group))


def test_operator_plan_modes(tmp_path):
    r, c, v, shape = _coo(seed=12)
    cb = CBMatrix.from_coo(r, c, v, shape, block_size=16,
                           val_dtype=np.float32)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(shape[1]),
                    jnp.float32)

    cache = PlanCache(tmp_path / "plans")
    op_auto = CBLinearOperator.from_cb(cb, plan="auto", plan_cache=cache)
    assert op_auto.plan is not None
    assert op_auto.block_size == op_auto.plan.block_size
    assert op_auto.streams.group_size == op_auto.plan.group_size

    # explicit Plan object path is bit-identical to the auto path
    op_plan = CBLinearOperator.from_cb(cb, plan=op_auto.plan)
    y_auto = np.asarray(op_auto.matvec(x, impl="reference"))
    y_plan = np.asarray(op_plan.matvec(x, impl="reference"))
    np.testing.assert_array_equal(y_auto, y_plan)

    # tuned result matches the untuned operator's math
    y_default = np.asarray(CBLinearOperator.from_cb(cb).matvec(
        x, impl="reference"))
    np.testing.assert_allclose(y_auto, y_default, rtol=1e-5, atol=1e-5)

    with pytest.raises(ValueError, match="not both"):
        CBLinearOperator.from_cb(cb, plan="auto", group_size=4)
    with pytest.raises(ValueError, match="unknown plan mode"):
        CBLinearOperator.from_cb(cb, plan="bogus")


def test_sparse_linear_plan_threading():
    import jax as _jax
    from repro.sparse.linear import (
        cb_linear_apply, cb_linear_init,
    )

    params, spec = cb_linear_init(
        _jax.random.PRNGKey(0), 64, 48, block_size=16, keep_fraction=0.5
    )
    x = _jax.random.normal(_jax.random.PRNGKey(1), (4, 64))
    plan = _mini_plan(block_size=16, group_size=4)
    y_plan = cb_linear_apply(params, spec, x, plan=plan)
    y_group = cb_linear_apply(params, spec, x, group_size=4)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_group))
    with pytest.raises(ValueError, match="conflicting"):
        cb_linear_apply(params, spec, x, plan=plan, group_size=8)
