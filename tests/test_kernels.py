"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True).

Every kernel is validated against (a) its stream-level oracle in
kernels/ref.py and (b) the independent dense oracle, across matrix
families, block sizes, dtypes, and column-aggregation settings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CBMatrix
from repro.core.spmv_ref import dense_oracle
from repro.core.streams import build_streams, build_tile_stream
from repro.data import matrices
from repro.kernels import cb_block_dense, cb_colagg, cb_coo, ops, ref


def _dense_of(r, c, v, shape):
    d = np.zeros(shape, np.float32)
    np.add.at(d, (r, c), v.astype(np.float32))
    return d


@pytest.mark.parametrize("family,kw", [
    ("uniform", dict(density=0.01)),
    ("power_law", {}),
    ("banded", {}),
    ("block_clustered", {}),
])
@pytest.mark.parametrize("B", [8, 16])
@pytest.mark.parametrize("colagg", [True, False])
def test_cb_spmv_kernel_sweep(family, kw, B, colagg):
    m, n = 144, 128
    r, c, v = matrices.FAMILIES[family](m, n, seed=7, **kw)
    cb = CBMatrix.from_coo(r, c, v, (m, n), block_size=B,
                           val_dtype=np.float32,
                           use_column_aggregation=colagg)
    s = build_streams(cb).device_put()
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    expected = dense_oracle(r, c, v.astype(np.float32), (m, n), x)
    got_pl = ops.cb_spmv(s, jnp.asarray(x), impl="pallas", interpret=True)
    got_ref = ops.cb_spmv(s, jnp.asarray(x), impl="reference")
    np.testing.assert_allclose(np.asarray(got_pl), expected, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(got_ref), expected, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_cb_spmv_dtypes(dtype):
    m, n = 96, 96
    r, c, v = matrices.power_law(m, n, seed=1)
    cb = CBMatrix.from_coo(r, c, v, (m, n), block_size=16, val_dtype=dtype)
    s = build_streams(cb).device_put()
    x = np.random.default_rng(0).standard_normal(n).astype(dtype)
    got = ops.cb_spmv(s, jnp.asarray(x), impl="pallas", interpret=True)
    expected = dense_oracle(r, c, v.astype(dtype), (m, n),
                            x.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got), expected, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("G", [1, 3])
def test_block_dense_kernel_unit(G):
    """batched dense-tile kernel vs its own oracle on a controlled stream."""
    rng = np.random.default_rng(0)
    gd, B, mb = 4, 16, 5
    tiles = rng.standard_normal((gd, G * B, B)).astype(np.float32)
    brow = rng.integers(0, mb, (gd, G)).astype(np.int32)
    xg = rng.standard_normal((gd, G, B)).astype(np.float32)
    part = cb_block_dense.block_dense_spmv_batched(
        jnp.asarray(tiles), jnp.asarray(xg), interpret=True
    )
    assert part.shape == (gd, G, B)
    y = np.zeros((mb, B), np.float32)
    np.add.at(y, brow.reshape(-1), np.asarray(part).reshape(-1, B))
    expected = ref.block_dense_spmv(
        jnp.asarray(tiles.reshape(gd * G, B, B)),
        jnp.asarray(brow.reshape(-1)),
        jnp.asarray(xg.reshape(gd * G, B)), mb,
    )
    np.testing.assert_allclose(y, np.asarray(expected), rtol=1e-4, atol=1e-4)


def test_coo_kernel_packs_paper_layout():
    """Alg. 3 bit layout: the kernel must decode col<<bits|row."""
    B = 16
    codes = np.zeros((1, 8), np.int32)
    codes[0, :2] = [(3 << 4) | 5, (0 << 4) | 0]
    vals = np.zeros((1, 8), np.float32)
    vals[0, :2] = [2.0, 4.0]                      # lanes 2.. are padding
    xg = np.zeros((1, 8), np.float32)
    xg[0, :2] = [10.0, 100.0]
    out = cb_coo.coo_spmv_batched(
        jnp.asarray(codes), jnp.asarray(vals), jnp.asarray(xg),
        block_size=B, interpret=True,
    )
    out = np.asarray(out)[0, 0]
    assert out[5] == pytest.approx(20.0)   # row 5 <- 2*10
    assert out[0] == pytest.approx(400.0)  # row 0 <- 4*100
    assert np.count_nonzero(out) == 2      # padding contributed nothing


def test_coo_kernel_slots_split_at_sublane_boundaries():
    """Lanes route to the output tile of lane // SUBLANE, not a neighbour."""
    B = 8
    codes = np.zeros((1, 16), np.int32)
    codes[0, 0] = (2 << 3) | 1     # lane 0 -> slot 0, row 1
    codes[0, 8] = (4 << 3) | 1     # lane 8 -> slot 1, row 1
    vals = np.zeros((1, 16), np.float32)
    vals[0, 0], vals[0, 8] = 3.0, 7.0
    xg = np.ones((1, 16), np.float32)
    out = np.asarray(cb_coo.coo_spmv_batched(
        jnp.asarray(codes), jnp.asarray(vals), jnp.asarray(xg),
        block_size=B, interpret=True,
    ))[0]
    assert out.shape == (2, B)
    assert out[0, 1] == pytest.approx(3.0)
    assert out[1, 1] == pytest.approx(7.0)
    assert np.count_nonzero(out) == 2


@pytest.mark.parametrize("K", [8, 16, 24])
def test_panel_kernel_shapes(K):
    rng = np.random.default_rng(2)
    gp, B = 5, 16
    panels = rng.standard_normal((gp, B, K)).astype(np.float32)
    xg = rng.standard_normal((gp, K)).astype(np.float32)
    got = cb_colagg.panel_spmv_batched(
        jnp.asarray(panels), jnp.asarray(xg), interpret=True,
    )
    # slot s sums lanes [8s, 8s+8); summing slots recovers the panel dot
    assert got.shape == (gp, K // 8, B)
    expected = np.einsum("bik,bk->bi", panels, xg)
    np.testing.assert_allclose(np.asarray(got).sum(axis=1), expected,
                               rtol=1e-4, atol=1e-4)
    slot0 = np.einsum("bik,bk->bi", panels[:, :, :8], xg[:, :8])
    np.testing.assert_allclose(np.asarray(got)[:, 0], slot0,
                               rtol=1e-4, atol=1e-4)


def test_panel_kernel_lane_packing():
    """Two panels fused into one slab must match the two separate dots."""
    rng = np.random.default_rng(5)
    B, k0, k1 = 8, 8, 16
    p0 = rng.standard_normal((B, k0)).astype(np.float32)
    p1 = rng.standard_normal((B, k1)).astype(np.float32)
    slab = np.concatenate([p0, p1], axis=1)[None]           # (1, B, 24)
    xg = rng.standard_normal((1, k0 + k1)).astype(np.float32)
    got = np.asarray(cb_colagg.panel_spmv_batched(
        jnp.asarray(slab), jnp.asarray(xg), interpret=True,
    ))[0]
    # p0 owns slot 0; p1 owns slots 1+2 (its partials sum to the full dot)
    np.testing.assert_allclose(got[0], p0 @ xg[0, :k0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got[1] + got[2], p1 @ xg[0, k0:],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [8, 16])
@pytest.mark.parametrize("N", [1, 8, 24])
def test_cb_spmm_sweep(B, N):
    m, n = 120, 104
    r, c, v = matrices.pruned_weight(m, n, block_size=B, seed=3)
    ts = build_tile_stream(r, c, v.astype(np.float32), (m, n), B)
    ts = jax.tree_util.tree_map(jnp.asarray, ts)
    X = np.random.default_rng(1).standard_normal((n, N)).astype(np.float32)
    expected = _dense_of(r, c, v, (m, n)) @ X
    got = ops.cb_spmm(ts, jnp.asarray(X), impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(got), expected, rtol=3e-4, atol=3e-4)
    got_ref = ops.cb_spmm(ts, jnp.asarray(X), impl="reference")
    np.testing.assert_allclose(np.asarray(got_ref), expected, rtol=3e-4, atol=3e-4)


def test_spmm_empty_rows_covered():
    """Block rows with no tiles must still produce zeros (coverage pad)."""
    B = 8
    m, n = 4 * B, 2 * B
    r = np.array([0, 1]); c = np.array([0, 1])   # only block-row 0
    v = np.array([1.0, 2.0], np.float32)
    ts = build_tile_stream(r, c, v, (m, n), B)
    ts = jax.tree_util.tree_map(jnp.asarray, ts)
    X = np.ones((n, 4), np.float32)
    got = np.asarray(ops.cb_spmm(ts, jnp.asarray(X), interpret=True))
    assert got.shape == (m, 4)
    assert np.all(got[B:] == 0)
    np.testing.assert_allclose(got[:2, 0], [1.0, 2.0])
