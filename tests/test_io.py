"""Persistence + ingestion: MatrixMarket parsing and CBMatrix save/load."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import errors
from repro.core.cb_matrix import CBMatrix
from repro.core.formats import FormatThresholds
from repro.core.streams import build_streams, build_super_streams
from repro.core.spmv_ref import dense_oracle
from repro.data import matrices
from repro.data.matrices import load_matrix_market
from repro.kernels import ops


# ---------------------------------------------------------------------------
# MatrixMarket
# ---------------------------------------------------------------------------

def _write(tmp_path, text):
    p = tmp_path / "m.mtx"
    p.write_text(text)
    return p


def test_mm_general_real(tmp_path):
    p = _write(tmp_path, """%%MatrixMarket matrix coordinate real general
% a comment line
2 4 3
1 1 1.0
2 3 -2.5
1 4 0.5
""")
    rows, cols, vals, shape = load_matrix_market(p)
    assert shape == (2, 4)
    A = np.zeros(shape)
    A[rows, cols] = vals
    expect = np.zeros((2, 4))
    expect[0, 0], expect[1, 2], expect[0, 3] = 1.0, -2.5, 0.5
    np.testing.assert_array_equal(A, expect)


def test_mm_symmetric_expansion(tmp_path):
    p = _write(tmp_path, """%%MatrixMarket matrix coordinate real symmetric
3 3 4
1 1 2.0
2 1 -1.0
3 2 -1.0
3 3 2.0
""")
    rows, cols, vals, shape = load_matrix_market(p)
    assert len(rows) == 6  # two off-diagonal entries mirrored
    A = np.zeros(shape)
    A[rows, cols] = vals
    assert np.array_equal(A, A.T)
    assert A[0, 1] == -1.0 and A[1, 0] == -1.0


def test_mm_skew_symmetric(tmp_path):
    p = _write(tmp_path, """%%MatrixMarket matrix coordinate real skew-symmetric
3 3 2
2 1 1.5
3 1 -2.0
""")
    rows, cols, vals, shape = load_matrix_market(p)
    A = np.zeros(shape)
    A[rows, cols] = vals
    assert np.array_equal(A, -A.T)


def test_mm_pattern_unit_values(tmp_path):
    p = _write(tmp_path, """%%MatrixMarket matrix coordinate pattern symmetric
3 3 3
1 1
2 1
3 2
""")
    rows, cols, vals, shape = load_matrix_market(p)
    assert np.all(vals == 1.0)
    assert len(rows) == 5  # diagonal kept once, off-diagonals mirrored


def test_mm_integer_field(tmp_path):
    p = _write(tmp_path, """%%MatrixMarket matrix coordinate integer general
2 2 2
1 2 3
2 1 -4
""")
    _r, _c, vals, _shape = load_matrix_market(p)
    np.testing.assert_array_equal(np.sort(vals), [-4.0, 3.0])


@pytest.mark.parametrize("header,err", [
    ("%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1 1\n",
     "unsupported field"),
    ("%%MatrixMarket matrix array real general\n1 1\n1.0\n",
     "matrix coordinate"),
    ("%%MatrixMarket matrix coordinate real hermitian\n1 1 1\n1 1 1\n",
     "unsupported symmetry"),
    ("not a matrix market file\n", "not a MatrixMarket"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n",
     "promises 3 entries"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n",
     "out of bounds"),
])
def test_mm_rejects_malformed(tmp_path, header, err):
    p = _write(tmp_path, header)
    with pytest.raises(ValueError, match=err):
        load_matrix_market(p)


@pytest.mark.robustness
def test_mm_rejects_nonfinite_values(tmp_path):
    p = _write(tmp_path, """%%MatrixMarket matrix coordinate real general
2 2 2
1 1 nan
2 2 1.0
""")
    with pytest.raises(errors.IngestError, match="non-finite"):
        load_matrix_market(p)


@pytest.mark.robustness
def test_mm_dedup_sums_duplicates_like_canonical_triplets(tmp_path):
    from repro.autotune import canonical_triplets

    p = _write(tmp_path, """%%MatrixMarket matrix coordinate real general
2 3 4
1 1 1.5
2 3 2.0
1 1 -0.5
2 1 4.0
""")
    rows, cols, vals, shape = load_matrix_market(p)
    assert len(rows) == 3                    # (0,0) merged by summation
    cr, cc, cv = canonical_triplets(
        np.array([0, 1, 0, 1]), np.array([0, 2, 0, 0]),
        np.array([1.5, 2.0, -0.5, 4.0]), shape, val_dtype=np.float64)
    np.testing.assert_array_equal(rows, cr)
    np.testing.assert_array_equal(cols, cc)
    np.testing.assert_allclose(vals, cv)


@pytest.mark.robustness
@pytest.mark.parametrize("body,err", [
    # truncated mid-entry: final line lost its value column
    ("2 2 2\n1 1 1.0\n2 2\n", "malformed entry"),
    # absurd size lines
    ("0 0 5\n", "absurd"),
    ("-2 2 1\n1 1 1.0\n", "absurd"),
    ("2 2 -1\n", "absurd"),
    ("2 x 3\n", "malformed size line"),
])
def test_mm_rejects_truncated_and_absurd(tmp_path, body, err):
    p = _write(tmp_path,
               "%%MatrixMarket matrix coordinate real general\n" + body)
    with pytest.raises(errors.IngestError, match=err) as e:
        load_matrix_market(p)
    assert e.value.code == errors.INGEST_INVALID


def test_mm_to_cb_spmv_roundtrip(tmp_path):
    """A .mtx file drives the full pipeline: load -> CBMatrix -> cb_spmv."""
    rng = np.random.default_rng(0)
    rows, cols, vals = matrices.uniform_random(60, 44, density=0.05, seed=1)
    lines = [f"{r + 1} {c + 1} {v:.17g}"
             for r, c, v in zip(rows, cols, vals)]
    p = _write(tmp_path,
               "%%MatrixMarket matrix coordinate real general\n"
               f"60 44 {len(rows)}\n" + "\n".join(lines) + "\n")
    r2, c2, v2, shape = load_matrix_market(p)
    cb = CBMatrix.from_coo(r2, c2, v2.astype(np.float32), shape,
                           block_size=16, val_dtype=np.float32)
    x = rng.standard_normal(shape[1]).astype(np.float32)
    y = ops.cb_spmv(build_streams(cb).device_put(), jnp.asarray(x),
                    impl="reference")
    y_ref = dense_oracle(rows, cols, vals.astype(np.float32), shape, x)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# CBMatrix save / load
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("colagg", [True, False])
def test_cb_save_load_roundtrip(tmp_path, colagg):
    rows, cols, vals = matrices.power_law(120, 120, seed=2)
    cb = CBMatrix.from_coo(rows, cols, vals.astype(np.float32), (120, 120),
                           block_size=16, val_dtype=np.float32,
                           use_column_aggregation=colagg)
    path = tmp_path / "m.npz"
    cb.save(path)
    cb2 = CBMatrix.load(path)

    assert cb2.shape == cb.shape
    assert cb2.block_size == cb.block_size
    assert cb2.val_dtype == cb.val_dtype
    assert cb2.thresholds == cb.thresholds
    assert cb2.nnz == cb.nnz
    assert cb.stats() == cb2.stats()
    np.testing.assert_array_equal(cb.to_dense(), cb2.to_dense())

    # the derived kernel streams are bit-identical -> the loaded plan IS
    # the saved plan (preprocessing amortized across processes)
    import jax

    for build in (build_streams, build_super_streams):
        a = jax.tree_util.tree_leaves(build(cb))
        b = jax.tree_util.tree_leaves(build(cb2))
        assert all(np.array_equal(x, y) for x, y in zip(a, b))


def test_cb_save_load_spmv_identical(tmp_path):
    rows, cols, vals = matrices.banded(100, 90, seed=4)
    cb = CBMatrix.from_coo(rows, cols, vals.astype(np.float32), (100, 90),
                           block_size=16, val_dtype=np.float32)
    path = tmp_path / "m.npz"
    cb.save(path)
    cb2 = CBMatrix.load(path)
    x = jnp.asarray(
        np.random.default_rng(5).standard_normal(90).astype(np.float32)
    )
    y1 = ops.cb_spmv(build_super_streams(cb), x, impl="reference")
    y2 = ops.cb_spmv(build_super_streams(cb2), x, impl="reference")
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


def test_cb_load_rejects_unknown_schema(tmp_path):
    path = tmp_path / "bogus.npz"
    np.savez(path, schema=np.asarray("cb-matrix/v999"))
    with pytest.raises(ValueError, match="schema"):
        CBMatrix.load(path)


def test_cb_save_load_float64(tmp_path):
    rows, cols, vals = matrices.uniform_random(64, 64, density=0.03, seed=6)
    cb = CBMatrix.from_coo(rows, cols, vals, (64, 64), block_size=8,
                           val_dtype=np.float64)
    path = tmp_path / "m64.npz"
    cb.save(path)
    cb2 = CBMatrix.load(path)
    assert cb2.val_dtype == np.dtype(np.float64)
    np.testing.assert_array_equal(cb.to_dense(), cb2.to_dense())


@pytest.mark.parametrize("th", [
    FormatThresholds(th0=0.3, th1=8, th2=64),     # fully explicit
    FormatThresholds(th1=1, th2=256),             # forced-dense style
    FormatThresholds(th0=0.05),                   # derive th1/th2 from B
])
def test_cb_save_load_nondefault_thresholds(tmp_path, th):
    """Non-default (incl. autotuned) thresholds survive save/load exactly —
    a restored plan must re-derive the same formats, not the defaults."""
    rows, cols, vals = matrices.power_law(96, 96, seed=7)
    cb = CBMatrix.from_coo(rows, cols, vals.astype(np.float32), (96, 96),
                           block_size=16, val_dtype=np.float32,
                           thresholds=th)
    path = tmp_path / "th.npz"
    cb.save(path)
    cb2 = CBMatrix.load(path)
    assert cb2.thresholds == th
    assert cb2.thresholds.resolve(16) == th.resolve(16)
    np.testing.assert_array_equal(cb.type_per_blk, cb2.type_per_blk)
