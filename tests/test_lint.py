"""cblint gate + framework tests (marker: ``lint``).

Three layers:

  * **repo gate** — the analyzer over ``src/repro`` against the
    checked-in (empty) baseline must report zero findings; a violation
    anywhere in the library fails tier-1, which is the enforcement
    mechanism ROADMAP's standing guardrails point at.
  * **rule fixtures** — one positive + one negative file per rule under
    ``tests/fixtures/lint/``: the positive must fire exactly its code,
    the negative must be entirely clean, and the CLI must exit nonzero
    on every positive (the check.sh failure proof).
  * **framework** — suppression semantics (incl. CB001 rot detection),
    baseline multiset matching, byte-identical ``--json`` determinism,
    and the obs lint-health gauges.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro import analysis, errors, obs
from repro.analysis.findings import Finding

pytestmark = pytest.mark.lint

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO_ROOT, "tests", "fixtures", "lint")
SRC_REPRO = os.path.join(REPO_ROOT, "src", "repro")
CLI = os.path.join(REPO_ROOT, "scripts", "cblint.py")

# code -> fixture stem (CB302 lives under kernels/ because its rule is
# scoped to kernel modules by path).
RULE_FIXTURES = {
    "CB001": "cb001",
    "CB002": "cb002",
    "CB101": "cb101",
    "CB102": "cb102",
    "CB103": "cb103",
    "CB104": "cb104",
    "CB201": "cb201",
    "CB202": "cb202",
    "CB203": "cb203",
    "CB301": "cb301",
    "CB302": "kernels/cb302",
    "CB401": "cb401",
    "CB501": "cb501",
}


def _fixture(stem: str, kind: str) -> str:
    return os.path.join(FIXTURES, f"{stem}_{kind}.py")


def _lint(paths, **kwargs):
    return analysis.lint_paths(paths, root=REPO_ROOT, **kwargs)


# ---------------------------------------------------------------------------
# repo gate
# ---------------------------------------------------------------------------


def test_repo_is_lint_clean():
    """Every repo invariant holds across src/repro (empty baseline)."""
    result = _lint([SRC_REPRO], baseline_path=analysis.DEFAULT_BASELINE)
    report = "\n".join(f.format() for f in result.findings)
    assert not result.findings, f"cblint findings in src/repro:\n{report}"


def test_checked_in_baseline_is_empty():
    """ISSUE 9 policy: violations get fixed, not grandfathered."""
    entries = analysis.load_baseline(analysis.DEFAULT_BASELINE)
    assert entries == []


def test_every_rule_has_a_fixture():
    assert set(RULE_FIXTURES) == set(analysis.known_codes())


# ---------------------------------------------------------------------------
# per-rule fixtures
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("code", sorted(RULE_FIXTURES))
def test_rule_fires_on_positive(code):
    result = _lint([_fixture(RULE_FIXTURES[code], "pos")])
    codes = {f.code for f in result.findings}
    assert code in codes, f"{code} did not fire; got {sorted(codes)}"


@pytest.mark.parametrize("code", sorted(RULE_FIXTURES))
def test_rule_quiet_on_negative(code):
    result = _lint([_fixture(RULE_FIXTURES[code], "neg")])
    report = "\n".join(f.format() for f in result.findings)
    assert not result.findings, f"negative fixture not clean:\n{report}"


@pytest.mark.parametrize("code", sorted(RULE_FIXTURES))
def test_cli_fails_on_injected_violation(code):
    """check.sh's lint stage exits nonzero for every rule class."""
    proc = subprocess.run(
        [sys.executable, CLI, "--baseline", "none", "--no-obs",
         _fixture(RULE_FIXTURES[code], "pos")],
        capture_output=True, text=True, cwd=REPO_ROOT,
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert code in proc.stdout


def test_cli_clean_exit_and_json():
    proc = subprocess.run(
        [sys.executable, CLI, "--baseline", "none", "--no-obs", "--json",
         _fixture("cb401", "neg")],
        capture_output=True, text=True, cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["schema"] == analysis.SCHEMA
    assert payload["findings"] == []
    assert payload["files"] == 1


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_suppression_silences_named_code(tmp_path):
    path = tmp_path / "mod.py"
    path.write_text(
        "def f(x):\n"
        "    raise ValueError(x)  # cblint: disable=CB401\n"
    )
    result = analysis.lint_paths([str(path)], root=str(tmp_path))
    assert not result.findings
    assert result.suppressed == 1


def test_suppression_is_line_scoped(tmp_path):
    path = tmp_path / "mod.py"
    path.write_text(
        "def f(x):\n"
        "    # cblint: disable=CB401\n"
        "    raise ValueError(x)\n"
    )
    result = analysis.lint_paths([str(path)], root=str(tmp_path))
    codes = sorted(f.code for f in result.findings)
    # the raise still fires AND the off-line pragma is rot
    assert codes == ["CB001", "CB401"]


def test_cb001_not_inline_suppressible(tmp_path):
    path = tmp_path / "mod.py"
    path.write_text("x = 1  # cblint: disable=CB001\n")
    result = analysis.lint_paths([str(path)], root=str(tmp_path))
    assert [f.code for f in result.findings] == ["CB001"]
    assert "cannot be inline-suppressed" in result.findings[0].message


def test_docstring_mention_is_not_a_pragma(tmp_path):
    path = tmp_path / "mod.py"
    path.write_text('"""Docs showing `# cblint: disable=CB999`."""\nx = 1\n')
    result = analysis.lint_paths([str(path)], root=str(tmp_path))
    assert not result.findings


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def test_baseline_multiset_roundtrip(tmp_path):
    f1 = Finding(path="a.py", line=3, col=1, code="CB401", message="m")
    f2 = Finding(path="a.py", line=9, col=1, code="CB401", message="m")
    f3 = Finding(path="a.py", line=4, col=1, code="CB301", message="n")
    bl = tmp_path / "baseline.json"
    analysis.save_baseline(str(bl), [f1, f3])
    entries = analysis.load_baseline(str(bl))
    # one entry excuses exactly one of the two identical-message findings
    fresh, used = analysis.subtract_baseline([f1, f2, f3], entries)
    assert [f.line for f in fresh] == [9]
    assert sum(e["count"] for e in used) == 2
    # line drift does not un-excuse a baselined finding
    drifted = Finding(path="a.py", line=30, col=1, code="CB401", message="m")
    fresh, _ = analysis.subtract_baseline([drifted, f3], entries)
    assert fresh == []


def test_baseline_schema_rejected(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text('{"schema": "wrong/v0", "findings": []}')
    with pytest.raises(errors.SchemaError):
        analysis.load_baseline(str(bl))


# ---------------------------------------------------------------------------
# determinism + obs
# ---------------------------------------------------------------------------


def test_json_report_is_byte_deterministic():
    a = _lint([SRC_REPRO]).to_json()
    b = _lint([SRC_REPRO]).to_json()
    assert a == b
    payload = json.loads(a)
    records = payload["findings"]
    keys = [(r["path"], r["line"], r["col"], r["code"]) for r in records]
    assert keys == sorted(keys)


def test_fixture_findings_sorted_and_deterministic():
    a = _lint([FIXTURES]).to_json()
    b = _lint([FIXTURES]).to_json()
    assert a == b
    counts = json.loads(a)["counts"]
    assert all(n > 0 for n in counts.values())


def test_obs_lint_health_gauges():
    obs.reset()
    _lint([_fixture("cb401", "pos")], record_obs=True)
    snap = obs.snapshot()
    series = snap["repro.analysis.findings"]["series"]
    by_rule = {s["labels"]["rule"]: s["value"] for s in series}
    assert by_rule["CB401"] == 2
    assert by_rule["total"] == 2
    assert snap["repro.analysis.files"]["series"][0]["value"] == 1
    obs.reset()
