"""Fault-injection axis: every injector in ``runtime/faults.py`` is either
detected with a typed reason from ``repro.errors`` or tolerated with a
correct result — solvers, artifacts, plan cache, serving, supervision."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import errors
from repro.autotune import Plan, PlanCache
from repro.checkpoint import Checkpointer
from repro.core.cb_matrix import CBMatrix
from repro.data import matrices
from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.runtime import (
    FlakyStepFn,
    HeartbeatMonitor,
    RestartPolicy,
    corrupt_packed_values,
    flip_file_bytes,
    lose_host,
    plan_mesh,
    poison_vector,
    reshard_instructions,
    run_supervised,
)
from repro.serving import Request, ServingEngine
from repro.solvers import CBLinearOperator, SolverStatus, cg, gmres, robust_solve
from repro.solvers import krylov as krylov_mod

pytestmark = pytest.mark.robustness


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _spd(d=64, seed=1, bandwidth=7):
    r, c, v = matrices.spd_banded(d, bandwidth=bandwidth, seed=seed)
    cb = CBMatrix.from_coo(r, c, v.astype(np.float32), (d, d),
                           block_size=16, val_dtype=np.float32)
    return cb, CBLinearOperator.from_cb(cb)


def _rhs(d, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(d).astype(np.float32))


def _mini_plan(**overrides):
    kw = dict(
        structure_hash="0" * 64, shape=(16, 16), nnz=4, val_dtype="float32",
        block_size=16, th0=0.15, th1=4, th2=32, colagg=False, group_size=4,
        mode="heuristic", predicted_padded_elems=100, predicted_steps=2,
        measured_padded_elems=90, measured_steps=2,
    )
    kw.update(overrides)
    return Plan(**kw)


# ---------------------------------------------------------------------------
# Injector determinism
# ---------------------------------------------------------------------------

def test_injectors_are_deterministic(tmp_path):
    p1, p2 = tmp_path / "a.bin", tmp_path / "b.bin"
    payload = bytes(range(256)) * 8
    p1.write_bytes(payload)
    p2.write_bytes(payload)
    f1 = flip_file_bytes(p1, n=4, seed=7)
    f2 = flip_file_bytes(p2, n=4, seed=7)
    assert f1 == f2
    assert p1.read_bytes() == p2.read_bytes()
    assert p1.read_bytes() != payload

    x = np.arange(32, dtype=np.float32)
    a = poison_vector(x, n=3, seed=5)
    b = poison_vector(x, n=3, seed=5)
    np.testing.assert_array_equal(np.isnan(a), np.isnan(b))
    assert np.isnan(a).sum() == 3
    assert np.all(np.isfinite(x))          # input untouched

    cb, _ = _spd()
    c1 = corrupt_packed_values(cb, n=2, seed=3)
    c2 = corrupt_packed_values(cb, n=2, seed=3)
    np.testing.assert_array_equal(c1.packed, c2.packed)
    assert not np.array_equal(c1.packed, cb.packed)


def test_flaky_step_fn_counts_and_raises():
    fn = FlakyStepFn(lambda v: v + 1, fail_on={0, 2})
    with pytest.raises(errors.InjectedFault) as e:
        fn(1)
    assert e.value.code == errors.INJECTED
    assert fn(1) == 2
    with pytest.raises(errors.InjectedFault):
        fn(1)
    assert fn(10) == 11
    assert (fn.calls, fn.failures) == (4, 2)


# ---------------------------------------------------------------------------
# Artifact integrity: checksummed npz + validate()
# ---------------------------------------------------------------------------

def test_cb_save_load_checksum_roundtrip(tmp_path):
    cb, _ = _spd()
    p = tmp_path / "m.npz"
    cb.save(p)
    lo = CBMatrix.load(p)
    np.testing.assert_array_equal(lo.to_dense(), cb.to_dense())


def test_cb_byteflip_detected_or_bit_correct(tmp_path):
    """Every byte flip is detected (typed ArtifactError) or harmless."""
    cb, _ = _spd()
    dense = cb.to_dense()
    p = str(tmp_path / "m.npz")
    detected = 0
    for seed in range(10):
        cb.save(p)
        flip_file_bytes(p, n=1, seed=seed)
        try:
            lo = CBMatrix.load(p)
        except errors.ArtifactError as e:
            assert e.code in (errors.ARTIFACT_CORRUPT, errors.ARTIFACT_SCHEMA)
            detected += 1
        else:
            # tolerated is only acceptable when the payload is bit-correct
            np.testing.assert_array_equal(lo.to_dense(), dense)
    assert detected >= 8        # flips overwhelmingly land in checked bytes


def test_cb_multibyte_flip_always_detected(tmp_path):
    cb, _ = _spd()
    p = str(tmp_path / "m.npz")
    for seed in range(6):
        cb.save(p)
        flip_file_bytes(p, n=16, seed=seed)
        with pytest.raises(errors.ArtifactError):
            CBMatrix.load(p)


def test_validate_catches_mutated_metadata():
    cb, _ = _spd()
    assert cb.validate() is cb
    # value pointer past the packed buffer
    vp = cb.vp_per_blk.copy()
    real = np.nonzero(cb.nnz_per_blk > 0)[0][0]
    vp[real] = len(cb.packed) + 64
    with pytest.raises(errors.ArtifactError):
        dataclasses.replace(cb, vp_per_blk=vp).validate()
    # block row index out of range
    br = cb.blk_row_idx.copy()
    br[real] = 10_000
    with pytest.raises(errors.ArtifactError):
        dataclasses.replace(cb, blk_row_idx=br).validate()
    # nnz ledger mismatch
    nz = cb.nnz_per_blk.copy()
    nz[real] += 1
    with pytest.raises(errors.ArtifactError):
        dataclasses.replace(cb, nnz_per_blk=nz).validate()
    # unknown format code
    tp = cb.type_per_blk.copy()
    tp[real] = 99
    with pytest.raises(errors.ArtifactError):
        dataclasses.replace(cb, type_per_blk=tp).validate()


def test_corrupt_payload_passes_structure_fails_finite_check():
    cb, _ = _spd()
    bad = corrupt_packed_values(cb, n=2, seed=0)
    bad.validate()                       # structure metadata untouched
    with pytest.raises(errors.NonFiniteError):
        bad.validate(check_finite=True)


def test_nonfinite_policy_on_build_and_update():
    r = np.array([0, 1, 2])
    c = np.array([0, 1, 2])
    v = np.array([1.0, np.nan, 3.0])
    with pytest.raises(errors.NonFiniteError):
        CBMatrix.from_coo(r, c, v, (3, 3), block_size=2)
    cb = CBMatrix.from_coo(r, c, v, (3, 3), block_size=2,
                           nonfinite="sanitize")
    assert np.all(np.isfinite(cb.to_dense()))
    cb_ok = CBMatrix.from_coo(r, c, np.array([1.0, 2.0, 3.0]), (3, 3),
                              block_size=2)
    with pytest.raises(errors.NonFiniteError):
        cb_ok.update_values(np.array([1.0, np.inf, 3.0]))
    san = cb_ok.update_values(np.array([1.0, np.inf, 3.0]),
                              nonfinite="sanitize")
    assert np.all(np.isfinite(san.to_dense()))
    raw = cb_ok.update_values(np.array([1.0, np.inf, 3.0]),
                              nonfinite="allow")
    assert np.isinf(raw.to_dense()).any()


def test_structure_drift_is_typed():
    cb, _ = _spd(d=32)
    with pytest.raises(errors.StructureDriftError, match="structure drift"):
        cb.update_from_coo(np.array([0]), np.array([0]), np.array([1.0]))


# ---------------------------------------------------------------------------
# Plan-cache corruption fuzz: every corruption = one counted miss, no crash
# ---------------------------------------------------------------------------

def test_plan_cache_byteflip_fuzz_v2(tmp_path):
    cache = PlanCache(tmp_path / "plans")
    plan = _mini_plan(structure_hash="a" * 64)
    for seed in range(20):
        cache.put(plan)                  # fresh, uncorrupted file
        flip_file_bytes(cache.path_for(plan.structure_hash), n=1, seed=seed)
        before = cache.hits + cache.misses
        got = cache.get(plan.structure_hash, shape=(16, 16), nnz=4)
        assert cache.hits + cache.misses == before + 1
        if got is not None:              # neutral flip (e.g. whitespace)
            assert got == plan


def test_plan_cache_byteflip_fuzz_v1_migration_path(tmp_path):
    """v1 files predate the payload checksum, so a flip that still parses
    as valid JSON can slip through migration — but it must never crash,
    always count exactly one lookup, and anything returned must pass
    ``check_valid`` for the requested matrix (a corrupted-but-resolvable
    plan builds a correct, merely differently-tuned, CBMatrix). The
    migration re-save stamps a v2 checksum, closing the window."""
    from repro.autotune import PLAN_SCHEMA_V1

    cache = PlanCache(tmp_path / "plans")
    legacy_key, struct_key = "e" * 64, "f" * 64
    v1 = _mini_plan(structure_hash=legacy_key)
    d = v1.to_json()
    d["schema"] = PLAN_SCHEMA_V1
    d["matrix_hash"] = d.pop("structure_hash")
    d.pop("value_hash")
    d.pop("payload_checksum")
    for seed in range(12):
        with open(cache.path_for(legacy_key), "w") as f:
            json.dump(d, f, indent=1)
        flip_file_bytes(cache.path_for(legacy_key), n=1, seed=seed)
        before = cache.hits + cache.misses
        got = cache.get(struct_key, legacy_hash=legacy_key,
                        shape=(16, 16), nnz=4)
        assert cache.hits + cache.misses == before + 1
        if got is not None:
            assert got.structure_hash == struct_key
            assert got.check_valid(shape=(16, 16), nnz=4) is None
        # drop any migrated v2 file so each round starts clean
        import os
        if os.path.exists(cache.path_for(struct_key)):
            os.remove(cache.path_for(struct_key))


def test_plan_field_tamper_is_counted_stale(tmp_path):
    """A semantic edit that keeps valid JSON trips the payload checksum."""
    cache = PlanCache(tmp_path / "plans")
    plan = _mini_plan(structure_hash="a" * 64)
    cache.put(plan)
    path = cache.path_for(plan.structure_hash)
    with open(path) as f:
        d = json.load(f)
    d["group_size"] = 8                  # valid value, silently retuned
    with open(path, "w") as f:
        json.dump(d, f, indent=1)
    before = (cache.hits, cache.misses, cache.stale)
    assert cache.get(plan.structure_hash, shape=(16, 16), nnz=4) is None
    assert (cache.hits, cache.misses, cache.stale) == (
        before[0], before[1] + 1, before[2] + 1)


def test_plan_checksum_survives_roundtrip_and_equality(tmp_path):
    plan = _mini_plan()
    path = tmp_path / "p.json"
    plan.save(path)
    loaded = Plan.load(path)
    assert loaded == plan                # payload_checksum is compare=False
    assert loaded.payload_checksum is not None
    assert loaded.check_valid(shape=(16, 16), nnz=4) is None
    tampered = dataclasses.replace(loaded, group_size=8)
    reason = tampered.check_valid()
    assert reason is not None
    assert errors.reason_code(reason) == errors.ARTIFACT_CORRUPT


def test_from_plan_raises_typed_stale_error():
    r, c, v = matrices.spd_banded(32, bandwidth=5, seed=0)
    plan = _mini_plan(shape=(16, 16))
    with pytest.raises(errors.PlanStaleError, match="plan was made for shape"):
        CBMatrix.from_plan(r, c, v, (32, 32), plan)


# ---------------------------------------------------------------------------
# Breakdown-aware solvers
# ---------------------------------------------------------------------------

def _indefinite(d=64, seed=1):
    """SPD matrix with one diagonal entry negated — CG breaks down."""
    r, c, v = matrices.spd_banded(d, bandwidth=7, seed=seed)
    dense = np.zeros((d, d), np.float32)
    np.add.at(dense, (r, c), v)
    rr, cc = np.nonzero(dense)
    vv = dense[rr, cc].copy()
    vv[(rr == d - 1) & (cc == d - 1)] = -50.0
    cb = CBMatrix.from_coo(rr, cc, vv, (d, d), block_size=16,
                           val_dtype=np.float32)
    return cb, CBLinearOperator.from_cb(cb)


def test_cg_flags_breakdown_on_indefinite_matrix():
    _cb, op = _indefinite()
    res = cg(op, _rhs(64), tol=1e-10, maxiter=200, impl="reference")
    assert not bool(res.converged)
    assert int(res.status) == SolverStatus.BREAKDOWN
    assert res.reason == "solver-breakdown"


def test_cg_flags_nonfinite_rhs_without_iterating():
    _cb, op = _spd()
    res = cg(op, jnp.full(64, np.nan, jnp.float32), tol=1e-8, maxiter=50,
             impl="reference")
    assert int(res.status) == SolverStatus.NONFINITE
    assert int(res.iterations) == 0


def test_cg_flags_nonfinite_from_corrupt_payload():
    cb, _ = _spd()
    bad = CBLinearOperator.from_cb(corrupt_packed_values(cb, n=3, seed=0))
    res = cg(bad, _rhs(64), tol=1e-8, maxiter=50, impl="reference")
    assert int(res.status) == SolverStatus.NONFINITE
    assert not bool(res.converged)


def test_cg_flags_divergence_against_divtol():
    _cb, op = _spd()
    res = cg(op, _rhs(64), tol=1e-12, maxiter=50, impl="reference",
             divtol=1e-6)
    assert int(res.status) == SolverStatus.DIVERGED


def test_gmres_flags_stagnation_on_rotation():
    """GMRES(1) on a rotation matrix famously makes zero progress."""
    r = np.array([0, 1])
    c = np.array([1, 0])
    v = np.array([1.0, -1.0], np.float32)
    cb = CBMatrix.from_coo(r, c, v, (2, 2), block_size=2,
                           val_dtype=np.float32)
    op = CBLinearOperator.from_cb(cb)
    res = gmres(op, jnp.asarray(np.array([1.0, 0.0], np.float32)),
                tol=1e-8, restart=1, maxiter=40, impl="reference")
    assert not bool(res.converged)
    assert int(res.status) == SolverStatus.STAGNATION


def test_solver_returns_best_iterate_on_failure():
    """On a failed solve, SolveResult.x is the best iterate, not the last."""
    _cb, op = _indefinite()
    b = _rhs(64)
    res = cg(op, b, tol=1e-10, maxiter=200, impl="reference")
    hist = np.asarray(res.history)
    reached = hist[hist >= 0]
    r = np.asarray(b) - np.asarray(op.matvec(res.x, impl="reference"))
    np.testing.assert_allclose(np.linalg.norm(r), reached.min(),
                               rtol=1e-3, atol=1e-5)


# -- satellite: dtype-aware guards ------------------------------------------

def test_safe_div_respects_f16_tiny():
    num = jnp.asarray(1.0, jnp.float16)
    den = jnp.asarray(1e-6, jnp.float16)   # subnormal: 1/den overflows f16
    assert float(krylov_mod._safe_div(num, den)) == 0.0
    assert float(krylov_mod._safe_div(num, jnp.asarray(0.5, jnp.float16))) == 2.0


def test_norm_upcasts_low_precision():
    # a bf16 square-sum saturates at 256 (ulp > 1), giving norm 16 not 32
    assert float(krylov_mod._norm(jnp.ones(1024, jnp.bfloat16))) == \
        pytest.approx(32.0, rel=1e-2)
    assert float(krylov_mod._norm(jnp.ones(1024, jnp.float16))) == \
        pytest.approx(32.0, rel=1e-2)


# -- robust_solve -----------------------------------------------------------

def test_robust_solve_recovers_cg_breakdown():
    cb, op = _indefinite()
    b = _rhs(64)
    res = robust_solve(op, b, tol=1e-6, maxiter=300, impl="reference")
    assert res.converged
    assert res.attempts[0].solver == "cg"
    assert not res.attempts[0].converged
    assert res.solver != "cg"
    x_ref = np.linalg.solve(cb.to_dense(), np.asarray(b))
    np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=1e-3, atol=1e-3)


def test_robust_solve_recovers_every_seeded_breakdown_on_corpus():
    """Acceptance: robust_solve converges every case plain CG fails."""
    for seed in range(3):
        _cb, op = _indefinite(seed=seed)
        b = _rhs(64, seed=seed)
        plain = cg(op, b, tol=1e-6, maxiter=300, impl="reference")
        assert not bool(plain.converged)
        res = robust_solve(op, b, tol=1e-6, maxiter=300, impl="reference")
        assert res.converged, f"seed {seed}: {res.reason}"


def test_robust_solve_rejects_nonfinite_rhs_tolerates_bad_x0():
    _cb, op = _spd()
    with pytest.raises(errors.NonFiniteError):
        robust_solve(op, jnp.full(64, np.inf, jnp.float32), impl="reference")
    b = _rhs(64)
    x0 = jnp.asarray(poison_vector(np.zeros(64, np.float32), n=2, seed=0))
    res = robust_solve(op, b, x0=x0, tol=1e-6, maxiter=300, impl="reference")
    assert res.converged and res.sanitized_x0


def test_robust_solve_preserves_single_trace():
    """Fallback retries re-invoke the jitted solvers with identical static
    args — a second robust_solve must not trace anything new."""
    _cb, op = _indefinite()
    b = _rhs(64)
    robust_solve(op, b, tol=1e-6, maxiter=300, impl="reference")
    snapshot = dict(krylov_mod._TRACE_COUNTS)
    res = robust_solve(op, b, tol=1e-6, maxiter=300, impl="reference")
    assert res.converged
    assert dict(krylov_mod._TRACE_COUNTS) == snapshot


# ---------------------------------------------------------------------------
# Serving degradation
# ---------------------------------------------------------------------------

def _tiny_model():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
                      attn_chunk=32, remat="none", dtype="float32")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_serving_queue_backpressure_is_typed():
    model, params = _tiny_model()
    eng = ServingEngine(model, params, slots=1, max_len=64, max_queue=1)
    reqs = [Request(uid=i, prompt=np.array([i + 1], np.int32),
                    max_new_tokens=2) for i in range(3)]
    statuses = [eng.submit(r) for r in reqs]
    assert statuses == [errors.ACCEPTED, errors.QUEUE_FULL, errors.QUEUE_FULL]
    assert reqs[1].status == errors.QUEUE_FULL
    assert eng.health()["rejected"] == 2
    done = eng.run_until_done()
    assert [r.uid for r in done] == [0]


def test_serving_deadline_expires_and_frees_slot():
    model, params = _tiny_model()
    eng = ServingEngine(model, params, slots=1, max_len=64)
    slow = Request(uid=0, prompt=np.array([1], np.int32),
                   max_new_tokens=500, deadline_ticks=3)
    quick = Request(uid=1, prompt=np.array([2], np.int32), max_new_tokens=2)
    eng.submit(slow)
    eng.submit(quick)
    done = eng.run_until_done(max_ticks=50)
    assert [r.uid for r in done] == [1]          # slot was reclaimed
    assert slow.status == errors.DEADLINE_EXCEEDED
    assert not slow.done
    h = eng.health()
    assert h["deadline_expired"] == 1 and h["completed"] == 1


def test_serving_tick_retry_is_bit_identical_to_fault_free():
    model, params = _tiny_model()
    prompt = np.array([3, 14, 15], np.int32)

    ref = ServingEngine(model, params, slots=2, max_len=64)
    ref.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    baseline = ref.run_until_done()[0].generated

    eng = ServingEngine(model, params, slots=2, max_len=64,
                        max_step_retries=2, retry_backoff_s=0.01,
                        sleep=lambda s: None)
    eng.step_fn = FlakyStepFn(eng.step_fn, fail_on={1, 3})
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    out = eng.run_until_done()[0].generated
    assert out == baseline
    assert eng.health()["retries"] == 2


def test_serving_retry_exhaustion_raises_tick_error():
    model, params = _tiny_model()
    eng = ServingEngine(model, params, slots=1, max_len=64,
                        max_step_retries=1, sleep=lambda s: None)
    eng.step_fn = FlakyStepFn(eng.step_fn, fail_on=set(range(10)))
    eng.submit(Request(uid=0, prompt=np.array([1], np.int32),
                       max_new_tokens=2))
    with pytest.raises(errors.TickError) as e:
        eng.tick()
    assert e.value.code == errors.TICK_FAILED
    assert "injected" in eng.health()["last_error"].lower()


# ---------------------------------------------------------------------------
# Supervision: checkpoint/restart + heartbeat loss + restart budget
# ---------------------------------------------------------------------------

def _supervised(tmp_path, fail_on, max_restarts, num_steps=8):
    def step(state, step_idx):
        return state * 2 + step_idx

    flaky = FlakyStepFn(step, fail_on=fail_on)
    ckpt = Checkpointer(str(tmp_path / "ckpt"), async_write=False)
    mon = HeartbeatMonitor(num_hosts=1, timeout_s=1e9, clock=FakeClock())
    policy = RestartPolicy(ckpt, mon, max_restarts=max_restarts)
    final = run_supervised(flaky, np.asarray(1, np.int64),
                           num_steps=num_steps, checkpointer=ckpt,
                           policy=policy, checkpoint_every=2)
    return final, policy


def test_failed_step_restarts_from_checkpoint_bitwise(tmp_path):
    fault_free, _ = _supervised(tmp_path / "a", fail_on=(), max_restarts=0)
    injected, policy = _supervised(tmp_path / "b", fail_on={5},
                                   max_restarts=3)
    assert int(injected) == int(fault_free)      # deterministic replay
    assert policy.restarts == 1


def test_restart_budget_exhaustion_raises(tmp_path):
    with pytest.raises(errors.RestartBudgetError) as e:
        _supervised(tmp_path, fail_on=set(range(100)), max_restarts=2)
    assert e.value.code == errors.RESTART_BUDGET_EXHAUSTED


def test_heartbeat_loss_detected_and_drives_remesh(tmp_path):
    clock = FakeClock()
    mon = HeartbeatMonitor(num_hosts=4, timeout_s=10.0, clock=clock)
    clock.t = 5.0
    for h in range(4):
        mon.heartbeat(0, host_id=h)
    lose_host(mon, 2)
    assert mon.check() == [2]
    assert mon.alive_hosts == [0, 1, 3]
    ckpt = Checkpointer(str(tmp_path / "ckpt"), async_write=False)
    ckpt.save(np.asarray(7), 3)
    decision = RestartPolicy(ckpt, mon).on_failure()
    assert decision.restore_step == 3
    assert decision.needs_remesh
    assert decision.surviving_hosts == [0, 1, 3]


def test_straggler_ewma_records_slow_step():
    clock = FakeClock()
    mon = HeartbeatMonitor(num_hosts=1, timeout_s=1e9,
                           straggler_factor=2.0, clock=clock)
    for step in range(6):
        clock.t += 1.0
        mon.heartbeat(step)
    clock.t += 10.0                      # one 10x-slow step
    mon.heartbeat(6)
    assert [s for s, _d in mon.stragglers] == [6]
    mon.report_straggler(9, 42.0)
    assert (9, 42.0) in mon.stragglers


def test_plan_mesh_degrades_after_host_loss():
    full = plan_mesh(32, prefer_model=16)
    assert full.shape == (2, 16) and full.dropped_devices == 0
    # lose 8 devices: model width steps down to keep the grid full
    shrunk = plan_mesh(24, prefer_model=16)
    assert shrunk.shape == (3, 8) and shrunk.dropped_devices == 0
    # global batch must stay divisible by the data axis
    batched = plan_mesh(10, prefer_model=4, global_batch=8)
    assert batched.shape[0] in (1, 2, 4) and 8 % batched.shape[0] == 0
    instr = reshard_instructions(full, shrunk)
    assert instr["old"]["shape"] == (2, 16)
    assert instr["new"]["shape"] == (3, 8)
    assert "replay" in instr["data_replay"]


def test_plan_mesh_splits_pod_axis():
    plan = plan_mesh(512, prefer_model=16, pod_size=256)
    assert plan.axis_names == ("pod", "data", "model")
    assert plan.shape == (2, 16, 16)


# ---------------------------------------------------------------------------
# Error taxonomy plumbing
# ---------------------------------------------------------------------------

def test_reason_code_roundtrip():
    text = errors.reason(errors.ARTIFACT_CORRUPT, "checksum mismatch")
    assert errors.reason_code(text) == errors.ARTIFACT_CORRUPT
    assert errors.reason_code(None) is None
    assert errors.reason_code("plain prose sentence") is None


def test_exceptions_remain_builtin_compatible():
    # historical call sites catch ValueError/RuntimeError
    assert issubclass(errors.ArtifactError, ValueError)
    assert issubclass(errors.NonFiniteError, ValueError)
    assert issubclass(errors.StructureDriftError, ValueError)
    assert issubclass(errors.IngestError, ValueError)
    assert issubclass(errors.TickError, RuntimeError)
    assert issubclass(errors.InjectedFault, RuntimeError)


def test_solver_reason_covers_all_statuses():
    for status in SolverStatus:
        assert errors.solver_reason(status).startswith("solver-")
    assert "unknown" in errors.solver_reason(99)
