"""End-to-end system behaviour: train -> checkpoint -> fail -> restart ->
serve, plus the CB sparse-weight integration path.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models import Model
from repro.runtime import HeartbeatMonitor, RestartPolicy
from repro.serving import Request, ServingEngine
from repro.training import OPTIMIZERS, TrainLoopConfig, TrainState, run_training

pytestmark = pytest.mark.system


def _loss_improved(hist, k=3):
    """Robust learning signal: mean of the last k logged losses must beat
    the mean of the first k. Single-step comparisons flap on per-batch
    noise when only a handful of steps run."""
    losses = [h["loss"] for h in hist]
    assert len(losses) >= 2 * k, losses
    return float(np.mean(losses[-k:])) < float(np.mean(losses[:k]))


def test_train_crash_restart_serve_cycle():
    cfg = get_smoke_config("granite-8b")
    model = Model(cfg)
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    )
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_write=False)
        mon = HeartbeatMonitor(num_hosts=1)

        # phase 1: train to step 8, checkpoint at 4 and 8 — then "crash"
        # (warmup_steps=2 so the LR actually reaches peak inside the run)
        state, hist = run_training(
            model, stream,
            TrainLoopConfig(total_steps=8, checkpoint_every=4, log_every=1,
                            warmup_steps=2),
            checkpointer=ck, monitor=mon,
        )
        assert _loss_improved(hist)

        # phase 2: restart decision + restore + replay
        decision = RestartPolicy(ck, mon).on_failure()
        assert decision.restore_step == 8
        opt = OPTIMIZERS["adamw"]()
        params, _ = model.init(jax.random.PRNGKey(0))
        restored = ck.restore(TrainState.create(params, opt),
                              step=decision.restore_step)
        restored = jax.tree_util.tree_map(jnp.asarray, restored)
        state2, hist2 = run_training(
            model, stream,
            TrainLoopConfig(total_steps=12, log_every=2, warmup_steps=2),
            initial_state=restored,
        )
        assert int(state2.step) == 12

        # phase 3: serve from the trained weights
        eng = ServingEngine(model, state2.params, slots=2, max_len=64)
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=4))
        done = eng.run_until_done()
        assert len(done) == 1 and len(done[0].generated) == 4


def test_cb_sparse_model_trains():
    """The paper's technique as a model feature: CB sparse MLP trains."""
    cfg = get_smoke_config("cb-paper")
    assert cfg.sparse_mlp
    model = Model(cfg)
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    )
    state, hist = run_training(
        model, stream,
        TrainLoopConfig(total_steps=6, log_every=1, warmup_steps=2),
    )
    assert _loss_improved(hist)
    # sparsity metadata static: tile count unchanged by training
    spec = model.specs["gate"]
    assert state.params["layers"]["ffn"]["gate"]["tiles"].shape[1] == spec.num_tiles
