"""Minimal deterministic property-test harness (hypothesis stand-in).

The container cannot pip-install ``hypothesis``, so this module provides
the tiny subset the test-suite needs, with two deliberate differences:

  * **Deterministic**: every example is drawn from a PRNG seeded by
    ``(seed, example_index)``, so a failure is reproducible by rerunning
    the test — no example database, no flaky shrink paths.
  * **Shrinking-free**: on failure the harness re-raises the original
    assertion annotated with the example index, the seed, and a repr of
    the drawn arguments; matrices here are small enough to debug as-is.

API sketch (mirrors ``hypothesis.strategies`` where it matters):

    from proptest import forall, integers, floats, lists, sampled_from, composite

    @composite
    def my_pairs(draw):
        n = draw(integers(1, 9))
        return n, draw(lists(floats(-1, 1), min_size=n, max_size=n))

    @forall(my_pairs(), sampled_from([4, 8]), examples=50)
    def test_something(pair, block):
        ...
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import numpy as np


class Strategy:
    """A deterministic value generator: ``sample(rng) -> value``."""

    def __init__(self, sample_fn: Callable[[np.random.Generator], Any],
                 label: str = "strategy"):
        self._sample = sample_fn
        self.label = label

    def sample(self, rng: np.random.Generator) -> Any:
        return self._sample(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._sample(rng)),
                        label=f"{self.label}.map")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Strategy {self.label}>"


# ---------------------------------------------------------------------------
# primitive strategies
# ---------------------------------------------------------------------------

def integers(min_value: int, max_value: int) -> Strategy:
    """Inclusive integer range, like ``st.integers``."""
    if min_value > max_value:
        raise ValueError(f"empty range [{min_value}, {max_value}]")
    return Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        label=f"integers({min_value},{max_value})",
    )


def floats(min_value: float, max_value: float) -> Strategy:
    """Uniform floats in [min_value, max_value] — never NaN/inf."""
    return Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        label=f"floats({min_value},{max_value})",
    )


def sampled_from(options: Sequence[Any]) -> Strategy:
    options = list(options)
    if not options:
        raise ValueError("sampled_from needs at least one option")
    return Strategy(
        lambda rng: options[int(rng.integers(len(options)))],
        label=f"sampled_from({options!r})",
    )


def lists(elements: Strategy, *, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    if not (0 <= min_size <= max_size):
        raise ValueError(f"bad sizes [{min_size}, {max_size}]")

    def sample(rng: np.random.Generator) -> list:
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(size)]

    return Strategy(sample, label=f"lists({elements.label})")


def composite(fn: Callable) -> Callable[..., Strategy]:
    """Build a strategy from a function taking ``draw`` as first argument.

    ``draw(strategy)`` pulls one value from the shared example PRNG, so a
    composite's internal draws stay reproducible.
    """

    @functools.wraps(fn)
    def make(*args: Any, **kwargs: Any) -> Strategy:
        def sample(rng: np.random.Generator) -> Any:
            return fn(lambda strategy: strategy.sample(rng), *args, **kwargs)

        return Strategy(sample, label=f"composite({fn.__name__})")

    return make


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------

def forall(*strategies: Strategy, examples: int = 25, seed: int = 0):
    """Run the decorated test once per deterministic example.

    Replaces ``@settings(max_examples=N) @given(...)``: each example ``i``
    draws every positional strategy from ``default_rng((seed, i))`` and
    calls the test with the drawn values. Failures re-raise with enough
    context to reproduce (example index, seed, argument reprs).
    """
    if not strategies:
        raise ValueError("forall needs at least one strategy")

    def decorate(test_fn: Callable) -> Callable:
        def run() -> None:
            for i in range(examples):
                rng = np.random.default_rng((seed, i))
                drawn = [s.sample(rng) for s in strategies]
                try:
                    test_fn(*drawn)
                except Exception as exc:
                    arg_repr = ", ".join(_short_repr(d) for d in drawn)
                    raise AssertionError(
                        f"{test_fn.__name__} failed on example {i}/{examples}"
                        f" (seed={seed}): args=({arg_repr})"
                    ) from exc

        # Copy identity but NOT __wrapped__: pytest reads the wrapped
        # signature through it and would demand fixtures for the drawn
        # parameters. The runner takes no pytest-visible arguments.
        run.__name__ = test_fn.__name__
        run.__qualname__ = getattr(test_fn, "__qualname__", test_fn.__name__)
        run.__doc__ = test_fn.__doc__
        run.__module__ = test_fn.__module__
        return run

    return decorate


def _short_repr(value: Any, limit: int = 200) -> str:
    r = repr(value)
    return r if len(r) <= limit else r[: limit - 3] + "..."
