"""Runtime: fault tolerance (simulated clocks), elasticity, serving engine."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.runtime import HeartbeatMonitor, RestartPolicy, plan_mesh
from repro.runtime.elastic import reshard_instructions
from repro.runtime.pipeline import bubble_fraction
from repro.serving import Request, ServingEngine, greedy_decode


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_failure_detection_with_simulated_clock():
    clock = FakeClock()
    mon = HeartbeatMonitor(num_hosts=3, timeout_s=10.0, clock=clock)
    for step in range(3):
        clock.t += 1.0
        for h in range(3):
            mon.heartbeat(step, host_id=h)
    assert mon.check() == []
    # host 2 goes silent
    for step in range(3, 8):
        clock.t += 3.0
        mon.heartbeat(step, host_id=0)
        mon.heartbeat(step, host_id=1)
    assert mon.check() == [2]
    assert mon.alive_hosts == [0, 1]
    # no double-reporting
    assert mon.check() == []


def test_straggler_detection():
    clock = FakeClock()
    mon = HeartbeatMonitor(num_hosts=1, straggler_factor=2.0, clock=clock)
    for step in range(10):
        clock.t += 1.0
        mon.heartbeat(step)
    clock.t += 10.0   # one very slow step
    mon.heartbeat(10)
    assert any(s[0] == 10 for s in mon.stragglers)


def test_restart_policy():
    class FakeCk:
        def latest_step(self):
            return 40

    clock = FakeClock()
    mon = HeartbeatMonitor(num_hosts=4, timeout_s=5.0, clock=clock)
    for h in range(4):
        mon.heartbeat(0, host_id=h)
    clock.t += 100.0
    mon.heartbeat(1, host_id=0)
    mon.check()
    dec = RestartPolicy(FakeCk(), mon).on_failure()
    assert dec.restore_step == 40
    assert dec.replay_from_step == 40
    assert dec.needs_remesh
    assert dec.surviving_hosts == [0]


def test_plan_mesh_shapes():
    p = plan_mesh(256, prefer_model=16)
    assert p.shape == (16, 16) and p.dropped_devices == 0
    p = plan_mesh(512, prefer_model=16)
    assert p.shape == (2, 16, 16)
    assert p.axis_names == ("pod", "data", "model")
    p = plan_mesh(240, prefer_model=16)   # lost a host: 240 = 15*16
    assert p.num_devices == 240
    p = plan_mesh(7, prefer_model=16)
    assert p.num_devices <= 7
    ri = reshard_instructions(plan_mesh(512), plan_mesh(256))
    assert "device_put" in ri["mechanism"]


def test_bubble_fraction():
    assert bubble_fraction(2, 8) == 1 / 9
    assert bubble_fraction(1, 8) == 0.0


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _tiny_model():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
                      attn_chunk=32, remat="none", dtype="float32")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_matches_direct_decode():
    """Continuous batching must produce the same tokens as greedy_decode."""
    model, params = _tiny_model()
    prompt = np.array([3, 14, 15, 9], np.int32)
    direct = np.asarray(
        greedy_decode(model, params, jnp.asarray(prompt)[None, :], 5)
    )[0]

    eng = ServingEngine(model, params, slots=3, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    # interference: other requests share the batch
    eng.submit(Request(uid=1, prompt=np.array([7, 7], np.int32),
                       max_new_tokens=3))
    eng.submit(Request(uid=2, prompt=np.array([100], np.int32),
                       max_new_tokens=7))
    done = {r.uid: r for r in eng.run_until_done()}
    np.testing.assert_array_equal(np.asarray(done[0].generated), direct)


def test_engine_slot_reuse():
    model, params = _tiny_model()
    eng = ServingEngine(model, params, slots=1, max_len=64)
    for uid in range(3):
        eng.submit(Request(uid=uid, prompt=np.array([uid + 1], np.int32),
                           max_new_tokens=2))
    done = eng.run_until_done()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert all(len(r.generated) == 2 for r in done)
