"""Solver subsystem tests: Krylov convergence vs CSR references,
preconditioners, the transposed-stream rmatvec contract, spectral
drivers, and the single-trace acceptance criterion."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cb_matrix import CBMatrix
from repro.core.streams import build_super_streams
from repro.data import matrices
from repro.kernels import ops
from repro.solvers import (
    CBLinearOperator,
    bicgstab,
    block_jacobi,
    cg,
    chebyshev_subspace,
    gmres,
    jacobi,
    pagerank,
    pagerank_operator,
    power_iteration,
)
from repro.solvers import krylov as krylov_mod

TOL = 1e-6


def _dense_of(rows, cols, vals, shape):
    A = np.zeros(shape, np.float32)
    np.add.at(A, (rows, cols), vals)  # duplicate coords sum, like the CB path
    return A


def _spd_case(d=96, seed=3, block_size=16, group_size=None):
    rows, cols, vals = matrices.spd_banded(d, bandwidth=7, seed=seed)
    vals = vals.astype(np.float32)
    cb = CBMatrix.from_coo(rows, cols, vals, (d, d), block_size=block_size,
                           val_dtype=np.float32)
    op = CBLinearOperator.from_cb(cb, group_size=group_size,
                                  with_rmatvec=True, with_matmat=True)
    return cb, op, _dense_of(rows, cols, vals, (d, d))


def _nonsym_case(d=96, seed=5):
    rows, cols, vals = matrices.banded(d, d, bandwidth=7, fill=0.8, seed=seed)
    diag = np.arange(d)
    rows = np.concatenate([rows, diag])
    cols = np.concatenate([cols, diag])
    vals = np.concatenate([vals, np.full(d, 8.0)]).astype(np.float32)
    cb = CBMatrix.from_coo(rows, cols, vals, (d, d), block_size=16,
                           val_dtype=np.float32)
    return cb, CBLinearOperator.from_cb(cb), _dense_of(rows, cols, vals,
                                                       (d, d))


def _scipy_iters(kind, A, b, tol=TOL, maxiter=500):
    """Iteration count of the scipy CSR reference, same stopping rule."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla

    count = [0]
    fn = {"cg": spla.cg, "bicgstab": spla.bicgstab}[kind]
    x, info = fn(sp.csr_matrix(A), b, rtol=tol, atol=0.0, maxiter=maxiter,
                 callback=lambda *_: count.__setitem__(0, count[0] + 1))
    assert info == 0
    return count[0]


# ---------------------------------------------------------------------------
# Krylov convergence vs the CSR references
# ---------------------------------------------------------------------------

def test_cg_iterations_match_csr_reference():
    _cb, op, A = _spd_case()
    b = np.random.default_rng(0).standard_normal(A.shape[0]).astype(np.float32)
    res = cg(op, jnp.asarray(b), tol=TOL, maxiter=500, impl="reference")
    assert bool(res.converged)
    assert float(res.residual) <= TOL * np.linalg.norm(b)
    ref_iters = _scipy_iters("cg", A.astype(np.float64), b)
    assert abs(int(res.iterations) - ref_iters) <= 2
    x_ref = np.linalg.solve(A.astype(np.float64), b)
    assert np.linalg.norm(np.asarray(res.x) - x_ref) <= 1e-4 * np.linalg.norm(x_ref)


def test_bicgstab_iterations_match_csr_reference():
    _cb, op, A = _nonsym_case()
    b = np.random.default_rng(1).standard_normal(A.shape[0]).astype(np.float32)
    res = bicgstab(op, jnp.asarray(b), tol=TOL, maxiter=500, impl="reference")
    assert bool(res.converged)
    assert float(res.residual) <= TOL * np.linalg.norm(b)
    ref_iters = _scipy_iters("bicgstab", A.astype(np.float64), b)
    assert abs(int(res.iterations) - ref_iters) <= 2
    x_ref = np.linalg.solve(A.astype(np.float64), b)
    assert np.linalg.norm(np.asarray(res.x) - x_ref) <= 1e-4 * np.linalg.norm(x_ref)


def test_gmres_converges_nonsymmetric():
    _cb, op, A = _nonsym_case(seed=9)
    b = np.random.default_rng(2).standard_normal(A.shape[0]).astype(np.float32)
    res = gmres(op, jnp.asarray(b), tol=TOL, restart=15, maxiter=30,
                impl="reference")
    assert bool(res.converged)
    x_ref = np.linalg.solve(A.astype(np.float64), b)
    assert np.linalg.norm(np.asarray(res.x) - x_ref) <= 1e-4 * np.linalg.norm(x_ref)


def test_residual_history_buffer_semantics():
    _cb, op, A = _spd_case(seed=11)
    b = np.random.default_rng(3).standard_normal(A.shape[0]).astype(np.float32)
    res = cg(op, jnp.asarray(b), tol=TOL, maxiter=64, impl="reference")
    hist = np.asarray(res.history)
    k = int(res.iterations)
    assert hist.shape == (65,)
    assert np.all(hist[: k + 1] >= 0)          # reached entries recorded
    assert np.all(hist[k + 1 :] == -1.0)       # fixed buffer, -1 beyond
    assert hist[0] == pytest.approx(np.linalg.norm(b), rel=1e-5)
    assert hist[k] == pytest.approx(float(res.residual), rel=1e-5)


# ---------------------------------------------------------------------------
# Preconditioners from the CB block structure
# ---------------------------------------------------------------------------

def test_jacobi_apply_matches_diag():
    cb, _op, A = _spd_case(seed=7)
    M = jacobi(cb)
    r = np.random.default_rng(4).standard_normal(A.shape[0]).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(M.apply(jnp.asarray(r))), r / np.diag(A), rtol=1e-5
    )


def test_block_jacobi_apply_matches_dense_blockdiag_inverse():
    cb, _op, A = _spd_case(d=90, seed=8)  # ragged last block
    M = block_jacobi(cb)
    B, m = cb.block_size, A.shape[0]
    r = np.random.default_rng(5).standard_normal(m).astype(np.float32)
    expect = np.zeros(m)
    for b0 in range(0, m, B):
        hi = min(b0 + B, m)
        blk = A[b0:hi, b0:hi].astype(np.float64)
        expect[b0:hi] = np.linalg.solve(blk, r[b0:hi])
    np.testing.assert_allclose(
        np.asarray(M.apply(jnp.asarray(r))), expect, rtol=2e-4, atol=2e-5
    )


def test_block_jacobi_cuts_cg_iterations():
    cb, op, A = _spd_case(seed=13)
    b = np.random.default_rng(6).standard_normal(A.shape[0]).astype(np.float32)
    plain = cg(op, jnp.asarray(b), tol=TOL, maxiter=500, impl="reference")
    pre = cg(op, jnp.asarray(b), block_jacobi(cb), tol=TOL, maxiter=500,
             impl="reference")
    assert bool(pre.converged)
    assert int(pre.iterations) <= int(plain.iterations)


# ---------------------------------------------------------------------------
# Operator contracts
# ---------------------------------------------------------------------------

def test_rmatvec_bit_agreement_with_dense_transpose():
    """rmatvec through the precomputed transposed stream is bit-identical
    to building the CB pipeline on the dense transpose's triplets."""
    cb, op, A = _spd_case(d=90, seed=17, group_size=4)
    At = A.T
    rt, ct = np.nonzero(At)
    cbT = CBMatrix.from_coo(rt, ct, At[rt, ct], At.shape,
                            block_size=cb.block_size, val_dtype=np.float32,
                            thresholds=cb.thresholds)
    sT_ref = build_super_streams(cbT, group_size=4)
    y = jnp.asarray(
        np.random.default_rng(7).standard_normal(A.shape[0]).astype(np.float32)
    )
    ours = np.asarray(op.rmatvec(y, impl="pallas", interpret=True))
    ref = np.asarray(ops.cb_spmv(sT_ref, y, impl="pallas", interpret=True))
    assert np.array_equal(ours, ref)
    # and it is the transpose, numerically
    np.testing.assert_allclose(ours, A.T @ np.asarray(y), rtol=1e-4,
                               atol=1e-4)


def test_matmat_multi_rhs():
    _cb, op, A = _spd_case(seed=19)
    X = np.random.default_rng(8).standard_normal((A.shape[1], 5)).astype(
        np.float32
    )
    out = np.asarray(op.matmat(jnp.asarray(X), impl="reference"))
    np.testing.assert_allclose(out, A @ X, rtol=1e-4, atol=1e-4)


def test_capability_gating():
    cb, _, _ = _spd_case(seed=23)
    op = CBLinearOperator.from_cb(cb)  # capabilities default OFF
    with pytest.raises(ValueError, match="with_rmatvec"):
        op.rmatvec(jnp.zeros(op.shape[0]))
    with pytest.raises(ValueError, match="with_matmat"):
        op.matmat(jnp.zeros((op.shape[1], 2)))


def test_cb_spmv_into_accumulates():
    cb, op, A = _spd_case(seed=29)
    rng = np.random.default_rng(9)
    x = rng.standard_normal(A.shape[1]).astype(np.float32)
    y0 = rng.standard_normal(A.shape[0]).astype(np.float32)
    for impl in ("reference", "pallas"):
        out = ops.cb_spmv_into(jnp.asarray(y0), op.streams, jnp.asarray(x),
                               impl=impl, interpret=True)
        np.testing.assert_allclose(np.asarray(out), y0 + A @ x, rtol=1e-4,
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# Acceptance: single-trace CG on the batched engine
# ---------------------------------------------------------------------------

def test_cg_block_jacobi_single_trace_batched_engine():
    """CG + block-Jacobi to 1e-6 on the SPD corpus in ONE jit trace, inner
    matvec on the batched super-block engine (group_size > 1)."""
    cb, op, A = _spd_case(d=96, seed=31, group_size=4)
    assert op.group_size > 1
    # the packer really fused blocks: fewer grid steps than blocks
    s = op.streams
    groups = s.num_dense_groups + s.num_panel_groups + s.num_coo_groups
    assert groups < cb.stats()["num_blocks"]

    M = block_jacobi(cb)
    rng = np.random.default_rng(10)
    before = dict(krylov_mod._TRACE_COUNTS)
    maxiter = 77  # unique static config -> this test owns its cache entry
    for seed in (0, 1):
        b = rng.standard_normal(A.shape[0]).astype(np.float32)
        res = cg(op, jnp.asarray(b), M, tol=TOL, maxiter=maxiter,
                 impl="pallas", interpret=True)
        assert bool(res.converged)
        assert int(res.iterations) > 1
        assert float(res.residual) <= TOL * np.linalg.norm(b)
    after = dict(krylov_mod._TRACE_COUNTS)
    # one trace of the solver, one of the loop body, across BOTH solves —
    # zero per-iteration retrace despite iterations > 1 each solve
    assert after.get("cg", 0) - before.get("cg", 0) == 1
    assert after.get("cg_body", 0) - before.get("cg_body", 0) == 1


# ---------------------------------------------------------------------------
# Spectral drivers
# ---------------------------------------------------------------------------

def test_power_iteration_dominant_eigenvalue():
    # seed 3's spectrum has a healthy dominant gap (lam2/lam1 ~ 0.81)
    _cb, op, A = _spd_case(seed=3)
    ev = np.linalg.eigvalsh(A.astype(np.float64))
    v0 = jnp.asarray(
        np.random.default_rng(11).standard_normal(A.shape[0]).astype(
            np.float32)
    )
    res = power_iteration(op, v0, tol=1e-6, maxiter=1000, impl="reference")
    assert bool(res.converged)
    assert float(res.eigenvalue) == pytest.approx(ev[-1], rel=1e-4)


def test_chebyshev_subspace_top_eigenpairs():
    _cb, op, A = _spd_case(seed=41)
    ev = np.linalg.eigvalsh(A.astype(np.float64))
    V0 = jnp.asarray(
        np.random.default_rng(12).standard_normal((A.shape[0], 6)).astype(
            np.float32)
    )
    vals, vecs = chebyshev_subspace(op, V0, lb=float(ev[0]),
                                    ub=float(ev[-8]), degree=8, iters=6,
                                    impl="reference")
    np.testing.assert_allclose(np.asarray(vals)[-4:], ev[-4:], rtol=1e-3)
    # Ritz vectors are eigenvectors: ||A q - lambda q|| small
    q = np.asarray(vecs)[:, -1]
    lam = float(np.asarray(vals)[-1])
    assert np.linalg.norm(A @ q - lam * q) <= 1e-2 * abs(lam)


def test_pagerank_power_law_matches_numpy():
    n = 200
    src, dst, _ = matrices.power_law(n, n, seed=5)
    op, dangling = pagerank_operator(src, dst, n, group_size=4)
    assert op.group_size > 1
    res = pagerank(op, dangling, maxiter=300, impl="reference")
    p = np.asarray(res.eigenvector)
    assert p.sum() == pytest.approx(1.0, abs=1e-5)
    assert np.all(p > 0)
    # numpy reference on the dense Google matrix
    key = np.unique(src.astype(np.int64) * n + dst.astype(np.int64))
    s, d = key // n, key % n
    outdeg = np.bincount(s, minlength=n).astype(np.float64)
    P = np.zeros((n, n))
    P[d, s] = 1.0 / outdeg[s]
    x = np.full(n, 1.0 / n)
    for _ in range(300):
        xn = 0.85 * (P @ x + x[outdeg == 0].sum() / n) + 0.15 / n
        xn /= xn.sum()
        if np.abs(xn - x).sum() < 1e-14:
            break
        x = xn
    np.testing.assert_allclose(p, x, atol=1e-6)


# ---------------------------------------------------------------------------
# Dynamic sparsity: with_values / DiagScatter / EvolvingPageRank
# ---------------------------------------------------------------------------

def _updatable_case(seed=0, m=70, n=70, group_size=4):
    rng = np.random.default_rng(seed)
    rows = np.concatenate([rng.integers(0, m, 500), np.arange(m)])
    cols = np.concatenate([rng.integers(0, n, 500), np.arange(m)])
    vals = np.concatenate([rng.standard_normal(500),
                           np.full(m, 3.0)]).astype(np.float32)
    cb = CBMatrix.from_coo(rows, cols, vals, (m, n), block_size=16,
                           val_dtype=np.float32)
    op = CBLinearOperator.from_cb(cb, group_size=group_size,
                                  with_rmatvec=True, with_matmat=True,
                                  updatable=True)
    return cb, op, rng


def _nonzero_values(cb, rng):
    v = rng.standard_normal(cb.value_layout().count).astype(np.float32)
    v[v == 0] = 1.0
    return v


def test_with_values_bit_identical_to_rebuild():
    cb, op, rng = _updatable_case(seed=7)
    new_vals = _nonzero_values(cb, rng)
    op_new = op.with_values(new_vals)
    op_ref = CBLinearOperator.from_cb(cb.update_values(new_vals),
                                      group_size=4, with_rmatvec=True,
                                      with_matmat=True)
    x = jnp.asarray(rng.standard_normal(cb.shape[1]), jnp.float32)
    y = jnp.asarray(rng.standard_normal(cb.shape[0]), jnp.float32)
    X = jnp.asarray(rng.standard_normal((cb.shape[1], 5)), jnp.float32)
    for got, want in [
        (op_new.matvec(x, impl="reference"),
         op_ref.matvec(x, impl="reference")),
        (op_new.rmatvec(y, impl="reference"),
         op_ref.rmatvec(y, impl="reference")),
        (op_new.matmat(X, impl="reference"),
         op_ref.matmat(X, impl="reference")),
    ]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # static metadata shared object-for-object (the no-retrace contract)
    assert op_new.updater is op.updater
    assert op_new.updater_T is op.updater_T
    assert op_new.tile_updater is op.tile_updater


def test_with_values_requires_updatable():
    cb, _, rng = _updatable_case(seed=8)
    op = CBLinearOperator.from_cb(cb)
    with pytest.raises(ValueError, match="updatable=True"):
        op.with_values(_nonzero_values(cb, rng))


def test_with_values_single_trace_across_updates():
    cb, op, rng = _updatable_case(seed=9)
    traces = []

    @jax.jit
    def apply(op, x):
        traces.append(1)
        return op.matvec(x, impl="reference")

    x = jnp.asarray(rng.standard_normal(cb.shape[1]), jnp.float32)
    y0 = np.asarray(apply(op, x))
    for _ in range(3):
        op2 = op.with_values(_nonzero_values(cb, rng))
        y2 = np.asarray(apply(op2, x))
        assert not np.array_equal(y2, y0)  # values really changed
    assert len(traces) == 1  # value churn never retraced


def test_diag_scatter_matches_rebuilt_preconditioners():
    from repro.solvers import diag_scatter

    cb, _, rng = _updatable_case(seed=10)
    ds = diag_scatter(cb)
    for _ in range(2):
        new_vals = _nonzero_values(cb, rng)
        cb_new = cb.update_values(new_vals)
        np.testing.assert_array_equal(
            np.asarray(ds.jacobi(new_vals).inv_diag),
            np.asarray(jacobi(cb_new).inv_diag),
        )
        got = ds.block_jacobi(new_vals)
        want = block_jacobi(cb_new)
        np.testing.assert_array_equal(np.asarray(got.inv_blocks),
                                      np.asarray(want.inv_blocks))
        assert (got.m, got.block_size) == (want.m, want.block_size)


def test_evolving_pagerank_matches_fresh_builds():
    from repro.solvers import EvolvingPageRank

    n = 64
    rng = np.random.default_rng(21)
    src = rng.integers(0, n, 400)
    dst = rng.integers(0, n, 400)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    ev = EvolvingPageRank.build(src, dst, n, block_size=16)
    for step in range(3):
        w = rng.uniform(0.1, 2.0, len(src))
        res = ev.step(w, impl="reference", maxiter=150)
        # reference: full rebuild with the same weights
        key = src.astype(np.int64) * n + dst.astype(np.int64)
        uk, inv = np.unique(key, return_inverse=True)
        s_u, d_u = uk // n, uk % n
        w_u = np.zeros(len(uk)); np.add.at(w_u, inv, w)
        outsum = np.zeros(n); np.add.at(outsum, s_u, w_u)
        cb_f = CBMatrix.from_coo(d_u, s_u,
                                 (w_u / outsum[s_u]).astype(np.float32),
                                 (n, n), block_size=16)
        op_f = CBLinearOperator.from_cb(cb_f)
        res_f = pagerank(op_f,
                         jnp.asarray(np.bincount(s_u, minlength=n) == 0,
                                     jnp.float32),
                         impl="reference", maxiter=150)
        np.testing.assert_allclose(np.asarray(res.eigenvector),
                                   np.asarray(res_f.eigenvector), atol=1e-6)


def test_evolving_pagerank_rejects_structure_drift():
    from repro.solvers import EvolvingPageRank

    n = 32
    src = np.array([0, 1, 2, 3])
    dst = np.array([1, 2, 3, 0])
    ev = EvolvingPageRank.build(src, dst, n, block_size=16)
    with pytest.raises(ValueError, match="structure drift"):
        ev.canonical_values(np.array([1.0, 0.0, 1.0, 1.0]))
    with pytest.raises(ValueError, match="one weight per"):
        ev.canonical_values(np.ones(3))
