"""Core CB-SpMV pipeline: unit + property tests (proptest harness).

Invariants under test (the paper's §3 claims as executable properties):
  * blocking partitions losslessly (CB round-trips to the dense matrix)
  * packed coordinates decode to the originals (Alg. 3 bit layout)
  * virtual-pointer regions are aligned and non-overlapping (Fig. 7b)
  * column aggregation preserves the matrix under restore_cols (Fig. 6b)
  * format selection respects th1/th2 (§3.3.2)
  * pq balance: equal slot count per group, near-optimal nnz spread (Alg. 2)
"""
import numpy as np
import pytest
from proptest import composite, forall, floats, integers, lists, sampled_from

from repro.core import (
    CBMatrix, FMT_COO, FMT_CSR, FMT_DENSE, FormatThresholds,
    aggregate_blocks, apply_balance, column_aggregate, partition_coo,
    select_formats, tb_load_balance,
)
from repro.core.aggregation import (
    coord_dtype, decode_coords, encode_coords, pack_block, unpack_block,
)
from repro.core.spmv_ref import dense_oracle, spmm_ref, spmv_ref
from repro.data import matrices


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@composite
def coo_matrices(draw):
    m = draw(integers(8, 120))
    n = draw(integers(8, 120))
    nnz = draw(integers(1, 200))
    rows = draw(lists(integers(0, m - 1), min_size=nnz, max_size=nnz))
    cols = draw(lists(integers(0, n - 1), min_size=nnz, max_size=nnz))
    vals = draw(lists(floats(-100, 100), min_size=nnz, max_size=nnz))
    return (np.asarray(rows), np.asarray(cols),
            np.asarray(vals, np.float32), (m, n))


# ---------------------------------------------------------------------------
# blocking
# ---------------------------------------------------------------------------

@forall(coo_matrices(), sampled_from([4, 8, 16]), examples=50)
def test_partition_roundtrip(mat, B):
    rows, cols, vals, shape = mat
    part = partition_coo(rows, cols, vals, shape, B)
    dense = np.zeros(shape, np.float32)
    np.add.at(dense, (rows, cols), vals)
    rebuilt = np.zeros(shape, np.float32)
    for i in range(part.num_blocks):
        r, c, v = part.block_elems(i)
        rebuilt[part.blk_row_idx[i] * B + r, part.blk_col_idx[i] * B + c] += v
    np.testing.assert_allclose(rebuilt, dense, rtol=1e-5, atol=1e-5)


@forall(coo_matrices(), sampled_from([8, 16]), examples=50)
def test_partition_intra_block_row_major(mat, B):
    rows, cols, vals, shape = mat
    part = partition_coo(rows, cols, vals, shape, B)
    for i in range(part.num_blocks):
        r, c, _ = part.block_elems(i)
        keys = r.astype(np.int64) * B + c
        assert np.all(np.diff(keys) > 0), "block elems must be row-major unique"


# ---------------------------------------------------------------------------
# packed coordinates + VP aggregation
# ---------------------------------------------------------------------------

@forall(sampled_from([4, 8, 16]), integers(1, 64), integers(0, 2**31),
        examples=50)
def test_coord_pack_roundtrip(B, nnz, seed):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, B, nnz).astype(np.int32)
    c = rng.integers(0, B, nnz).astype(np.int32)
    packed = encode_coords(r, c, B)
    assert packed.dtype == coord_dtype(B)
    r2, c2 = decode_coords(packed, B)
    np.testing.assert_array_equal(r, r2)
    np.testing.assert_array_equal(c, c2)


@pytest.mark.parametrize("fmt", [FMT_COO, FMT_CSR, FMT_DENSE])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_pack_unpack_block(fmt, dtype):
    rng = np.random.default_rng(0)
    B = 16
    nnz = 40
    flat = rng.choice(B * B, nnz, replace=False)
    flat.sort()
    r, c = (flat // B).astype(np.int32), (flat % B).astype(np.int32)
    v = rng.standard_normal(nnz).astype(dtype)
    blob = pack_block(fmt, r, c, v, B)
    buf = np.concatenate([np.zeros(8, np.uint8), blob])  # offset region
    r2, c2, v2 = unpack_block(buf, 8, fmt, nnz, B, np.dtype(dtype))
    order = np.argsort(r * B + c)
    order2 = np.argsort(r2 * B + c2)
    np.testing.assert_array_equal(r[order], r2[order2])
    np.testing.assert_array_equal(c[order], c2[order2])
    np.testing.assert_allclose(v[order], v2[order2], rtol=1e-6)


def test_vp_alignment_and_disjointness():
    rng = np.random.default_rng(1)
    B = 16
    fmts, elems = [], []
    for i in range(20):
        nnz = int(rng.integers(1, B * B))
        flat = rng.choice(B * B, nnz, replace=False)
        flat.sort()
        r, c = (flat // B).astype(np.int32), (flat % B).astype(np.int32)
        v = rng.standard_normal(nnz).astype(np.float32)
        fmts.append(int(select_formats(np.array([nnz]), B)[0]))
        elems.append((r, c, v))
    packed = aggregate_blocks(np.asarray(fmts), elems, B, np.dtype(np.float32))
    ends = packed.vp_per_blk + packed.nbytes_per_blk
    # aligned starts, disjoint monotone regions
    assert np.all(packed.vp_per_blk % 4 == 0)
    assert np.all(packed.vp_per_blk[1:] >= ends[:-1])
    assert ends[-1] <= len(packed.packed)


# ---------------------------------------------------------------------------
# column aggregation
# ---------------------------------------------------------------------------

@forall(coo_matrices(), sampled_from([8, 16]), examples=40)
def test_column_aggregation_preserves_matrix(mat, B):
    rows, cols, vals, shape = mat
    agg = column_aggregate(rows, cols, shape, B)
    # every element's compacted column restores to its original column
    for i in range(len(rows)):
        panel = rows[i] // B
        assert agg.original_col(panel, int(agg.new_cols[i])) == cols[i]


@forall(coo_matrices(), sampled_from([8, 16]), examples=40)
def test_column_aggregation_compacts(mat, B):
    rows, cols, vals, shape = mat
    agg = column_aggregate(rows, cols, shape, B)
    # compacted width = number of distinct columns per panel
    for p in range(agg.num_panels):
        in_panel = (rows // B) == p
        expected = len(np.unique(cols[in_panel])) if in_panel.any() else 0
        assert agg.panel_width[p] == expected


# ---------------------------------------------------------------------------
# format selection + load balance
# ---------------------------------------------------------------------------

def test_format_thresholds_paper_values():
    th1, th2 = FormatThresholds().resolve(16)
    assert (th1, th2) == (32, 128)  # the paper's th1/th2 at B=16
    nnz = np.array([1, 31, 32, 128, 129, 256])
    fmt = select_formats(nnz, 16)
    assert list(fmt) == [FMT_COO, FMT_COO, FMT_CSR, FMT_CSR, FMT_DENSE, FMT_DENSE]


@forall(lists(integers(1, 256), min_size=1, max_size=300),
        sampled_from([4, 8]), examples=40)
def test_tb_balance_invariants(nnzs, warps):
    nnz = np.asarray(nnzs)
    res = tb_load_balance(nnz, warps_per_tb=warps)
    slots = res.slots
    real = slots[slots >= 0]
    # every block placed exactly once
    assert sorted(real.tolist()) == list(range(len(nnz)))
    # group loads match slot assignment
    loads = np.zeros(res.num_groups, np.int64)
    for g in range(res.num_groups):
        s = slots[g * warps : (g + 1) * warps]
        loads[g] = nnz[s[s >= 0]].sum()
    np.testing.assert_array_equal(loads, res.group_loads)
    # near-optimal: max load <= optimal + max single block (greedy LPT bound)
    assert res.group_loads.max() <= nnz.sum() / res.num_groups + nnz.max()


def test_balance_beats_naive_on_powerlaw():
    r, c, v = matrices.power_law(512, 512, seed=3)
    part = partition_coo(r, c, v, (512, 512), 16)
    from repro.core.balance import tb_load_stddev
    naive, balanced = tb_load_stddev(part.nnz_per_blk)
    assert balanced <= naive  # Fig. 4 claim


# ---------------------------------------------------------------------------
# end-to-end CBMatrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,kw", [
    ("uniform", dict(density=0.01)),
    ("power_law", {}),
    ("banded", {}),
    ("block_clustered", {}),
    ("pruned", {}),
])
@pytest.mark.parametrize("colagg", ["auto", True, False])
def test_cb_matrix_spmv_matches_oracle(family, kw, colagg):
    gen = matrices.FAMILIES[family]
    m, n = 160, 144
    r, c, v = gen(m, n, seed=11, **kw)
    cb = CBMatrix.from_coo(r, c, v, (m, n), block_size=16,
                           val_dtype=np.float32,
                           use_column_aggregation=colagg)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(
        spmv_ref(cb, x), dense_oracle(r, c, v.astype(np.float32), (m, n), x),
        rtol=2e-4, atol=2e-4,
    )
    # to_dense agrees too
    dense = np.zeros((m, n), np.float32)
    np.add.at(dense, (r, c), v.astype(np.float32))
    np.testing.assert_allclose(cb.to_dense(), dense, rtol=1e-5, atol=1e-5)


def test_cb_matrix_spmm_matches_oracle():
    r, c, v = matrices.block_clustered(128, 128, seed=5)
    cb = CBMatrix.from_coo(r, c, v, (128, 128), block_size=16,
                           val_dtype=np.float32)
    X = np.random.default_rng(1).standard_normal((128, 8)).astype(np.float32)
    dense = np.zeros((128, 128), np.float32)
    np.add.at(dense, (r, c), v.astype(np.float32))
    np.testing.assert_allclose(spmm_ref(cb, X), dense @ X, rtol=2e-4, atol=2e-4)


def test_storage_accounting_matches_paper_model():
    """§4.4.1: CB storage ~ CSR parity, far below BSR."""
    r, c, v = matrices.uniform_random(512, 512, density=0.01, seed=2)
    cb = CBMatrix.from_coo(r, c, v, (512, 512), block_size=16,
                           val_dtype=np.float64,
                           use_column_aggregation=False)
    nnz = cb.nnz
    sizes = cb.nbytes_structure()
    csr = (512 + 1) * 4 + nnz * 4 + nnz * 8
    nblk = cb.num_blocks
    bsr = 256 * 8 * nblk + (512 // 16 + 1) * 4 + nblk * 4
    assert sizes["total"] < bsr / 4
    assert sizes["total"] < 4 * csr


# ---------------------------------------------------------------------------
# value layout + in-place value updates (dynamic sparsity)
# ---------------------------------------------------------------------------

@forall(coo_matrices(), sampled_from([8, 16]), examples=30)
def test_update_values_bit_identical_to_fresh_build(mat, B):
    rows, cols, vals, shape = mat
    cb = CBMatrix.from_coo(rows, cols, vals, shape, block_size=B,
                           val_dtype=np.float32)
    layout = cb.value_layout()
    r, c, _ = cb.to_coo()
    assert layout.count == len(r)
    rng = np.random.default_rng(layout.count)
    new_vals = rng.uniform(0.5, 2.0, layout.count).astype(np.float32)
    cb_up = cb.update_values(new_vals)
    cb_fresh = CBMatrix.from_coo(r, c, new_vals, shape, block_size=B,
                                 val_dtype=np.float32)
    np.testing.assert_array_equal(cb_up.packed, cb_fresh.packed)
    _, _, v_up = cb_up.to_coo()
    np.testing.assert_array_equal(v_up, new_vals)


def test_update_values_validates_length():
    cb = CBMatrix.from_coo(np.array([0, 5]), np.array([1, 3]),
                           np.array([1.0, 2.0], np.float32), (8, 8),
                           block_size=8, val_dtype=np.float32)
    with pytest.raises(ValueError, match="canonical"):
        cb.update_values(np.ones(3, np.float32))


def test_update_from_coo_dedups_and_rejects_drift():
    rows = np.array([0, 0, 2, 5])
    cols = np.array([1, 1, 2, 4])
    vals = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    cb = CBMatrix.from_coo(rows, cols, vals, (8, 8), block_size=8,
                           val_dtype=np.float32)
    # same coords (duplicates split differently) -> accepted, summed
    cb2 = cb.update_from_coo(rows, cols,
                             np.array([5.0, 5.0, 6.0, 7.0], np.float32))
    _, _, v = cb2.to_coo()
    np.testing.assert_array_equal(np.sort(v), [6.0, 7.0, 10.0])
    # a new coordinate is structure drift
    with pytest.raises(ValueError, match="structure drift"):
        cb.update_from_coo(np.array([0, 2, 5, 7]), cols, vals)


def test_value_layout_keys_are_canonical_order():
    rng = np.random.default_rng(3)
    rows = rng.integers(0, 40, 120)
    cols = rng.integers(0, 48, 120)
    vals = rng.standard_normal(120).astype(np.float32)
    for colagg in (True, False):
        cb = CBMatrix.from_coo(rows, cols, vals, (40, 48), block_size=16,
                               val_dtype=np.float32,
                               use_column_aggregation=colagg)
        layout = cb.value_layout()
        r, c, _ = cb.to_coo()
        np.testing.assert_array_equal(layout.keys, r * 48 + c)
        assert np.all(np.diff(layout.keys) > 0)
