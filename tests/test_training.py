"""Training substrate: optimizers, schedules, compression, loop, checkpoint."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import floats, forall, lists

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models import Model
from repro.training import (
    OPTIMIZERS, TrainLoopConfig, TrainState, build_train_step, run_training,
    warmup_cosine,
)
from repro.training.grad_compression import (
    dequantize_int8, ef_quantize, quantize_int8,
)
from repro.training.optimizer import adamw, clip_by_global_norm, lion


def _tiny_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=32,
                num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=256,
                attn_chunk=32, remat="none")
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = adamw(weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        updates, state = opt.update(grads, state, params, lr=0.1)
        params = {"w": params["w"] + updates["w"]}
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_lion_reduces_quadratic():
    # sign-based updates descend at a fixed rate and then oscillate with
    # amplitude ~lr around the optimum
    opt = lion(weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(500):
        grads = {"w": 2 * params["w"]}
        updates, state = opt.update(grads, state, params, lr=0.05)
        params = {"w": params["w"] + updates["w"]}
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    from repro.training.optimizer import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-2)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(55)) < float(lr(20))


# ---------------------------------------------------------------------------
# int8 EF compression
# ---------------------------------------------------------------------------

@forall(lists(floats(-1e3, 1e3), min_size=1, max_size=64), examples=30)
def test_quantize_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


def test_error_feedback_converges():
    """EF contract: sum of compressed grads -> sum of true grads."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(16, np.float32)
    comp_sum = np.zeros(16, np.float32)
    ef = jnp.zeros(16, jnp.float32)
    for _ in range(200):
        g = jnp.asarray(rng.standard_normal(16), jnp.float32)
        q, s, ef = ef_quantize(g, ef)
        comp_sum += np.asarray(q, np.float32) * float(s)
        true_sum += np.asarray(g)
    # residual error is bounded by the LAST step's quantization error
    assert np.abs(comp_sum - true_sum).max() < 1.0


# ---------------------------------------------------------------------------
# train loop + checkpoint
# ---------------------------------------------------------------------------

def test_loss_decreases_and_resume_is_exact():
    cfg = _tiny_cfg()
    model = Model(cfg)
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    )
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_write=False)
        # warmup_steps=2 so the LR reaches peak inside the short run; the
        # learning signal is mean-of-last-k vs first-k (single-step
        # comparisons flap on per-batch noise).
        state, hist = run_training(
            model, stream,
            TrainLoopConfig(total_steps=10, checkpoint_every=5, log_every=1,
                            warmup_steps=2),
            checkpointer=ck,
        )
        losses = [h["loss"] for h in hist]
        assert np.mean(losses[-3:]) < np.mean(losses[:3])

        # restore at step 5 and re-run 5..10 -> identical final params
        # (the resumed run must use the same schedule)
        opt = OPTIMIZERS["adamw"]()
        params, _ = model.init(jax.random.PRNGKey(0))
        example = TrainState.create(params, opt)
        mid = ck.restore(example, step=5)
        mid = jax.tree_util.tree_map(jnp.asarray, mid)
        state2, _ = run_training(
            model, stream,
            TrainLoopConfig(total_steps=10, log_every=2, warmup_steps=2),
            initial_state=mid,
        )
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(state2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


def test_microbatched_grads_match_full_batch():
    cfg = _tiny_cfg()
    model = Model(cfg)
    opt = OPTIMIZERS["adamw"]()
    lr = warmup_cosine(1e-3, 2, 100)
    params, _ = model.init(jax.random.PRNGKey(0))
    state = TrainState.create(params, opt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 256)
    batch = {"tokens": toks, "targets": toks}

    s1, m1 = build_train_step(model, opt, lr, microbatches=1)(state, batch)
    s2, m2 = build_train_step(model, opt, lr, microbatches=2)(state, batch)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_compressed_training_tracks_uncompressed():
    cfg = _tiny_cfg()
    model = Model(cfg)
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    )
    _, h_plain = run_training(model, stream, TrainLoopConfig(total_steps=8, log_every=7))
    _, h_comp = run_training(
        model, stream,
        TrainLoopConfig(total_steps=8, log_every=7, compression="int8_ef"),
    )
    assert abs(h_comp[-1]["loss"] - h_plain[-1]["loss"]) < 0.25


def test_checkpointer_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_write=False)
        state = {"w": jnp.arange(4.0), "step": jnp.asarray(3)}
        for s in (1, 2, 3):
            ck.save(state, s)
        assert ck.list_steps() == [2, 3]
        got = ck.restore({"w": jnp.zeros(4), "step": jnp.asarray(0)})
        np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(4.0))
        # tmp dirs never left behind
        assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_data_stream_determinism():
    dc = DataConfig(vocab_size=64, seq_len=16, global_batch=4)
    s1 = SyntheticTokenStream(dc)
    s2 = SyntheticTokenStream(dc)
    np.testing.assert_array_equal(s1.batch(7)["tokens"], s2.batch(7)["tokens"])
    # host sharding partitions the global batch deterministically
    h0 = SyntheticTokenStream(dc, host_id=0, num_hosts=2)
    h1 = SyntheticTokenStream(dc, host_id=1, num_hosts=2)
    assert h0.batch(3)["tokens"].shape == (2, 16)
    assert not np.array_equal(h0.batch(3)["tokens"], h1.batch(3)["tokens"])
